"""Deterministic synthetic data pipeline (tokens + stub modality frontends).

Determinism is the elastic-training contract: batch(step) depends only on
(seed, step), so a run restarted from checkpoint step k on a different pod
count consumes byte-identical data from step k onward — no data-loader
state to checkpoint. Sharded device_put when a mesh is supplied.

The modality frontends are STUBS per the assignment: ``[audio]``/``[vlm]``
entries specify the transformer backbone only, so enc_embeds/patch_embeds
arrive as precomputed frame/patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import ModelConfig, ShapeConfig


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, *,
               seed: int = 0, dtype=jnp.float32, batch_override=None):
    """Host-side batch for one training step (pure function of step)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    if cfg.frontend is not None:
        S = S - cfg.frontend.num_patches
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kt, ke, kp = jax.random.split(key, 3)
    # zipf-ish skewed tokens (realistic embedding access pattern)
    u = jax.random.uniform(kt, (B, S + 1), minval=1e-6, maxval=1.0)
    toks = (jnp.power(u, 3.0) * cfg.vocab_size).astype(jnp.int32)
    toks = jnp.clip(toks, 0, cfg.vocab_size - 1)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.is_encdec:
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            ke, (B, cfg.encoder.n_frames, cfg.d_model), dtype)
    if cfg.frontend is not None:
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            kp, (B, cfg.frontend.num_patches, cfg.d_model), dtype)
    return batch


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed=0,
                 mesh=None, dtype=jnp.float32, batch_override=None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.mesh = mesh
        self.dtype = dtype
        self.batch_override = batch_override

    def batch(self, step: int):
        b = make_batch(self.cfg, self.shape, step, seed=self.seed,
                       dtype=self.dtype, batch_override=self.batch_override)
        if self.mesh is not None:
            b = jax.device_put(b, sh.batch_shardings(b, self.mesh))
        return b
