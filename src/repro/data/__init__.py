from repro.data.pipeline import SyntheticPipeline, make_batch  # noqa: F401
