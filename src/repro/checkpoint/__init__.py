from repro.checkpoint.checkpointer import (Checkpointer, latest_step,  # noqa: F401
                                           restore, save)
