"""Checkpoint/restart for elastic, preemptible training.

Properties the IceCube adaptation needs (DESIGN.md §2):
  * atomic: tmp-dir + rename; a preemption mid-save never corrupts the
    latest checkpoint (spot instances give 30 s - 2 min warnings),
  * async: serialization happens on a background thread off the step
    critical path (``Checkpointer.save_async``),
  * reshape-on-restore: arrays are stored sharding-agnostically (full
    logical arrays), so a run restarted on a different pod count just
    device_puts them with the new shardings (core/elastic.py),
  * bounded retention: keep the last K checkpoints.

Format: one .npz per tree (params/opt), leaves keyed by '/'-joined tree
path, + manifest.json {step, wall_time, tree_hash}.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no native bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_into(struct, flat):
    def pick(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key].reshape(leaf.shape)
        if arr.dtype != leaf.dtype:           # bf16 round-trips via f32
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        return arr
    return jax.tree_util.tree_map_with_path(pick, struct)


def save(ckpt_dir, step, trees: dict):
    """trees: {"params": ..., "opt": ...}; blocking, atomic."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}-{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    for name, tree in trees.items():
        np.savez(os.path.join(tmp, f"{name}.npz"), **_flatten(tree))
    manifest = {"step": int(step), "wall_time": time.time(),
                "trees": sorted(trees)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(ckpt_dir, f"step_{int(step):010d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir, structs: dict, step=None):
    """structs: {"params": abstract/concrete tree, ...} -> same trees filled
    with stored numpy values (host); caller device_puts with its shardings.
    Returns (step, trees)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{int(step):010d}")
    out = {}
    for name, struct in structs.items():
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        out[name] = _unflatten_into(struct, flat)
    return step, out


class Checkpointer:
    """Async checkpointing with retention. ``save_async`` snapshots to host
    (device_get) synchronously — cheap — and serializes on a worker thread."""

    def __init__(self, ckpt_dir, keep=3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread = None
        self.saved_steps = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        for s in self.saved_steps[:-self.keep]:
            p = os.path.join(self.ckpt_dir, f"step_{int(s):010d}")
            if os.path.exists(p):
                shutil.rmtree(p)
        self.saved_steps = self.saved_steps[-self.keep:]

    def save_async(self, step, trees: dict):
        self.wait()
        host_trees = {k: jax.device_get(v) for k, v in trees.items()}

        def work():
            save(self.ckpt_dir, step, host_trees)
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saved_steps.append(step)
        self._gc()

    def save_blocking(self, step, trees: dict):
        self.wait()
        path = save(self.ckpt_dir, step,
                    {k: jax.device_get(v) for k, v in trees.items()})
        self.saved_steps.append(step)
        self._gc()
        return path
