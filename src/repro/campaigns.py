"""Campaign CLI: run serialized CampaignSpecs from the command line.

Because specs are data (JSON), campaigns become shell-scriptable and
CI-pinnable:

    PYTHONPATH=src python -m repro.campaigns run spec.json
    PYTHONPATH=src python -m repro.campaigns run spec.json \\
        --seeds 2021,2022,2023 --engine batched --csv sweep.csv
    PYTHONPATH=src python -m repro.campaigns show spec.json
    PYTHONPATH=src python -m repro.campaigns lint spec.json
    PYTHONPATH=src python -m repro.campaigns trace spec.json \\
        --out trace.jsonl
    PYTHONPATH=src python -m repro.campaigns diff a.jsonl b.jsonl.gz
    PYTHONPATH=src python -m repro.campaigns pareto --seeds 2021,2022
    PYTHONPATH=src python -m repro.campaigns paper --out paper.spec.json

``run`` executes the spec(s) through the ``repro.core.api.run`` front
door (solo for one spec x one seed, the batched lock-step sweep engine
otherwise), prints a summary, and optionally writes machine-readable
JSON/CSV artifacts.  ``trace`` runs one (spec, seed) campaign with
``collect="trace"`` and streams the typed event trace
(``repro.core.events.CampaignTrace``) as JSONL — byte-identical
whichever engine ran it (``--stream`` pipes it through the bounded-
window sink instead of holding it in memory; same bytes).  ``diff``
compares two serialized traces and exits 1 on any divergence — a CI
equivalence gate.  ``pareto`` sweeps a candidate grid (default:
``scenarios.pareto_grid()``) and prints the cost-vs-value Pareto
frontier.  ``paper`` emits the golden paper-replay spec (committed at
tests/data/paper_replay.spec.json and smoke-run in CI).
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import List, Optional

from repro.core.api import ENGINES, run as api_run
from repro.core.spec import (CampaignResult, CampaignSpec, lint_spec,
                             paper_spec)


def _load_spec(path: str) -> CampaignSpec:
    with open(path) as f:
        return CampaignSpec.from_json(f.read())


def _print_solo(res: CampaignResult):
    print(f"campaign {res.spec.name!r} seed={res.seed} "
          f"engine={res.engine}")
    for line in res.log:
        print(f"  {line}")
    print(f"  cost            ${res.cost:>12,.2f}")
    print(f"  GPU-days        {res.accel_days:>13,.1f}")
    print(f"  fp32 EFLOP-h    {res.eflop_hours_fp32:>13.3f}")
    print(f"  preemptions     {res.preemptions:>13,}")
    print(f"  jobs finished   {res.jobs_finished:>13,}")
    if res.spec is not None and res.spec.dataplane is not None:
        print(f"  egress          ${res.egress_usd:>12,.2f}")
        print(f"  stage-in hours  {res.stagein_hours:>13,.1f}")
        print(f"  cache hit frac  {res.cache_hit_fraction:>13.4f}")
    if res.spec is not None and res.spec.name == "paper":
        print("  paper-claim comparison:")
        for claim, row in res.compare_paper().items():
            print(f"    {claim:18s} sim={row['sim']:>12,.2f} "
                  f"paper={row['paper']:>10,.1f} "
                  f"err={row['err_pct']:+6.1f}%")


def cmd_run(args) -> int:
    specs = [_load_spec(p) for p in args.spec]
    seeds = [int(s) for s in args.seeds.split(",")]
    target = specs[0] if len(specs) == 1 else specs
    result = api_run(target, seeds=seeds if len(seeds) > 1 else seeds[0],
                     engine=args.engine)
    if isinstance(result, CampaignResult):
        _print_solo(result)
        payload = {"schema_version": 1, "kind": "campaign",
                   "spec": result.spec.to_dict(), "seed": result.seed,
                   "engine": result.engine,
                   "results": result.to_dict(),
                   "events_fired": list(result.events_fired)}
    else:
        print(f"swept {len(result.rows)} lanes "
              f"({len(specs)} specs x {len(seeds)} seeds, "
              f"engine={args.engine})\n")
        print(result.table())
        payload = {"schema_version": 1, "kind": "sweep",
                   "specs": [s.to_dict() for s in specs], "seeds": seeds,
                   "summary": result.summary(), "rows": result.rows}
        if args.csv:
            result.to_csv(args.csv)
            print(f"# wrote {args.csv}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=str)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    return 0


def cmd_show(args) -> int:
    for path in args.spec:
        spec = _load_spec(path)
        print(f"# {path}")
        print(spec.to_json(), end="")
    return 0


def _registry_findings() -> List[str]:
    """Registry completeness over the real engine classes: every
    registered event must compile to ops every engine implements —
    including "jax", whose :class:`~repro.core.sweep_jax.JaxLaneOps`
    consumes the ops through the compiled-timeline segment splitter
    (per-segment parameter planes) rather than at tick time.  The
    adapter roster is the ``ENGINE_ADAPTERS``/``PROVISIONER_FACADES``
    metadata in core/timeline.py — the same literal dicts the static
    analyzer (``campaigns check``) reads without importing."""
    from repro.core.timeline import (ENGINE_ADAPTERS, PROVISIONER_FACADES,
                                     registry_findings, resolve_adapters)
    return registry_findings(resolve_adapters(ENGINE_ADAPTERS),
                             resolve_adapters(PROVISIONER_FACADES))


#: every lint finding leads with its stable rule id (``SPEC014: ...``,
#: ``REG002: ...``) — split it back out for the --json payload
_RULE_PREFIX_RE = re.compile(r"^([A-Z]{3,5}\d{3}):\s+(.*)$", re.DOTALL)


def _lint_finding(path: str, text: str) -> dict:
    """One ``campaigns lint`` finding in the ``campaigns check --json``
    shape (file/line/rule/message/hint) — one schema for both gates."""
    m = _RULE_PREFIX_RE.match(text)
    rule, message = (m.group(1), m.group(2)) if m else ("SPEC000", text)
    return {"file": path, "line": 0, "rule": rule,
            "message": message, "hint": ""}


def cmd_lint(args) -> int:
    """Spec-level validation: report every finding (unsorted/duplicate
    event times, negative prices/targets, unknown catalog/provider
    names) and exit 1 if any spec has one.  ``--registry`` additionally
    fails on timeline events registered for fewer than all engines.
    ``--json PATH`` writes the machine-readable findings (``-`` for
    stdout, human summary moves to stderr) — same finding shape as
    ``campaigns check --json``."""
    as_json = getattr(args, "json", None)
    out = sys.stderr if as_json == "-" else sys.stdout
    bad = 0
    collected: List[dict] = []
    if getattr(args, "registry", False):
        findings = _registry_findings()
        collected.extend(_lint_finding("src/repro/core/timeline.py", f)
                         for f in findings)
        if findings:
            bad += 1
            for f in findings:
                print(f"registry: {f}", file=out)
        else:
            from repro.core.timeline import REGISTRY
            print(f"registry: OK ({len(REGISTRY)} event kinds on "
                  "all engines)", file=out)
    for path in args.spec:
        try:
            spec = _load_spec(path)
        except (OSError, ValueError, KeyError, TypeError) as e:
            print(f"{path}: ERROR: cannot load spec: {e}", file=out)
            collected.append(_lint_finding(
                path, f"SPEC100: cannot load spec: {e}"))
            bad += 1
            continue
        findings = lint_spec(spec)
        collected.extend(_lint_finding(path, f) for f in findings)
        if findings:
            bad += 1
            for f in findings:
                print(f"{path}: {f}", file=out)
        else:
            print(f"{path}: OK ({spec.name!r}, "
                  f"{len(spec.timeline)} timeline events)", file=out)
    if as_json:
        counts: dict = {}
        for f in collected:
            counts[f["rule"]] = counts.get(f["rule"], 0) + 1
        payload = json.dumps({
            "schema_version": 1,
            "tool": "repro.campaigns lint",
            "specs": list(args.spec),
            "ok": not bad,
            "counts": dict(sorted(counts.items())),
            "findings": collected,
        }, indent=2, sort_keys=True) + "\n"
        if as_json == "-":
            sys.stdout.write(payload)
        else:
            with open(as_json, "w") as f:
                f.write(payload)
            print(f"# wrote {as_json}", file=sys.stderr)
    return 1 if bad else 0


def cmd_check(args) -> int:
    """Engine-contract static analysis (``repro.analysis.staticcheck``):
    AST-level drift detection for registry completeness, RNG discipline,
    trace parity and kernel/oracle pairing.  Exit codes mirror ``diff``:
    0 clean, 1 findings, 2 bad arguments."""
    from repro.analysis.staticcheck.cli import run as staticcheck_run
    return staticcheck_run(args)


def cmd_trace(args) -> int:
    """Run one (spec, seed) campaign with ``collect="trace"`` and write
    the typed event stream as JSONL (stdout or ``--out``; a ``.gz``
    suffix gzips transparently — stage-in events make big-fleet traces
    long).  ``--stream`` feeds the events through the bounded-window
    sink (``collect="stream"``) instead of holding the full trace in
    memory; the written bytes are identical."""
    spec = _load_spec(args.spec)
    if args.stream:
        if not args.out:
            raise ValueError("--stream writes through a file sink; "
                             "pass --out (stdout needs the in-memory "
                             "path)")
        from repro.core.traceops import JsonlStreamSink
        sink = JsonlStreamSink(args.out)
        res = api_run(spec, seeds=args.seed, engine=args.engine,
                      collect="stream", sink=sink)
        print(f"# wrote {args.out}", file=sys.stderr)
        print(f"# trace {spec.name!r} seed={res.seed}: "
              f"{sink.events_written} events (streamed)",
              file=sys.stderr)
        return 0
    res = api_run(spec, seeds=args.seed, engine=args.engine,
                  collect="trace")
    text = res.trace.to_jsonl()
    if args.out:
        if args.out.endswith(".gz"):
            import gzip
            # mtime=0: byte-reproducible archives of the canonical
            # (sha256-pinned) trace bytes
            with gzip.GzipFile(args.out, "wb", mtime=0) as f:
                f.write(text.encode("utf-8"))
        else:
            # newline="\n": the trace bytes are canonical; platform
            # CRLF translation must not touch them
            with open(args.out, "w", newline="\n") as f:
                f.write(text)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        sys.stdout.write(text)
    counts = {k: v for k, v in sorted(res.trace.counts().items()) if v}
    print(f"# trace {spec.name!r} seed={res.seed}: "
          f"{len(res.trace)} events "
          + " ".join(f"{k}={v}" for k, v in counts.items()),
          file=sys.stderr)
    return 0


def cmd_diff(args) -> int:
    """Compare two serialized campaign traces (JSONL, ``.gz``
    transparently).  Exit 0 when byte-equivalent, 1 on any divergence
    (header, first-divergence point, per-kind / per-entity counts and
    digest deltas are reported) — usable directly as a CI equivalence
    gate.  ``--json PATH`` writes the machine-readable diff (``-`` for
    stdout, summary moves to stderr)."""
    from repro.core.traceops import diff_traces, load_trace
    try:
        a = load_trace(args.a)
        b = load_trace(args.b)
    except (OSError, KeyError, TypeError) as e:
        raise ValueError(f"cannot load trace: {e}")
    d = diff_traces(a, b)
    if args.json:
        payload = json.dumps(d.to_dict(), indent=2, sort_keys=True) + "\n"
        if args.json == "-":
            sys.stdout.write(payload)
            print(d.summary(), file=sys.stderr)
            return 0 if d.identical else 1
        with open(args.json, "w") as f:
            f.write(payload)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(d.summary())
    return 0 if d.identical else 1


def cmd_pareto(args) -> int:
    """Sweep a candidate grid and print the cost-vs-value Pareto
    frontier (``analysis.pareto.frontier``).  With no spec files the
    grid is ``scenarios.pareto_grid()`` — the price-curve x GPU-slicing
    x data-plane axes; ``--duration-h`` shortens every candidate for
    smoke runs."""
    from dataclasses import replace
    from repro.analysis.pareto import frontier
    if args.spec:
        specs = [_load_spec(p).to_spec() for p in args.spec]
    else:
        from repro.core.scenarios import pareto_grid
        specs = [s.to_spec() for s in pareto_grid()]
    if args.duration_h is not None:
        specs = [replace(s, duration_h=args.duration_h) for s in specs]
    seeds = [int(s) for s in args.seeds.split(",")]
    result = api_run(specs, seeds=seeds if len(seeds) > 1 else seeds[0],
                     engine=args.engine)
    front = frontier(result, x=args.x, y=args.y)
    print(f"pareto frontier over {len(specs)} scenarios x "
          f"{len(seeds)} seeds (minimize {front.x}, "
          f"maximize {front.y}):\n")
    print(front.table())
    names = ", ".join(p.scenario for p in front.frontier)
    print(f"\nnon-dominated: {names}")
    if args.json:
        payload = {"schema_version": 1, **front.to_dict(),
                   "seeds": seeds}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    return 0


def cmd_paper(args) -> int:
    text = paper_spec().to_json()
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        print(text, end="")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaigns",
        description="Run/inspect serialized CampaignSpecs.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="execute spec file(s)")
    p_run.add_argument("spec", nargs="+", help="CampaignSpec JSON file(s)")
    p_run.add_argument("--seeds", default="2021",
                       help="comma-separated seeds (default: 2021)")
    p_run.add_argument("--engine", default="auto",
                       choices=sorted(ENGINES))
    p_run.add_argument("--json", default=None,
                       help="write results JSON here")
    p_run.add_argument("--csv", default=None,
                       help="write the sweep row CSV here (sweeps only)")
    p_run.set_defaults(fn=cmd_run)

    p_show = sub.add_parser("show", help="pretty-print spec file(s)")
    p_show.add_argument("spec", nargs="+")
    p_show.set_defaults(fn=cmd_show)

    p_lint = sub.add_parser(
        "lint", help="validate spec file(s) without running them")
    p_lint.add_argument("spec", nargs="+")
    p_lint.add_argument("--registry", action="store_true",
                        help="also check the timeline-event registry: "
                             "fail on events registered for fewer than "
                             "all engines")
    p_lint.add_argument("--json", default=None, metavar="PATH",
                        help="write machine-readable findings here "
                             "('-' for stdout; same shape as "
                             "`check --json`)")
    p_lint.set_defaults(fn=cmd_lint)

    p_check = sub.add_parser(
        "check", help="engine-contract static analysis (AST-level "
                      "drift detection; exit 1 on findings)")
    from repro.analysis.staticcheck.cli import add_arguments
    add_arguments(p_check)
    p_check.set_defaults(fn=cmd_check)

    p_trace = sub.add_parser(
        "trace", help="run one campaign and emit its typed event trace "
                      "as JSONL")
    p_trace.add_argument("spec", help="CampaignSpec JSON file")
    p_trace.add_argument("--seed", default=2021, type=int,
                         help="campaign seed (default: 2021)")
    # trace is a bit-identity surface, so the redundant "sequential"
    # alias is absent; "jax" is accepted so the api layer can explain
    # WHY the statistical engine has no trace (one friendly line,
    # exit 2) instead of argparse rejecting the word
    p_trace.add_argument("--engine", default="auto",
                         choices=sorted(ENGINES - {"sequential"}))
    p_trace.add_argument("--out", default=None,
                         help="write the JSONL here (default: stdout)")
    p_trace.add_argument("--stream", action="store_true",
                         help="stream events through the bounded-window "
                              "sink instead of holding the trace in "
                              "memory (needs --out; identical bytes)")
    p_trace.set_defaults(fn=cmd_trace)

    p_diff = sub.add_parser(
        "diff", help="compare two serialized traces; exit 1 on "
                     "divergence")
    p_diff.add_argument("a", help="baseline trace (.jsonl or .jsonl.gz)")
    p_diff.add_argument("b", help="candidate trace (.jsonl or .jsonl.gz)")
    p_diff.add_argument("--json", default=None,
                        help="write the machine-readable diff here "
                             "('-' for stdout)")
    p_diff.set_defaults(fn=cmd_diff)

    p_pareto = sub.add_parser(
        "pareto", help="sweep a candidate grid and print the "
                       "cost-vs-value Pareto frontier")
    p_pareto.add_argument("spec", nargs="*",
                          help="candidate CampaignSpec JSON files "
                               "(default: scenarios.pareto_grid())")
    p_pareto.add_argument("--seeds", default="2021",
                          help="comma-separated seeds (default: 2021)")
    p_pareto.add_argument("--engine", default="batched",
                          choices=sorted(ENGINES - {"auto"}))
    p_pareto.add_argument("--x", default="cost",
                          help="cost axis, minimized (default: cost)")
    p_pareto.add_argument("--y", default="accel_days",
                          help="value axis, maximized "
                               "(default: accel_days)")
    p_pareto.add_argument("--duration-h", default=None, type=float,
                          help="override every candidate's duration "
                               "(reduced smoke grids)")
    p_pareto.add_argument("--json", default=None,
                          help="write the frontier JSON here")
    p_pareto.set_defaults(fn=cmd_pareto)

    p_paper = sub.add_parser("paper",
                             help="emit the paper-replay golden spec")
    p_paper.add_argument("--out", default=None)
    p_paper.set_defaults(fn=cmd_paper)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except ValueError as e:
        # the api layer's engine/collect errors (e.g. the statistical
        # jax engine has no trace surface) already say what to do —
        # surface them as one friendly line, not a traceback
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
