import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh(es) with 512 placeholder host devices, print
memory_analysis / cost_analysis, and extract roofline terms.

MUST be run as its own process (device count locks at first jax init):
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out artifacts/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.analysis import hlo as hlo_an
from repro.analysis import roofline as rl
from repro.configs import RunConfig, cells, get_config, get_shape
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.sharding_ctx import use_mesh


def run_cell(arch, shape_name, *, multi_pod=False, run_overrides=None,
             moe_overrides=None, keep_hlo=False):
    """Lower+compile one cell; returns a result dict (JSON-serializable)."""
    cfg = get_config(arch)
    if moe_overrides and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    shape = get_shape(shape_name)
    if run_overrides and "grad_accum" in run_overrides:
        import dataclasses
        shape = dataclasses.replace(
            shape, grad_accum=run_overrides.pop("grad_accum"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    run = RunConfig(model=cfg, shape=shape)
    if run_overrides:
        run = run.replace(**run_overrides)
    t0 = time.time()

    with use_mesh(mesh):
        pstruct = st.params_struct(cfg, jnp.bfloat16)
        psh = sh.param_shardings(pstruct, mesh)
        if shape.kind == "train":
            ostruct = st.opt_struct(cfg, pstruct)
            osh = sh.opt_shardings(ostruct, mesh)
            batch = st.input_specs(cfg, shape)
            bsh = sh.batch_shardings(batch, mesh)
            fn = st.make_train_step(cfg, run)
            jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pstruct, ostruct, batch)
        elif shape.kind == "prefill":
            batch = st.input_specs(cfg, shape)
            bsh = sh.batch_shardings(batch, mesh)
            fn = st.make_prefill_step(cfg, run)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(pstruct, batch)
        else:  # decode
            specs = st.input_specs(cfg, shape)
            csh = sh.cache_shardings(specs["caches"], mesh)
            tsh = sh.batch_shardings(
                {"t": specs["token"]}, mesh)["t"]
            fn = st.make_decode_step(cfg, run)
            jitted = jax.jit(fn, in_shardings=(psh, csh, tsh,
                                               sh.replicated(mesh)),
                             out_shardings=(None, csh),
                             donate_argnums=(1,))
            lowered = jitted.lower(pstruct, specs["caches"],
                                   specs["token"], specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older JAX: list of one dict
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    parsed = hlo_an.analyze(hlo_text)
    roof = rl.compute_roofline(cfg, shape, n_chips,
                               parsed["dot_flops"],
                               parsed["collective_bytes"])
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "xla_cost": {"flops": cost.get("flops"),
                     "bytes_accessed": cost.get("bytes accessed")},
        "hlo_parsed": parsed,
        "roofline": roof.to_dict(),
        "state_bytes_per_dev": rl.state_bytes(cfg, shape, n_chips),
        "status": "ok",
    }
    if keep_hlo:
        result["hlo_text"] = hlo_text
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"),
                    default="no")
    ap.add_argument("--out", default=None, help="artifact dir for JSON")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--moe-quant", default=None, choices=("none", "int8"))
    ap.add_argument("--moe-local-cf", type=float, default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.q_chunk:
        overrides["attention_q_chunk"] = args.q_chunk
    if args.grad_accum:
        overrides["grad_accum"] = args.grad_accum
    moe_overrides = {}
    if args.moe_quant:
        moe_overrides["dispatch_quant"] = args.moe_quant
    if args.moe_local_cf:
        moe_overrides["local_capacity_factor"] = args.moe_local_cf

    todo = []
    if args.all:
        todo = [(a, s, skip) for a, s, skip in cells()]
    else:
        cfgc = get_config(args.arch)
        skip = (args.shape == "long_500k" and not cfgc.is_subquadratic)
        todo = [(args.arch, args.shape, skip)]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[
        args.multi_pod]

    results, failures = [], 0
    for arch, shape_name, skip in todo:
        for mp in pods:
            tag = f"{arch}/{shape_name}/{'2x16x16' if mp else '16x16'}"
            if skip:
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": "skipped",
                                "reason": "full attention; no sub-quadratic "
                                          "path (DESIGN.md)"})
                print(f"[SKIP] {tag}")
                continue
            try:
                r = run_cell(arch, shape_name, multi_pod=mp,
                             run_overrides=overrides or None,
                             moe_overrides=moe_overrides or None)
                results.append(r)
                rf = r["roofline"]
                print(f"[OK]   {tag}  compile={r['compile_s']:.0f}s "
                      f"dotF/dev={rf['hlo_flops_device']:.3e} "
                      f"coll/dev={r['hlo_parsed']['collective_bytes']:.3e}B "
                      f"bound={rf['bottleneck']} "
                      f"terms(c/m/x)=({rf['compute_s']:.4f}/"
                      f"{rf['memory_s']:.4f}/{rf['collective_s']:.4f})s")
            except Exception as e:  # noqa: BLE001 — record, keep sweeping
                failures += 1
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": "error", "error": repr(e)})
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc(limit=4)
            sys.stdout.flush()

    if args.out:
        import pathlib
        out = pathlib.Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        suffix = (args.arch or "all") + "_" + (args.shape or "all")
        path = out / f"dryrun_{suffix}_{args.multi_pod}.json"
        path.write_text(json.dumps(results, indent=1))
        print(f"wrote {path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
