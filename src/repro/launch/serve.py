"""Batched serving launcher: prefill + decode with slot-based continuous
batching, fed through the overlay matchmaker (requests are "jobs", decode
slots are "pilots" — the same federation abstraction the CE applies to
clusters, applied to a single model server).

CPU-runnable with --reduced; the production path lowers the same serve_step
on the pod mesh (see dryrun decode cells).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import collections
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_config, get_reduced
from repro.launch import steps as st
from repro.models import decode_step, init_cache, init_params, prefill


@dataclass
class Request:
    id: int
    prompt: np.ndarray               # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    submitted: float = 0.0
    finished: Optional[float] = None


class BatchServer:
    """Fixed-slot decode batching: prefill one request at a time (CPU demo),
    decode all active slots in lockstep with a shared cache."""

    def __init__(self, cfg, *, slots=4, max_len=128, seed=0,
                 compute_dtype=jnp.float32):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.dtype = compute_dtype
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.queue: collections.deque = collections.deque()
        self.active: dict = {}           # slot -> Request
        self.caches = init_cache(cfg, slots, max_len, compute_dtype)
        self.pos = np.zeros(slots, np.int32)
        self.done: list = []
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos,
                                             compute_dtype=compute_dtype))

    def submit(self, req: Request):
        req.submitted = time.time()
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill: feed prompt tokens through decode steps (shared-cache
            # slot isolation keeps this simple for the demo server)
            for i, tok in enumerate(req.prompt):
                t = np.zeros((self.slots, 1), np.int32)
                t[slot, 0] = tok
                logits, self.caches = self._decode(
                    self.params, self.caches, jnp.asarray(t),
                    jnp.int32(int(self.pos[slot])))
                self.pos[slot] += 1
            req.out.append(int(jnp.argmax(logits[slot, -1])))
            self.active[slot] = req

    def _decode_tick(self):
        if not self.active:
            return
        t = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.active.items():
            t[slot, 0] = req.out[-1]
        pos = int(max(self.pos[s] for s in self.active))
        logits, self.caches = self._decode(self.params, self.caches,
                                           jnp.asarray(t), jnp.int32(pos))
        for slot in list(self.active):
            req = self.active[slot]
            req.out.append(int(jnp.argmax(logits[slot, -1])))
            self.pos[slot] += 1
            if len(req.out) >= req.max_new or \
                    self.pos[slot] >= self.max_len - 1:
                req.finished = time.time()
                self.done.append(req)
                del self.active[slot]

    def run(self):
        while self.queue or self.active:
            self._admit()
            self._decode_tick()
        return self.done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    rng = np.random.default_rng(0)
    server = BatchServer(cfg, slots=args.slots)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        server.submit(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), args.max_new))
    done = server.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
