"""Training launcher: elastic, preemptible, checkpointed.

CPU-runnable end-to-end with --reduced (examples/ use it); on a real fleet
the same loop runs per-controller with the production mesh. Wires together:
data pipeline -> jit(train_step) -> async checkpoints -> PodPool events
(join/leave/preemption-notice) -> straggler monitor.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as sh
from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs import RunConfig, SHAPES, ShapeConfig, get_config, get_reduced
from repro.core.straggler import StragglerMonitor
from repro.data import SyntheticPipeline
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim import adamw_init
from repro.sharding_ctx import use_mesh


def build(arch, *, reduced=True, shape_name="train_4k", steps_override=None,
          batch=None, seq=None, compute_dtype="float32", grad_accum=1):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    base = SHAPES[shape_name]
    shape = ShapeConfig("custom", seq or (64 if reduced else base.seq_len),
                        batch or (4 if reduced else base.global_batch),
                        "train", grad_accum=grad_accum)
    run = RunConfig(model=cfg, shape=shape, compute_dtype=compute_dtype,
                    remat=not reduced)
    return cfg, shape, run


class Trainer:
    def __init__(self, cfg, shape, run, *, mesh=None, ckpt_dir=None,
                 seed=0, keep=3):
        self.cfg, self.shape, self.run = cfg, shape, run
        self.mesh = mesh or make_host_mesh((len(jax.devices()), 1))
        self.pipe = SyntheticPipeline(cfg, shape, seed=seed, mesh=self.mesh)
        self.ckpt = Checkpointer(ckpt_dir, keep=keep) if ckpt_dir else None
        self.monitor = StragglerMonitor()
        self._preempt_requested = False
        self.step_num = 0

        with use_mesh(self.mesh):
            key = jax.random.PRNGKey(seed)
            params = init_params(cfg, key)
            if run.compute_dtype != "float32":
                params = jax.tree.map(
                    lambda x: x.astype(jnp.dtype(run.compute_dtype))
                    if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
            opt = adamw_init(params)
            psh = sh.param_shardings(params, self.mesh)
            osh = sh.opt_shardings(opt, self.mesh)
            self.params = jax.device_put(params, psh)
            self.opt = jax.device_put(opt, osh)
            fn = st.make_train_step(cfg, run)
            self._step = jax.jit(fn, in_shardings=(psh, osh, None),
                                 out_shardings=(psh, osh, None),
                                 donate_argnums=(0, 1))
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            self.restore(ckpt_dir)

    # -- preemption ------------------------------------------------------------
    def install_signal_handlers(self):
        """SIGTERM = the cloud's preemption notice: drain + durable state."""
        def handler(signum, frame):
            self._preempt_requested = True
        signal.signal(signal.SIGTERM, handler)

    def restore(self, ckpt_dir):
        step, trees = restore(ckpt_dir, {"params": self.params,
                                         "opt": self.opt})
        with use_mesh(self.mesh):
            self.params = jax.device_put(
                trees["params"], sh.param_shardings(trees["params"],
                                                    self.mesh))
            self.opt = jax.device_put(
                trees["opt"], sh.opt_shardings(trees["opt"], self.mesh))
        self.step_num = step
        return step

    # -- loop --------------------------------------------------------------------
    def train(self, num_steps, *, ckpt_every=25, log_every=10, log=print):
        losses = []
        with use_mesh(self.mesh):
            while self.step_num < num_steps:
                t0 = time.time()
                batch = self.pipe.batch(self.step_num)
                self.params, self.opt, m = self._step(self.params, self.opt,
                                                      batch)
                loss = float(m["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(
                        f"non-finite loss at step {self.step_num}")
                losses.append(loss)
                self.step_num += 1
                self.monitor.record("pod0", time.time() - t0)
                if log_every and self.step_num % log_every == 0:
                    log(f"step {self.step_num:5d} loss {loss:.4f} "
                        f"gnorm {float(m['grad_norm']):.3f} "
                        f"({time.time() - t0:.2f}s)")
                if self.ckpt and self.step_num % ckpt_every == 0:
                    self.ckpt.save_async(self.step_num,
                                         {"params": self.params,
                                          "opt": self.opt})
                if self._preempt_requested:
                    if self.ckpt:
                        self.ckpt.save_blocking(self.step_num,
                                                {"params": self.params,
                                                 "opt": self.opt})
                    log(f"preemption notice honored at step {self.step_num}")
                    break
        if self.ckpt:
            self.ckpt.wait()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, shape, run = build(args.arch, reduced=args.reduced,
                            shape_name=args.shape, batch=args.batch,
                            seq=args.seq)
    tr = Trainer(cfg, shape, run, ckpt_dir=args.ckpt_dir, seed=args.seed)
    tr.install_signal_handlers()
    losses = tr.train(args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}, "
          f"{len(losses)} steps)")


if __name__ == "__main__":
    main()
