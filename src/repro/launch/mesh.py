"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

from repro.sharding_ctx import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single v5e pod: (16,16)=(data,model), 256 chips.
    Multi-pod: (2,16,16)=(pod,data,model), 512 chips; "pod" is the elastic
    pure-DP axis the cloud provisioner grows/shrinks."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_elastic_mesh(n_pods: int, *, pod_shape=(16, 16)):
    """Mesh for an elastic pool of ``n_pods`` pods (n_pods >= 1). The pod
    axis is what core/elastic.py re-sizes when spot capacity changes."""
    if n_pods == 1:
        return make_mesh(pod_shape, ("data", "model"))
    return make_mesh((n_pods,) + pod_shape, ("pod", "data", "model"))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes)
