"""Step builders + abstract input specs for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation); the
dry-run lowers against them, the trainer/server allocate real buffers with
the same shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, cosine_schedule

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


# --------------------------------------------------------------------------
# abstract structures (no allocation)
# --------------------------------------------------------------------------

def params_struct(cfg: ModelConfig, dtype=BF16):
    """Abstract param tree with float leaves cast to ``dtype``."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    tree = jax.eval_shape(functools.partial(M.init_params, cfg), key)
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return x
    return jax.tree.map(cast, tree)


def opt_struct(cfg: ModelConfig, pstruct=None, dtype=BF16):
    pstruct = pstruct or params_struct(cfg, dtype)
    return jax.eval_shape(adamw_init, pstruct)


def cache_struct(cfg: ModelConfig, batch, max_len, dtype=BF16):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, max_len, dtype))


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.frontend is not None:
        return seq_len - cfg.frontend.num_patches
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=BF16):
    """Model inputs for a cell. train/prefill: token batch (+ stub frontend
    embeddings); decode: (caches, token, pos)."""
    B, S = shape.global_batch, shape.seq_len
    St = _text_len(cfg, S)
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, St), I32)}
        if shape.kind == "train":
            batch["targets"] = jax.ShapeDtypeStruct((B, St), I32)
        if cfg.is_encdec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder.n_frames, cfg.d_model), dtype)
        if cfg.frontend is not None:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend.num_patches, cfg.d_model), dtype)
        return batch
    # decode: one new token against a seq_len cache
    return {"caches": cache_struct(cfg, B, S, dtype),
            "token": jax.ShapeDtypeStruct((B, 1), I32),
            "pos": jax.ShapeDtypeStruct((), I32)}


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def _resolve_flash(run: RunConfig, flash_fn):
    if flash_fn is None and run.attention_impl == "pallas":
        from repro.kernels import ops as kops
        flash_fn = kops.flash_attention
    return flash_fn


def make_train_step(cfg: ModelConfig, run: RunConfig, flash_fn=None):
    dt = jnp.dtype(run.compute_dtype)
    accum = max(1, run.shape.grad_accum)
    flash_fn = _resolve_flash(run, flash_fn)

    def loss_fn(params, mb):
        loss, parts = M.forward_loss(params, cfg, mb, compute_dtype=dt,
                                     run_cfg=run, flash_fn=flash_fn)
        return loss, parts

    def train_step(params, opt_state, batch):
        lr = cosine_schedule(opt_state["step"],
                             base_lr=run.learning_rate)
        if accum == 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            (grads, loss), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum

        new_params, new_opt, om = adamw_update(
            grads, opt_state, params, lr=lr, beta1=run.beta1,
            beta2=run.beta2, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, run: RunConfig):
    dt = jnp.dtype(run.compute_dtype)

    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, compute_dtype=dt,
                                   q_chunk=run.attention_q_chunk)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, run: RunConfig):
    dt = jnp.dtype(run.compute_dtype)

    def serve_step(params, caches, token, pos):
        return M.decode_step(params, cfg, caches, token, pos,
                             compute_dtype=dt)

    return serve_step
