"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs.

``get_config(arch)`` returns the full assigned config; ``get_reduced(arch)``
returns a structurally identical but tiny config for CPU smoke tests (same
block pattern / family / attention flavor, shrunken dims).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (BlockDef, EncoderConfig, FrontendConfig,  # noqa: F401
                                MLAConfig, MambaConfig, MoEConfig, ModelConfig,
                                RunConfig, SHAPES, ShapeConfig, XLSTMConfig)

from repro.configs import (whisper_large_v3, qwen3_moe_30b_a3b, kimi_k2_1t_a32b,
                           minicpm3_4b, yi_9b, nemotron_4_15b, minitron_8b,
                           jamba_v01_52b, internvl2_2b, xlstm_350m)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (whisper_large_v3, qwen3_moe_30b_a3b, kimi_k2_1t_a32b,
              minicpm3_4b, yi_9b, nemotron_4_15b, minitron_8b,
              jamba_v01_52b, internvl2_2b, xlstm_350m)
}

ARCH_IDS = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = True):
    """All 40 (arch, shape) cells. Yields (arch_id, shape_name, skipped:bool).

    long_500k is skipped for pure full-attention archs (sub-quadratic path
    required); whisper decode shapes run (enc-dec has a decoder)."""
    for a, cfg in ARCHS.items():
        for s in SHAPES:
            skip = (s == "long_500k" and not cfg.is_subquadratic)
            if skip and not include_skipped:
                continue
            yield a, s, skip


def get_reduced(arch: str) -> ModelConfig:
    """Tiny config of the same family/pattern for CPU smoke tests."""
    cfg = get_config(arch)
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=len(cfg.block_defs),          # one super-block
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        max_position=4096,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=32,
            d_ff_shared=32 if cfg.moe.num_shared_experts else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=8, qk_rope_head_dim=8,
                              v_head_dim=8)
        kw["head_dim"] = 16
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, num_layers=2, n_frames=16)
    if cfg.frontend is not None:
        kw["frontend"] = dataclasses.replace(cfg.frontend, num_patches=8)
    return dataclasses.replace(cfg, **kw)


REDUCED_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
