"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2. Mamba:attn = 7:1 interleave, MoE every
other layer.  [arXiv:2403.19887; hf]

Period-8 super-block (Jamba paper Fig. 2): layers {0..7} are mamba except
layer 4 which is attention; odd layers carry MoE FFN, even layers dense FFN.
32L = 4 super-blocks, lax.scan'd.
"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

_PERIOD8 = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_defs=_PERIOD8,
    pos_embedding="none",           # Jamba uses no explicit positional encoding
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887; hf",
)
