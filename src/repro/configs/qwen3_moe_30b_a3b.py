"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, d_ff_expert=768.  [hf:Qwen/Qwen3-30B-A3B; hf]

head_dim=128 per the HF config (not d_model//num_heads). QK-norm per Qwen3.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # = per-expert intermediate
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
