"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B; hf]

MLA dims per the HF config: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32,
v_head=64. The KV cache stores the compressed latent (c_kv + k_rope), which
is the MLA decode-memory win visible in the decode roofline.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,                    # qk_nope (64) + qk_rope (32)
    d_ff=6400,
    vocab_size=73448,
    attention_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B; hf",
)
