"""internvl2-2b [vlm]: InternLM2 backbone, 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92553. InternViT frontend is a STUB per the assignment:
``input_specs()`` supplies 256 pre-projected patch embeddings prepended to
the token stream.  [arXiv:2404.16821; hf]
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend=FrontendConfig(num_patches=256),
    source="arXiv:2404.16821; hf",
)
