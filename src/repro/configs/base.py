"""Config dataclasses for the model zoo, shapes, and runtime.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s. Configs are plain frozen
dataclasses so they hash, print, and diff cleanly and never touch jax
device state at import time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# --------------------------------------------------------------------------
# sub-configs
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int              # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    # second-hop (per-expert buffer) headroom on top of the dispatch
    # capacity; 1.0 = no extra padding (hillclimb lever, §Perf cell 2)
    local_capacity_factor: float = 1.25
    # "none" | "int8": quantize the dispatch all-to-all payload (per-slot
    # scales, straight-through bwd also int8) — DeepSeek fp8-dispatch
    # analogue; combine stays bf16
    dispatch_quant: str = "none"
    router_jitter: float = 0.0
    num_shared_experts: int = 0   # kimi-style shared expert(s)
    d_ff_shared: int = 0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    # period-8 pattern, mLSTM:sLSTM = 7:1 (xLSTM[7:1])
    mlstm_per_block: int = 7
    slstm_per_block: int = 1
    proj_factor_mlstm: float = 2.0   # up-projection inside mLSTM block
    proj_factor_slstm: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a STUB:
    input_specs() supplies precomputed frame embeddings (B, n_frames, d)."""
    num_layers: int
    n_frames: int = 1500          # whisper: 30 s audio -> 1500 frames post-conv
    d_model: int = 0              # 0 -> same as decoder d_model
    num_heads: int = 0            # 0 -> same as decoder


@dataclass(frozen=True)
class FrontendConfig:
    """Vision frontend stub for VLMs. input_specs() supplies patch embeds."""
    num_patches: int = 256
    d_frontend: int = 0           # 0 -> d_model (pre-projected stub)


# --------------------------------------------------------------------------
# block pattern
# --------------------------------------------------------------------------
# A model is `n_super` repetitions (lax.scan) of a "super-block": an ordered
# tuple of (mixer, ffn) sub-blocks. Uniform models have a 1-layer super-block.
#   mixer in {"attn", "mamba", "mlstm", "slstm"}
#   ffn   in {"dense", "moe", "none"}
BlockDef = Tuple[str, str]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # block pattern (derived in __post_init__ when empty)
    block_defs: Tuple[BlockDef, ...] = ()
    # ffn / norm flavor
    ffn_type: str = "swiglu"      # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    qk_norm: bool = False
    # position encoding
    pos_embedding: str = "rope"   # rope | learned | sinusoidal | none
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    # attention flavor
    attention_type: str = "gqa"   # gqa | mla
    mla: Optional[MLAConfig] = None
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # hybrid / ssm
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec / vlm frontends
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    # embedding
    tie_embeddings: bool = False
    # citation tag from the assignment table
    source: str = ""

    # ---- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_defs:
            ffn = "moe" if (self.moe is not None and self.family == "moe") else "dense"
            object.__setattr__(self, "block_defs", (("attn", ffn),))

    @property
    def n_super(self) -> int:
        n, r = divmod(self.num_layers, len(self.block_defs))
        if r:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"super-block size {len(self.block_defs)}")
        return n

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch has a sub-quadratic path (SSM/hybrid/linear-attn),
        i.e. long_500k applies."""
        return any(m in ("mamba", "mlstm", "slstm") for m, _ in self.block_defs)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def padded_vocab(self, multiple: int = 2048) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple


# --------------------------------------------------------------------------
# shapes
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    grad_accum: int = 1           # training only: microbatch accumulation

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train", grad_accum=8),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}


# --------------------------------------------------------------------------
# runtime / training config
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    # precision
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"       # master copy dtype held by optimizer
    # optimizer
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    # memory policy
    remat: bool = True
    remat_policy: str = "dots"         # none | dots | full
    # distribution extras
    grad_compression: str = "none"     # none | int8  (cross-pod reduction)
    # attention impl: "reference" (chunked jnp; dry-run) | "pallas"
    attention_impl: str = "reference"
    attention_q_chunk: int = 1024

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
