"""whisper-large-v3 [audio]: enc-dec, conv frontend (stub).

32L (enc) + 32L (dec), d_model=1280, 20 heads (GQA kv=20 == MHA),
d_ff=5120, vocab=51866.  [arXiv:2212.04356; unverified]

The mel/conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, 1500, 1280).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    ffn_type="gelu",
    norm_type="layernorm",
    pos_embedding="learned",
    encoder=EncoderConfig(num_layers=32, n_frames=1500),
    source="arXiv:2212.04356; unverified",
)
