"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8, d_ff_expert=2048.  Trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

The assignment specifies GQA kv=8 (real K2 uses MLA); the assignment config
wins — see DESIGN.md §Arch-applicability. One shared expert per DeepSeek-V3
lineage. head_dim = 7168 // 64 = 112.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                      # = per-expert intermediate
    vocab_size=163840,
    rope_theta=5e4,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048),
    source="arXiv:2501.kimi2; unverified",
)
