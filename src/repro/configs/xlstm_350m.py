"""xlstm-350m [ssm]: 24 blocks, d_model=1024, 4 heads, vocab=50304,
d_ff=0 (no separate FFN: xLSTM blocks carry internal up/down projections).
sLSTM + mLSTM blocks in the paper's xLSTM[7:1] ratio -> period-8 super-block
of 7 mLSTM + 1 sLSTM, 24L = 3 super-blocks.  [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig, XLSTMConfig

_PERIOD8 = tuple(("mlstm", "none") for _ in range(7)) + (("slstm", "none"),)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_defs=_PERIOD8,
    pos_embedding="none",
    xlstm=XLSTMConfig(),
    source="arXiv:2405.04517; unverified",
)
