"""Data-driven sharding rules: param / optimizer / cache / batch specs.

Scheme (see DESIGN.md §4): mesh axes ("pod", "data", "model") or
("data", "model").
  * params: 2D sharded — megatron-style TP over "model" (column-parallel
    input projections, row-parallel output projections, EP for experts,
    vocab-parallel embeddings) + FSDP-style storage sharding over "data".
    Any dim the mesh cannot divide falls back to unsharded (whisper's 20
    heads, xLSTM's 4 heads, ...).
  * optimizer state: mirrors param specs leaf-for-leaf.
  * batch: batch dim over ("pod","data").
  * decode caches: batch over "data" when divisible, KV-seq over "model"
    (+"data" for batch-1 long-context).
All leaves are matched by (path name, shape), never by model type — new
architectures pick up rules for free.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axes(mesh):
    return set(mesh.axis_names)


def _div(dim, mesh, *axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def _maybe(dim, mesh, axis):
    return axis if (axis in _axes(mesh) and _div(dim, mesh, axis)) else None


ROW_PARALLEL = ("wo", "w_out", "w_down", "shared_wo")   # contraction first
# NOTE (§Perf cell 3, iters 2a/2b — REFUTED): three alternative xLSTM weight
# layouts (FSDP-only, fully replicated, recurrent-R replicated) each measured
# MORE collective bytes than GSPMD's own choice under the generic rules;
# kept generic. The confirmed cell-3 win was grad-accum restructuring.


def _param_spec(path, leaf, mesh):
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    shape = leaf.shape
    # stacked layer dim (n_super) leads every stack param: never shard it
    stacked = "stack" in names or "encoder" in names
    core = shape[1:] if stacked else shape
    if len(core) == 0 or min(core, default=0) == 0:
        return P()

    def build(parts):
        full = ([None] + parts) if stacked else parts
        while full and full[-1] is None:
            full.pop()
        return P(*full)

    if name == "table":                       # embed/pos tables
        if "pos" in names:
            return P()
        # vocab dim unsharded (token gather stays local); shard d_model over
        # model(+data) — avoids SPMD's "involuntary full remat" on gather
        if _div(core[1], mesh, *(a for a in ("model", "data")
                                 if a in _axes(mesh))):
            ax = tuple(a for a in ("model", "data") if a in _axes(mesh))
            return build([None, ax if len(ax) > 1 else ax[0]])
        return build([None, _maybe(core[1], mesh, "model")])
    if name == "w" and "lm_head" in names:
        return build([_maybe(core[0], mesh, "data"),
                      _maybe(core[1], mesh, "model")])
    if len(core) == 1:
        return P()                            # norms, biases, A_log rows etc.

    # MoE experts: (E, D, F) / (E, F, D) — EP over *data* (tokens all-to-all
    # stays on the axis that shards them; see moe_sharded.py), TP-in-expert
    # (F) over model, replicated over pod (pod-local expert replicas).
    if name in ("wi", "wg") and len(core) == 3:
        return build([_maybe(core[0], mesh, "data"), None,
                      _maybe(core[2], mesh, "model")])
    if name == "wo" and len(core) == 3 and "ffn" in names:
        return build([_maybe(core[0], mesh, "data"),
                      _maybe(core[1], mesh, "model"), None])

    # attention projections: (D, H, Dh) in / (H, Dh, D) out
    if name in ("wq", "wk", "wv") and len(core) == 3:
        return build([_maybe(core[0], mesh, "data"),
                      _maybe(core[1], mesh, "model"), None])
    if name == "wo" and len(core) == 3:
        return build([_maybe(core[0], mesh, "model"), None,
                      _maybe(core[2], mesh, "data")])
    if name in ("w_uq", "w_uk", "w_uv") and len(core) == 3:   # MLA up-proj
        # NEVER shard the lora-rank contraction dim: GSPMD defers the
        # partial-sum all the way into the (B,H,S,S) attention scores
        # (measured 342 TB/dev on minicpm prefill_32k — EXPERIMENTS.md §Perf
        # iter 1). These weights are ~1M params: shard heads when divisible,
        # else replicate.
        return build([None, _maybe(core[1], mesh, "model"), None])
    if name in ("w_dq", "w_dkv", "w_kr") and len(core) == 2:  # MLA down-proj
        # same partial-sum hazard on d_model: shard only the rank dim
        return build([None, _maybe(core[1], mesh, "model")])

    if name in ROW_PARALLEL:                  # (F, D): row-parallel
        return build([_maybe(core[0], mesh, "model"),
                      _maybe(core[1], mesh, "data")])
    # default 2D: column-parallel (D_in, F): FSDP over data, TP over model
    parts = [_maybe(core[0], mesh, "data")]
    parts += [None] * (len(core) - 2)
    parts += [_maybe(core[-1], mesh, "model")]
    return build(parts)


def param_shardings(param_tree, mesh):
    """param_tree: pytree of arrays or ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _param_spec(path, leaf, mesh)),
        param_tree)


def opt_shardings(opt_tree, mesh):
    """Moments/master mirror the param rules (drop the {mu,nu,master} key);
    scalars replicated."""
    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if not names or names[0] == "step":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _param_spec(path[1:], leaf, mesh))
    return jax.tree_util.tree_map_with_path(spec, opt_tree)


# --------------------------------------------------------------------------
# batch / cache
# --------------------------------------------------------------------------

def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in _axes(mesh))


def batch_shardings(batch_tree, mesh):
    axes = batch_axes(mesh)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if leaf.shape[0] % n == 0:
            parts = [axes if len(axes) > 1 else axes[0]]
        else:
            parts = [None]
        return NamedSharding(mesh, P(*parts))
    return jax.tree_util.tree_map_with_path(spec, batch_tree)


_SEQ_CACHE_LEAVES = {"k", "v", "c_kv", "k_rope"}


def cache_shardings(cache_tree, mesh):
    """Cache leaves are stacked: (n_super, B, S, ...) for attention,
    (n_super, B, ...) for recurrent state. Batch -> data when divisible;
    attention KV seq -> model (+data when batch is not shardable)."""
    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        shape = leaf.shape
        parts = [None]                        # n_super dim
        if len(shape) < 2:
            return NamedSharding(mesh, P())
        b_ok = _div(shape[1], mesh, "data")
        parts.append("data" if b_ok else None)
        if name in _SEQ_CACHE_LEAVES and len(shape) >= 3:
            seq_axes = ["model"] + ([] if b_ok else ["data"])
            seq_axes = [a for a in seq_axes if a in _axes(mesh)]
            n = 1
            for a in seq_axes:
                n *= mesh.shape[a]
            if shape[2] % n == 0 and shape[2] > 1:
                parts.append(tuple(seq_axes) if len(seq_axes) > 1
                             else seq_axes[0])
            else:
                parts.append(None)
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))
    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
