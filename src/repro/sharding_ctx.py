"""Mesh context + logical-axis sharding constraints.

Model code calls ``constrain(x, *logical_axes)`` with logical names; outside
a mesh context this is a no-op (single-device smoke tests), inside it maps
logical -> physical mesh axes and applies with_sharding_constraint, skipping
any dim the mesh cannot divide evenly (divisibility fallback — see DESIGN.md).

Logical axes:
  "batch"   -> ("pod", "data") when the mesh has a pod axis, else ("data",)
  "tokens"  -> same as batch (flattened token dim)
  "data"    -> ("data",)
  "model"/"expert"/"heads"/"ff"/"vocab" -> ("model",)
  "seq"     -> ("model",)   (context/sequence sharding for long KV)
  None      -> unsharded dim
"""
from __future__ import annotations

import contextlib
import inspect
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


# --------------------------------------------------------------------------
# JAX version compatibility (the installed JAX moved these APIs around):
#   * AbstractMesh: old signature is ``AbstractMesh(((name, size), ...))``,
#     new signature is ``AbstractMesh(axis_sizes, axis_names)``.
#   * jax.sharding.AxisType / make_mesh(axis_types=...): newer JAX only.
#   * shard_map: ``jax.shard_map(..., check_vma=)`` on newer JAX,
#     ``jax.experimental.shard_map.shard_map(..., check_rep=)`` on older.
# --------------------------------------------------------------------------
_ABSTRACT_OLD_STYLE = "shape_tuple" in inspect.signature(
    jax.sharding.AbstractMesh.__init__).parameters


def abstract_mesh(axis_sizes, axis_names):
    """Version-compat ``AbstractMesh`` constructor: always call as
    ``abstract_mesh((16, 16), ("data", "model"))``."""
    if _ABSTRACT_OLD_STYLE:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
    return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))


def shard_map(f, mesh, in_specs, out_specs, check_replication=False):
    """Version-compat shard_map (check_vma / check_rep kwarg rename)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=check_replication)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_replication)

def on_tpu() -> bool:
    """True when the default JAX backend is a TPU — the condition under
    which Pallas kernels compile natively.  Everywhere else (CPU CI,
    laptops) callers fall back to ``interpret=True``."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret):
    """The kernels' shared interpret-mode policy (the ``flash_attention``
    idiom): an explicit True/False wins; ``None`` means "interpret
    everywhere but TPU"."""
    return (not on_tpu()) if interpret is None else bool(interpret)


_LOGICAL = {
    "data": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "seq": ("model",),
}


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _physical(mesh, logical):
    if logical is None:
        return None
    if logical in ("batch", "tokens"):
        return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    axes = _LOGICAL[logical]
    return tuple(a for a in axes if a in mesh.axis_names) or None


def axis_size(mesh, physical):
    if physical is None:
        return 1
    n = 1
    for a in (physical if isinstance(physical, tuple) else (physical,)):
        n *= mesh.shape[a]
    return n


def spec_for(mesh, shape, logical_axes):
    """PartitionSpec with divisibility fallback per dim."""
    parts = []
    for dim, logical in zip(shape, logical_axes):
        phys = _physical(mesh, logical)
        if phys is not None and dim % axis_size(mesh, phys) == 0:
            parts.append(phys if len(phys) > 1 else phys[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x, *logical_axes):
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(mesh, x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
