"""Mesh context + logical-axis sharding constraints.

Model code calls ``constrain(x, *logical_axes)`` with logical names; outside
a mesh context this is a no-op (single-device smoke tests), inside it maps
logical -> physical mesh axes and applies with_sharding_constraint, skipping
any dim the mesh cannot divide evenly (divisibility fallback — see DESIGN.md).

Logical axes:
  "batch"   -> ("pod", "data") when the mesh has a pod axis, else ("data",)
  "tokens"  -> same as batch (flattened token dim)
  "data"    -> ("data",)
  "model"/"expert"/"heads"/"ff"/"vocab" -> ("model",)
  "seq"     -> ("model",)   (context/sequence sharding for long KV)
  None      -> unsharded dim
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()

_LOGICAL = {
    "data": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "seq": ("model",),
}


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _physical(mesh, logical):
    if logical is None:
        return None
    if logical in ("batch", "tokens"):
        return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    axes = _LOGICAL[logical]
    return tuple(a for a in axes if a in mesh.axis_names) or None


def axis_size(mesh, physical):
    if physical is None:
        return 1
    n = 1
    for a in (physical if isinstance(physical, tuple) else (physical,)):
        n *= mesh.shape[a]
    return n


def spec_for(mesh, shape, logical_axes):
    """PartitionSpec with divisibility fallback per dim."""
    parts = []
    for dim, logical in zip(shape, logical_axes):
        phys = _physical(mesh, logical)
        if phys is not None and dim % axis_size(mesh, phys) == 0:
            parts.append(phys if len(phys) > 1 else phys[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x, *logical_axes):
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(mesh, x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
