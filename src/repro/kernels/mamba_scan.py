"""Mamba selective-scan Pallas TPU kernel.

TPU adaptation: the GPU kernel (mamba's CUDA `selective_scan`) keeps the
(d_inner, d_state) state in registers and parallelizes over channels/SMs.
On TPU we tile channels into VREG-friendly (block_d) lanes, keep the
(block_d, N) state resident in VMEM scratch across the sequential chunk
grid dimension, and discretize (A_bar, B*x) on the fly inside the tile —
the (S, d_inner, N) expansion never touches HBM, which is the entire point
(the op is memory-bound; HBM traffic is ~4 passes over (S, d_inner)).

Grid: (B, d_inner/block_d, S/block_s) — last dim sequential, state carries.
Inputs are pre-computed gate/projection streams (xc = conv'd activations,
dt (softplus'd), Bm, Cm); A is (d_inner, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
                  block_s):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xc = xc_ref[0].astype(jnp.float32)          # (bs, bd)
    dt = dt_ref[0].astype(jnp.float32)          # (bs, bd)
    bm = b_ref[0].astype(jnp.float32)           # (bs, N)
    cm = c_ref[0].astype(jnp.float32)           # (bs, N)
    a = a_ref[...].astype(jnp.float32)          # (bd, N)

    def step(t, carry):
        h = carry                                # (bd, N)
        a_bar = jnp.exp(dt[t][:, None] * a)      # (bd, N)
        h = a_bar * h + (dt[t] * xc[t])[:, None] * bm[t][None, :]
        y_t = (h * cm[t][None, :]).sum(axis=1)   # (bd,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


def mamba_scan_kernel(xc, dt, bm, cm, a, *, block_d=128, block_s=64,
                      interpret=False):
    """xc/dt: (B, S, d_inner); bm/cm: (B, S, N); a: (d_inner, N).
    Returns y (B, S, d_inner) = selective_scan(x) before gating/D-skip."""
    B, S, di = xc.shape
    N = a.shape[1]
    grid = (B, di // block_d, S // block_s)
    kernel = functools.partial(_mamba_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d),
                         lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d),
                         lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, s: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d),
                               lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), xc.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(xc, dt, bm, cm, a)
