"""Pallas kernels for the jitted campaign-sweep engine's per-tick ops.

core/sweep_jax.py runs B campaigns as one ``lax.scan`` over ticks.  Its
state is *count planes*: instances within a (lane, group, progress-step)
cell are exchangeable, so the engine tracks how many sit in each cell
rather than per-instance rows.  The four ops here are its hot per-tick
phases over those planes:

  * ``campaign_preempt_kernel`` — preemption fan-out: distribute each
    (lane, group)'s sampled preemption count across its occupancy cells,
  * ``campaign_match_kernel``   — the queue->pilot matcher core: split a
    lane's matched-job count across groups by idle-pilot counts,
  * ``campaign_advance_kernel`` — pilot progress sync: completing jobs
    leave, the rest shift one dt step,
  * ``campaign_bill_kernel``    — the billing/ledger reduction.

Preempt and match share one body: a *systematic proportional integer
allocator* (cumulative largest-remainder rounding).  One cumsum, then
``floor(inclusive * k/tot) - floor(exclusive * k/tot)`` splits ``k``
units across cells proportionally, exactly and deterministically.

TPU adaptation notes:
  * the grid tiles the row axis only (``block_r`` rows per program); a
    program sees each row's full cell axis, so every op is one VPU pass
    with no cross-program reductions,
  * counts travel as int32 (Pallas TPU has no first-class bool tiles)
    and the allocator's scale factor rides in f32 — cumulative counts
    stay far below 2**24, so the f32 floors are exact,
  * the advance shift avoids gathers: ``lax.roll`` + an iota mask on
    the step axis,
  * like flash_attention, CPU/CI runs use ``interpret=True`` via the
    ops.py wrappers (sharding_ctx.default_interpret).

The jnp oracles live in kernels/ref.py; tests/test_kernels.py pins
kernel == ref exactly (integer ops throughout, so the comparison is
equality, not allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alloc_body(c_ref, k_ref, o_ref):
    counts = c_ref[...]                                # (br, C) i32
    tot = counts.sum(axis=-1, keepdims=True)
    kk = jnp.minimum(k_ref[...], tot)                  # (br, 1) i32
    s = kk.astype(jnp.float32) \
        / jnp.maximum(tot, 1).astype(jnp.float32)
    inc = jnp.cumsum(counts, axis=-1).astype(jnp.float32)
    exc = inc - counts.astype(jnp.float32)
    o_ref[...] = (jnp.floor(inc * s + 1e-3)
                  - jnp.floor(exc * s + 1e-3)).astype(jnp.int32)


def _alloc_call(counts, k, *, block_r, interpret):
    R, C = counts.shape
    spec = pl.BlockSpec((block_r, C), lambda i: (i, 0))
    return pl.pallas_call(
        _alloc_body,
        grid=(R // block_r,),
        in_specs=[spec, pl.BlockSpec((block_r, 1), lambda i: (i, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.int32),
        interpret=interpret)(counts, k)


def campaign_preempt_kernel(counts, k, *, block_r, interpret=False):
    """counts (R,C) i32 occupancy cells per (lane, group) row, k (R,1)
    i32 sampled preemption counts -> killed (R,C) i32 (proportional
    systematic split, killed <= counts, rows sum to min(k, total))."""
    return _alloc_call(counts, k, block_r=block_r, interpret=interpret)


def campaign_match_kernel(idle, k, *, block_r, interpret=False):
    """idle (B,G) i32 idle-pilot counts, k (B,1) i32 matched jobs per
    lane -> take (B,G) i32 (same allocator over lane rows)."""
    return _alloc_call(idle, k, block_r=block_r, interpret=interpret)


def _advance_body(b_ref, f_ref, a_ref, n_ref):
    busy = b_ref[...]                                  # (br, W) i32
    fin = busy * f_ref[...]
    rest = busy - fin
    # shift one dt step right, gather-free: roll + mask the rolled-in
    # column with an iota test
    w = jax.lax.broadcasted_iota(jnp.int32, busy.shape, busy.ndim - 1)
    a_ref[...] = jnp.where(w == 0, 0, jnp.roll(rest, 1, axis=-1))
    n_ref[...] = fin.sum(axis=-1, keepdims=True)


def campaign_advance_kernel(busy, fin_mask, *, block_r, interpret=False):
    """busy (R,W) i32 job counts by progress step, fin_mask (R,W) i32
    (1 where one more tick completes the job) -> (advanced (R,W) i32,
    finished (R,1) i32)."""
    R, W = busy.shape
    spec = pl.BlockSpec((block_r, W), lambda i: (i, 0))
    return pl.pallas_call(
        _advance_body,
        grid=(R // block_r,),
        in_specs=[spec, spec],
        out_specs=(spec, pl.BlockSpec((block_r, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((R, W), jnp.int32),
                   jax.ShapeDtypeStruct((R, 1), jnp.int32)),
        interpret=interpret)(busy, fin_mask)


def _bill_body(l_ref, r_ref, p_ref, s_ref, o_ref):
    amt = l_ref[...].astype(jnp.float32) * r_ref[...]  # (br, G)
    s_ref[...] = amt.sum(axis=-1, keepdims=True)
    o_ref[...] = jax.lax.dot_general(
        amt, p_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def campaign_bill_kernel(live, rate, prov_onehot, *, block_r,
                         interpret=False):
    """live (B,G) i32 instance counts, rate (B,G) f32 $/instance this
    interval, prov_onehot (G,P) f32 -> (spent (B,1) f32,
    by_provider (B,P) f32)."""
    B, G = live.shape
    P = prov_onehot.shape[1]
    spec = pl.BlockSpec((block_r, G), lambda i: (i, 0))
    return pl.pallas_call(
        _bill_body,
        grid=(B // block_r,),
        in_specs=[spec, spec, pl.BlockSpec((G, P), lambda i: (0, 0))],
        out_specs=(pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_r, P), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, P), jnp.float32)),
        interpret=interpret)(live, rate, prov_onehot)
