"""Chunkwise-parallel mLSTM Pallas TPU kernel (xLSTM matrix memory).

TPU adaptation of the TFLA/chunkwise形 GPU kernels: the (dqk, dv) matrix
state + (dqk,) normalizer + scalar stabilizer live in VMEM scratch and
carry across the sequential chunk grid dim; within a chunk the math is two
MXU matmuls (S_intra = Q K^T masked-decayed, then @ V) plus VPU cumsums —
numerically identical to the stabilized sequential recurrence (see
models/xlstm.py for the derivation, ref.py for the oracle).

Grid: (B*H, S/block_s). Layout: (BH, S, d) per q/k/v, gates (BH, S, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  c_ref, n_ref, m_ref, *, block_s, dqk):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bs, dqk)
    k = k_ref[0].astype(jnp.float32) * (dqk ** -0.5)
    v = v_ref[0].astype(jnp.float32)                     # (bs, dv)
    logi = li_ref[0, :, 0].astype(jnp.float32)           # (bs,)
    logf = lf_ref[0, :, 0].astype(jnp.float32)

    m0 = m_ref[0, 0]
    f_cum = jnp.cumsum(logf)                             # (bs,)
    a = logi - f_cum
    m_run = jnp.maximum(m0, jax.lax.cummax(a, axis=0))   # (bs,)
    m_new = f_cum + m_run

    w_state = jnp.exp(m0 - m_run)                        # (bs,)
    dmask = jnp.exp(a[None, :] - m_run[:, None])         # (bs, bs)
    bs = q.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    dmask = jnp.where(row >= col, dmask, 0.0)

    s_intra = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * dmask
    num = (jax.lax.dot_general(s_intra, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + w_state[:, None] * jax.lax.dot_general(
               q, c_ref[...], (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32))
    nvec = (w_state[:, None] * n_ref[0][None, :]
            + jax.lax.dot_general(dmask, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32))
    den = jnp.maximum(jnp.abs((nvec * q).sum(1)), jnp.exp(-m_new))
    h_ref[0] = (num / den[:, None]).astype(h_ref.dtype)

    # end-of-chunk state
    mc = m_run[-1]
    w_j = jnp.exp(a - mc)                                # (bs,)
    c_ref[...] = jnp.exp(m0 - mc) * c_ref[...] + jax.lax.dot_general(
        k * w_j[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[0] = jnp.exp(m0 - mc) * n_ref[0] + (k * w_j[:, None]).sum(0)
    m_ref[0, 0] = m_new[-1]


def mlstm_chunk_kernel(q, k, v, logi, logf, *, block_s=128, interpret=False):
    """q/k: (BH, S, dqk); v: (BH, S, dv); logi/logf: (BH, S, 1).
    Returns h (BH, S, dv)."""
    BH, S, dqk = q.shape
    dv = v.shape[2]
    grid = (BH, S // block_s)
    kernel = functools.partial(_mlstm_kernel, block_s=block_s, dqk=dqk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, dqk), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, dqk), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, dv), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, 1), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, 1), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, dv), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dqk, dv), jnp.float32),
            pltpu.VMEM((1, dqk), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, logi, logf)
