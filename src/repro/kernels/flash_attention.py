"""Flash attention Pallas TPU kernel: online-softmax over KV tiles in VMEM.

TPU adaptation notes (vs. the CUDA flash-attention algorithm):
  * tiles are MXU-aligned (block_q x block_k = 128 x 128 by default; head
    dim padded to a multiple of 128 by ops.py),
  * the KV axis is the innermost grid dimension — TPU grids execute
    sequentially per core, so the (acc, m, l) online-softmax state lives in
    VMEM scratch and carries across KV steps (no atomics / shared-memory
    reductions as on GPU),
  * causal masking skips fully-masked KV tiles via pl.when (block-level
    early exit, the TPU analogue of warp-level skipping).

Layout: q (BHG, Sq, D), k/v (BKV, Skv, D) with BHG = B*Hkv*G (GQA groups
flattened); the kv batch index is bhg // G via BlockSpec index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, block_q, block_k, kv_len, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + q_offset          # absolute q positions
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale         # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip KV tiles strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal, kv_len=None, scale=None,
                           q_offset=0, block_q=128, block_k=128,
                           interpret=False):
    """q: (BHG, Sq, D), k/v: (BKV, Skv, D), BHG = BKV * G. Sq % block_q ==
    Skv % block_k == 0 (ops.py pads). kv_len masks padded KV positions;
    scale defaults to D**-0.5 (pass the true-head-dim scale when padded)."""
    BHG, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BHG // BKV
    grid = (BHG, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale if scale is not None else D ** -0.5,
        causal=causal, block_q=block_q, block_k=block_k,
        kv_len=kv_len if kv_len is not None else Skv, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // G, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BHG, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
