"""Pallas TPU kernels for the data-plane hot spots, each with a pure-jnp
oracle (ref.py) and a layout-adapting jit wrapper (ops.py). Validated with
interpret=True on CPU; TPU is the compile target (explicit BlockSpec VMEM
tiling, MXU-aligned blocks)."""
