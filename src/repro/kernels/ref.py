"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal, kv_len=None, scale=None,
                        q_offset=0):
    """q: (BHG, Sq, D); k/v: (BKV, Skv, D). Plain softmax attention."""
    BHG, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BHG // BKV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(BKV, G, Sq, D).astype(jnp.float32) * scale
    s = jnp.einsum("bgqd,bkd->bgqk", qg, k.astype(jnp.float32))
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask &= qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
    return o.reshape(BHG, Sq, D).astype(q.dtype)


def mamba_scan_ref(xc, dt, bm, cm, a):
    """Sequential selective scan. Shapes as mamba_scan_kernel."""
    B, S, di = xc.shape

    def step(h, inputs):
        xc_t, dt_t, b_t, c_t = inputs
        a_bar = jnp.exp(dt_t[:, :, None] * a[None])          # (B,di,N)
        h = a_bar * h + (dt_t * xc_t)[:, :, None] * b_t[:, None, :]
        y = (h * c_t[:, None, :]).sum(-1)                    # (B,di)
        return h, y

    h0 = jnp.zeros((B, di, a.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xc.dtype)           # (B,S,di)


def mlstm_ref(q, k, v, logi, logf):
    """Exact stabilized sequential mLSTM. q/k: (BH,S,dqk); v: (BH,S,dv);
    logi/logf: (BH,S,1). Returns (BH,S,dv)."""
    BH, S, dqk = q.shape
    dv = v.shape[2]
    kf = k.astype(jnp.float32) * (dqk ** -0.5)

    def step(carry, inputs):
        C, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = inputs
        m1 = jnp.maximum(lf_t + m, li_t)                     # (BH,)
        fp = jnp.exp(lf_t + m - m1)
        ip = jnp.exp(li_t - m1)
        C = fp[:, None, None] * C + ip[:, None, None] * \
            jnp.einsum("bd,be->bde", k_t, v_t)
        n = fp[:, None] * n + ip[:, None] * k_t
        num = jnp.einsum("bd,bde->be", q_t, C)
        den = jnp.maximum(jnp.abs((n * q_t).sum(-1)), jnp.exp(-m1))
        return (C, n, m1), num / den[:, None]

    carry = (jnp.zeros((BH, dqk, dv), jnp.float32),
             jnp.zeros((BH, dqk), jnp.float32),
             jnp.zeros((BH,), jnp.float32))
    xs = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
          jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(v.astype(jnp.float32), 1, 0),
          jnp.moveaxis(logi[..., 0].astype(jnp.float32), 1, 0),
          jnp.moveaxis(logf[..., 0].astype(jnp.float32), 1, 0))
    _, hs = jax.lax.scan(step, carry, xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)


def moe_gmm_ref(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


# -- campaign-sweep tick ops (core/sweep_jax.py hot path) ------------------
# The jitted sweep engine tracks exchangeable instances as count planes
# (lane x group x progress-step), not per-instance rows; the tick ops are
# integer allocations and reductions over those planes.  The engine calls
# these jnp forms directly on CPU and swaps in the Pallas kernels
# (kernels/campaign_sweep.py) on TPU; test_kernels.py pins kernel == ref.

def campaign_alloc_ref(counts, k):
    """Proportional integer allocator: counts (R,C) i32 non-negative,
    k (R,) i32 -> take (R,C) i32 with 0 <= take <= counts and
    ``take.sum(-1) == min(k, counts.sum(-1))``.  Systematic (cumulative
    largest-remainder) rounding: exact, deterministic, one cumsum."""
    tot = counts.sum(axis=-1)
    kk = jnp.minimum(k, tot)
    s = kk.astype(jnp.float32) / jnp.maximum(tot, 1).astype(jnp.float32)
    inc = jnp.cumsum(counts, axis=-1).astype(jnp.float32)
    exc = inc - counts.astype(jnp.float32)
    return (jnp.floor(inc * s[:, None] + 1e-3)
            - jnp.floor(exc * s[:, None] + 1e-3)).astype(jnp.int32)


def campaign_preempt_ref(counts, k):
    """Preemption fan-out: distribute each (lane, group)'s sampled
    preemption count ``k`` across its instance categories (idle,
    pilot-dead, busy-at-step-w) proportionally to occupancy.
    counts (R,C) i32, k (R,) i32 -> killed (R,C) i32."""
    return campaign_alloc_ref(counts, k)


def campaign_match_ref(idle, k):
    """Queue->pilot matcher core: split each lane's ``k`` matched jobs
    across groups proportionally to idle-pilot counts.
    idle (B,G) i32, k (B,) i32 -> take (B,G) i32."""
    return campaign_alloc_ref(idle, k)


def campaign_advance_ref(busy, fin_mask):
    """Pilot progress sync: busy (R,W) i32 job counts by progress step,
    fin_mask (R,W) bool (steps whose jobs complete after one more tick)
    -> (advanced (R,W) i32, finished (R,) i32).  Completing jobs leave;
    the rest shift one dt step right."""
    fin = busy * fin_mask.astype(busy.dtype)
    rest = busy - fin
    advanced = jnp.concatenate(
        [jnp.zeros_like(rest[:, :1]), rest[:, :-1]], axis=-1)
    return advanced, fin.sum(axis=-1)


def campaign_bill_ref(live, rate, prov_onehot):
    """Billing/ledger reduction: live (B,G) i32 instance counts,
    rate (B,G) f32 ($ owed per instance this interval), prov_onehot
    (G,P) -> (spent (B,) f32, by_provider (B,P) f32)."""
    amt = live.astype(jnp.float32) * rate
    return amt.sum(axis=-1), amt @ prov_onehot
