"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal, kv_len=None, scale=None,
                        q_offset=0):
    """q: (BHG, Sq, D); k/v: (BKV, Skv, D). Plain softmax attention."""
    BHG, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BHG // BKV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(BKV, G, Sq, D).astype(jnp.float32) * scale
    s = jnp.einsum("bgqd,bkd->bgqk", qg, k.astype(jnp.float32))
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask &= qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
    return o.reshape(BHG, Sq, D).astype(q.dtype)


def mamba_scan_ref(xc, dt, bm, cm, a):
    """Sequential selective scan. Shapes as mamba_scan_kernel."""
    B, S, di = xc.shape

    def step(h, inputs):
        xc_t, dt_t, b_t, c_t = inputs
        a_bar = jnp.exp(dt_t[:, :, None] * a[None])          # (B,di,N)
        h = a_bar * h + (dt_t * xc_t)[:, :, None] * b_t[:, None, :]
        y = (h * c_t[:, None, :]).sum(-1)                    # (B,di)
        return h, y

    h0 = jnp.zeros((B, di, a.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xc.dtype)           # (B,S,di)


def mlstm_ref(q, k, v, logi, logf):
    """Exact stabilized sequential mLSTM. q/k: (BH,S,dqk); v: (BH,S,dv);
    logi/logf: (BH,S,1). Returns (BH,S,dv)."""
    BH, S, dqk = q.shape
    dv = v.shape[2]
    kf = k.astype(jnp.float32) * (dqk ** -0.5)

    def step(carry, inputs):
        C, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = inputs
        m1 = jnp.maximum(lf_t + m, li_t)                     # (BH,)
        fp = jnp.exp(lf_t + m - m1)
        ip = jnp.exp(li_t - m1)
        C = fp[:, None, None] * C + ip[:, None, None] * \
            jnp.einsum("bd,be->bde", k_t, v_t)
        n = fp[:, None] * n + ip[:, None] * k_t
        num = jnp.einsum("bd,bde->be", q_t, C)
        den = jnp.maximum(jnp.abs((n * q_t).sum(-1)), jnp.exp(-m1))
        return (C, n, m1), num / den[:, None]

    carry = (jnp.zeros((BH, dqk, dv), jnp.float32),
             jnp.zeros((BH, dqk), jnp.float32),
             jnp.zeros((BH,), jnp.float32))
    xs = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
          jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(v.astype(jnp.float32), 1, 0),
          jnp.moveaxis(logi[..., 0].astype(jnp.float32), 1, 0),
          jnp.moveaxis(logf[..., 0].astype(jnp.float32), 1, 0))
    _, hs = jax.lax.scan(step, carry, xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)


def moe_gmm_ref(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
