"""Grouped (per-expert) matmul Pallas TPU kernel for MoE expert FFNs.

Operates on the capacity-padded dispatch layout (E, C, D) x (E, D, F) ->
(E, C, F) produced by models/moe_sharded.py. Blocked over (C, F) with an
fp32 VMEM accumulator over the K (D) grid dimension; expert index is the
outermost (parallel) grid dim. MXU-aligned 128x128x128 blocks by default.

On real fleets this replaces the XLA einsum for the expert FFN hot spot;
the win is tile-local accumulation and no (E*C, D) re-materialization
between the gate/up/down matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_kernel(x, w, *, block_c=128, block_f=128, block_k=128,
                   interpret=False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    grid = (E, C // block_c, F // block_f, D // block_k)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, c, f, k: (e, c, k)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda e, c, f, k: (e, k, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, c, f, k: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
