"""jit'd public wrappers around the Pallas kernels: model-layout adapters,
MXU-alignment padding, and interpret-mode fallback on CPU.

``flash_attention`` plugs into models/attention.py via the flash_fn hook
(RunConfig.attention_impl == "pallas"); the others are drop-in replacements
for the reference einsums/scans at the same call sites.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.campaign_sweep import (campaign_advance_kernel,
                                          campaign_bill_kernel,
                                          campaign_match_kernel,
                                          campaign_preempt_kernel)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.mamba_scan import mamba_scan_kernel
from repro.kernels.mlstm_chunk import mlstm_chunk_kernel
from repro.kernels.moe_gmm import moe_gmm_kernel
from repro.sharding_ctx import default_interpret, on_tpu


def _on_tpu():
    return on_tpu()


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    """Model layout: q (B,Sq,H,D), k/v (B,Skv,Hkv,D) -> (B,Sq,H,D)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    qk = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    vv = v.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    Skv = kk.shape[1]
    qk, _ = _pad_to(qk, 2, 128)
    kk, _ = _pad_to(kk, 2, 128)
    vv, _ = _pad_to(vv, 2, 128)
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    qk, pq = _pad_to(qk, 1, bq)
    kk, _ = _pad_to(kk, 1, bk)
    vv, _ = _pad_to(vv, 1, bk)
    o = flash_attention_kernel(qk, kk, vv, causal=causal, kv_len=Skv,
                               scale=scale, block_q=bq, block_k=bk,
                               interpret=interpret)
    o = o[:, :Sq, :D].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return o


@functools.partial(jax.jit, static_argnames=("block_d", "block_s",
                                             "interpret"))
def mamba_scan(xc, dt, bm, cm, a, *, block_d=128, block_s=64,
               interpret=None):
    """xc/dt: (B,S,di); bm/cm: (B,S,N); a: (di,N) -> y (B,S,di)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, di = xc.shape
    bd = min(block_d, di)
    bs = min(block_s, S)
    if di % bd or S % bs:
        xc, _ = _pad_to(xc, 2, bd)
        dt, _ = _pad_to(dt, 2, bd)
        a, _ = _pad_to(a, 0, bd)
        xc, _ = _pad_to(xc, 1, bs)
        dt, _ = _pad_to(dt, 1, bs)
        bm, _ = _pad_to(bm, 1, bs)
        cm, _ = _pad_to(cm, 1, bs)
    y = mamba_scan_kernel(xc, dt, bm, cm, a, block_d=bd, block_s=bs,
                          interpret=interpret)
    return y[:, :S, :di]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def mlstm_chunk(q, k, v, logi, logf, *, block_s=128, interpret=None):
    """q/k: (BH,S,dqk); v: (BH,S,dv); gates (BH,S,1) -> h (BH,S,dv)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    S = q.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, "pad sequence to a chunk multiple upstream"
    return mlstm_chunk_kernel(q, k, v, logi, logf, block_s=bs,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k",
                                             "interpret"))
def moe_gmm(x, w, *, block_c=128, block_f=128, block_k=128, interpret=None):
    """x: (E,C,D) @ w: (E,D,F) -> (E,C,F), fp32 accumulation."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bk = min(block_c, C), min(block_f, F), min(block_k, D)
    xp, _ = _pad_to(_pad_to(x, 1, bc)[0], 2, bk)
    wp, _ = _pad_to(_pad_to(w, 1, bk)[0], 2, bf)
    o = moe_gmm_kernel(xp, wp, block_c=bc, block_f=bf, block_k=bk,
                       interpret=interpret)
    return o[:, :C, :F]


# -- campaign-sweep tick ops (core/sweep_jax.py) ---------------------------
# Same contract as the model kernels above: the wrapper owns layout
# padding (cell axis to a VPU lane multiple, row axis to the row-block)
# and the interpret-mode fallback; kernels/ref.py holds the jnp oracles
# the jitted engine runs on CPU.

def _pad2(x, block_r, c_mult=128):
    x, _ = _pad_to(x, 0, block_r)
    x, _ = _pad_to(x, 1, c_mult)
    return x


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def campaign_preempt(counts, k, *, block_r=8, interpret=None):
    """Preemption fan-out: counts (R,C) i32 occupancy cells per
    (lane, group) row, k (R,) i32 sampled preemption counts ->
    killed (R,C) i32 (proportional systematic split)."""
    interpret = default_interpret(interpret)
    R, C = counts.shape
    br = min(block_r, R)
    kp = _pad_to(k.astype(jnp.int32)[:, None], 0, br)[0]
    killed = campaign_preempt_kernel(
        _pad2(counts.astype(jnp.int32), br), kp,
        block_r=br, interpret=interpret)
    return killed[:R, :C]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def campaign_match(idle, k, *, block_r=8, interpret=None):
    """Queue->pilot matcher core: idle (B,G) i32 idle-pilot counts,
    k (B,) i32 matched jobs per lane -> take (B,G) i32."""
    interpret = default_interpret(interpret)
    B, G = idle.shape
    br = min(block_r, B)
    kp = _pad_to(k.astype(jnp.int32)[:, None], 0, br)[0]
    take = campaign_match_kernel(
        _pad2(idle.astype(jnp.int32), br), kp,
        block_r=br, interpret=interpret)
    return take[:B, :G]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def campaign_advance(busy, fin_mask, *, block_r=8, interpret=None):
    """Pilot progress sync: busy (R,W) i32 job counts by progress step,
    fin_mask (R,W) bool -> (advanced (R,W) i32, finished (R,) i32)."""
    interpret = default_interpret(interpret)
    R, W = busy.shape
    br = min(block_r, R)
    adv, fin = campaign_advance_kernel(
        _pad2(busy.astype(jnp.int32), br),
        _pad2(fin_mask.astype(jnp.int32), br),
        block_r=br, interpret=interpret)
    return adv[:R, :W], fin[:R, 0]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def campaign_bill(live, rate, prov_onehot, *, block_r=8, interpret=None):
    """Billing/ledger reduction: live (B,G) i32 instance counts,
    rate (B,G) f32, prov_onehot (G,P) f32 -> (spent (B,) f32,
    by_provider (B,P) f32)."""
    interpret = default_interpret(interpret)
    B, G = live.shape
    P = prov_onehot.shape[1]
    br = min(block_r, B)
    oh = _pad_to(_pad_to(prov_onehot.astype(jnp.float32), 0, 128)[0],
                 1, 128)[0]
    spent, by_prov = campaign_bill_kernel(
        _pad2(live.astype(jnp.int32), br),
        _pad2(rate.astype(jnp.float32), br), oh,
        block_r=br, interpret=interpret)
    return spent[:B, 0], by_prov[:B, :P]
