"""GQA attention with a memory-bounded chunked reference path + KV cache.

The reference path (used by the dry-run; XLA:CPU cannot lower Mosaic) chunks
the query dimension with lax.scan so 32k-token prefill never materializes a
full (S, S) score tensor — the same working-set discipline the Pallas flash
kernel applies at the VMEM level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_head_norm
from repro.sharding_ctx import constrain


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_attention(key, d_model, num_heads, num_kv_heads, head_dim,
                   qk_norm=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d_model, num_heads, head_dim)),
        "wk": dense_init(kk, (d_model, num_kv_heads, head_dim)),
        "wv": dense_init(kv, (d_model, num_kv_heads, head_dim)),
        "wo": dense_init(ko, (num_heads, head_dim, d_model),
                         in_axis_size=num_heads * head_dim),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


# --------------------------------------------------------------------------
# core scaled-dot-product with GQA grouping
# --------------------------------------------------------------------------

def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D), mask: (B?,Sq,Skv) bool or None.
    Returns (B,Sq,H,D). Softmax in fp32."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scale = D ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        big_neg = jnp.finfo(jnp.float32).min
        scores = jnp.where(mask[:, None, None, :, :], scores, big_neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])   # v dim may differ (MLA)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal,
                      kv_valid_len=None, q_chunk=1024, _segment=True):
    """Query-chunked attention. Shapes as _sdpa. Positions are (Sq,)/(Skv,)
    int32 absolute positions used for causal masking; kv_valid_len (scalar)
    masks unwritten cache slots.

    Causal self-attention is KV-*segmented* (triangular blocking): query
    segment j only sees kv[: (j+1)*Sq/nseg], statically — cutting ~37.5 % of
    the quadratic FLOPs XLA would spend on fully-masked blocks (the Pallas
    flash kernel gets the full 50 % via per-block skipping)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]

    if (_segment and causal and Sq == Skv and kv_valid_len is None
            and Sq % q_chunk == 0 and Sq // q_chunk >= 2):
        nseg = min(4, Sq // q_chunk)
        if Sq % nseg == 0:
            qs = Sq // nseg
            outs = []
            for j in range(nseg):
                kv_end = (j + 1) * qs
                outs.append(chunked_attention(
                    q[:, j * qs:(j + 1) * qs], k[:, :kv_end], v[:, :kv_end],
                    q_positions=q_positions[j * qs:(j + 1) * qs],
                    kv_positions=kv_positions[:kv_end], causal=True,
                    q_chunk=q_chunk, _segment=False))
            return jnp.concatenate(outs, axis=1)

    def mask_for(qpos):
        m = jnp.ones((qpos.shape[0], Skv), bool)
        if causal:
            m &= qpos[:, None] >= kv_positions[None, :]
        if kv_valid_len is not None:
            m &= (kv_positions < kv_valid_len)[None, :]
        return jnp.broadcast_to(m[None], (B,) + m.shape)

    needs_mask = causal or (kv_valid_len is not None)
    if Sq <= q_chunk or Sq % q_chunk != 0:
        return _sdpa(q, k, v, mask_for(q_positions) if needs_mask else None)

    nc = Sq // q_chunk
    qc = q.reshape(B, nc, q_chunk, H, D).swapaxes(0, 1)     # (nc,B,c,H,D)
    pc = q_positions.reshape(nc, q_chunk)

    # Pin batch->data, everything else replicated. Without this GSPMD is
    # free to shard the head-dim CONTRACTION over "model" and defer the
    # partial sum into the (B,H,c,Skv) scores — measured 342 TB/device on
    # minicpm prefill_32k (EXPERIMENTS.md §Perf cell 1, iter 2).
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)

    def body(_, xs):
        qi, pi = xs
        qi = constrain(qi, "batch", None, None, None)
        oi = _sdpa(qi, k, v, mask_for(pi) if needs_mask else None)
        oi = constrain(oi, "batch", None, None, None)
        return None, oi

    _, out = jax.lax.scan(body, None, (qc, pc))
    return out.swapaxes(0, 1).reshape(B, Sq, H, v.shape[-1])


# --------------------------------------------------------------------------
# block-level apply
# --------------------------------------------------------------------------

def _project_qkv(p, x, x_kv, rope_theta, q_positions, kv_positions,
                 qk_norm, use_rope):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x_kv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x_kv, p["wv"].astype(dt))
    if qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, q_positions, rope_theta)
        k = apply_rope(k, kv_positions, rope_theta)
    return q, k, v


def attention_forward(p, x, *, positions, causal=True, rope_theta=1e4,
                      use_rope=True, qk_norm=False, q_chunk=1024,
                      x_cross=None, flash_fn=None):
    """Full-sequence attention (train / prefill / encoder).
    x: (B,S,D); x_cross: encoder output for cross-attention (kv source).
    Returns (out, (k, v)) — k/v returned so prefill can seed the cache."""
    x_kv = x if x_cross is None else x_cross
    kv_pos = positions if x_cross is None else jnp.arange(x_kv.shape[1])
    q, k, v = _project_qkv(p, x, x_kv, rope_theta, positions, kv_pos,
                           qk_norm, use_rope and x_cross is None)
    if flash_fn is not None and x_cross is None:
        out = flash_fn(q, k, v, causal=causal)
    else:
        out = chunked_attention(q, k, v, q_positions=positions,
                                kv_positions=kv_pos,
                                causal=causal and x_cross is None,
                                q_chunk=q_chunk)
    dt = x.dtype
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt)), (k, v)


def init_kv_cache(batch, max_len, num_kv_heads, head_dim, dtype):
    shape = (batch, max_len, num_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p, x, cache, *, pos, rope_theta=1e4, use_rope=True,
                     qk_norm=False, cross=False):
    """One-token decode. x: (B,1,D); cache {"k","v"}: (B,Smax,Hkv,D);
    pos: scalar int32 — index of the new token. Returns (out, new_cache)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    if qk_norm:
        q = rms_head_norm(q, p["q_norm"])
    if use_rope and not cross:
        q = apply_rope(q, pos[None] if pos.ndim == 0 else pos, rope_theta)

    if cross:
        k, v = cache["k"], cache["v"]          # static encoder kv
        kv_valid = None
        new_cache = cache
    else:
        k_new = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt))
        v_new = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt))
        if qk_norm:
            k_new = rms_head_norm(k_new, p["k_norm"])
        if use_rope:
            k_new = apply_rope(k_new, pos[None], rope_theta)
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": k, "v": v}
        kv_valid = pos + 1

    kv_positions = jnp.arange(k.shape[1])
    out = chunked_attention(q, k.astype(dt), v.astype(dt),
                            q_positions=pos[None], kv_positions=kv_positions,
                            causal=False, kv_valid_len=kv_valid)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt)), new_cache
