"""Public model API: init / train forward (loss) / prefill / decode.

Handles the family-specific input plumbing:
  - LM        : batch = {tokens, targets}
  - encdec    : batch = {enc_embeds, tokens, targets}   (frontend STUB)
  - vlm       : batch = {patch_embeds, tokens, targets} (frontend STUB;
                total positions = num_patches + len(tokens) = shape.seq_len)
All functions are pure; distribution comes from jit shardings + the
constrain() hints. Compute dtype is cast at the embedding boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import (apply_embed, apply_lm_head, apply_norm,
                                 cross_entropy_loss, embed_init, init_embed,
                                 init_lm_head, init_norm, sinusoidal_table)
from repro.sharding_ctx import constrain


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab()
    p = {
        "embed": init_embed(ks[0], vp, cfg.d_model),
        "stack": tf.init_stack(ks[1], cfg, cross=cfg.is_encdec),
        "final_norm": init_norm(None, cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_lm_head(ks[2], cfg.d_model, vp)
    if cfg.pos_embedding == "learned":
        p["pos"] = {"table": embed_init(ks[3], (min(cfg.max_position, 65536),
                                                cfg.d_model))}
    if cfg.is_encdec:
        import dataclasses
        enc = cfg.encoder
        enc_cfg = dataclasses.replace(
            cfg, num_layers=enc.num_layers, block_defs=(("attn", "dense"),),
            encoder=None, moe=None)
        p["encoder"] = {"stack": tf.init_stack(ks[4], enc_cfg),
                        "final_norm": init_norm(None, cfg.d_model,
                                                cfg.norm_type)}
    return p


def _encoder_cfg(cfg):
    import dataclasses
    return dataclasses.replace(cfg, num_layers=cfg.encoder.num_layers,
                               block_defs=(("attn", "dense"),), encoder=None,
                               moe=None)


def _lm_head(p, cfg, x):
    if cfg.tie_embeddings:
        return x @ p["embed"]["table"].astype(x.dtype).T
    return apply_lm_head(p["lm_head"], x, cfg.vocab_size)


def _embed_tokens(p, cfg, tokens, dtype, offset=0):
    x = apply_embed(p["embed"], tokens, dtype)
    if cfg.pos_embedding == "learned":
        S = tokens.shape[1]
        pos_tab = jax.lax.dynamic_slice_in_dim(
            p["pos"]["table"], offset, S, axis=0).astype(dtype)
        x = x + pos_tab
    return x


def run_encoder(p, cfg, enc_embeds, *, q_chunk=1024, run_cfg=None):
    """Whisper-style encoder over stub frame embeddings (B,F,D)."""
    ecfg = _encoder_cfg(cfg)
    dtype = enc_embeds.dtype
    x = enc_embeds + sinusoidal_table(enc_embeds.shape[1],
                                      cfg.d_model).astype(dtype)
    positions = jnp.arange(x.shape[1])
    x, _, _ = tf.apply_stack(p["encoder"]["stack"], x, ecfg,
                             positions=positions, causal=False,
                             q_chunk=q_chunk, run_cfg=run_cfg)
    return apply_norm(p["encoder"]["final_norm"], x, cfg.norm_type)


def _assemble_inputs(p, cfg, batch, dtype):
    """Returns (x, positions, targets, enc_out, n_prefix)."""
    enc_out = None
    n_prefix = 0
    tokens = batch["tokens"]
    x = _embed_tokens(p, cfg, tokens, dtype)
    if cfg.is_encdec:
        enc_out = run_encoder(p, cfg, batch["enc_embeds"].astype(dtype))
    elif cfg.frontend is not None:
        patches = batch["patch_embeds"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    positions = jnp.arange(x.shape[1])
    return x, positions, enc_out, n_prefix


def forward_loss(p, cfg: ModelConfig, batch, *, compute_dtype=jnp.bfloat16,
                 run_cfg=None, flash_fn=None):
    """Training forward: mean CE loss (+ MoE aux). targets==-1 masked."""
    q_chunk = getattr(run_cfg, "attention_q_chunk", 1024) if run_cfg else 1024
    x, positions, enc_out, n_prefix = _assemble_inputs(
        p, cfg, batch, compute_dtype)
    x = constrain(x, "batch", None, None)
    x, _, aux = tf.apply_stack(p["stack"], x, cfg, positions=positions,
                               causal=True, q_chunk=q_chunk, enc_out=enc_out,
                               cross=cfg.is_encdec, run_cfg=run_cfg,
                               flash_fn=flash_fn)
    x = apply_norm(p["final_norm"], x, cfg.norm_type)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = _lm_head(p, cfg, x)
    loss = cross_entropy_loss(logits, batch["targets"], cfg.vocab_size)
    return loss + aux.astype(jnp.float32), {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    return tf.init_stack_state(cfg, batch, max_len, dtype,
                               cross=cfg.is_encdec)


def prefill(p, cfg: ModelConfig, batch, *, compute_dtype=jnp.bfloat16,
            q_chunk=1024):
    """Full-sequence prefill; returns (last-token logits, stacked caches).

    Attention caches come back seq-aligned with the prompt (length = prompt
    length); SSM/xLSTM states are O(1). For encdec the cross cache is the
    encoder's kv."""
    x, positions, enc_out, n_prefix = _assemble_inputs(
        p, cfg, batch, compute_dtype)
    x, caches, _ = tf.apply_stack(p["stack"], x, cfg, positions=positions,
                                  causal=True, q_chunk=q_chunk,
                                  enc_out=enc_out, cross=cfg.is_encdec,
                                  collect_cache=True)
    x = apply_norm(p["final_norm"], x, cfg.norm_type)
    logits = _lm_head(p, cfg, x[:, -1:, :])
    return logits, caches


def decode_step(p, cfg: ModelConfig, caches, token, pos, *,
                compute_dtype=jnp.bfloat16):
    """One decode step. token: (B,1) int32; pos: scalar int32 (write index).
    Returns (logits (B,1,V), new caches)."""
    x = apply_embed(p["embed"], token, compute_dtype)
    if cfg.pos_embedding == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            p["pos"]["table"], pos, 1, axis=0).astype(compute_dtype)
    x = constrain(x, "batch", None, None)
    x, new_caches = tf.decode_stack(p["stack"], x, caches, cfg, pos=pos)
    x = apply_norm(p["final_norm"], x, cfg.norm_type)
    logits = _lm_head(p, cfg, x)
    return logits, new_caches


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
