"""Core functional layers: inits, norms, FFN variants, position encodings.

All modules are (init, apply) pairs over plain dict pytrees. No framework
dependency; everything shards via GSPMD from the top-level jit shardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(key, d, norm_type="rmsnorm"):
    del key
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x, norm_type="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps=1e-6):
    """Per-head RMS norm over the trailing dim (Qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# FFN variants
# --------------------------------------------------------------------------

def init_ffn(key, d_model, d_ff, ffn_type="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if ffn_type == "swiglu":
        return {"wi": dense_init(k1, (d_model, d_ff)),
                "wg": dense_init(k2, (d_model, d_ff)),
                "wo": dense_init(k3, (d_ff, d_model), in_axis_size=d_ff)}
    return {"wi": dense_init(k1, (d_model, d_ff)),
            "wo": dense_init(k3, (d_ff, d_model), in_axis_size=d_ff)}


def apply_ffn(p, x, ffn_type="swiglu"):
    dt = x.dtype
    if ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    elif ffn_type == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"].astype(dt)))
    elif ffn_type == "gelu":
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    else:
        raise ValueError(ffn_type)
    return h @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# position encodings
# --------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponent)          # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (...,S,d/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (...,S,1,d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_table(n_pos, d_model):
    pos = np.arange(n_pos, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    out = np.zeros((n_pos, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# --------------------------------------------------------------------------
# embedding / lm head
# --------------------------------------------------------------------------

def init_embed(key, vocab_padded, d_model):
    return {"table": embed_init(key, (vocab_padded, d_model))}


def apply_embed(p, tokens, dtype):
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def init_lm_head(key, d_model, vocab_padded):
    return {"w": dense_init(key, (d_model, vocab_padded))}


def apply_lm_head(p, x, vocab_size):
    logits = x @ p["w"].astype(x.dtype)
    vp = p["w"].shape[1]
    if vp != vocab_size:  # mask padded vocab entries
        mask = (jnp.arange(vp) < vocab_size)
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def cross_entropy_loss(logits, targets, vocab_size):
    """targets == -1 are masked (e.g. image-patch positions)."""
    valid = targets >= 0
    tgt = jnp.where(valid, targets, 0)
    logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
