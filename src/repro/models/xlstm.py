"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) + sequential sLSTM.

mLSTM uses the exact stabilized chunkwise-parallel form (TFLA-style): within
a chunk an attention-like (c x c) masked matmul, across chunks a
(B,H,dk,dv) state recurrence carried by lax.scan — numerically identical to
the sequential recurrence (tests assert allclose vs. the step-by-step
oracle). Decode is an O(1)-state single step (the long_500k path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ==========================================================================
# mLSTM
# ==========================================================================

def mlstm_dims(d_model, xcfg, num_heads):
    d_inner = int(d_model * xcfg.proj_factor_mlstm)
    return d_inner, d_inner // num_heads


def init_mlstm(key, d_model, num_heads, xcfg):
    d_inner, dh = mlstm_dims(d_model, xcfg, num_heads)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d_model, 2 * d_inner)),
        "conv_w": dense_init(ks[1], (xcfg.conv1d_kernel, d_inner),
                             in_axis_size=xcfg.conv1d_kernel),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "wq": dense_init(ks[2], (d_inner, d_inner)),
        "wk": dense_init(ks[3], (d_inner, d_inner)),
        "wv": dense_init(ks[4], (d_inner, d_inner)),
        "w_if": dense_init(ks[5], (d_inner, 2 * num_heads)),
        "b_i": jnp.full((num_heads,), -10.0),   # small initial input gate
        "b_f": jnp.full((num_heads,), 3.0),     # forget-gate bias ~ open
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_down": dense_init(ks[6], (d_inner, d_model), in_axis_size=d_inner),
    }


def _headwise_norm(h, scale, num_heads, eps=1e-6):
    """GroupNorm with one group per head over (B,S,H,dh)."""
    hf = h.astype(jnp.float32)
    mu = hf.mean(-1, keepdims=True)
    var = hf.var(-1, keepdims=True)
    out = (hf - mu) * jax.lax.rsqrt(var + eps)
    B, S, H, dh = h.shape
    return (out * scale.reshape(H, dh)).astype(h.dtype)


def _mlstm_chunk(carry, qkv_if, dh):
    """One chunk of the stabilized chunkwise-parallel mLSTM.
    carry: C (B,H,dk,dv), n (B,H,dk), m (B,H).
    qkv_if: q,k,v (B,H,c,dh); logi, logf (B,H,c)."""
    C0, n0, m0 = carry
    q, k, v, logi, logf = qkv_if
    kf = k.astype(jnp.float32) * (dh ** -0.5)
    qf = q.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    F = jnp.cumsum(logf, axis=2)                        # (B,H,c)
    a = logi - F
    M = jnp.maximum(m0[..., None], jax.lax.cummax(a, axis=2))
    m_new = F + M                                       # running stabilizer

    w_state = jnp.exp(m0[..., None] - M)                # (B,H,c)
    Dmask = jnp.exp(a[:, :, None, :] - M[:, :, :, None])
    c = q.shape[2]
    tril = jnp.tril(jnp.ones((c, c), bool))
    Dmask = jnp.where(tril, Dmask, 0.0)

    S_intra = jnp.einsum("bhtd,bhjd->bhtj", qf, kf) * Dmask
    num = (jnp.einsum("bhtj,bhjd->bhtd", S_intra, vf)
           + w_state[..., None] * jnp.einsum("bhtd,bhde->bhte", qf, C0))
    nvec = (w_state[..., None] * n0[:, :, None, :]
            + jnp.einsum("bhtj,bhjd->bhtd", Dmask, kf))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", nvec, qf)),
                      jnp.exp(-m_new))
    h = num / den[..., None]                            # (B,H,c,dv)

    # end-of-chunk state
    Mc = M[..., -1]
    wc = jnp.exp(m0 - Mc)                               # (B,H)
    w_j = jnp.exp(a - Mc[..., None])                    # (B,H,c)
    C1 = wc[..., None, None] * C0 + jnp.einsum("bhj,bhjd,bhje->bhde",
                                               w_j, kf, vf)
    n1 = wc[..., None] * n0 + jnp.einsum("bhj,bhjd->bhd", w_j, kf)
    m1 = m_new[..., -1]
    return (C1, n1, m1), h


def _qkv_gates(p, x, num_heads, d_inner, dh, conv0=None):
    from repro.models.mamba import _causal_conv
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    xi, z = jnp.split(up, 2, axis=-1)
    xc, conv_new = _causal_conv(xi, p["conv_w"].astype(dt),
                                p["conv_b"].astype(dt), conv0)
    xc = jax.nn.silu(xc)
    B, S, _ = x.shape

    def heads(t):
        return t.reshape(B, S, num_heads, dh).transpose(0, 2, 1, 3)
    q = heads(xc @ p["wq"].astype(dt))
    k = heads(xc @ p["wk"].astype(dt))
    v = heads(xi @ p["wv"].astype(dt))
    gif = (xc @ p["w_if"].astype(dt)).astype(jnp.float32)
    i_raw = gif[..., :num_heads] + p["b_i"]
    f_raw = gif[..., num_heads:] + p["b_f"]
    logi = i_raw.transpose(0, 2, 1)                     # (B,H,S)
    logf = jax.nn.log_sigmoid(f_raw).transpose(0, 2, 1)
    return q, k, v, logi, logf, z, conv_new


def mlstm_forward(p, x, num_heads, xcfg, *, chunk=128, state=None):
    """x: (B,S,D) -> (y, new_state). state: {"C","n","m","conv"}."""
    B, S, D = x.shape
    dt = x.dtype
    d_inner, dh = mlstm_dims(D, xcfg, num_heads)
    conv0 = state["conv"] if state is not None else None
    q, k, v, logi, logf, z, conv_new = _qkv_gates(p, x, num_heads, d_inner,
                                                  dh, conv0)
    if state is None:
        C0 = jnp.zeros((B, num_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, num_heads, dh), jnp.float32)
        m0 = jnp.zeros((B, num_heads), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    c = min(chunk, S)
    if S % c:
        c = S
    nc = S // c

    def split_chunks(t, time_axis):
        # (B,H,S,*) -> (nc,B,H,c,*)
        shp = t.shape
        t = t.reshape(shp[:time_axis] + (nc, c) + shp[time_axis + 1:])
        return jnp.moveaxis(t, time_axis, 0)

    qs, ks_, vs = (split_chunks(t, 2) for t in (q, k, v))
    lis, lfs = (split_chunks(t, 2) for t in (logi, logf))

    def body(carry, xs):
        return _mlstm_chunk(carry, xs, dh)

    (C1, n1, m1), hs = jax.lax.scan(body, (C0, n0, m0),
                                    (qs, ks_, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 2).reshape(B, num_heads, S, dh)
    h = h.transpose(0, 2, 1, 3)                          # (B,S,H,dh)
    h = _headwise_norm(h, p["norm_scale"], num_heads)
    h = h.reshape(B, S, d_inner) * jax.nn.silu(z)
    y = h.astype(dt) @ p["w_down"].astype(dt)
    return y, {"C": C1, "n": n1, "m": m1, "conv": conv_new}


def init_mlstm_state(batch, d_model, num_heads, xcfg, dtype):
    d_inner, dh = mlstm_dims(d_model, xcfg, num_heads)
    return {"C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
            "m": jnp.zeros((batch, num_heads), jnp.float32),
            "conv": jnp.zeros((batch, xcfg.conv1d_kernel - 1, d_inner), dtype)}


def mlstm_decode(p, x, state, num_heads, xcfg):
    """Exact sequential single-token step."""
    B, _, D = x.shape
    d_inner, dh = mlstm_dims(D, xcfg, num_heads)
    q, k, v, logi, logf, z, conv_new = _qkv_gates(
        p, x, num_heads, d_inner, dh, state["conv"])
    qf = q[:, :, 0].astype(jnp.float32)                  # (B,H,dh)
    kf = k[:, :, 0].astype(jnp.float32) * (dh ** -0.5)
    vf = v[:, :, 0].astype(jnp.float32)
    li, lf = logi[:, :, 0], logf[:, :, 0]                # (B,H)
    m1 = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m1)
    ip = jnp.exp(li - m1)
    C1 = fp[..., None, None] * state["C"] + ip[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kf, vf)
    n1 = fp[..., None] * state["n"] + ip[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n1, qf)),
                      jnp.exp(-m1))
    h = (num / den[..., None])[:, None]                  # (B,1,H,dh)
    h = _headwise_norm(h, p["norm_scale"], num_heads)
    h = h.reshape(B, 1, d_inner) * jax.nn.silu(z)
    y = h.astype(x.dtype) @ p["w_down"].astype(x.dtype)
    return y, {"C": C1, "n": n1, "m": m1, "conv": conv_new}


# ==========================================================================
# sLSTM
# ==========================================================================

def init_slstm(key, d_model, num_heads, xcfg):
    dh = d_model // num_heads
    ks = jax.random.split(key, 12)
    d_ff = int(d_model * xcfg.proj_factor_slstm)
    p = {"conv_w": dense_init(ks[0], (xcfg.conv1d_kernel, d_model),
                              in_axis_size=xcfg.conv1d_kernel),
         "conv_b": jnp.zeros((d_model,), jnp.float32),
         "norm_scale": jnp.ones((d_model,), jnp.float32),
         "w_up1": dense_init(ks[9], (d_model, d_ff)),
         "w_up2": dense_init(ks[10], (d_model, d_ff)),
         "w_down": dense_init(ks[11], (d_ff, d_model), in_axis_size=d_ff)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = dense_init(ks[1 + i], (d_model, d_model))
        p[f"r_{g}"] = dense_init(ks[5 + i], (num_heads, dh, dh),
                                 in_axis_size=dh)
        p[f"b_{g}"] = jnp.zeros((d_model,), jnp.float32)
    return p


def _slstm_gates_x(p, x, conv0):
    """Input-side gate pre-activations (no recurrence)."""
    from repro.models.mamba import _causal_conv
    dt = x.dtype
    xc, conv_new = _causal_conv(x, p["conv_w"].astype(dt),
                                p["conv_b"].astype(dt), conv0)
    xc = jax.nn.silu(xc)
    gz = x @ p["w_z"].astype(dt) + p["b_z"].astype(dt)
    go = x @ p["w_o"].astype(dt) + p["b_o"].astype(dt)
    gi = xc @ p["w_i"].astype(dt) + p["b_i"].astype(dt)
    gf = xc @ p["w_f"].astype(dt) + p["b_f"].astype(dt)
    return gz, gi, gf, go, conv_new


def _slstm_step(p, carry, gates, num_heads):
    """One recurrent step. carry: (c,n,h,m), all (B,H,dh) — per-cell gates
    and per-cell stabilizer m, per the xLSTM paper."""
    c, n, h, m = carry
    gz, gi, gf, go = (g.astype(jnp.float32) for g in gates)  # (B,D)
    B = gz.shape[0]
    dh = c.shape[-1]

    def rec(g):
        return jnp.einsum("bhd,hde->bhe", h, p[f"r_{g}"])
    shape = (B, num_heads, dh)
    z_t = jnp.tanh(gz.reshape(shape) + rec("z"))
    i_t = gi.reshape(shape) + rec("i")
    f_t = gf.reshape(shape) + rec("f")
    o_t = jax.nn.sigmoid(go.reshape(shape) + rec("o"))
    m1 = jnp.maximum(f_t + m, i_t)                           # (B,H,dh)
    ip = jnp.exp(i_t - m1)
    fp = jnp.exp(f_t + m - m1)
    c1 = fp * c + ip * z_t
    n1 = fp * n + ip
    h1 = o_t * c1 / jnp.maximum(n1, 1.0)
    return (c1, n1, h1, m1)


def slstm_forward(p, x, num_heads, xcfg, *, state=None):
    B, S, D = x.shape
    dt = x.dtype
    dh = D // num_heads
    conv0 = state["conv"] if state is not None else None
    gz, gi, gf, go, conv_new = _slstm_gates_x(p, x, conv0)
    if state is None:
        zeros = jnp.zeros((B, num_heads, dh), jnp.float32)
        carry = (zeros, zeros, zeros, zeros)
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    def body(cr, g):
        cr1 = _slstm_step(p, cr, g, num_heads)
        return cr1, cr1[2]                                # emit h

    gseq = tuple(jnp.moveaxis(g, 1, 0) for g in (gz, gi, gf, go))
    carry1, hs = jax.lax.scan(body, carry, gseq)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)
    # headwise norm + gated FFN (proj_factor 4/3 GLU), block-internal
    hf = h.astype(jnp.float32)
    mu, var = hf.mean(-1, keepdims=True), hf.var(-1, keepdims=True)
    h = ((hf - mu) * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]).astype(dt)
    y = (jax.nn.gelu(h @ p["w_up1"].astype(dt)) * (h @ p["w_up2"].astype(dt))
         ) @ p["w_down"].astype(dt)
    new_state = {"c": carry1[0], "n": carry1[1], "h": carry1[2],
                 "m": carry1[3], "conv": conv_new}
    return y, new_state


def init_slstm_state(batch, d_model, num_heads, xcfg, dtype):
    dh = d_model // num_heads
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z,
            "conv": jnp.zeros((batch, xcfg.conv1d_kernel - 1, d_model), dtype)}


def slstm_decode(p, x, state, num_heads, xcfg):
    y, new_state = slstm_forward(p, x, num_heads, xcfg, state=state)
    return y, new_state
