"""Block / super-block assembly and the lax.scan'd layer stack.

A model is ``cfg.n_super`` scan iterations over a "super-block" — an ordered
tuple of (mixer, ffn) sub-blocks (cfg.block_defs). Uniform archs have a
1-sub-block super-block; jamba/xlstm use period-8 patterns. Per-super-block
params/caches are stacked on a leading axis and consumed by lax.scan, keeping
the HLO one super-block big regardless of depth (compile-time and
remat-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import apply_ffn, apply_norm, init_ffn, init_norm


# --------------------------------------------------------------------------
# single sub-block
# --------------------------------------------------------------------------

def init_subblock(key, cfg, mixer, ffn, cross=False):
    ks = jax.random.split(key, 6)
    p = {"norm1": init_norm(None, cfg.d_model, cfg.norm_type)}
    if mixer == "attn":
        if cfg.attention_type == "mla":
            p["mixer"] = mla_mod.init_mla(ks[0], cfg.d_model, cfg.num_heads,
                                          cfg.mla)
        else:
            p["mixer"] = attn.init_attention(
                ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, qk_norm=cfg.qk_norm)
        if cross:
            p["norm_cross"] = init_norm(None, cfg.d_model, cfg.norm_type)
            p["cross"] = attn.init_attention(
                ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim)
    elif mixer == "mamba":
        p["mixer"] = mb.init_mamba(ks[0], cfg.d_model, cfg.mamba)
    elif mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(ks[0], cfg.d_model, cfg.num_heads,
                                   cfg.xlstm)
    elif mixer == "slstm":
        p["mixer"] = xl.init_slstm(ks[0], cfg.d_model, cfg.num_heads,
                                   cfg.xlstm)
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        p["norm2"] = init_norm(None, cfg.d_model, cfg.norm_type)
        p["ffn"] = init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_type)
    elif ffn == "moe":
        p["norm2"] = init_norm(None, cfg.d_model, cfg.norm_type)
        p["ffn"] = moe_mod.init_moe(ks[2], cfg.d_model, cfg.moe, cfg.ffn_type)
    return p


def apply_subblock(p, x, cfg, mixer, ffn, *, positions, causal, q_chunk,
                   enc_out=None, cross=False, flash_fn=None):
    """Full-sequence apply. Returns (x, cache_seed, aux)."""
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    seed = None
    if mixer == "attn":
        if cfg.attention_type == "mla":
            y, seed = mla_mod.mla_forward(p["mixer"], h, positions=positions,
                                          mla=cfg.mla,
                                          rope_theta=cfg.rope_theta,
                                          q_chunk=q_chunk)
            seed = {"c_kv": seed[0], "k_rope": seed[1]}
        else:
            y, (k, v) = attn.attention_forward(
                p["mixer"], h, positions=positions, causal=causal,
                rope_theta=cfg.rope_theta,
                use_rope=(cfg.pos_embedding == "rope"),
                qk_norm=cfg.qk_norm, q_chunk=q_chunk, flash_fn=flash_fn)
            seed = {"k": k, "v": v}
        x = x + y
        if cross:
            hc = apply_norm(p["norm_cross"], x, cfg.norm_type)
            yc, (kc, vc) = attn.attention_forward(
                p["cross"], hc, positions=positions, causal=False,
                use_rope=False, q_chunk=q_chunk, x_cross=enc_out)
            x = x + yc
            seed = {"self": seed, "cross": {"k": kc, "v": vc}}
    elif mixer == "mamba":
        y, (h_last, conv_last) = mb.mamba_forward(p["mixer"], h, cfg.mamba)
        seed = {"h": h_last, "conv": conv_last}
        x = x + y
    elif mixer == "mlstm":
        y, st = xl.mlstm_forward(p["mixer"], h, cfg.num_heads, cfg.xlstm)
        seed = st
        x = x + y
    elif mixer == "slstm":
        y, st = xl.slstm_forward(p["mixer"], h, cfg.num_heads, cfg.xlstm)
        seed = st
        x = x + y

    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        x = x + apply_ffn(p["ffn"], apply_norm(p["norm2"], x, cfg.norm_type),
                          cfg.ffn_type)
    elif ffn == "moe":
        y, aux = moe_mod.apply_moe(p["ffn"],
                                   apply_norm(p["norm2"], x, cfg.norm_type),
                                   cfg.moe, cfg.ffn_type)
        x = x + y
    return x, seed, aux


def apply_subblock_decode(p, x, state, cfg, mixer, ffn, *, pos):
    """One-token apply. Returns (x, new_state)."""
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if mixer == "attn":
        if cfg.attention_type == "mla":
            y, new_self = mla_mod.mla_decode(
                p["mixer"], h, state["self"] if "cross" in state else state,
                pos=pos, mla=cfg.mla, rope_theta=cfg.rope_theta)
        else:
            y, new_self = attn.attention_decode(
                p["mixer"], h, state["self"] if "cross" in state else state,
                pos=pos, rope_theta=cfg.rope_theta,
                use_rope=(cfg.pos_embedding == "rope"), qk_norm=cfg.qk_norm)
        x = x + y
        if "cross" in state:
            hc = apply_norm(p["norm_cross"], x, cfg.norm_type)
            yc, _ = attn.attention_decode(p["cross"], hc, state["cross"],
                                          pos=pos, use_rope=False, cross=True)
            x = x + yc
            new_state = {"self": new_self, "cross": state["cross"]}
        else:
            new_state = new_self
    elif mixer == "mamba":
        y, new_state = mb.mamba_decode(p["mixer"], h, state, cfg.mamba)
        x = x + y
    elif mixer == "mlstm":
        y, new_state = xl.mlstm_decode(p["mixer"], h, state, cfg.num_heads,
                                       cfg.xlstm)
        x = x + y
    elif mixer == "slstm":
        y, new_state = xl.slstm_decode(p["mixer"], h, state, cfg.num_heads,
                                       cfg.xlstm)
        x = x + y

    if ffn == "dense":
        x = x + apply_ffn(p["ffn"], apply_norm(p["norm2"], x, cfg.norm_type),
                          cfg.ffn_type)
    elif ffn == "moe":
        y, _ = moe_mod.apply_moe(p["ffn"],
                                 apply_norm(p["norm2"], x, cfg.norm_type),
                                 cfg.moe, cfg.ffn_type)
        x = x + y
    return x, new_state


def init_subblock_state(cfg, idx_def, batch, max_len, dtype, cross=False):
    mixer, _ = cfg.block_defs[idx_def]
    if mixer == "attn":
        if cfg.attention_type == "mla":
            st = mla_mod.init_mla_cache(batch, max_len, cfg.mla, dtype)
        else:
            st = attn.init_kv_cache(batch, max_len, cfg.num_kv_heads,
                                    cfg.head_dim, dtype)
        if cross:
            enc = cfg.encoder
            st = {"self": st,
                  "cross": attn.init_kv_cache(batch, enc.n_frames,
                                              cfg.num_kv_heads, cfg.head_dim,
                                              dtype)}
        return st
    if mixer == "mamba":
        return mb.init_mamba_state(batch, cfg.d_model, cfg.mamba, dtype)
    if mixer == "mlstm":
        return xl.init_mlstm_state(batch, cfg.d_model, cfg.num_heads,
                                   cfg.xlstm, dtype)
    if mixer == "slstm":
        return xl.init_slstm_state(batch, cfg.d_model, cfg.num_heads,
                                   cfg.xlstm, dtype)
    raise ValueError(mixer)


# --------------------------------------------------------------------------
# stacked super-block stack
# --------------------------------------------------------------------------

def init_stack(key, cfg, cross=False):
    """Stacked params: each leaf has leading dim n_super."""
    def init_one(k):
        ks = jax.random.split(k, len(cfg.block_defs))
        return {f"b{i}": init_subblock(ks[i], cfg, m, f, cross=cross)
                for i, (m, f) in enumerate(cfg.block_defs)}
    keys = jax.random.split(key, cfg.n_super)
    return jax.vmap(init_one)(keys)


def _remat(fn, cfg_run):
    if cfg_run is None or not getattr(cfg_run, "remat", False):
        return fn
    policy = {"dots": jax.checkpoint_policies.checkpoint_dots,
              "none": None,
              "full": jax.checkpoint_policies.nothing_saveable}[
                  getattr(cfg_run, "remat_policy", "dots")]
    return jax.checkpoint(fn, policy=policy) if policy else fn


def apply_stack(stack_params, x, cfg, *, positions, causal=True, q_chunk=1024,
                enc_out=None, cross=False, run_cfg=None, collect_cache=False,
                flash_fn=None):
    """Scan the super-block stack over x. Returns (x, caches|None, aux)."""

    def body(carry, layer_p):
        xc, aux = carry
        seeds = {}
        for i, (m, f) in enumerate(cfg.block_defs):
            xc, seed, a = apply_subblock(
                layer_p[f"b{i}"], xc, cfg, m, f, positions=positions,
                causal=causal, q_chunk=q_chunk, enc_out=enc_out, cross=cross,
                flash_fn=flash_fn)
            aux = aux + a
            if collect_cache:
                seeds[f"b{i}"] = seed
        return (xc, aux), (seeds if collect_cache else None)

    body = _remat(body, run_cfg)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    stack_params)
    return x, caches, aux


def decode_stack(stack_params, x, caches, cfg, *, pos):
    """Scan one-token decode; caches are stacked pytrees (leading n_super)."""

    def body(xc, xs):
        layer_p, cache = xs
        new_cache = {}
        for i, (m, f) in enumerate(cfg.block_defs):
            xc, nc = apply_subblock_decode(layer_p[f"b{i}"], xc,
                                           cache[f"b{i}"], cfg, m, f, pos=pos)
            new_cache[f"b{i}"] = nc
        return xc, new_cache

    x, new_caches = jax.lax.scan(body, x, (stack_params, caches))
    return x, new_caches


def init_stack_state(cfg, batch, max_len, dtype, cross=False):
    one = {f"b{i}": init_subblock_state(cfg, i, batch, max_len, dtype,
                                        cross=cross)
           for i in range(len(cfg.block_defs))}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_super,) + a.shape).copy(), one)
