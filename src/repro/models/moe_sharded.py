"""Expert-parallel MoE dispatch with explicit shard_map all-to-alls.

Why this exists: the naive GSPMD path (moe.py) scatters tokens into a
(num_experts, capacity, d_model) buffer; SPMD cannot shard a scatter over
the indexed dim, so it replicates the buffer per device — measured 92 TB of
collectives/device/step on kimi-k2 train_4k (artifacts/dryrun). This module
makes the token movement explicit and minimal:

  layout: experts sharded over "data" (EP), expert FFN hidden dim over
  "model" (TP-in-expert), tokens sharded over ("pod","data"); expert weights
  replicated over "pod" (pod-local expert replicas -> dispatch stays on
  intra-pod ICI, never DCN — the elastic pod axis carries only the gradient
  all-reduce, exactly the property the IceCube adaptation needs).

  per layer: route locally -> bucket by destination data-shard ->
  all_to_all(data) -> local capacity-bounded dispatch -> grouped FFN
  (psum over model for the F contraction) -> all_to_all(data) back ->
  weighted combine at the source.

Collectives per token per layer = 2 x d_model x 2B x (there+back), the
information-theoretic minimum for token-choice EP without locality-aware
routing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding_ctx import current_mesh, shard_map


def _round8(n):
    return max(8, -(-int(n) // 8) * 8)


def _a2a_data(x):
    return jax.lax.all_to_all(x, "data", split_axis=0, concat_axis=0,
                              tiled=False)


@jax.custom_vjp
def _a2a_int8(x):
    """Dispatch all-to-all with an int8 wire format (per-slot scales).
    Forward quantizes the payload; backward quantizes the token-gradient
    all-to-all the same way (DeepSeek-V3 quantizes both dispatch
    directions; combine stays bf16). Halves dispatch bytes incl. the remat
    re-execution (§Perf cell 2)."""
    return _q_roundtrip(x)


def _q_roundtrip(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    q_t = _a2a_data(q)
    s_t = _a2a_data(scale.astype(jnp.float32))
    return (q_t.astype(jnp.float32) * s_t).astype(x.dtype)


def _a2a_int8_fwd(x):
    return _q_roundtrip(x), None


def _a2a_int8_bwd(_, g):
    return (_q_roundtrip(g),)    # a2a(split=concat=0) is self-transposed


_a2a_int8.defvjp(_a2a_int8_fwd, _a2a_int8_bwd)


def sharded_moe_available(mesh, moe, num_tokens):
    if mesh is None or "data" not in mesh.axis_names:
        return False
    nd = mesh.shape["data"]
    if moe.num_experts % nd or num_tokens % (nd * mesh.shape.get("pod", 1)):
        return False
    if "model" in mesh.axis_names and moe.d_ff_expert % mesh.shape["model"]:
        return False
    return True


def apply_moe_sharded(p, x, moe, ffn_type, mesh):
    """Routed-expert part only (shared experts handled by the caller).
    x: (B,S,D) batch-sharded over ("pod","data"). Returns (y, aux)."""
    B, S, D = x.shape
    E, K = moe.num_experts, moe.top_k
    nd = mesh.shape["data"]
    has_pod = "pod" in mesh.axis_names
    has_model = "model" in mesh.axis_names
    E_loc = E // nd
    batch_axes = ("pod", "data") if has_pod else ("data",)
    model_ax = "model" if has_model else None

    wi_spec = P("data", None, model_ax)
    wo_spec = P("data", model_ax, None)
    x_spec = P(batch_axes, None, None)

    def local_fn(xl, router, wi, wg, wo):
        # xl: (B_loc,S,D); wi/wg: (E_loc,D,F_loc); wo: (E_loc,F_loc,D)
        dt = xl.dtype
        B_loc = xl.shape[0]
        T = B_loc * S
        xt = xl.reshape(T, D)
        logits = (xt @ router.astype(dt)).astype(jnp.float32)     # (T,E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # ---- bucket slots by destination data-shard -----------------------
        eid = top_e.T.reshape(-1)                                  # (KT,)
        gate = top_p.T.reshape(-1)
        dest = eid // E_loc                                        # (KT,)
        le = eid % E_loc
        C_send = _round8(T * K / nd * moe.capacity_factor)
        oh_d = jax.nn.one_hot(dest, nd, dtype=jnp.int32)
        pos_d = jnp.take_along_axis(jnp.cumsum(oh_d, 0) - 1,
                                    dest[:, None], 1)[:, 0]
        keep = pos_d < C_send
        pd = jnp.where(keep, pos_d, 0)
        tok_idx = jnp.tile(jnp.arange(T), K)
        send_x = jnp.zeros((nd, C_send, D), dt).at[dest, pd].add(
            xt[tok_idx] * keep[:, None].astype(dt), mode="drop")
        send_le = jnp.full((nd, C_send), E_loc, jnp.int32).at[
            dest, pd].min(jnp.where(keep, le, E_loc), mode="drop")
        send_ok = jnp.zeros((nd, C_send), jnp.int32).at[dest, pd].max(
            keep.astype(jnp.int32), mode="drop")

        # ---- dispatch all-to-all over the data axis ------------------------
        a2a = _a2a_data
        recv_x = (_a2a_int8(send_x) if moe.dispatch_quant == "int8"
                  else a2a(send_x))                                # (nd,C,D)
        recv_le = a2a(send_le)
        recv_ok = a2a(send_ok)

        # ---- local capacity-bounded expert buffers -------------------------
        rx = recv_x.reshape(nd * C_send, D)
        rle = recv_le.reshape(-1)
        rok = recv_ok.reshape(-1).astype(bool) & (rle < E_loc)
        rle_s = jnp.where(rok, rle, 0)
        C_e = _round8(nd * C_send / E_loc * moe.local_capacity_factor)
        oh_e = jax.nn.one_hot(rle_s, E_loc, dtype=jnp.int32) * \
            rok[:, None].astype(jnp.int32)
        pos_e = jnp.take_along_axis(jnp.cumsum(oh_e, 0) - 1,
                                    rle_s[:, None], 1)[:, 0]
        keep_e = rok & (pos_e < C_e)
        pe = jnp.where(keep_e, pos_e, 0)
        buf = jnp.zeros((E_loc, C_e, D), dt).at[rle_s, pe].add(
            rx * keep_e[:, None].astype(dt), mode="drop")

        # ---- grouped expert FFN (F sharded over model) ---------------------
        if ffn_type == "swiglu":
            h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt)))
                 * jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt)))
        elif ffn_type == "squared_relu":
            h = jnp.square(jax.nn.relu(
                jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt))))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt)))
        y_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))
        if has_model:
            y_buf = jax.lax.psum(y_buf, "model")

        # ---- return trip ----------------------------------------------------
        ret = (y_buf[rle_s, pe] * keep_e[:, None].astype(dt)
               ).reshape(nd, C_send, D)
        back = a2a(ret)                                            # (nd,C,D)
        y_slot = back[dest, pd] * (keep & (send_ok[dest, pd] > 0)
                                   )[:, None].astype(dt)
        yt = (y_slot * gate[:, None].astype(dt)).reshape(K, T, D).sum(0)

        # ---- aux load-balancing loss (global means via psum) ----------------
        frac_tok = jnp.mean(jax.nn.one_hot(top_e[:, 0], E,
                                           dtype=jnp.float32), axis=0)
        frac_prob = jnp.mean(probs, axis=0)
        frac_tok = jax.lax.pmean(frac_tok, "data")
        frac_prob = jax.lax.pmean(frac_prob, "data")
        if has_pod:
            frac_tok = jax.lax.pmean(frac_tok, "pod")
            frac_prob = jax.lax.pmean(frac_prob, "pod")
        aux = E * jnp.sum(frac_tok * frac_prob) * moe.aux_loss_weight
        return yt.reshape(B_loc, S, D), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), wi_spec, wi_spec, wo_spec),
        out_specs=(x_spec, P()),
        check_replication=False)
    wg = p.get("wg", p["wi"])
    y, aux = fn(x, p["router"], p["wi"], wg, p["wo"])
    return y, aux
