"""Token-choice top-k MoE with capacity-bounded scatter dispatch.

Static-shape (dry-run friendly) dispatch: tokens are scattered into a
(num_experts, capacity, d) buffer (XLA scatter, drop mode), expert FFNs run
as a grouped matmul over the expert dim, and outputs gather back weighted by
the renormalized router probabilities. Experts shard over the "model" mesh
axis (EP); per-expert hidden dim shards over "data" (TP-in-expert) — see
sharding.py. The Pallas `moe_gmm` kernel is the optimized expert-FFN path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding_ctx import constrain


def init_moe(key, d_model, moe, ffn_type="swiglu"):
    ks = jax.random.split(key, 8)
    E, F = moe.num_experts, moe.d_ff_expert
    p = {"router": dense_init(ks[0], (d_model, E))}
    p["wi"] = dense_init(ks[1], (E, d_model, F))
    p["wo"] = dense_init(ks[2], (E, F, d_model), in_axis_size=F)
    if ffn_type == "swiglu":
        p["wg"] = dense_init(ks[3], (E, d_model, F))
    if moe.num_shared_experts:
        Fs = moe.d_ff_shared * moe.num_shared_experts
        p["shared_wi"] = dense_init(ks[4], (d_model, Fs))
        p["shared_wo"] = dense_init(ks[5], (Fs, d_model), in_axis_size=Fs)
        if ffn_type == "swiglu":
            p["shared_wg"] = dense_init(ks[6], (d_model, Fs))
    return p


def _expert_ffn(p, buf, ffn_type):
    """buf: (E, C, D) -> (E, C, D), grouped matmul over experts."""
    dt = buf.dtype
    if ffn_type == "swiglu":
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt)))
             * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt)))
    elif ffn_type == "squared_relu":
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt)))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))


def capacity(num_tokens, moe):
    c = int(num_tokens * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(8, -(-c // 8) * 8)        # >=8, rounded up to multiple of 8


def apply_moe(p, x, moe, ffn_type="swiglu"):
    """x: (B,S,D) -> (y, aux_loss). Token-choice top-k, capacity drop.

    Dispatch impl auto-selects: explicit shard_map EP all-to-all when a
    compatible mesh is active (see moe_sharded.py), else the naive
    GSPMD-scatter path below (single-device smoke tests, decode batches)."""
    from repro.models.moe_sharded import (apply_moe_sharded,
                                          sharded_moe_available)
    from repro.sharding_ctx import current_mesh
    mesh = current_mesh()
    if sharded_moe_available(mesh, moe, x.shape[0] * x.shape[1]):
        y, aux = apply_moe_sharded(p, x, moe, ffn_type, mesh)
        return y + _shared_expert(p, x, ffn_type), aux
    return _apply_moe_naive(p, x, moe, ffn_type)


def _shared_expert(p, x, ffn_type):
    if "shared_wi" not in p:
        return jnp.zeros_like(x)
    dt = x.dtype
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if ffn_type == "swiglu":
        h = (jax.nn.silu(xt @ p["shared_wg"].astype(dt))
             * (xt @ p["shared_wi"].astype(dt)))
    else:
        h = jax.nn.gelu(xt @ p["shared_wi"].astype(dt))
    return (h @ p["shared_wo"].astype(dt)).reshape(B, S, D)


def _apply_moe_naive(p, x, moe, ffn_type="swiglu"):
    B, S, D = x.shape
    dt = x.dtype
    T = B * S
    E, K = moe.num_experts, moe.top_k
    C = capacity(T, moe)

    xt = x.reshape(T, D)
    xt = constrain(xt, "tokens", None)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, slot-major order
    eid = top_e.T.reshape(-1)                                    # (K*T,)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)             # (KT,E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              eid[:, None], axis=1)[:, 0]        # (KT,)
    keep = pos < C

    # dispatch: scatter tokens into (E, C, D)
    x_rep = jnp.tile(xt, (K, 1))                                 # (KT,D) slot-major
    buf = jnp.zeros((E, C, D), dt)
    buf = buf.at[eid, jnp.where(keep, pos, 0)].add(
        x_rep * keep[:, None].astype(dt), mode="drop")
    buf = constrain(buf, "expert", None, None)

    out_buf = _expert_ffn(p, buf, ffn_type)                      # (E,C,D)

    # combine: gather back, weight by router prob
    gath = out_buf[eid, jnp.where(keep, pos, 0)]                 # (KT,D)
    w = (top_p.T.reshape(-1) * keep).astype(dt)                  # slot-major
    yt = (gath * w[:, None]).reshape(K, T, D).sum(0)
    y = yt.reshape(B, S, D) + _shared_expert(p, x, ffn_type)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * moe.aux_loss_weight
    return y, aux
