"""Mamba (S6) selective-state-space mixer — chunked reference path.

The (S, d_inner, d_state) discretized tensors are never materialized for the
full sequence: the sequence is processed in chunks with lax.scan carrying the
(B, d_inner, d_state) SSM state, and the intra-chunk recurrence uses an
associative scan. This bounds the working set exactly like the Pallas
``mamba_scan`` kernel bounds VMEM. Single-token decode is a pure elementwise
state update (the long_500k path).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_dims(d_model, mcfg):
    d_inner = mcfg.expand * d_model
    dt_rank = mcfg.dt_rank or -(-d_model // 16)
    return d_inner, dt_rank


def init_mamba(key, d_model, mcfg):
    d_inner, dt_rank = mamba_dims(d_model, mcfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, mcfg.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    dt = jnp.exp(jax.random.uniform(ks[4], (d_inner,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "w_in": dense_init(ks[0], (d_model, 2 * d_inner)),
        "conv_w": dense_init(ks[1], (mcfg.d_conv, d_inner), in_axis_size=mcfg.d_conv),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "w_x": dense_init(ks[2], (d_inner, dt_rank + 2 * mcfg.d_state)),
        "w_dt": dense_init(ks[3], (dt_rank, d_inner)),
        "dt_bias": jnp.log(jnp.expm1(dt)),     # softplus^-1(dt)
        "A_log": jnp.log(a),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[5], (d_inner, d_model), in_axis_size=d_inner),
    }


def _causal_conv(x, w, b, carry=None):
    """x: (B,S,di); w: (k,di) depthwise causal conv.
    carry: (B,k-1,di) previous inputs (decode) or None (zero history)."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else carry
    return y + b, new_carry


def _ssm_params(p, x_conv, mcfg, dt_rank):
    """Discretize: returns (A_bar, Bx, C) for a chunk. x_conv: (B,c,di)."""
    dt_f = x_conv.dtype
    xdb = x_conv @ p["w_x"].astype(dt_f)                     # (B,c,R+2N)
    dt_raw, Bm, Cm = jnp.split(xdb, [dt_rank, dt_rank + mcfg.d_state], -1)
    dt = jax.nn.softplus(
        (dt_raw @ p["w_dt"].astype(dt_f)).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                 # (di,N)
    A_bar = jnp.exp(dt[..., None] * A)                       # (B,c,di,N)
    Bx = (dt[..., None] * Bm[:, :, None, :].astype(jnp.float32)
          * x_conv[..., None].astype(jnp.float32))           # (B,c,di,N)
    return A_bar, Bx, Cm.astype(jnp.float32)


def _scan_chunk(h0, A_bar, Bx):
    """Intra-chunk associative scan. h0: (B,di,N). Returns (h_all, h_last)."""
    def combine(a, b):
        (a1, x1), (a2, x2) = a, b
        return a1 * a2, x1 * a2 + x2
    A_all, h_all = jax.lax.associative_scan(combine, (A_bar, Bx), axis=1)
    h_all = h_all + A_all * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_forward(p, x, mcfg, *, chunk=256, h0=None, conv0=None):
    """x: (B,S,D) -> (y, (h_last, conv_last)). Chunked over S."""
    B, S, D = x.shape
    dt = x.dtype
    d_inner, dt_rank = mamba_dims(D, mcfg)
    xz = x @ p["w_in"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_last = _causal_conv(x_in, p["conv_w"].astype(dt),
                                     p["conv_b"].astype(dt), conv0)
    x_conv = jax.nn.silu(x_conv)

    if h0 is None:
        h0 = jnp.zeros((B, d_inner, mcfg.d_state), jnp.float32)

    c = min(chunk, S)
    if S % c:
        c = S  # fallback: single chunk
    nc = S // c
    xc = x_conv.reshape(B, nc, c, d_inner).swapaxes(0, 1)    # (nc,B,c,di)

    def body(h, xi):
        A_bar, Bx, Cm = _ssm_params(p, xi, mcfg, dt_rank)
        h_all, h_last = _scan_chunk(h, A_bar, Bx)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cm)           # (B,c,di)
        return h_last, y.astype(dt)

    h_last, ys = jax.lax.scan(body, h0, xc)
    y = ys.swapaxes(0, 1).reshape(B, S, d_inner)
    y = y + x_conv * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt), (h_last, conv_last)


def init_mamba_state(batch, d_model, mcfg, dtype):
    d_inner, _ = mamba_dims(d_model, mcfg)
    return {"h": jnp.zeros((batch, d_inner, mcfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, mcfg.d_conv - 1, d_inner), dtype)}


def mamba_decode(p, x, state, mcfg):
    """One-token step. x: (B,1,D)."""
    B, _, D = x.shape
    dt = x.dtype
    d_inner, dt_rank = mamba_dims(D, mcfg)
    xz = x @ p["w_in"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_new = _causal_conv(x_in, p["conv_w"].astype(dt),
                                    p["conv_b"].astype(dt), state["conv"])
    x_conv = jax.nn.silu(x_conv)
    A_bar, Bx, Cm = _ssm_params(p, x_conv, mcfg, dt_rank)    # (B,1,di,N)
    h = state["h"] * A_bar[:, 0] + Bx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :].astype(dt)
    y = y + x_conv * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt), {"h": h, "conv": conv_new}
