"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Train/prefill use the naive (decompressed) form; decode uses the ABSORBED
form against the compressed latent cache (c_kv + k_rope) — the cache is
kv_lora + rope_dim floats per token instead of 2*H*head_dim, which is the
MLA decode-memory win the roofline table surfaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm
from repro.models.attention import _sdpa, chunked_attention
from repro.sharding_ctx import constrain


def init_mla(key, d_model, num_heads, mla):
    ks = jax.random.split(key, 8)
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], (d_model, mla.q_lora_rank)),
        "q_norm": init_norm(None, mla.q_lora_rank),
        "w_uq": dense_init(ks[1], (mla.q_lora_rank, num_heads, qk_head)),
        "w_dkv": dense_init(ks[2], (d_model, mla.kv_lora_rank)),
        "kv_norm": init_norm(None, mla.kv_lora_rank),
        "w_kr": dense_init(ks[3], (d_model, mla.qk_rope_head_dim)),
        "w_uk": dense_init(ks[4], (mla.kv_lora_rank, num_heads,
                                   mla.qk_nope_head_dim)),
        "w_uv": dense_init(ks[5], (mla.kv_lora_rank, num_heads,
                                   mla.v_head_dim)),
        "wo": dense_init(ks[6], (num_heads, mla.v_head_dim, d_model),
                         in_axis_size=num_heads * mla.v_head_dim),
    }


def _latents(p, x, positions, mla, rope_theta):
    """Compressed latents for the kv side: c_kv (B,S,r), k_rope (B,S,dr)."""
    dt = x.dtype
    c_kv = apply_norm(p["kv_norm"], x @ p["w_dkv"].astype(dt))
    # gather the ~100 MB latent here rather than let GSPMD defer the
    # partial sum into the ~1 GB/layer up-projected K (§Perf cell 1 iter 4)
    c_kv = constrain(c_kv, "batch", None, None)
    k_rope = (x @ p["w_kr"].astype(dt))[:, :, None, :]        # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _queries(p, x, positions, mla, rope_theta):
    dt = x.dtype
    c_q = apply_norm(p["q_norm"], x @ p["w_dq"].astype(dt))
    c_q = constrain(c_q, "batch", None, None)
    q = jnp.einsum("bsr,rhe->bshe", c_q, p["w_uq"].astype(dt))
    q_nope = q[..., :mla.qk_nope_head_dim]
    q_rope = apply_rope(q[..., mla.qk_nope_head_dim:], positions, rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, *, positions, mla, rope_theta, q_chunk=1024):
    """Full-sequence causal MLA (decompressed form). Returns (out, latents)
    so prefill can seed the compressed cache."""
    dt = x.dtype
    B, S, _ = x.shape
    q_nope, q_rope = _queries(p, x, positions, mla, rope_theta)
    c_kv, k_rope = _latents(p, x, positions, mla, rope_theta)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"].astype(dt))
    H = q_nope.shape[2]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, mla.qk_rope_head_dim))], axis=-1)
    out = chunked_attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            q_chunk=q_chunk)
    return (jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt)),
            (c_kv, k_rope))


def init_mla_cache(batch, max_len, mla, dtype):
    return {"c_kv": jnp.zeros((batch, max_len, mla.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, mla.qk_rope_head_dim), dtype)}


def mla_decode(p, x, cache, *, pos, mla, rope_theta):
    """Absorbed-form one-token decode against the compressed cache."""
    dt = x.dtype
    B = x.shape[0]
    q_nope, q_rope = _queries(p, x, pos[None], mla, rope_theta)  # (B,1,H,*)
    c_new, kr_new = _latents(p, x, pos[None], mla, rope_theta)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))

    # absorb W_uk into q: q_abs (B,1,H,r)
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"].astype(dt))
    scale = (mla.qk_nope_head_dim + mla.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, c_kv.astype(dt)) +
              jnp.einsum("bshe,bte->bhst", q_rope, k_rope.astype(dt)))
    scores = scores.astype(jnp.float32) * scale
    t_pos = jnp.arange(c_kv.shape[1])
    scores = jnp.where((t_pos <= pos)[None, None, None, :], scores,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(dt))   # (B,1,H,r)
    out = jnp.einsum("bshr,rhe->bshe", ctx, p["w_uv"].astype(dt))
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    return y, {"c_kv": c_kv, "k_rope": k_rope}
