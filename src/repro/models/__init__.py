from repro.models.model import (decode_step, forward_loss, init_cache,  # noqa: F401
                                init_params, param_count, prefill)
