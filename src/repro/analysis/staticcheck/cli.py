"""CLI for the engine-contract static analyzer.

::

    PYTHONPATH=src python -m repro.analysis.staticcheck
    PYTHONPATH=src python -m repro.analysis.staticcheck --json out.json
    PYTHONPATH=src python -m repro.analysis.staticcheck \\
        --baseline .staticcheck-baseline.json
    PYTHONPATH=src python -m repro.analysis.staticcheck --list-rules
    PYTHONPATH=src python -m repro.analysis.staticcheck \\
        --rules RNG001,RNG002

Exit codes mirror ``campaigns diff``: **0** clean, **1** at least one
finding, **2** bad arguments / unreadable baseline.  ``--json`` writes
the machine-readable findings payload (``-`` for stdout; the human
summary moves to stderr) — the same finding shape ``campaigns lint
--json`` emits, so CI asserts on one schema.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.staticcheck import RULES, analyze, find_repo_root
from repro.analysis.staticcheck.baseline import (BaselineError,
                                                 apply_baseline,
                                                 load_baseline,
                                                 write_baseline)
from repro.analysis.staticcheck.findings import Finding

#: default committed baseline location (repo-root-relative); absent
#: file simply means "no baseline"
DEFAULT_BASELINE = ".staticcheck-baseline.json"


def payload(findings: List[Finding], checked_root: str) -> dict:
    counts = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "schema_version": 1,
        "tool": "repro.analysis.staticcheck",
        "root": checked_root,
        "ok": not findings,
        "counts": dict(sorted(counts.items())),
        "findings": [f.to_dict() for f in findings],
    }


def add_arguments(ap: argparse.ArgumentParser) -> None:
    """Install the analyzer's options on ``ap`` — shared between the
    standalone ``python -m repro.analysis.staticcheck`` entry point and
    the ``campaigns check`` subcommand (one flag surface, two spellings).
    """
    ap.add_argument("--root", default=None,
                    help="repository root (default: auto-located)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the findings JSON here ('-' for stdout)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="baseline file of accepted findings (default: "
                         f"{DEFAULT_BASELINE} at the root, if present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file (report everything)")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write the current findings as a baseline and "
                         "exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="AST-level drift detection for the four-engine "
                    "contracts (registry completeness, RNG discipline, "
                    "trace parity, kernel/oracle pairing).")
    add_arguments(ap)
    return run(ap.parse_args(argv))


def run(args: argparse.Namespace) -> int:
    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    try:
        root = args.root or str(find_repo_root())
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    rules = None
    if args.rules:
        rules = frozenset(r.strip() for r in args.rules.split(",")
                          if r.strip())
        unknown = sorted(rules - set(RULES))
        if unknown:
            print(f"error: unknown rule id(s) {unknown}; see "
                  "--list-rules", file=sys.stderr)
            return 2

    findings = analyze(root, rules=rules)

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"# wrote {args.write_baseline} "
              f"({len(findings)} suppression(s))", file=sys.stderr)
        return 0

    unused: List[dict] = []
    if not args.no_baseline:
        from pathlib import Path
        bl_path = args.baseline or str(Path(root) / DEFAULT_BASELINE)
        bl_exists = Path(bl_path).is_file()
        if args.baseline and not bl_exists:
            print(f"error: baseline {bl_path} not found",
                  file=sys.stderr)
            return 2
        if bl_exists:
            try:
                sups = load_baseline(bl_path)
            except BaselineError as e:
                print(f"error: {e}", file=sys.stderr)
                return 2
            findings, unused = apply_baseline(findings, sups)

    pay = payload(findings, root)
    if unused:
        pay["unused_suppressions"] = unused
    text = json.dumps(pay, indent=2, sort_keys=True) + "\n"
    if args.json == "-":
        sys.stdout.write(text)
    elif args.json:
        with open(args.json, "w") as f:
            f.write(text)
        print(f"# wrote {args.json}", file=sys.stderr)

    out = sys.stderr if args.json == "-" else sys.stdout
    for f in findings:
        print(f.render(), file=out)
    for s in unused:
        print(f"note: unused baseline suppression {s['rule']} "
              f"{s['file']} — remove it", file=out)
    n = len(findings)
    checked = ", ".join(sorted({f.rule[:3] for f in findings})) \
        if findings else "REG, RNG, TRC, KRN"
    if n:
        print(f"staticcheck: {n} finding(s) [{checked}]", file=out)
        return 1
    print(f"staticcheck: OK ({len(RULES)} rules, families {checked})",
          file=out)
    return 0


if __name__ == "__main__":                       # pragma: no cover
    raise SystemExit(main())
