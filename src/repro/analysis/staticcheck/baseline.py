"""Baseline file support: grandfather intentional exceptions.

A baseline is a committed JSON file listing finding fingerprints
(``rule:file:message`` — no line numbers, so entries survive unrelated
edits) that the analyzer should not fail on.  Prefer inline
``# staticcheck: ignore[RULE]`` comments for single-line suppressions —
the intent lives next to the code; the baseline is for findings with no
single line to annotate (file-level parity findings) or for adopting
the analyzer on a tree with known, accepted debt.

Format::

    {
      "schema_version": 1,
      "suppressions": [
        {"rule": "TRC001", "file": "src/...", "match": "<message>",
         "reason": "why this is intentional"},
        ...
      ]
    }

``match`` is compared against the finding message exactly, or as a
prefix when it ends with ``*``.
"""
from __future__ import annotations

import json
from typing import List, Optional, Tuple

from repro.analysis.staticcheck.findings import Finding

SCHEMA_VERSION = 1


class BaselineError(ValueError):
    pass


def load_baseline(path: str) -> List[dict]:
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise BaselineError(f"cannot read baseline {path}: {e}")
    except json.JSONDecodeError as e:
        raise BaselineError(f"baseline {path} is not valid JSON: {e}")
    if not isinstance(data, dict) or "suppressions" not in data:
        raise BaselineError(
            f"baseline {path} must be an object with a 'suppressions' "
            "list")
    sups = data["suppressions"]
    for s in sups:
        if not isinstance(s, dict) or not {"rule", "file"} <= set(s):
            raise BaselineError(
                f"baseline {path}: each suppression needs at least "
                "'rule' and 'file' keys")
    return sups


def _matches(sup: dict, finding: Finding) -> bool:
    if sup["rule"] != finding.rule or sup["file"] != finding.file:
        return False
    match = sup.get("match")
    if match is None:
        return True
    if match.endswith("*"):
        return finding.message.startswith(match[:-1])
    return finding.message == match


def apply_baseline(findings: List[Finding], suppressions: List[dict]
                   ) -> Tuple[List[Finding], List[dict]]:
    """(kept findings, unused suppressions).  Unused entries are
    surfaced so stale baselines shrink instead of rotting."""
    used = [False] * len(suppressions)
    kept: List[Finding] = []
    for f in findings:
        hit = False
        for i, sup in enumerate(suppressions):
            if _matches(sup, f):
                used[i] = True
                hit = True
        if not hit:
            kept.append(f)
    unused = [s for s, u in zip(suppressions, used) if not u]
    return kept, unused


def write_baseline(path: str, findings: List[Finding],
                   reason: Optional[str] = None) -> None:
    payload = {
        "schema_version": SCHEMA_VERSION,
        "suppressions": [
            {"rule": f.rule, "file": f.file, "match": f.message,
             **({"reason": reason} if reason else {})}
            for f in findings],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
