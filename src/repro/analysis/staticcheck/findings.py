"""The finding record and rule catalog shared by every staticcheck rule.

A :class:`Finding` is one contract violation at one location; its
``rule`` id is stable (baselines and inline suppressions key on it) and
shares the ``ABC123`` shape with the ``SPEC``-prefixed ids that
``spec.lint_spec`` findings carry, so ``campaigns lint --json`` and
``campaigns check --json`` payloads have one schema.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List


@dataclass(frozen=True, order=True)
class Finding:
    """One static-analysis finding, sortable into the canonical
    (file, line, rule) report order."""
    file: str                      # repo-relative posix path
    line: int                      # 1-based; 0 when file-level
    rule: str                      # stable id, e.g. "REG002"
    message: str
    hint: str = field(default="", compare=False)

    def to_dict(self) -> dict:
        return asdict(self)

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file (so a
        baselined finding survives unrelated edits above it)."""
        return f"{self.rule}:{self.file}:{self.message}"

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        out = f"{loc}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


#: the rule catalog: id -> one-line description (the README's table and
#: ``--list-rules`` both render this)
RULES: Dict[str, str] = {
    # (a) registry completeness — the four-engine EngineOps contract
    "REG001": "registered event compiles to an op with no registered "
              "handler",
    "REG002": "op requires an EngineOps member missing on an engine "
              "adapter (event not implemented for all engines)",
    "REG003": "op requires a provisioner-facade member missing on a "
              "solo provisioner",
    "REG004": "ENGINE_ADAPTERS / PROVISIONER_FACADES metadata names an "
              "unresolvable module or class",
    # (b) RNG / determinism discipline inside core/
    "RNG001": "global numpy RNG call (np.random.*) in a core engine "
              "module — breaks bit-identical lanes",
    "RNG002": "stdlib random-module call in a core engine module",
    "RNG003": "wall-clock call (time.time/monotonic/perf_counter, "
              "datetime.now) in a core engine module",
    "RNG004": "iteration over an unordered set in a core engine module "
              "— iteration order is not deterministic",
    # (c) trace choke-point parity across the trace-capable engines
    "TRC001": "TraceRecorder method invoked by some but not all "
              "trace-capable engines",
    "TRC002": "call to a method that does not exist on "
              "events.TraceRecorder",
    "TRC003": "api.TRACE_ENGINES and the analyzer's engine-module map "
              "disagree",
    # (d) kernel / oracle pairing
    "KRN001": "Pallas kernel has no matching oracle in kernels/ref.py",
    "KRN002": "Pallas kernel is not exercised by tests/test_kernels.py",
}


def sort_findings(findings: List[Finding]) -> List[Finding]:
    return sorted(findings)
