"""Rule family KRN — kernel / oracle pairing.

Every Pallas kernel in ``kernels/`` exists twice by contract: the
kernel itself and a pure-``jnp`` oracle in ``kernels/ref.py`` that
``tests/test_kernels.py`` sweeps it against (interpret mode on CPU).
A kernel that lands without its oracle or its test exercise is
unverifiable on every platform that can't run the compiled path — the
exact drift the differential harness exists to prevent.

Statically enforced:

  * KRN001 — every public ``*_kernel`` function in a kernel module has
    a ``*_ref`` oracle in ``ref.py`` whose name matches at an
    underscore boundary (``mlstm_chunk_kernel`` pairs with
    ``mlstm_ref``; ``campaign_bill_kernel`` with
    ``campaign_bill_ref``).
  * KRN002 — the kernel (or an ``ops.py`` wrapper that calls it) is
    referenced by name in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.staticcheck.findings import Finding
from repro.analysis.staticcheck.tree import SourceTree

KERNEL_GLOB = "src/repro/kernels/*.py"
REF = "src/repro/kernels/ref.py"
OPS = "src/repro/kernels/ops.py"
KERNEL_TESTS = "tests/test_kernels.py"
NON_KERNEL_FILES = {"src/repro/kernels/__init__.py", REF, OPS}


def _public_functions(tree: SourceTree, rel: str,
                      suffix: str) -> Dict[str, int]:
    """Top-level public ``*suffix`` functions -> def lineno."""
    mod = tree.parse(rel)
    if mod is None:
        return {}
    return {n.name: n.lineno for n in mod.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")
            and n.name.endswith(suffix)}


def _names_referenced(tree: SourceTree, rel: str) -> Set[str]:
    """Every Name id and Attribute attr in a module (how tests refer to
    ``ops.flash_attention`` / ``ref.mlstm_ref``)."""
    mod = tree.parse(rel)
    if mod is None:
        return set()
    out: Set[str] = set()
    for node in ast.walk(mod):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def _base_match(kernel_base: str, ref_base: str) -> bool:
    """Name pairing at an underscore boundary, either direction."""
    return (kernel_base == ref_base
            or kernel_base.startswith(ref_base + "_")
            or ref_base.startswith(kernel_base + "_"))


def _ops_wrappers(tree: SourceTree,
                  kernel_names: Set[str]) -> Dict[str, List[str]]:
    """kernel name -> ops.py wrapper function names that call it."""
    out: Dict[str, List[str]] = {}
    mod = tree.parse(OPS)
    if mod is None:
        return out
    for fn in mod.body:
        if not isinstance(fn, ast.FunctionDef) \
                or fn.name.startswith("_"):
            continue
        for node in ast.walk(fn):
            name = None
            if isinstance(node, ast.Name):
                name = node.id
            elif isinstance(node, ast.Attribute):
                name = node.attr
            if name in kernel_names:
                out.setdefault(name, []).append(fn.name)
    return out


def check_kernels(tree: SourceTree) -> List[Finding]:
    findings: List[Finding] = []
    kernels: List[Tuple[str, str, int]] = []   # (name, file, line)
    for rel in tree.glob(KERNEL_GLOB):
        if rel in NON_KERNEL_FILES:
            continue
        for name, line in sorted(_public_functions(tree, rel,
                                                   "_kernel").items()):
            kernels.append((name, rel, line))
    if not kernels:
        return findings

    refs = _public_functions(tree, REF, "_ref")
    ref_bases = {r[: -len("_ref")] for r in refs}
    test_names = _names_referenced(tree, KERNEL_TESTS)
    wrappers = _ops_wrappers(tree, {k for k, _f, _l in kernels})

    for name, rel, line in kernels:
        base = name[: -len("_kernel")]
        if not any(_base_match(base, rb) for rb in sorted(ref_bases)):
            findings.append(Finding(
                rel, line, "KRN001",
                f"kernel `{name}` has no `{base}_ref` oracle in "
                "kernels/ref.py",
                hint="add a pure-jnp reference implementation; the "
                     "kernel is unverifiable without one"))
        exercised = name in test_names or any(
            w in test_names for w in wrappers.get(name, []))
        if not exercised:
            via = wrappers.get(name)
            hint = ("reference it (or its ops.py wrapper "
                    f"{', '.join(sorted(set(via)))}) in a "
                    "tests/test_kernels.py sweep vs the oracle"
                    if via else
                    "add an ops.py wrapper and a tests/test_kernels.py "
                    "sweep vs the oracle")
            findings.append(Finding(
                rel, line, "KRN002",
                f"kernel `{name}` is never exercised by "
                "tests/test_kernels.py", hint=hint))
    return findings
