"""Rule family REG — registry completeness (the four-engine contract).

The runtime drift guard (``timeline.registry_findings``, surfaced as
``campaigns lint --registry``) hasattr-checks the live adapter classes;
this is its static twin: the same checks on the *syntax* of
``core/timeline.py`` and the adapter modules, without importing or
executing any engine code.  An event registered without a
``JaxLaneOps`` method body is caught here even if ``sweep_jax`` no
longer imports (the exact situation the runtime check cannot see).

What is read, all statically:

  * every ``register_op(OpSpec(kind=..., requires=(...),
    prov_requires=(...)))`` call — the EngineOps/provisioner members an
    op depends on;
  * every ``register_event(EventType(kind=X.kind, ops=(...)))`` call —
    which ops each event compiles to (``X.kind`` resolved from the
    event dataclass's ``kind = "..."`` class attribute);
  * the ``ENGINE_ADAPTERS`` / ``PROVISIONER_FACADES`` literal metadata
    in ``core/timeline.py`` — the single source of truth for *which*
    classes implement the contract (``campaigns lint --registry``
    resolves the same dicts at runtime).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.staticcheck.findings import Finding
from repro.analysis.staticcheck.tree import (SourceTree, call_kwargs,
                                             class_members, find_class,
                                             literal_str_tuple, module_path,
                                             module_str_dicts)

TIMELINE = "src/repro/core/timeline.py"


def _registration_calls(mod: ast.Module, fn_name: str):
    """Top-level ``fn_name(Ctor(...))`` calls -> the inner ctor call."""
    for node in ast.walk(mod):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == fn_name and node.args
                and isinstance(node.args[0], ast.Call)):
            yield node.args[0]


def _class_kind_consts(mod: ast.Module) -> Dict[str, str]:
    """``ClassName -> kind`` for every class with ``kind = "..."``."""
    out: Dict[str, str] = {}
    for node in mod.body:
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                if (isinstance(sub, ast.Assign)
                        and any(isinstance(t, ast.Name) and t.id == "kind"
                                for t in sub.targets)
                        and isinstance(sub.value, ast.Constant)
                        and isinstance(sub.value.value, str)):
                    out[node.name] = sub.value.value
    return out


def parse_registry(tree: SourceTree):
    """(ops, events, adapters, facades, findings): the registry as data.

    ``ops``: op kind -> (requires, prov_requires, line);
    ``events``: event kind -> (op kinds, line);
    ``adapters``/``facades``: name -> "module:Class" from the metadata
    dicts in core/timeline.py.
    """
    findings: List[Finding] = []
    mod = tree.parse(TIMELINE)
    if mod is None:
        findings.append(Finding(TIMELINE, 0, "REG004",
                                "cannot parse core/timeline.py"))
        return {}, {}, {}, {}, findings

    kinds = _class_kind_consts(mod)
    ops: Dict[str, Tuple[Tuple[str, ...], Tuple[str, ...], int]] = {}
    for call in _registration_calls(mod, "register_op"):
        kw = call_kwargs(call)
        kind_node = kw.get("kind")
        if not (isinstance(kind_node, ast.Constant)
                and isinstance(kind_node.value, str)):
            continue
        requires = literal_str_tuple(kw.get("requires", ast.Tuple([], None))) \
            or ()
        prov = literal_str_tuple(kw.get("prov_requires",
                                        ast.Tuple([], None))) or ()
        ops[kind_node.value] = (requires, prov, call.lineno)

    events: Dict[str, Tuple[Tuple[str, ...], int]] = {}
    for call in _registration_calls(mod, "register_event"):
        kw = call_kwargs(call)
        kind_node = kw.get("kind")
        kind: Optional[str] = None
        if isinstance(kind_node, ast.Constant) \
                and isinstance(kind_node.value, str):
            kind = kind_node.value
        elif (isinstance(kind_node, ast.Attribute)
              and kind_node.attr == "kind"
              and isinstance(kind_node.value, ast.Name)):
            kind = kinds.get(kind_node.value.id)
        if kind is None:
            continue
        op_names = literal_str_tuple(kw.get("ops", ast.Tuple([], None))) \
            or ()
        events[kind] = (op_names, call.lineno)

    dicts = module_str_dicts(mod)
    adapters = dicts.get("ENGINE_ADAPTERS", {})
    facades = dicts.get("PROVISIONER_FACADES", {})
    if not adapters:
        findings.append(Finding(
            TIMELINE, 0, "REG004",
            "core/timeline.py has no literal ENGINE_ADAPTERS metadata "
            "dict (the analyzer and `campaigns lint --registry` both "
            "read it)",
            hint='declare ENGINE_ADAPTERS = {"solo": '
                 '"repro.core.spec:TimelineController", ...}'))
    return ops, events, adapters, facades, findings


def _resolve_members(tree: SourceTree, ref: str, role: str,
                     findings: List[Finding]):
    """``"repro.core.spec:TimelineController"`` -> (rel_path, line,
    member set) or None (REG004 queued)."""
    module, _, cls_name = ref.partition(":")
    rel = module_path(module)
    mod = tree.parse(rel)
    if mod is None:
        findings.append(Finding(
            TIMELINE, 0, "REG004",
            f"{role} {ref!r}: module {module!r} has no parseable "
            f"source at {rel}"))
        return None
    cls = find_class(mod, cls_name)
    if cls is None:
        findings.append(Finding(
            rel, 0, "REG004",
            f"{role} {ref!r}: class {cls_name!r} not found in {rel}"))
        return None
    return rel, cls.lineno, class_members(cls)


def check_registry(tree: SourceTree) -> List[Finding]:
    ops, events, adapters, facades, findings = parse_registry(tree)

    # which events need each op (for actionable messages)
    op_events: Dict[str, List[str]] = {}
    for kind, (op_names, line) in sorted(events.items()):
        for op in op_names:
            if op not in ops:
                findings.append(Finding(
                    TIMELINE, line, "REG001",
                    f"event {kind!r} compiles to op {op!r} which has no "
                    "register_op entry",
                    hint="add a register_op(OpSpec(kind=...)) block in "
                         "core/timeline.py"))
            else:
                op_events.setdefault(op, []).append(kind)

    resolved = {}
    for engine, ref in sorted(adapters.items()):
        resolved[engine] = _resolve_members(tree, ref, "engine adapter",
                                            findings)
    prov_resolved = {}
    for name, ref in sorted(facades.items()):
        prov_resolved[name] = _resolve_members(tree, ref,
                                               "provisioner facade",
                                               findings)

    for op, (requires, prov_requires, _line) in sorted(ops.items()):
        evs = sorted(op_events.get(op, []))
        for engine, res in sorted(resolved.items()):
            if res is None:
                continue
            rel, cls_line, members = res
            missing = sorted(m for m in requires if m not in members)
            if missing:
                findings.append(Finding(
                    rel, cls_line, "REG002",
                    f"the {engine!r} adapter lacks EngineOps member(s) "
                    f"{missing} required by op {op!r} (event(s): "
                    f"{', '.join(evs) or op})",
                    hint="add the method/attribute so every engine "
                         "interprets the event; see EngineOps in "
                         "core/timeline.py"))
        for name, res in sorted(prov_resolved.items()):
            if res is None:
                continue
            rel, cls_line, members = res
            missing = sorted(m for m in prov_requires
                             if m not in members)
            if missing:
                findings.append(Finding(
                    rel, cls_line, "REG003",
                    f"the {name!r} provisioner facade lacks member(s) "
                    f"{missing} required by op {op!r} (event(s): "
                    f"{', '.join(evs) or op})",
                    hint="solo engines drive this op through "
                         "sim.prov — both facades need the body"))
    return findings
