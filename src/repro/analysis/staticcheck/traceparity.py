"""Rule family TRC — trace choke-point parity across engines.

The trace contract (ROADMAP, PR 5): every engine that claims
``collect="trace"`` support (``api.TRACE_ENGINES``) must invoke the
same set of RNG-free :class:`~repro.core.events.TraceRecorder` methods
at its choke points, because the sha256-pinned byte-identity of
serialized traces only needs *set* identity per tick — but it needs
every engine to emit every kind.  The classic failure mode is a new
event kind instrumented in two of the three engines: nothing crashes,
the property tests may not cover the surface, and the first symptom is
a failed sha256 pin at golden-regeneration time.

This family compares, statically, the recorder methods each engine's
modules call:

  * the engine -> module map below mirrors the instrumentation notes in
    ROADMAP.md (object: provisioner/overlay/simulator; array: fleet;
    batched: sweep), with ``SHARED_MODULES`` (spec.py's
    TimelineController, dataplane.py's bill hook) counted toward every
    engine because all engines route through them;
  * ``api.TRACE_ENGINES`` is evaluated from ``core/api.py``'s literal
    set algebra (no import), and checked against the map — adding a
    trace-capable engine without teaching this rule where its
    instrumentation lives is itself a finding (TRC003).

A *recorder call* is any ``X.method(...)`` whose receiver chain ends in
an attribute/name called ``recorder`` or ``recorders`` — the repo-wide
naming convention for ``events.TraceRecorder`` handles.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.staticcheck.findings import Finding
from repro.analysis.staticcheck.tree import (SourceTree, eval_engine_sets,
                                             find_class)

API = "src/repro/core/api.py"
EVENTS = "src/repro/core/events.py"
TRACEOPS = "src/repro/core/traceops.py"

#: engine name (canonical) -> modules holding its recorder choke points
ENGINE_MODULES: Dict[str, tuple] = {
    "object": ("src/repro/core/provisioner.py",
               "src/repro/core/overlay.py",
               "src/repro/core/simulator.py"),
    "array": ("src/repro/core/fleet.py",),
    "batched": ("src/repro/core/sweep.py",),
}

#: modules every engine routes through (timeline provenance mirroring in
#: spec.TimelineController; egress billing in dataplane.bill)
SHARED_MODULES = ("src/repro/core/spec.py",
                  "src/repro/core/dataplane.py")

#: api engine names that are aliases of a canonical engine above
ENGINE_ALIASES = {"sequential": "array", "auto": None}


def _class_public_methods(tree: SourceTree, rel: str,
                          cls_name: str) -> Set[str]:
    mod = tree.parse(rel)
    if mod is None:
        return set()
    cls = find_class(mod, cls_name)
    if cls is None:
        return set()
    return {n.name for n in cls.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")}


def recorder_methods(tree: SourceTree) -> Set[str]:
    """Public method names of events.TraceRecorder — the trace-event
    emission surface that must stay engine-parallel (TRC001)."""
    return _class_public_methods(tree, EVENTS, "TraceRecorder")


def lifecycle_methods(tree: SourceTree) -> Set[str]:
    """Extra public methods of traceops.StreamingRecorder (``finish``
    and friends): legal to call on a recorder handle (no TRC002) but
    lifecycle plumbing, not event emission — exempt from parity."""
    return _class_public_methods(tree, TRACEOPS, "StreamingRecorder")


def _recorder_rooted(node: ast.AST) -> bool:
    """Does this receiver expression end in ``recorder``/``recorders``
    (possibly through subscripts: ``self.recorders[b]``)?"""
    if isinstance(node, ast.Name):
        return node.id in ("recorder", "recorders")
    if isinstance(node, ast.Attribute):
        return node.attr in ("recorder", "recorders")
    if isinstance(node, ast.Subscript):
        return _recorder_rooted(node.value)
    return False


def recorder_calls(tree: SourceTree, rel: str) -> Dict[str, List[int]]:
    """``method -> [linenos]`` of recorder-rooted calls in a module."""
    out: Dict[str, List[int]] = {}
    mod = tree.parse(rel)
    if mod is None:
        return out
    for node in ast.walk(mod):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and _recorder_rooted(node.func.value):
            out.setdefault(node.func.attr, []).append(node.lineno)
    return out


def check_trace_parity(tree: SourceTree) -> List[Finding]:
    findings: List[Finding] = []
    legal = recorder_methods(tree)
    lifecycle = lifecycle_methods(tree) - legal
    if not legal:
        findings.append(Finding(
            EVENTS, 0, "TRC003",
            "cannot find the events.TraceRecorder class — the trace "
            "parity rule has no method surface to check against"))
        return findings

    # -- TRACE_ENGINES vs the module map (TRC003) -------------------------
    api_mod = tree.parse(API)
    declared: Set[str] = set()
    if api_mod is None:
        findings.append(Finding(
            API, 0, "TRC003", "cannot parse core/api.py to evaluate "
            "TRACE_ENGINES"))
    else:
        sets = eval_engine_sets(api_mod)
        trace_engines = sets.get("TRACE_ENGINES")
        if trace_engines is None:
            findings.append(Finding(
                API, 0, "TRC003",
                "TRACE_ENGINES is not statically evaluable from "
                "core/api.py's literal set algebra",
                hint="keep SOLO_ENGINES/SWEEP_ENGINES/TRACE_ENGINES as "
                     "literal frozenset expressions"))
        else:
            for eng in sorted(trace_engines):
                canon = ENGINE_ALIASES.get(eng, eng)
                if canon is None:
                    continue
                declared.add(canon)
                if canon not in ENGINE_MODULES:
                    findings.append(Finding(
                        API, 0, "TRC003",
                        f"api.TRACE_ENGINES claims trace support for "
                        f"{eng!r} but the analyzer's ENGINE_MODULES map "
                        "has no instrumentation modules for it",
                        hint="teach repro.analysis.staticcheck."
                             "traceparity.ENGINE_MODULES where the new "
                             "engine's recorder choke points live"))
            for canon in sorted(ENGINE_MODULES):
                if canon not in declared:
                    findings.append(Finding(
                        API, 0, "TRC003",
                        f"ENGINE_MODULES lists engine {canon!r} but "
                        "api.TRACE_ENGINES does not claim trace support "
                        "for it"))

    # -- per-engine recorder method sets ----------------------------------
    shared: Dict[str, List[int]] = {}
    shared_where: Dict[str, str] = {}
    for rel in SHARED_MODULES:
        for meth, lines in recorder_calls(tree, rel).items():
            shared.setdefault(meth, []).extend(lines)
            shared_where.setdefault(meth, rel)

    engine_meths: Dict[str, Dict[str, str]] = {}   # engine -> meth -> file
    for engine, modules in sorted(ENGINE_MODULES.items()):
        meths: Dict[str, str] = {m: shared_where[m] for m in shared}
        for rel in modules:
            if not tree.exists(rel):
                findings.append(Finding(
                    rel, 0, "TRC003",
                    f"engine {engine!r} instrumentation module {rel} "
                    "does not exist"))
                continue
            for meth, lines in recorder_calls(tree, rel).items():
                meths.setdefault(meth, rel)
                # -- TRC002: calls outside the TraceRecorder surface ---
                if meth not in legal and meth not in lifecycle:
                    for ln in lines:
                        findings.append(Finding(
                            rel, ln, "TRC002",
                            f"recorder call `.{meth}(...)` has no "
                            "matching method on events.TraceRecorder",
                            hint="add the method (and its trace event "
                                 "kind) to core/events.py, or fix the "
                                 "typo"))
        engine_meths[engine] = meths
    for meth, lines in sorted(shared.items()):
        if meth not in legal and meth not in lifecycle:
            rel = shared_where[meth]
            for ln in lines:
                findings.append(Finding(
                    rel, ln, "TRC002",
                    f"recorder call `.{meth}(...)` has no matching "
                    "method on events.TraceRecorder",
                    hint="add the method (and its trace event kind) to "
                         "core/events.py, or fix the typo"))

    # -- TRC001: parity ----------------------------------------------------
    all_meths = sorted({m for d in engine_meths.values() for m in d}
                       & legal)
    for meth in all_meths:
        have = sorted(e for e, d in engine_meths.items() if meth in d)
        miss = sorted(e for e in engine_meths if meth not in
                      engine_meths[e])
        if miss:
            for engine in miss:
                anchor = ENGINE_MODULES[engine][0]
                findings.append(Finding(
                    anchor, 0, "TRC001",
                    f"TraceRecorder.{meth} is emitted by engine(s) "
                    f"{', '.join(have)} but never by the {engine!r} "
                    "engine — serialized traces will diverge on the "
                    "first such event",
                    hint=f"instrument the {engine!r} engine's choke "
                         "point (see the PR-5 trace note in ROADMAP.md) "
                         "or remove the kind everywhere"))
    return findings
