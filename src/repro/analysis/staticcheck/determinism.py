"""Rule family RNG — determinism discipline inside ``core/``.

Every ``core/`` module feeds at least one of the bit-identity
contracts: lane-vs-solo reproducibility (``tests/test_sweep.py``),
byte-identical traces (the seed-2021 sha256 pin) and the goldens.  One
stray global-RNG draw, wall-clock read or unordered-set iteration in a
tick path silently breaks all three — at golden-regeneration time, not
review time.  This family flags the syntactic forms that can do that:

  * RNG001 — ``np.random.*`` global-state calls (``seed``, ``rand``,
    ``shuffle``, ...).  Explicitly-seeded constructors
    (``default_rng``, ``PCG64``, ``SeedSequence``, ...) are the
    sanctioned idiom and stay silent.
  * RNG002 — stdlib ``random`` module calls (module-global Mersenne
    state); ``random.Random(seed)`` instances are allowed.
  * RNG003 — wall-clock reads (``time.time``/``monotonic``/
    ``perf_counter``, ``datetime.now``...).  Engine time is ``sim.now``;
    real-runner wall timing must be suppressed with a comment so the
    intent is recorded.
  * RNG004 — direct iteration over a set literal / ``set(...)`` call
    (``for x in {...}``): Python set order is not deterministic across
    runs for str/object elements.  Sort first (``sorted(...)``).

Suppress intentional uses inline::

    t0 = time.time()   # staticcheck: ignore[RNG003] — real wall clock
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.staticcheck.findings import Finding
from repro.analysis.staticcheck.tree import SourceTree, dotted

#: modules under the determinism contract
CORE_GLOB = "src/repro/core/*.py"

#: np.random constructors that take an explicit seed — allowed
NP_RANDOM_SAFE = frozenset({
    "default_rng", "Generator", "PCG64", "PCG64DXSM", "MT19937",
    "Philox", "SFC64", "SeedSequence", "BitGenerator",
})

#: wall-clock callables by dotted suffix
WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.clock_gettime",
    "datetime.now", "datetime.utcnow", "datetime.today", "date.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
})


def _iter_over_set(node: ast.AST) -> bool:
    """Is this expression an unordered set flowing straight into
    iteration?  (Set literals, set comprehensions, ``set(...)`` /
    ``frozenset(...)`` calls and set-algebra on them.)"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) \
            and isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.Sub,
                                     ast.BitXor)):
        # {...} - other / set(a) | set(b): still a set
        return _iter_over_set(node.left) or _iter_over_set(node.right)
    return False


def _scan_module(tree: SourceTree, rel: str, mod: ast.Module
                 ) -> List[Finding]:
    out: List[Finding] = []
    has_import_random = any(
        isinstance(n, ast.Import) and any(a.name == "random"
                                          for a in n.names)
        for n in ast.walk(mod))

    for node in ast.walk(mod):
        if isinstance(node, ast.ImportFrom) and node.module == "random":
            names = sorted(a.name for a in node.names
                           if a.name != "Random")
            if names:
                out.append(Finding(
                    rel, node.lineno, "RNG002",
                    f"`from random import {', '.join(names)}` pulls "
                    "module-global RNG state into an engine module",
                    hint="use the per-lane np.random.default_rng(seed) "
                         "streams (or random.Random(seed))"))
            continue

        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name is None:
                continue
            parts = name.split(".")
            # -- RNG001: numpy global RNG ------------------------------
            if len(parts) >= 3 and parts[-2] == "random" \
                    and parts[0] in ("np", "numpy"):
                fn = parts[-1]
                if fn not in NP_RANDOM_SAFE:
                    out.append(Finding(
                        rel, node.lineno, "RNG001",
                        f"global numpy RNG call `{name}(...)` — shared "
                        "state breaks per-lane bit-reproducibility",
                        hint="draw from the engine's seeded "
                             "np.random.default_rng(seed) generator"))
                continue
            # -- RNG002: stdlib random module --------------------------
            if has_import_random and len(parts) == 2 \
                    and parts[0] == "random" and parts[1] != "Random":
                out.append(Finding(
                    rel, node.lineno, "RNG002",
                    f"stdlib `{name}(...)` uses the module-global "
                    "Mersenne state",
                    hint="use the engine's seeded generator (or a "
                         "random.Random(seed) instance)"))
                continue
            # -- RNG003: wall clock ------------------------------------
            if name in WALL_CLOCK or any(name.endswith("." + w)
                                         for w in WALL_CLOCK):
                out.append(Finding(
                    rel, node.lineno, "RNG003",
                    f"wall-clock call `{name}()` in a core module — "
                    "simulated time is `sim.now`",
                    hint="pass time in explicitly; suppress with "
                         "`# staticcheck: ignore[RNG003]` if this is "
                         "deliberate real-runner timing"))

        # -- RNG004: unordered-set iteration ---------------------------
        iters: List[ast.AST] = []
        if isinstance(node, ast.For):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(gen.iter for gen in node.generators)
        for it in iters:
            if _iter_over_set(it):
                out.append(Finding(
                    rel, it.lineno, "RNG004",
                    "iterating an unordered set — element order can "
                    "differ across processes (str hashes are salted)",
                    hint="wrap in sorted(...) to pin the order"))
    return out


def check_determinism(tree: SourceTree) -> List[Finding]:
    out: List[Finding] = []
    for rel in tree.glob(CORE_GLOB):
        mod = tree.parse(rel)
        if mod is not None:
            out.extend(_scan_module(tree, rel, mod))
    return out
