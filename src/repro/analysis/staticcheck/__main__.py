"""``python -m repro.analysis.staticcheck`` entry point."""
from repro.analysis.staticcheck.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
