"""Engine-contract static analyzer: AST-level drift detection for the
four-engine invariants.

The simulator's correctness story is a set of *cross-engine contracts*:
every :class:`~repro.core.spec.CampaignSpec` must be interpreted
identically by the object, array, batched and jax engines.  Until now
those contracts were enforced only at runtime — goldens, the seed-2021
trace sha256, ``campaigns lint --registry`` — which catches drift
*after* it ships into a failing test.  This package enforces them at
lint time, on the syntax alone (stdlib ``ast``, no imports of engine
code, no new dependencies):

  ===== ==============================================================
  REG   registry completeness — every ``register_event``/
        ``register_op`` in ``core/timeline.py`` has concrete EngineOps
        bodies on all adapters (``TimelineController``,
        ``sweep._LaneOps``, ``sweep_jax.JaxLaneOps``) and provisioner
        facades
  RNG   determinism discipline — no global RNG, wall-clock reads or
        unordered-set iteration inside ``core/``
  TRC   trace choke-point parity — every TraceRecorder method one
        trace-capable engine emits, all of them emit
  KRN   kernel/oracle pairing — every Pallas kernel has a ``ref.py``
        oracle and a ``tests/test_kernels.py`` exercise
  ===== ==============================================================

Run it::

    PYTHONPATH=src python -m repro.analysis.staticcheck [--json out.json]
    PYTHONPATH=src python -m repro.campaigns check

Exit codes mirror ``campaigns diff``: 0 clean, 1 findings, 2 bad
usage/internal error.  Intentional exceptions are suppressed inline
(``# staticcheck: ignore[RNG003] — reason``) or via a committed
baseline file (see :mod:`repro.analysis.staticcheck.baseline`).

The public entry point for tools and tests is :func:`analyze`;
``overrides`` lets tests inject contract mutations (a deleted adapter
method, a stray ``np.random.seed``) without touching the tree.
"""
from __future__ import annotations

from typing import List, Mapping, Optional

from repro.analysis.staticcheck.determinism import check_determinism
from repro.analysis.staticcheck.findings import (Finding, RULES,
                                                 sort_findings)
from repro.analysis.staticcheck.kernels import check_kernels
from repro.analysis.staticcheck.registry import check_registry
from repro.analysis.staticcheck.traceparity import check_trace_parity
from repro.analysis.staticcheck.tree import SourceTree, find_repo_root

__all__ = ["analyze", "Finding", "RULES", "SourceTree",
           "find_repo_root"]

#: rule family -> checker (order = report grouping order)
CHECKERS = (check_registry, check_determinism, check_trace_parity,
            check_kernels)


def analyze(root=None,
            overrides: Optional[Mapping[str, Optional[str]]] = None,
            rules: Optional[frozenset] = None) -> List[Finding]:
    """Run every contract rule over the repository at ``root`` (default:
    auto-located checkout root) and return the surviving findings in
    canonical (file, line, rule) order.  Inline suppression comments
    are honored here; baseline filtering is the CLI's job (so library
    callers always see the raw contract state)."""
    tree = SourceTree(root if root is not None else find_repo_root(),
                      overrides=overrides)
    findings: List[Finding] = []
    for checker in CHECKERS:
        findings.extend(checker(tree))
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    findings = [f for f in findings
                if not tree.is_suppressed(f.file, f.line, f.rule)]
    return sort_findings(findings)
