"""Source-tree model for the engine-contract static analyzer.

Everything the rules need from the repository is funneled through
:class:`SourceTree`: file discovery, cached ``ast`` parses, inline
suppression comments and a handful of AST helpers (dotted-name
rendering, class-member collection, a tiny evaluator for the literal
``frozenset`` algebra in ``core/api.py``).  The tree never *imports*
repository code — every contract is checked on the syntax alone, so a
drifted engine is caught even when it no longer imports.

``overrides`` maps repo-relative paths to replacement source text
(``None`` deletes the file).  The rule tests use it to seed contract
mutations — an event without a ``JaxLaneOps`` body, a stray
``np.random.seed`` — without touching the working tree.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Mapping, Optional, Set, Tuple

#: inline suppression: ``# staticcheck: ignore[RULE1,RULE2] — reason``
_SUPPRESS_RE = re.compile(
    r"#\s*staticcheck:\s*ignore\[([A-Z0-9_,\s]+)\]")

#: rule-id shape shared with the SPEC/lint prefixes (see spec.lint_spec)
RULE_ID_RE = re.compile(r"^[A-Z]{3,5}\d{3}$")


def find_repo_root(start: Optional[Path] = None) -> Path:
    """Walk up from this file (or ``start``) to the checkout root — the
    first directory holding both ``src`` and ``tests``."""
    here = (start or Path(__file__)).resolve()
    for cand in [here] + list(here.parents):
        if (cand / "src").is_dir() and (cand / "tests").is_dir():
            return cand
    raise FileNotFoundError(
        "cannot locate the repository root (no ancestor of "
        f"{here} contains both src/ and tests/); pass --root")


class SourceTree:
    """A parse-cached view of the repository's Python sources."""

    def __init__(self, root, overrides: Optional[Mapping[str, Optional[str]]]
                 = None):
        self.root = Path(root)
        self.overrides: Dict[str, Optional[str]] = {
            self._norm(k): v for k, v in (overrides or {}).items()}
        self._src: Dict[str, Optional[str]] = {}
        self._ast: Dict[str, Optional[ast.Module]] = {}
        self._suppress: Dict[str, Dict[int, Set[str]]] = {}

    @staticmethod
    def _norm(rel: str) -> str:
        return str(rel).replace("\\", "/").lstrip("./")

    # -- file access -------------------------------------------------------
    def read(self, rel: str) -> Optional[str]:
        rel = self._norm(rel)
        if rel not in self._src:
            if rel in self.overrides:
                self._src[rel] = self.overrides[rel]
            else:
                p = self.root / rel
                try:
                    self._src[rel] = p.read_text(encoding="utf-8")
                except (OSError, UnicodeDecodeError):
                    self._src[rel] = None
        return self._src[rel]

    def exists(self, rel: str) -> bool:
        return self.read(rel) is not None

    def parse(self, rel: str) -> Optional[ast.Module]:
        rel = self._norm(rel)
        if rel not in self._ast:
            text = self.read(rel)
            if text is None:
                self._ast[rel] = None
            else:
                try:
                    self._ast[rel] = ast.parse(text, filename=rel)
                except SyntaxError:
                    self._ast[rel] = None
        return self._ast[rel]

    def glob(self, pattern: str) -> List[str]:
        """Repo-relative posix paths matching ``pattern``, overrides
        merged in (an override of a non-existent path adds a file; a
        ``None`` override deletes one)."""
        found = {self._norm(str(p.relative_to(self.root)))
                 for p in self.root.glob(pattern) if p.is_file()}
        import fnmatch
        for rel, text in self.overrides.items():
            if text is None:
                found.discard(rel)
            elif fnmatch.fnmatch(rel, pattern):
                found.add(rel)
        return sorted(found)

    # -- suppressions ------------------------------------------------------
    def suppressions(self, rel: str) -> Dict[int, Set[str]]:
        """``lineno -> {rule ids}`` for ``# staticcheck: ignore[...]``
        comments (1-based, the line the comment sits on)."""
        rel = self._norm(rel)
        if rel not in self._suppress:
            out: Dict[int, Set[str]] = {}
            text = self.read(rel)
            if text is not None:
                for i, line in enumerate(text.splitlines(), start=1):
                    m = _SUPPRESS_RE.search(line)
                    if m:
                        ids = {s.strip() for s in m.group(1).split(",")}
                        out[i] = {s for s in ids if s}
            self._suppress[rel] = out
        return self._suppress[rel]

    def is_suppressed(self, rel: str, line: int, rule: str) -> bool:
        sup = self.suppressions(rel)
        for ln in (line, line - 1):      # same line or the line above
            ids = sup.get(ln)
            if ids and (rule in ids or "*" in ids):
                return True
        return False


# -- AST helpers -----------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute/name chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def find_class(mod: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in mod.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def class_members(cls: ast.ClassDef) -> Set[str]:
    """Statically visible members of a class: methods/properties,
    class-level assignments and ``self.X = ...`` in ``__init__`` —
    exactly what a runtime ``hasattr`` on a constructed instance would
    see for the adapter classes the registry drift guard checks."""
    out: Set[str] = set()
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(node.name)
            if node.name == "__init__":
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Assign):
                        for tgt in sub.targets:
                            if (isinstance(tgt, ast.Attribute)
                                    and isinstance(tgt.value, ast.Name)
                                    and tgt.value.id == "self"):
                                out.add(tgt.attr)
                    elif isinstance(sub, ast.AnnAssign):
                        tgt = sub.target
                        if (isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            out.add(tgt.attr)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    out.update(e.id for e in tgt.elts
                               if isinstance(e, ast.Name))
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name):
                out.add(node.target.id)
    # __slots__ entries are attributes too (sweep._LaneOps)
    for node in cls.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__slots__"
                        for t in node.targets)
                and isinstance(node.value, (ast.Tuple, ast.List, ast.Set))):
            out.update(e.value for e in node.value.elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, str))
    return out


def literal_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """A ``("a", "b")`` literal as a tuple of strings, else None."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def call_kwargs(call: ast.Call) -> Dict[str, ast.AST]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg}


def eval_engine_sets(mod: ast.Module) -> Dict[str, frozenset]:
    """Evaluate the literal set algebra of module-level assignments —
    enough for ``core/api.py``'s engine sets (``frozenset({...})``,
    ``NAME | {...}``, ``frozenset(NAME - {...})``) without importing the
    module."""
    env: Dict[str, frozenset] = {}

    def ev(node: ast.AST) -> Optional[frozenset]:
        if isinstance(node, ast.Set):
            vals = [e.value for e in node.elts
                    if isinstance(e, ast.Constant)]
            return frozenset(vals) if len(vals) == len(node.elts) else None
        if isinstance(node, ast.Name):
            return env.get(node.id)
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("frozenset", "set") \
                and len(node.args) == 1 and not node.keywords:
            return ev(node.args[0])
        if isinstance(node, ast.BinOp):
            left, right = ev(node.left), ev(node.right)
            if left is None or right is None:
                return None
            if isinstance(node.op, ast.BitOr):
                return left | right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.BitAnd):
                return left & right
        return None

    for node in mod.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            val = ev(node.value)
            if val is not None:
                env[node.targets[0].id] = val
    return env


def literal_str_dict(node: ast.AST) -> Optional[Dict[str, str]]:
    """A ``{"k": "v"}`` literal as a str->str dict, else None."""
    if isinstance(node, ast.Dict):
        out: Dict[str, str] = {}
        for k, v in zip(node.keys, node.values):
            if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)):
                out[k.value] = v.value
            else:
                return None
        return out
    return None


def module_str_dicts(mod: ast.Module) -> Dict[str, Dict[str, str]]:
    """Every module-level ``NAME = {"k": "v", ...}`` literal dict
    (plain or annotated assignment)."""
    out: Dict[str, Dict[str, str]] = {}
    for node in mod.body:
        target = value = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target, value = node.targets[0].id, node.value
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.value is not None:
            target, value = node.target.id, node.value
        if target is not None:
            d = literal_str_dict(value)
            if d is not None:
                out[target] = d
    return out


def module_path(module: str) -> str:
    """``repro.core.spec`` -> ``src/repro/core/spec.py``."""
    return "src/" + module.replace(".", "/") + ".py"
