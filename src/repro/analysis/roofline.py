"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants from the assignment).

Three terms, all in seconds PER STEP, per device (SPMD module is
per-device, so per-device quantities divide by per-chip rates):

  compute    = dot_flops_per_device / PEAK_FLOPS
  memory     = hbm_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

dot FLOPs and collective bytes come from the optimized HLO text with
while-trip multipliers (analysis/hlo.py); raw cost_analysis() numbers are
recorded alongside as a cross-check (they undercount scanned layers).
HBM traffic is analytic (see `hbm_bytes`): weights + optimizer/cache state
+ boundary activations — the irreducible traffic a perfect fusion would
still pay; XLA's bytes-accessed is recorded as a cross-check.

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) over the step's tokens,
and the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip effective)


# --------------------------------------------------------------------------
# analytic parameter / activation accounting
# --------------------------------------------------------------------------

def count_params(cfg):
    """Total and active (per-token) params, from the ModelConfig alone."""
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    Vp = cfg.padded_vocab()
    total = active = 0

    def attn_params():
        if cfg.attention_type == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (D * m.q_lora_rank + m.q_lora_rank * H * qk
                    + D * m.kv_lora_rank + D * m.qk_rope_head_dim
                    + m.kv_lora_rank * H * (m.qk_nope_head_dim
                                            + m.v_head_dim)
                    + H * m.v_head_dim * D)
        return D * Dh * (H + 2 * Hkv) + H * Dh * D

    def ffn_params(dff):
        mult = 3 if cfg.ffn_type == "swiglu" else 2
        return mult * D * dff

    def mamba_params():
        di = cfg.mamba.expand * D
        R = cfg.mamba.dt_rank or -(-D // 16)
        N = cfg.mamba.d_state
        return (D * 2 * di + cfg.mamba.d_conv * di + di * (R + 2 * N)
                + R * di + di * N + di + di * D)

    def mlstm_params():
        di = int(D * cfg.xlstm.proj_factor_mlstm)
        return D * 2 * di + 3 * di * di + di * 2 * cfg.num_heads + di * D

    def slstm_params():
        dh = D // cfg.num_heads
        dff = int(D * cfg.xlstm.proj_factor_slstm)
        return 4 * (D * D + cfg.num_heads * dh * dh) + 3 * D * dff

    for mixer, ffn in cfg.block_defs:
        t = a = 0
        if mixer == "attn":
            t = a = attn_params()
        elif mixer == "mamba":
            t = a = mamba_params()
        elif mixer == "mlstm":
            t = a = mlstm_params()
        elif mixer == "slstm":
            t = a = slstm_params()
        if ffn == "dense":
            f = ffn_params(cfg.d_ff)
            t, a = t + f, a + f
        elif ffn == "moe":
            moe = cfg.moe
            per_exp = ffn_params(moe.d_ff_expert)
            t += moe.num_experts * per_exp + D * moe.num_experts
            a += moe.top_k * per_exp
            if moe.num_shared_experts:
                s = ffn_params(moe.d_ff_shared * moe.num_shared_experts)
                t, a = t + s, a + s
        total += t * cfg.n_super
        active += a * cfg.n_super

    emb = Vp * D * (1 if cfg.tie_embeddings else 2)
    if cfg.pos_embedding == "learned":
        emb += min(cfg.max_position, 65536) * D
    total += emb
    active += emb
    if cfg.is_encdec:
        enc = cfg.encoder
        per = D * Dh * (H + 2 * Hkv) + H * Dh * D + ffn_params(cfg.d_ff)
        # decoder cross-attention already counted? no — add it:
        cross = (D * Dh * (H + 2 * Hkv) + H * Dh * D) * cfg.num_layers
        total += per * enc.num_layers + cross
        active += per * enc.num_layers + cross
    return total, active


def model_flops(cfg, shape):
    """6*N_active*tokens for training; 2*N_active*tokens for inference fwd;
    decode: one token per sequence."""
    _, n_active = count_params(cfg)
    if shape.kind == "train":
        return 6 * n_active * shape.tokens_per_step
    if shape.kind == "prefill":
        return 2 * n_active * shape.tokens_per_step
    return 2 * n_active * shape.global_batch          # decode: 1 tok/seq


def state_bytes(cfg, shape, n_chips, bytes_per_param_train=18.0,
                bytes_per_param_serve=2.0):
    """Sharded per-device resident state: params(+opt) or params(+cache)."""
    total, _ = count_params(cfg)
    if shape.kind == "train":
        return total * bytes_per_param_train / n_chips
    cache = cache_bytes(cfg, shape)
    return (total * bytes_per_param_serve + cache) / n_chips


def cache_bytes(cfg, shape, dtype_bytes=2):
    """Global KV/state cache bytes for a decode/prefill shape."""
    B, S = shape.global_batch, shape.seq_len
    per_layer = 0
    for mixer, _ in cfg.block_defs:
        if mixer == "attn":
            if cfg.attention_type == "mla":
                m = cfg.mla
                per_layer += B * S * (m.kv_lora_rank + m.qk_rope_head_dim)
            else:
                per_layer += 2 * B * S * cfg.num_kv_heads * cfg.head_dim
        elif mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            per_layer += B * di * (cfg.mamba.d_state * 2 + cfg.mamba.d_conv)
        elif mixer in ("mlstm", "slstm"):
            di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
            dh = di // cfg.num_heads
            per_layer += B * cfg.num_heads * (dh * dh + 2 * dh) * 2
    return per_layer * cfg.n_super * dtype_bytes


def hbm_bytes(cfg, shape, n_chips):
    """Analytic irreducible HBM traffic per device per step (bytes).

    train:   read params(bf16) + write grads(f32) + r/w opt moments+master
             + boundary activations (saved layer inputs, bf16, x2 for
             fwd-write/bwd-read) per microbatch
    prefill: read params + write cache + boundary activations
    decode:  read params(active experts only for MoE) + read full cache
             + write one cache slot
    """
    total, active = count_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    if shape.kind == "train":
        opt = total * (2 + 4 + 4 + 4 + 4)      # p.bf16,g.f32,mu,nu,master
        act = 2 * (B * S * D * 2) * cfg.num_layers * 2   # save+reload, bf16
        return (opt + act) / n_chips
    if shape.kind == "prefill":
        return (total * 2 + cache_bytes(cfg, shape)
                + 2 * B * S * D * 2 * cfg.num_layers) / n_chips
    # decode: weights actually touched + full cache read + tiny write.
    # MoE: each of B tokens touches ~N_active params, different tokens hit
    # different experts -> touched ~ min(total, B * N_active).
    touched = min(total, active * max(1, B)) if cfg.moe is not None else total
    return (touched * 2 + cache_bytes(cfg, shape)) / n_chips


# --------------------------------------------------------------------------
# terms
# --------------------------------------------------------------------------

@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_device: float
    useful_ratio: float
    bottleneck: str

    def to_dict(self):
        return dict(compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s,
                    model_flops=self.model_flops,
                    hlo_flops_device=self.hlo_flops_device,
                    useful_ratio=self.useful_ratio,
                    bottleneck=self.bottleneck)


def compute_roofline(cfg, shape, n_chips, dot_flops_device,
                     collective_bytes_device):
    mf = model_flops(cfg, shape)
    hbm = hbm_bytes(cfg, shape, n_chips)
    compute_s = dot_flops_device / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = collective_bytes_device / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = mf / max(dot_flops_device * n_chips, 1)
    return Roofline(compute_s, memory_s, coll_s, mf,
                    dot_flops_device, useful, bottleneck)
