"""Render EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python -m repro.analysis.report artifacts/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(art_dir):
    cells = {}
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        for r in json.load(open(path)):
            cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_bytes(b):
    for unit, d in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6)):
        if b >= d:
            return f"{b / d:.2f}{unit}"
    return f"{b:.0f}B"


def roofline_md(cells, mesh="16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | HLO dotF/dev | MODEL_FLOPS | useful | "
           "coll B/dev | mem/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | skipped "
                       f"(full attention) | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {arch} | {shape} | ERROR | | | | | | | | |")
            continue
        rf = r["roofline"]
        arg = (r["memory"]["argument_bytes"] or 0)
        out.append(
            f"| {arch} | {shape} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['bottleneck']}** | {rf['hlo_flops_device']:.2e} | "
            f"{rf['model_flops']:.2e} | {min(rf['useful_ratio'], 9.99):.2f} | "
            f"{fmt_bytes(r['hlo_parsed']['collective_bytes'])} | "
            f"{fmt_bytes(arg)} |")
    return "\n".join(out)


def dryrun_md(cells):
    out = ["| arch | shape | mesh | status | compile s | arg bytes/dev | "
           "temp bytes/dev | dot GF/dev | coll B/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if r.get("status") == "skipped":
            out.append(f"| {arch} | {shape} | {m} | SKIP (full attn) "
                       f"| | | | | |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {arch} | {shape} | {m} | ERROR | | | | | |")
            continue
        out.append(
            f"| {arch} | {shape} | {m} | ok | {r['compile_s']:.0f} | "
            f"{fmt_bytes(r['memory']['argument_bytes'] or 0)} | "
            f"{fmt_bytes(r['memory']['temp_bytes'] or 0)} | "
            f"{r['hlo_parsed']['dot_flops'] / 1e9:.0f} | "
            f"{fmt_bytes(r['hlo_parsed']['collective_bytes'])} |")
    return "\n".join(out)


if __name__ == "__main__":
    art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    cells = load(art)
    mode = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if mode == "roofline":
        print(roofline_md(cells))
    elif mode == "roofline2":
        print(roofline_md(cells, mesh="2x16x16"))
    else:
        print(dryrun_md(cells))
