"""Post-optimization HLO text analysis: dot FLOPs + collective bytes with
while-loop trip-count multipliers.

XLA's HloCostAnalysis visits each while body ONCE, so for scan-over-layers
models cost_analysis() undercounts by ~num_layers. This parser rebuilds the
call graph (entry -> fusions/calls/whiles), reads each while's
``backend_config known_trip_count`` (XLA annotates lax.scan loops), and
multiplies nested costs accordingly — giving faithful per-device FLOPs and
collective bytes for the roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# opcode: first identifier followed by '(' after the shape part; shapes end
# with ']', '{...}' layout, or ')' for tuples.
_OPCODE_RE = re.compile(r"[\]\}\)]\s*([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_REFS = re.compile(r"(?:calls=|to_apply=|body=|condition=)"
                        r"%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shapes_bytes(text):
    """Sum of bytes over all array shapes in `text` (tuple-aware)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            n = 1
            for x in dims.split(","):
                if x:
                    n *= int(x)
            total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text):
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = m.group(2)
    return tuple(int(x) for x in dims.split(",")) if dims else ()


@dataclass
class Instr:
    name: str
    opcode: str
    shape: tuple | None
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # %name -> shape tuple


_HEADER_PARAM_RE = re.compile(r"([\w\.\-]+):\s+((?:[a-z0-9]+\[[0-9,]*\]"
                              r"(?:\{[0-9,]*\})?)+)")


def parse_module(hlo_text):
    """Returns ({name: Computation}, entry_name)."""
    comps, cur, entry = {}, None, None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not ls or ls.startswith("//") or ls.startswith("HloModule"):
            continue
        # computation headers sit at column 0: [ENTRY] %name (params) -> ret {
        at_top = not raw[:1].isspace()
        if at_top and ls.endswith("{") and "->" in ls and \
                (ls.startswith("%") or ls.startswith("ENTRY")):
            toks = ls.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = Computation(name.lstrip("%"))
            comps[cur.name] = cur
            if toks[0] == "ENTRY":
                entry = cur.name
            # header params into symbol table
            for pname, pshape in _HEADER_PARAM_RE.findall(ls):
                cur.symbols[pname] = _first_shape(pshape)
            continue
        if ls == "}" or cur is None:
            continue
        if "=" not in ls or not ls.startswith("%"):
            # ROOT lines: 'ROOT %x = ...'
            if ls.startswith("ROOT %"):
                ls = ls[5:]
            else:
                continue
        lhs, rhs = ls.split("=", 1)
        iname = lhs.strip().lstrip("%")
        om = _OPCODE_RE.search(rhs)
        opcode = om.group(1) if om else ""
        shape = _first_shape(rhs)
        cur.symbols[iname] = shape
        cur.instrs.append(Instr(iname, opcode, shape, ls))
    return comps, entry


def _operands(line):
    """Operand %names inside the op's parentheses."""
    om = _OPCODE_RE.search(line.split("=", 1)[1])
    if not om:
        return []
    start = line.index(om.group(0)) + len(om.group(0))
    depth, i = 1, start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    inner = line[start:i - 1]
    return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", inner)]


def _dot_flops(instr, comp):
    out = instr.shape
    if out is None:
        return 0
    out_n = 1
    for d in out:
        out_n *= d
    ops = _operands(instr.line)
    lhs_shape = comp.symbols.get(ops[0]) if ops else None
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    k = 1
    if lhs_shape and lc:
        for idx in lc.group(1).split(","):
            if idx:
                k *= lhs_shape[int(idx)]
    return 2 * out_n * k


def _collective_bytes(instr, comp):
    # output may be a tuple: sum all shapes left of the opcode
    rhs = instr.line.split("=", 1)[1]
    om = _OPCODE_RE.search(rhs)
    out_b = _shapes_bytes(rhs[:om.start() + 1]) if om else 0
    # XLA promotes bf16 all-reduces to f32 (convert -> reduce ->
    # reduce-precision); the wire payload on TPU stays 16-bit. The promoted
    # reduction computation is suffixed "_promoted" — halve those bytes.
    if "promoted" in instr.line and instr.opcode.startswith("all-reduce"):
        out_b //= 2
    return out_b


def while_trip_count(comps, instr):
    m = _TRIP_RE.search(instr.line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", instr.line)
    cond = comps.get(cm.group(1)) if cm else None
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        consts += [int(x) for x in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def analyze(hlo_text):
    """dict: dot_flops, collective_bytes(+by kind), per device, with while
    multipliers applied."""
    comps, entry = parse_module(hlo_text)
    memo = {}

    def cost(name, depth=0):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return {"flops": 0, "coll": {}}
        memo[name] = {"flops": 0, "coll": {}}   # cycle guard
        flops, coll = 0, {}

        def add_coll(kind, b, mult=1):
            coll[kind] = coll.get(kind, 0) + b * mult

        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                flops += _dot_flops(ins, comp)
                continue
            if any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                add_coll(kind, _collective_bytes(ins, comp))
                continue
            if op == "while":
                refs = dict(
                    (k, v) for k, v in
                    re.findall(r"(body|condition)=%?([\w\.\-]+)", ins.line))
                trips = while_trip_count(comps, ins)
                if "body" in refs:
                    sub = cost(refs["body"], depth + 1)
                    flops += sub["flops"] * trips
                    for k, v in sub["coll"].items():
                        add_coll(k, v, trips)
                continue
            subnames = [m.group(1) for m in _CALL_REFS.finditer(ins.line)]
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                subnames += [s.strip().lstrip("%")
                             for s in bm.group(1).split(",")]
            for sub_name in set(subnames):
                if sub_name == name:
                    continue
                sub = cost(sub_name, depth + 1)
                flops += sub["flops"]
                for k, v in sub["coll"].items():
                    add_coll(k, v)
        out = {"flops": flops, "coll": coll}
        memo[name] = out
        return out

    res = cost(entry) if entry else {"flops": 0, "coll": {}}
    return {"dot_flops": res["flops"],
            "collective_bytes": sum(res["coll"].values()),
            "collective_bytes_by_kind": res["coll"]}
