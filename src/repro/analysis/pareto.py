"""Cost-vs-goodput Pareto frontiers over campaign sweeps.

The HEPCloud cost-optimization question (arXiv 1710.00100) is "which
point on the cost/throughput frontier should we buy?" — and the
repo's sweep engines make the candidate set cheap to generate
(``scenarios.pareto_grid()`` composes the price-curve × GPU-slicing ×
data-plane axes into one grid).  This module turns a
:class:`~repro.core.sweep.SweepResult` into the answer:

    result = api.run(scenarios.pareto_grid(), seeds=[2021, 2022])
    front = pareto.frontier(result)            # cost vs accel_days
    print(front.table())

:func:`frontier` aggregates rows per scenario (mean over seeds),
computes the exact non-dominated set under (minimize cost, maximize
value), and returns every candidate with its frontier membership —
dominated points matter in the report (they are what you should NOT
buy).  ``cost`` is the ledger total, which already includes metered
egress — never add ``egress_usd`` on top.  The value axis is any
numeric row metric (``accel_days``, ``jobs_finished``, ...); when the
sweep carried per-lane traces, :func:`goodput_rows` augments rows with
a measured ``goodput_fraction`` by replaying each trace into the
elastic pod-pool model (:func:`repro.core.elastic.drive_pool`), so the
frontier can be drawn against *delivered* training goodput rather than
raw GPU-days.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["ParetoPoint", "ParetoFrontier", "frontier", "goodput_rows"]


@dataclass(frozen=True)
class ParetoPoint:
    """One aggregated sweep candidate on the (cost, value) plane."""
    scenario: str
    cost: float
    value: float
    seeds: int
    on_frontier: bool

    def to_dict(self) -> dict:
        return {"scenario": self.scenario, "cost": self.cost,
                "value": self.value, "seeds": self.seeds,
                "on_frontier": self.on_frontier}


@dataclass(frozen=True)
class ParetoFrontier:
    """All candidates plus their non-dominated subset (sorted by
    cost).  ``points`` keeps every candidate — the dominated ones are
    the answer to "what should we not buy"."""
    x: str
    y: str
    points: Tuple[ParetoPoint, ...]

    @property
    def frontier(self) -> Tuple[ParetoPoint, ...]:
        return tuple(p for p in self.points if p.on_frontier)

    @property
    def dominated(self) -> Tuple[ParetoPoint, ...]:
        return tuple(p for p in self.points if not p.on_frontier)

    def to_dict(self) -> dict:
        return {"kind": "pareto_frontier", "x": self.x, "y": self.y,
                "points": [p.to_dict() for p in self.points]}

    def table(self) -> str:
        """Markdown-ish frontier report, cheapest candidate first;
        frontier members are starred."""
        rows = [f"| {'':1s} | {'scenario':24s} | {self.x:>12s} "
                f"| {self.y:>14s} |",
                "|---|" + "-" * 26 + "|" + "-" * 14 + "|"
                + "-" * 16 + "|"]
        for p in self.points:
            star = "*" if p.on_frontier else " "
            rows.append(f"| {star} | {p.scenario:24s} "
                        f"| {p.cost:>12,.2f} | {p.value:>14,.3f} |")
        return "\n".join(rows)


def _aggregate(rows: Sequence[dict], x: str, y: str
               ) -> List[Tuple[str, float, float, int]]:
    """Per-scenario (mean x, mean y, n seeds) in first-seen order."""
    order: List[str] = []
    acc: Dict[str, List[Tuple[float, float]]] = {}
    for row in rows:
        name = row.get("scenario", "?")
        for axis in (x, y):
            if axis not in row:
                have = sorted(k for k, v in row.items()
                              if isinstance(v, (int, float))
                              and not isinstance(v, bool))
                raise ValueError(
                    f"row for scenario {name!r} has no {axis!r} metric "
                    f"(numeric metrics: {', '.join(have)})")
        if name not in acc:
            order.append(name)
            acc[name] = []
        acc[name].append((float(row[x]), float(row[y])))
    out = []
    for name in order:
        pts = acc[name]
        n = len(pts)
        out.append((name, sum(p[0] for p in pts) / n,
                    sum(p[1] for p in pts) / n, n))
    return out


def _non_dominated(pts: Sequence[Tuple[float, float]]) -> List[bool]:
    """Exact weak-dominance filter: point p is dominated iff some q has
    ``q.cost <= p.cost`` and ``q.value >= p.value`` with at least one
    strict.  Duplicate (cost, value) points are all kept — neither
    strictly beats the other."""
    flags = []
    for i, (cx, cy) in enumerate(pts):
        dominated = any(
            (qx <= cx and qy >= cy) and (qx < cx or qy > cy)
            for j, (qx, qy) in enumerate(pts) if j != i)
        flags.append(not dominated)
    return flags


def frontier(sweep_or_rows, x: str = "cost", y: str = "accel_days"
             ) -> ParetoFrontier:
    """Compute the Pareto frontier of a sweep on (minimize ``x``,
    maximize ``y``).

    ``sweep_or_rows`` is a :class:`~repro.core.sweep.SweepResult` or a
    plain row-dict sequence; rows are aggregated per scenario (mean
    over seeds) before the dominance test.  Returns every candidate
    sorted by cost (ties by scenario name) with frontier membership
    flags."""
    rows = getattr(sweep_or_rows, "rows", sweep_or_rows)
    if not rows:
        raise ValueError("frontier() needs at least one sweep row")
    agg = _aggregate(rows, x, y)
    flags = _non_dominated([(c, v) for _n, c, v, _s in agg])
    points = [ParetoPoint(scenario=name, cost=round(c, 6),
                          value=round(v, 6), seeds=n, on_frontier=f)
              for (name, c, v, n), f in zip(agg, flags)]
    points.sort(key=lambda p: (p.cost, p.scenario))
    return ParetoFrontier(x=x, y=y, points=tuple(points))


def goodput_rows(sweep, *, max_pods: int = 4096, rebuild_s: float = 30.0,
                 step_time_s: float = 2.0,
                 checkpoint_period_s: float = 600.0) -> List[dict]:
    """Augment a trace-carrying sweep's rows with measured
    ``goodput_fraction``: each lane's :class:`~repro.core.events.
    CampaignTrace` is replayed into an elastic pod pool
    (:func:`repro.core.elastic.drive_pool` with a
    :class:`~repro.core.elastic.SimulatedElasticRunner`), so the
    frontier's value axis can be delivered training goodput instead of
    raw GPU-days.  Requires ``collect="trace"``; rows come back copied,
    in order, ready for :func:`frontier(..., y="goodput_fraction")`."""
    from repro.core.elastic import (PodPool, SimulatedElasticRunner,
                                    drive_pool)
    traces = getattr(sweep, "traces", None)
    if traces is None:
        raise ValueError(
            "goodput_rows() needs a sweep run with collect=\"trace\" "
            "(SweepResult.traces is None)")
    out = []
    for row, trace in zip(sweep.rows, traces):
        pool = PodPool(max_pods=max_pods)
        runner = SimulatedElasticRunner(rebuild_s=rebuild_s)
        report = drive_pool(trace, pool, runner,
                            step_time_s=step_time_s,
                            checkpoint_period_s=checkpoint_period_s)
        row = dict(row)
        row["goodput_fraction"] = report.goodput_fraction
        out.append(row)
    return out
