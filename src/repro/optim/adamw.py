"""Sharded AdamW with fp32 master weights, global-norm clipping, schedules.

Optimizer state mirrors the param tree leaf-for-leaf (same shardings apply),
so FSDP sharding of params automatically shards moments and master copy —
ZeRO-style without any bespoke machinery.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, base_lr, warmup_steps=100, decay_steps=10000,
                    min_ratio=0.1):
    warm = jnp.minimum((step + 1.0) / jnp.maximum(warmup_steps, 1), 1.0)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(decay_steps, 1), 0., 1.)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params, *, keep_master=True):
    """params may be bf16 (compute copy); master fp32 copy lives here.
    When params are already fp32 no master is kept (it would alias the
    param buffers and double memory for nothing)."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"mu": zeros,
             "nu": jax.tree.map(jnp.zeros_like, zeros),
             "step": jnp.zeros((), jnp.int32)}
    low_precision = any(
        jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != jnp.float32
        for x in jax.tree.leaves(params))
    if keep_master and low_precision:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(grads, state, params, *, lr, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    c1 = 1.0 - beta1 ** step.astype(jnp.float32)
    c2 = 1.0 - beta2 ** step.astype(jnp.float32)
    master = state.get("master", params)

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu1 = beta1 * mu + (1 - beta1) * g
        nu1 = beta2 * nu + (1 - beta2) * g * g
        upd_ = (mu1 / c1) / (jnp.sqrt(nu1 / c2) + eps)
        m1 = m - lr * (upd_ + weight_decay * m)
        return mu1, nu1, m1

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_m = treedef.flatten_up_to(master)
    out = [upd(*t) for t in zip(flat_g, flat_mu, flat_nu, flat_m)]
    mu1 = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu1 = jax.tree.unflatten(treedef, [o[1] for o in out])
    m1 = jax.tree.unflatten(treedef, [o[2] for o in out])

    flat_p = treedef.flatten_up_to(params)
    p1 = jax.tree.unflatten(
        treedef, [nm.astype(p.dtype) for nm, p in
                  zip([o[2] for o in out], flat_p)])
    new_state = {"mu": mu1, "nu": nu1, "step": step}
    if "master" in state:
        new_state["master"] = m1
    return p1, new_state, {"grad_norm": gnorm, "lr": lr}
