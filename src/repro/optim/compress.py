"""Cross-pod gradient compression (int8 + error feedback).

The paper's elastic axis crosses pods — on real fleets that is DCN, an
order of magnitude slower than intra-pod ICI. This module compresses the
pure-DP gradient exchange on the "pod" axis only:

  * int8 per-tensor quantization with fp32 scales (4x fewer wire bytes than
    fp32, 2x fewer than bf16),
  * exchange via all_gather(int8) + local dequant-mean (for small pod
    counts the gathered payload n_pod x 1B still beats a ring all-reduce of
    2 x 2B at n_pod <= 4; beyond that switch to quantized reduce-scatter),
  * optional error-feedback residual so the quantization error is carried
    into the next step instead of lost (Seide et al.; keeps convergence).

Usage inside a shard_map whose manual axes include "pod":
    g_sync, resid = compressed_psum_mean(g_local, "pod", resid)
Pure-jnp; property-tested in tests/test_compress.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """(q, scale): q int8, per-tensor scale. Exact for zeros."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x, axis_name, residual=None):
    """Mean over `axis_name` with an int8 wire format + error feedback.
    Returns (mean, new_residual). Call inside shard_map with `axis_name`
    manual."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    q, scale = quantize_int8(xf)
    new_residual = xf - dequantize_int8(q, scale)
    qs = jax.lax.all_gather(q, axis_name)            # (n_pod, ...)
    ss = jax.lax.all_gather(scale, axis_name)        # (n_pod,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return deq.mean(axis=0).astype(x.dtype), new_residual


def compressed_tree_psum_mean(tree, axis_name, residuals=None):
    """Tree version; residuals tree threads error feedback across steps."""
    leaves, treedef = jax.tree.flatten(tree)
    res_leaves = (jax.tree.leaves(residuals) if residuals is not None
                  else [None] * len(leaves))
    outs, new_res = [], []
    for x, r in zip(leaves, res_leaves):
        m, nr = compressed_psum_mean(x, axis_name, r)
        outs.append(m)
        new_res.append(nr)
    return jax.tree.unflatten(treedef, outs), \
        jax.tree.unflatten(treedef, new_res)


def wire_bytes(tree, n_pod, compressed=True):
    """Bytes each device sends per sync (analysis helper for §Perf)."""
    n = sum(x.size for x in jax.tree.leaves(tree))
    if compressed:
        return n * 1 + 4 * len(jax.tree.leaves(tree))
    return n * 4 * 2 * (n_pod - 1) / n_pod          # fp32 ring all-reduce
