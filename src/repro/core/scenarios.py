"""What-if scenario library for pre-burst planning sweeps.

The paper's headline numbers come from a *single* two-week run; HEPCloud-
style pre-burst planning (Holzman et al. 2017) and per-scenario cost
studies (Sfiligoi et al. 2022) want Monte-Carlo sweeps over seeds and
operational what-ifs.  Each library function below returns ready-made
:class:`~repro.core.spec.CampaignSpec` variants — catalog, spot mix,
budget floor, and declarative timeline events (ramp steps, CE outages,
price/capacity shifts) — that every execution path understands:

  * solo: ``api.run(spec, seeds=seed)`` drives one ``CloudSimulator``
    campaign (the reference semantics), and
  * batched: ``api.run(specs, seeds=seeds)`` ticks all (spec, seed)
    lanes in lock-step as one array program, bit-reproducible against
    the solo run at the same (seed, spec).

The frozen :class:`Scenario` dataclass is the legacy declaration (ramp/
outage as dedicated fields rather than a timeline); it remains importable
as a deprecation-warned shim with a ``to_spec()`` bridge.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.campaign import (OUTAGE_AT_H, OUTAGE_DURATION_H, PAPER_RAMP,
                                 POST_OUTAGE_TARGET, RampStage, _timeline)
from repro.core.provider import T4_FP32_TFLOPS, ProviderSpec
from repro.core.simulator import SimConfig
from repro.core.spec import (CacheFlush, CampaignSpec, CEOutage, DataOrigin,
                             DataPlane, GpuSlicing, OriginDegrade,
                             OriginOutage, PAPER_RAMP_EVENTS, PAPER_TIMELINE,
                             PriceCurve, WorkloadCurve,
                             build_catalog as _spec_build_catalog,
                             paper_spec, run_solo)


@dataclass(frozen=True)
class Scenario:
    """Deprecated: one campaign variant as dedicated ramp/outage fields;
    defaults reproduce the paper replay.  Use ``CampaignSpec`` (same
    defaults) with a declarative ``timeline`` instead."""
    name: str = "paper"
    catalog: str = "t4"                  # "t4" | "heterogeneous" (§III pool)
    capacity_scale: float = 1.0          # multiply every region's capacity
    spot: bool = True                    # spot (paper) vs on-demand pricing
    ondemand_fraction: float = 0.0       # carve this capacity share into
    #                                      preemption-free on-demand pools
    price_scale: float = 1.0             # uniform price-curve perturbation
    ramp: Tuple[RampStage, ...] = PAPER_RAMP
    outage: bool = True
    outage_at_h: float = OUTAGE_AT_H
    outage_duration_h: float = OUTAGE_DURATION_H
    resume_target: int = POST_OUTAGE_TARGET
    budget: float = 58000.0
    budget_floor_fraction: float = 0.2
    downscale_target: int = POST_OUTAGE_TARGET
    duration_h: float = 14 * 24.0
    dt_h: float = 0.25
    lease_interval_s: float = 120.0
    job_wall_h: float = 4.0
    job_checkpoint_h: float = 1.0
    min_queue: int = 4000
    overhead_per_day: float = 390.0
    accel_tflops: float = T4_FP32_TFLOPS

    def __post_init__(self):
        warnings.warn(
            "Scenario is deprecated; declare campaigns as "
            "repro.core.spec.CampaignSpec (Scenario(...).to_spec() "
            "bridges existing code)", DeprecationWarning, stacklevel=3)

    def to_spec(self) -> CampaignSpec:
        """The equivalent declarative spec (ramp/outage fields become
        timeline events); runs bit-identically on every engine."""
        return CampaignSpec(
            name=self.name, catalog=self.catalog,
            capacity_scale=self.capacity_scale, spot=self.spot,
            ondemand_fraction=self.ondemand_fraction,
            price_scale=self.price_scale, budget=self.budget,
            budget_floor_fraction=self.budget_floor_fraction,
            downscale_target=self.downscale_target,
            duration_h=self.duration_h, dt_h=self.dt_h,
            lease_interval_s=self.lease_interval_s,
            job_wall_h=self.job_wall_h,
            job_checkpoint_h=self.job_checkpoint_h,
            min_queue=self.min_queue,
            overhead_per_day=self.overhead_per_day,
            accel_tflops=self.accel_tflops,
            timeline=_timeline(self.ramp, self.outage,
                               outage_at_h=self.outage_at_h,
                               outage_duration_h=self.outage_duration_h,
                               resume_target=self.resume_target))


def build_catalog(sc) -> Dict[str, ProviderSpec]:
    """Shim: the spec's provider catalog (accepts CampaignSpec or the
    deprecated Scenario)."""
    return _spec_build_catalog(sc.to_spec())


def sim_config(sc, seed: int) -> SimConfig:
    """Shim: the spec's engine knobs as a SimConfig."""
    return SimConfig.from_spec(sc.to_spec(), seed)


def run_scenario(sc, seed: int, engine=None):
    """Deprecated shim: solo reference execution of one (scenario, seed)
    campaign; returns (results dict, controller).  Use
    ``api.run(spec, seeds=seed)`` — typed results — instead."""
    warnings.warn("run_scenario() is deprecated; use "
                  "repro.core.api.run(spec, seeds=seed)",
                  DeprecationWarning, stacklevel=2)
    res, ctl = run_solo(sc.to_spec(), seed, engine=engine)
    return res.to_dict(), ctl


# -- the library (all entries are CampaignSpecs) ---------------------------

def paper_baseline() -> CampaignSpec:
    return paper_spec()


def ondemand_fallback(budget: float = 58000.0) -> CampaignSpec:
    """All on-demand: zero preemptions, ~4.4x the $/GPU-day — how far does
    the same budget get without spot risk?"""
    return paper_spec(name="ondemand", spot=False, budget=budget)


def spot_ondemand_mixes(fracs: Sequence[float] = (0.1, 0.25, 0.5)
                        ) -> List[CampaignSpec]:
    return [paper_spec(name=f"mix-od{int(f * 100):02d}",
                       ondemand_fraction=f) for f in fracs]


def heterogeneous_burst(capacity_scale: float = 1.0) -> CampaignSpec:
    """The §III mixed T4/V100/P100/M60 pool under the paper's controller."""
    return paper_spec(name="hetero", catalog="heterogeneous",
                      capacity_scale=capacity_scale)


def outage_grid(times_h: Sequence[float] = (60.0, 252.0, 300.0),
                durations_h: Sequence[float] = (2.0, 12.0)
                ) -> List[CampaignSpec]:
    """What if the CE had died earlier / stayed down longer?"""
    # keep the declared timeline time-sorted (lint SPEC103): the outage
    # lands mid-ramp, not appended after the 192 h ramp steps
    return [paper_spec(name=f"outage-t{int(t)}-d{int(d)}",
                       timeline=tuple(sorted(
                           PAPER_RAMP_EVENTS + (
                               CEOutage(t, d, POST_OUTAGE_TARGET),),
                           key=lambda ev: ev.at_h)))
            for t in times_h for d in durations_h]


def outage_burst(at_h: float = 60.0, duration_h: float = 6.0
                 ) -> CampaignSpec:
    """One outage-grid member as a single named spec — the
    preemption-bearing campaign the elastic-goodput path replays:
    ``api.run(outage_burst(), collect="trace")`` ->
    ``elastic.drive_pool(result.trace, pool, runner)`` (see
    examples/elastic_goodput.py).  Defaults match the
    ``outage-t60-d6`` entry of :func:`default_suite`."""
    return outage_grid((at_h,), (duration_h,))[0]


def budget_floor_variants(floors: Sequence[float] = (0.1, 0.2, 0.3)
                          ) -> List[CampaignSpec]:
    """How early the 'downscale to 1k' tripwire fires vs GPU-days kept."""
    return [paper_spec(name=f"floor{int(f * 100):02d}",
                       budget_floor_fraction=f) for f in floors]


def price_perturbations(factors: Sequence[float] = (0.8, 1.0, 1.25)
                        ) -> List[CampaignSpec]:
    """Uniform spot-price-curve shifts (market drift between planning and
    burst day)."""
    return [paper_spec(name=f"price{int(f * 100):03d}", price_scale=f)
            for f in factors]


# named multi-day market curves for the paper's two-week window
# (piecewise-constant daily factors; the paper priced everything off the
# burst-day spot rate — these ask what the drift it ignored would cost)
MARKET_CURVES: Dict[str, PriceCurve] = {
    # steady upward drift as the burst itself tightens the spot pools
    "drift-up": PriceCurve(((72.0, 1.1), (144.0, 1.25), (240.0, 1.4))),
    # weekday-peak / weekend-dip rhythm
    "weekend-dip": PriceCurve(((96.0, 0.85), (144.0, 1.0),
                               (264.0, 0.85))),
    # the favored provider gets squeezed mid-burst, others stay flat
    "azure-squeeze": PriceCurve(((120.0, 1.5), (216.0, 1.1)),
                                provider="azure"),
}


def _sorted_timeline(*events):
    """Anchor-time-sorted (lint-clean) timeline; engines tie-break
    stably by declaration position either way."""
    return tuple(sorted(events, key=lambda e: e.at_h))


def price_curve_scenarios(curves: Sequence[str] = tuple(MARKET_CURVES)
                          ) -> List[CampaignSpec]:
    """The paper burst priced under realistic *drifting* spot markets:
    each variant weaves one named multi-day ``PriceCurve`` into the
    paper timeline (first-class spec data — serializable, sweepable)."""
    return [paper_spec(name=f"curve-{name}",
                       timeline=_sorted_timeline(*PAPER_TIMELINE,
                                                 MARKET_CURVES[name]))
            for name in curves]


# named request-rate curves (piecewise-constant factors on the CE queue
# top-up level).  The paper treated the job supply as infinite; these ask
# the HEPCloud cost question of *serving* load — what the same pool costs
# when demand breathes.  Factors below ~0.03 starve the queue at full
# fleet (int(4000 * f) jobs vs ~125 matched per tick at 2000 pilots).
WORKLOAD_CURVES: Dict[str, WorkloadCurve] = {
    # office-hours rhythm over the two-week window: full demand from
    # 08:00, near-idle troughs from 20:00 each day
    "diurnal": WorkloadCurve(tuple(
        p for d in range(14)
        for p in ((24.0 * d + 8.0, 1.0), (24.0 * d + 20.0, 0.02)))),
    # near-idle background, then a 12 h flash crowd mid-burst
    "flash-crowd": WorkloadCurve(((0.0, 0.05), (120.0, 1.0),
                                  (132.0, 0.05))),
}


def workload_curve_scenarios(curves: Sequence[str] = tuple(WORKLOAD_CURVES)
                             ) -> List[CampaignSpec]:
    """The paper burst serving *time-varying* demand: each variant weaves
    one named ``WorkloadCurve`` into the paper timeline, scaling the job
    arrival rate all three engines see bit-identically."""
    return [paper_spec(name=f"load-{name}",
                       timeline=_sorted_timeline(*PAPER_TIMELINE,
                                                 WORKLOAD_CURVES[name]))
            for name in curves]


def workload_burst() -> CampaignSpec:
    """Demand and market shifting at once — the WorkloadCurve golden
    campaign (tests/data/workload_curve.spec.json, pinned at seed 2021):
    the paper burst under a drifting spot market while serving a
    flash-crowd demand profile."""
    return paper_spec(
        name="workload-burst",
        timeline=_sorted_timeline(*PAPER_TIMELINE,
                                  MARKET_CURVES["drift-up"],
                                  WORKLOAD_CURVES["flash-crowd"]))


def gpu_slicing_variants(slices: Sequence[int] = (2, 4, 7)
                         ) -> List[CampaignSpec]:
    """Sfiligoi 2022 sub-GPU accounting: the same burst planned in
    1/2..1/7-GPU slices (k-fold capacity at ~1/k price and TFLOPS per
    slot) instead of whole devices."""
    return [paper_spec(name=f"slice{k}",
                       gpu_slicing=GpuSlicing(slices=k)) for k in slices]


def curve_sliced_burst(slices: int = 4) -> CampaignSpec:
    """Both new surfaces at once — the golden regression campaign
    (tests/data/curve_sliced.spec.json, pinned at seed 2021): the §III
    heterogeneous pool in 1/4-GPU slices, priced under a drifting
    market plus a provider-targeted squeeze on the sliced Azure T4
    pool."""
    return paper_spec(
        name="curve-sliced", catalog="heterogeneous",
        gpu_slicing=GpuSlicing(slices=slices),
        timeline=_sorted_timeline(
            *PAPER_TIMELINE, MARKET_CURVES["drift-up"],
            PriceCurve(((120.0, 1.5), (216.0, 1.1)),
                       provider=f"azure-t4/{slices}")))


# named data-plane layouts for the paper's t4 catalog (azure/gcp/aws).
# The paper treated jobs as pure compute; the follow-on IceCube data-
# federation work (arXiv 2308.07999) and HEPCloud's egress accounting
# (arXiv 1710.00100) make stage-in bandwidth, cache tiers and per-GB
# egress first-order campaign inputs — these origin maps price them.
DATA_PLANES: Dict[str, DataPlane] = {
    # one well-connected origin per cloud, regional caches on the two
    # majority providers; azure (the paper's favored pool) pays the
    # steepest per-GB egress on misses
    "federated": DataPlane({
        "azure": DataOrigin(bandwidth_gbps=4.0, egress_usd_per_gb=0.087,
                            cache_hit_rate=0.7,
                            cache_bandwidth_gbps=16.0),
        "gcp": DataOrigin(bandwidth_gbps=3.0, egress_usd_per_gb=0.12,
                          cache_hit_rate=0.5, cache_bandwidth_gbps=12.0),
        "aws": DataOrigin(bandwidth_gbps=3.0, egress_usd_per_gb=0.09),
    }),
    # cache-less worst case: every stage-in streams from the origin
    # and pays egress — the upper bound on the data bill
    "no-cache": DataPlane({
        "azure": DataOrigin(bandwidth_gbps=4.0, egress_usd_per_gb=0.087),
        "gcp": DataOrigin(bandwidth_gbps=3.0, egress_usd_per_gb=0.12),
        "aws": DataOrigin(bandwidth_gbps=3.0, egress_usd_per_gb=0.09),
    }),
}


def data_heavy_mix(sizes_gb: Sequence[float] = (2.0, 25.0, 100.0),
                   plane: str = "federated") -> List[CampaignSpec]:
    """The paper burst with per-job input data: the same campaign at
    photon-table (~2 GB), typical-simulation (~25 GB) and raw-readout
    (~100 GB) stage-in sizes — how fast does goodput become
    bandwidth-bound, and what does the egress line item grow to?"""
    return [paper_spec(name=f"data{int(s):03d}gb", job_input_gb=s,
                       dataplane=DATA_PLANES[plane])
            for s in sizes_gb]


def origin_outage_grid(times_h: Sequence[float] = (60.0, 252.0),
                       durations_h: Sequence[float] = (6.0, 24.0),
                       provider: str = "azure",
                       size_gb: float = 25.0) -> List[CampaignSpec]:
    """What if the favored provider's data origin — not the CE — went
    dark?  Pilots stay up and billed but take no new jobs until the
    origin recovers (the data-plane mirror of :func:`outage_grid`)."""
    return [paper_spec(
                name=f"origin-{provider}-t{int(t)}-d{int(d)}",
                job_input_gb=size_gb,
                dataplane=DATA_PLANES["federated"],
                timeline=_sorted_timeline(*PAPER_RAMP_EVENTS,
                                          OriginOutage(t, d, provider)))
            for t in times_h for d in durations_h]


def egress_cost_scenarios(size_gb: float = 25.0) -> List[CampaignSpec]:
    """The egress-bill optimization question: the same data-heavy burst
    with and without regional caches, plus a mid-burst cache flush on
    the favored provider — what do the cache tiers actually save, and
    what does re-warming after a flush cost?"""
    flush = paper_spec(
        name="egress-flushed", job_input_gb=size_gb,
        dataplane=DATA_PLANES["federated"],
        timeline=_sorted_timeline(*PAPER_TIMELINE,
                                  CacheFlush(180.0, "azure")))
    return [paper_spec(name="egress-cached", job_input_gb=size_gb,
                       dataplane=DATA_PLANES["federated"]),
            paper_spec(name="egress-nocache", job_input_gb=size_gb,
                       dataplane=DATA_PLANES["no-cache"]),
            flush]


def dataplane_burst() -> CampaignSpec:
    """The full data-plane surface in one campaign — the DataPlane
    golden (tests/data/dataplane.spec.json, pinned at seed 2021): the
    paper burst staging 25 GB per job through the federated origin map
    while the azure origin suffers a mid-burst outage, the aws WAN
    degrades for the back half, and the azure cache is flushed cold
    late in the window."""
    return paper_spec(
        name="dataplane-burst", job_input_gb=25.0,
        dataplane=DATA_PLANES["federated"],
        timeline=_sorted_timeline(*PAPER_TIMELINE,
                                  OriginOutage(98.0, 12.0, "azure"),
                                  OriginDegrade(168.0, 0.5, "aws"),
                                  CacheFlush(250.0, "azure")))


def planning_grid(price_scales: Sequence[float] = (0.8, 0.9, 1.0,
                                                   1.1, 1.25),
                  floors: Sequence[float] = (0.1, 0.2, 0.3, 0.4),
                  budgets: Sequence[float] = (40000.0, 58000.0, 80000.0)
                  ) -> List[CampaignSpec]:
    """A dense pre-burst planning grid: every (price drift x budget
    floor x budget) paper variant — 60 specs by default, ~1024 lanes at
    17 seeds.  Every member keeps the paper catalog and capacity, so the
    whole grid shares one structural batch key and ``engine="jax"``
    compiles it into a *single* scan (the batched numpy engine chunks it
    identically; it just ticks each lane from Python)."""
    return [paper_spec(
                name=f"grid-p{int(p * 100):03d}-f{int(f * 100):02d}"
                     f"-b{int(b / 1000)}k",
                price_scale=p, budget_floor_fraction=f, budget=b)
            for p in price_scales for f in floors for b in budgets]


def pareto_grid(curves: Sequence[Optional[str]] = (None, "drift-up",
                                                   "azure-squeeze"),
                slices: Sequence[int] = (1, 4),
                planes: Sequence[Optional[str]] = (None, "federated"),
                size_gb: float = 25.0) -> List[CampaignSpec]:
    """The cost-vs-goodput frontier candidate set: every (market curve
    x GPU slicing x data plane) paper variant — the axes the repo
    already prices (``MARKET_CURVES``, ``GpuSlicing``, ``DATA_PLANES``)
    composed into one sweepable grid for
    ``analysis.pareto.frontier()`` / the ``campaigns pareto`` CLI.
    ``None`` entries mean "paper baseline" on that axis; 12 specs by
    default."""
    from dataclasses import replace as _replace
    specs = []
    for c in curves:
        for k in slices:
            for plane in planes:
                kw = {}
                if c is not None:
                    curve = MARKET_CURVES[c]
                    if curve.provider is not None and k > 1:
                        # slicing renames catalog providers to "name/k";
                        # a provider-targeted curve must follow
                        curve = _replace(curve,
                                         provider=f"{curve.provider}/{k}")
                    kw["timeline"] = _sorted_timeline(*PAPER_TIMELINE,
                                                      curve)
                if k > 1:
                    kw["gpu_slicing"] = GpuSlicing(slices=k)
                if plane is not None:
                    kw["dataplane"] = DATA_PLANES[plane]
                    kw["job_input_gb"] = size_gb
                specs.append(paper_spec(
                    name=f"par-{c or 'flat'}-s{k}-{plane or 'nodata'}",
                    **kw))
    return specs


def default_suite() -> List[CampaignSpec]:
    """A representative pre-burst planning suite: the paper baseline plus
    one of each what-if family."""
    return [paper_baseline(),
            ondemand_fallback(),
            *spot_ondemand_mixes((0.25,)),
            heterogeneous_burst(),
            *outage_grid((60.0, 300.0), (6.0,)),
            *budget_floor_variants((0.3,)),
            *price_perturbations((0.8, 1.25)),
            *price_curve_scenarios(("drift-up", "azure-squeeze")),
            *workload_curve_scenarios(),
            *gpu_slicing_variants((4,)),
            *data_heavy_mix((25.0,)),
            *origin_outage_grid((60.0,), (6.0,)),
            *egress_cost_scenarios()]
