"""What-if scenario library for pre-burst planning sweeps.

The paper's headline numbers come from a *single* two-week run; HEPCloud-
style pre-burst planning (Holzman et al. 2017) and per-scenario cost
studies (Sfiligoi et al. 2022) want Monte-Carlo sweeps over seeds and
operational what-ifs.  A :class:`Scenario` is a frozen, declarative
description of one such campaign variant — catalog, spot/on-demand mix,
ramp schedule, outage timing, budget floor, price perturbation — that both
execution paths understand:

  * solo: :func:`run_scenario` drives one ``CloudSimulator`` campaign
    (the reference semantics), and
  * batched: ``core/sweep.py`` ticks many (scenario, seed) lanes in
    lock-step as one array program, bit-reproducible against the solo run
    at the same (seed, scenario).

``Scenario()`` with no arguments is exactly the paper replay
(``campaign.replay_paper_campaign``): T4 catalog, $58k budget, staged
ramp to 2k GPUs, the d10.5 CE outage, the 20 %-budget-floor downscale.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Sequence, Tuple

from repro.core.campaign import (OUTAGE_AT_H, OUTAGE_DURATION_H, PAPER_RAMP,
                                 POST_OUTAGE_TARGET, RampStage, run_campaign)
from repro.core.provider import (T4_FP32_TFLOPS, ProviderSpec, RegionSpec,
                                 heterogeneous_catalog, t4_catalog)
from repro.core.simulator import SimConfig


@dataclass(frozen=True)
class Scenario:
    """One campaign variant; defaults reproduce the paper replay."""
    name: str = "paper"
    catalog: str = "t4"                  # "t4" | "heterogeneous" (§III pool)
    capacity_scale: float = 1.0          # multiply every region's capacity
    spot: bool = True                    # spot (paper) vs on-demand pricing
    ondemand_fraction: float = 0.0       # carve this capacity share into
    #                                      preemption-free on-demand pools
    price_scale: float = 1.0             # uniform price-curve perturbation
    ramp: Tuple[RampStage, ...] = PAPER_RAMP
    outage: bool = True
    outage_at_h: float = OUTAGE_AT_H
    outage_duration_h: float = OUTAGE_DURATION_H
    resume_target: int = POST_OUTAGE_TARGET
    budget: float = 58000.0
    budget_floor_fraction: float = 0.2
    downscale_target: int = POST_OUTAGE_TARGET
    duration_h: float = 14 * 24.0
    dt_h: float = 0.25
    lease_interval_s: float = 120.0
    job_wall_h: float = 4.0
    job_checkpoint_h: float = 1.0
    min_queue: int = 4000
    overhead_per_day: float = 390.0
    accel_tflops: float = T4_FP32_TFLOPS


# -- catalog surgery ------------------------------------------------------

def _scale_capacity(cat: Dict[str, ProviderSpec],
                    f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, regions=tuple(
        replace(r, capacity=max(1, int(r.capacity * f)))
        for r in p.regions)) for name, p in cat.items()}


def _scale_prices(cat: Dict[str, ProviderSpec],
                  f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, spot_price_per_day=p.spot_price_per_day * f,
                          ondemand_price_per_day=p.ondemand_price_per_day * f)
            for name, p in cat.items()}


def _split_ondemand(cat: Dict[str, ProviderSpec],
                    frac: float) -> Dict[str, ProviderSpec]:
    """Carve ``frac`` of every region's capacity into a preemption-free
    on-demand pool (priced at the on-demand rate) alongside the remaining
    spot capacity — the spot/on-demand *mix* what-if: how much preemption
    churn does a reliability floor buy off, and at what $."""
    if frac <= 0.0:
        return cat
    out: Dict[str, ProviderSpec] = {}
    for name, p in cat.items():
        spot_regions = []
        od_regions = []
        for r in p.regions:
            od_cap = max(1, int(r.capacity * frac))
            spot_cap = max(1, r.capacity - od_cap)
            spot_regions.append(replace(r, capacity=spot_cap))
            od_regions.append(RegionSpec(r.name, od_cap, 0.0, 1.0))
        out[name] = replace(p, regions=tuple(spot_regions))
        out[f"{name}-od"] = replace(
            p, name=f"{p.name}-od",
            spot_price_per_day=p.ondemand_price_per_day,
            regions=tuple(od_regions))
    return out


def build_catalog(sc: Scenario) -> Dict[str, ProviderSpec]:
    if sc.catalog == "t4":
        cat = t4_catalog()
    elif sc.catalog == "heterogeneous":
        cat = heterogeneous_catalog()
    else:
        raise ValueError(f"unknown catalog {sc.catalog!r}")
    cat = _scale_capacity(cat, sc.capacity_scale)
    cat = _scale_prices(cat, sc.price_scale)
    cat = _split_ondemand(cat, sc.ondemand_fraction)
    return cat


def sim_config(sc: Scenario, seed: int) -> SimConfig:
    return SimConfig(duration_h=sc.duration_h, dt_h=sc.dt_h, seed=seed,
                     lease_interval_s=sc.lease_interval_s,
                     job_wall_h=sc.job_wall_h,
                     job_checkpoint_h=sc.job_checkpoint_h,
                     accel_tflops=sc.accel_tflops,
                     overhead_per_day=sc.overhead_per_day,
                     min_queue=sc.min_queue, spot=sc.spot)


def run_scenario(sc: Scenario, seed: int, engine=None):
    """Solo reference execution of one (scenario, seed) campaign; the
    batched sweep engine is pinned lane-by-lane against this
    (tests/test_sweep.py)."""
    return run_campaign(
        build_catalog(sc), budget=sc.budget, ramp=sc.ramp,
        sim_cfg=sim_config(sc, seed), engine=engine, outage=sc.outage,
        outage_at_h=sc.outage_at_h, outage_duration_h=sc.outage_duration_h,
        resume_target=sc.resume_target,
        budget_floor_fraction=sc.budget_floor_fraction,
        downscale_target=sc.downscale_target)


# -- the library ----------------------------------------------------------

def paper_baseline() -> Scenario:
    return Scenario()


def ondemand_fallback(budget: float = 58000.0) -> Scenario:
    """All on-demand: zero preemptions, ~4.4x the $/GPU-day — how far does
    the same budget get without spot risk?"""
    return Scenario(name="ondemand", spot=False, budget=budget)


def spot_ondemand_mixes(fracs: Sequence[float] = (0.1, 0.25, 0.5)
                        ) -> List[Scenario]:
    return [Scenario(name=f"mix-od{int(f * 100):02d}", ondemand_fraction=f)
            for f in fracs]


def heterogeneous_burst(capacity_scale: float = 1.0) -> Scenario:
    """The §III mixed T4/V100/P100/M60 pool under the paper's controller."""
    return Scenario(name="hetero", catalog="heterogeneous",
                    capacity_scale=capacity_scale)


def outage_grid(times_h: Sequence[float] = (60.0, 252.0, 300.0),
                durations_h: Sequence[float] = (2.0, 12.0)) -> List[Scenario]:
    """What if the CE had died earlier / stayed down longer?"""
    return [Scenario(name=f"outage-t{int(t)}-d{int(d)}",
                     outage_at_h=t, outage_duration_h=d)
            for t in times_h for d in durations_h]


def budget_floor_variants(floors: Sequence[float] = (0.1, 0.2, 0.3)
                          ) -> List[Scenario]:
    """How early the 'downscale to 1k' tripwire fires vs GPU-days kept."""
    return [Scenario(name=f"floor{int(f * 100):02d}",
                     budget_floor_fraction=f) for f in floors]


def price_perturbations(factors: Sequence[float] = (0.8, 1.0, 1.25)
                        ) -> List[Scenario]:
    """Uniform spot-price-curve shifts (market drift between planning and
    burst day)."""
    return [Scenario(name=f"price{int(f * 100):03d}", price_scale=f)
            for f in factors]


def default_suite() -> List[Scenario]:
    """A representative pre-burst planning suite: the paper baseline plus
    one of each what-if family."""
    return [paper_baseline(),
            ondemand_fallback(),
            *spot_ondemand_mixes((0.25,)),
            heterogeneous_burst(),
            *outage_grid((60.0, 300.0), (6.0,)),
            *budget_floor_variants((0.3,)),
            *price_perturbations((0.8, 1.25))]
