"""Data-plane model: per-provider origins, stage-in, cache tiers, egress.

The source paper treated jobs as pure compute, but the follow-on IceCube
work (arXiv 2308.07999) shows GPU workflows are gated by XRootD data
origins — stage-in latency, cache hit rates and origin availability
decide real goodput — while HEPCloud (arXiv 1710.00100) shows egress
charges are a first-order line item in any cloud cost answer.  This
module makes those surfaces first-class campaign inputs:

  * :class:`DataOrigin` — the origin serving one provider's regions:
    WAN bandwidth (Gbit/s), per-GB egress price, and an optional
    regional cache (hit rate + cache-tier bandwidth),
  * :class:`DataPlane` — the frozen spec surface: the provider ->
    origin map carried by ``CampaignSpec.dataplane``,
  * the shared stage math (:func:`stage_ticks`, :func:`cache_hit`,
    :func:`stage_decision`) — ONE float/int expression per quantity, so
    the solo-object, solo-array and batched engines stage and bill
    bit-identically (the same contract the ``((price/24) * shift) *
    curve`` billing rate already follows),
  * :class:`DataPlaneRuntime` — one campaign's mutable data-plane
    state: per-provider origin outage flags, cumulative degrade
    factors, cache-flush epochs, the per-tick egress miss counter the
    bill phase drains into the budget ledger, and the campaign totals
    behind the ``egress_usd`` / ``stagein_hours`` /
    ``cache_hit_fraction`` result columns.

Semantics (identical in every bit-exact engine):

  * a matched pilot first completes a **stage-in** of
    ``job_input_gb`` at the effective bandwidth — cache hits stream
    from the cache tier, misses from the origin (scaled by any
    ``OriginDegrade`` factors) — rounded up to whole ticks; the job
    makes no progress until the stage-in finishes, and a preempted or
    NAT-dropped pilot abandons the transfer (a re-match restarts it),
  * cache hits are deterministic per pilot: the k-th stage-in of a
    pilot hits iff ``floor((k+1)*r) > floor(k*r)`` — a rotation whose
    long-run hit frequency converges to ``r`` with error <= 1/k, with
    no RNG consumed (traces stay byte-identical with and without a
    recorder attached).  ``CacheFlush`` bumps the provider's epoch,
    lazily resetting every pilot's rotation,
  * each cache **miss** moves ``job_input_gb`` out of the origin's
    cloud: the bill phase charges ``gb * egress_usd_per_gb`` to the
    ledger next to the GPU-hour charges and emits one
    ``EgressBilled`` trace event per (tick, provider),
  * ``OriginOutage`` gates **new** matches for the affected provider's
    pilots (in-flight stage-ins keep streaming); other providers keep
    matching.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple, Union

__all__ = ["DataOrigin", "DataPlane", "stage_ticks", "cache_hit",
           "stage_decision", "DataPlaneRuntime"]


@dataclass(frozen=True)
class DataOrigin:
    """The data origin serving one provider's regions.

    ``bandwidth_gbps`` is the origin's WAN bandwidth in Gbit/s per
    pilot transfer; ``egress_usd_per_gb`` the provider's per-GB egress
    price for cache misses; ``cache_hit_rate`` in [0, 1] the fraction
    of stage-ins served by the regional cache (0 disables the cache);
    ``cache_bandwidth_gbps`` the cache tier's bandwidth (falls back to
    the origin bandwidth when 0 — a cache that only saves egress)."""
    bandwidth_gbps: float
    egress_usd_per_gb: float = 0.0
    cache_hit_rate: float = 0.0
    cache_bandwidth_gbps: float = 0.0


@dataclass(frozen=True)
class DataPlane:
    """The frozen spec surface: provider name -> :class:`DataOrigin`.

    Accepts a mapping or an iterable of (name, origin) pairs and
    normalizes to a name-sorted tuple so equal planes compare and
    serialize identically."""
    origins: Tuple[Tuple[str, DataOrigin], ...] = ()

    def __post_init__(self):
        items = (self.origins.items()
                 if isinstance(self.origins, Mapping) else self.origins)
        norm = []
        for name, origin in items:
            if isinstance(origin, Mapping):
                origin = DataOrigin(**origin)
            norm.append((str(name), origin))
        norm.sort(key=lambda kv: kv[0])
        object.__setattr__(self, "origins", tuple(norm))

    def origin_for(self, provider: str) -> Optional[DataOrigin]:
        """The origin serving ``provider`` (sliced pools like
        ``azure/4`` inherit their base provider's origin), or None."""
        base = provider.split("/", 1)[0]
        for name, origin in self.origins:
            if name == provider or name == base:
                return origin
        return None

    def providers(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.origins)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        from dataclasses import asdict
        return {"origins": {name: asdict(o) for name, o in self.origins}}

    @classmethod
    def from_dict(cls, d: Mapping) -> "DataPlane":
        d = dict(d)
        origins = d.pop("origins", {})
        if d:
            raise ValueError(f"unknown DataPlane fields {sorted(d)}")
        items = origins.items() if isinstance(origins, Mapping) else origins
        return cls(tuple((name, DataOrigin(**dict(o)))
                         for name, o in items))


# -- the shared stage math (one expression, every engine) ------------------

def stage_ticks(size_gb: float, gbps: float, dt_h: float) -> int:
    """Whole ticks to stage ``size_gb`` at ``gbps``: transfer hours =
    GB * 8 bits / (Gbit/s) / 3600, rounded up to ticks (>= 1 for any
    positive transfer — a job never starts the tick it matched)."""
    if size_gb <= 0.0 or gbps <= 0.0 or dt_h <= 0.0:
        return 0
    hours = size_gb * 8.0 / gbps / 3600.0
    return max(1, int(math.ceil(hours / dt_h - 1e-9)))


def cache_hit(k: int, rate: float) -> bool:
    """Deterministic cache-hit rotation: the k-th (0-based) stage-in of
    a pilot hits iff the integer part of ``k * rate`` advances — hit
    frequency converges to ``rate`` with error <= 1/k, RNG-free."""
    if rate <= 0.0:
        return False
    if rate >= 1.0:
        return True
    return int((k + 1) * rate) > int(k * rate)


def stage_decision(origin: DataOrigin, degrade: float, size_gb: float,
                   dt_h: float, k: int) -> Tuple[int, bool]:
    """The k-th stage-in of one pilot against ``origin`` under the
    cumulative ``degrade`` bandwidth factor -> (ticks, cache_hit)."""
    hit = cache_hit(k, origin.cache_hit_rate)
    if hit:
        gbps = origin.cache_bandwidth_gbps \
            if origin.cache_bandwidth_gbps > 0.0 else origin.bandwidth_gbps
    else:
        gbps = origin.bandwidth_gbps * degrade
    return stage_ticks(size_gb, gbps, dt_h), hit


# -- one campaign's mutable data-plane state -------------------------------

class DataPlaneRuntime:
    """Per-campaign (per-lane) data-plane bookkeeping, engine-shared.

    Engines call :meth:`decide` at match time (stage length + cache-hit
    provenance + egress miss metering) and :meth:`bill` in their bill
    phase (drains the per-tick miss counter into the ledger, in sorted
    provider order, after the GPU-hour charges).  The ``OriginOutage``
    / ``OriginDegrade`` / ``CacheFlush`` timeline ops land on
    :meth:`set_outage` / :meth:`degrade_origin` / :meth:`flush_cache`.
    All state is plain Python ints/floats: identical across engines."""

    __slots__ = ("plane", "size_gb", "dt_h", "down", "degrade", "epoch",
                 "pending", "hits", "misses", "staged_ticks",
                 "egress_usd")

    def __init__(self, plane: Optional[DataPlane], job_input_gb: float,
                 dt_h: float):
        self.plane = plane if plane is not None else DataPlane()
        self.size_gb = float(job_input_gb)
        self.dt_h = float(dt_h)
        self.down: Dict[str, bool] = {}
        self.degrade: Dict[str, float] = {}
        self.epoch: Dict[str, int] = {}
        self.pending: Dict[str, int] = {}     # provider -> misses this tick
        self.hits = 0
        self.misses = 0
        self.staged_ticks = 0
        self.egress_usd = 0.0

    @property
    def active(self) -> bool:
        """Whether any data-plane behavior is possible at all."""
        return bool(self.plane.origins)

    @property
    def staging(self) -> bool:
        """Whether matches actually stage data (origins declared AND a
        positive job input size) — zero-input campaigns skip the stage
        machinery entirely, in every engine."""
        return self.size_gb > 0.0 and bool(self.plane.origins)

    # -- match-time hooks --------------------------------------------------
    def eligible(self, provider: str) -> bool:
        """Whether ``provider`` pilots may take NEW jobs (its origin is
        not in outage; providers without a declared origin always are)."""
        return not self.down.get(self._base(provider), False)

    def decide(self, provider: str, k: int) -> Tuple[int, bool]:
        """Stage decision for the k-th stage-in of a ``provider`` pilot:
        (ticks, cache_hit); meters a miss into the pending egress
        counter.  Providers without a declared origin stage nothing."""
        base = self._base(provider)
        origin = self.plane.origin_for(base)
        if origin is None:
            return 0, False
        ticks, hit = stage_decision(origin, self.degrade.get(base, 1.0),
                                    self.size_gb, self.dt_h, k)
        if hit:
            self.hits += 1
        else:
            self.misses += 1
            if self.size_gb > 0.0:
                self.pending[base] = self.pending.get(base, 0) + 1
        return ticks, hit

    def current_epoch(self, provider: str) -> int:
        return self.epoch.get(self._base(provider), 0)

    # -- bill-phase hook ---------------------------------------------------
    def bill(self, ledger, now: float, recorder=None) -> float:
        """Charge this tick's cache-miss egress to the ledger (sorted
        provider order — deterministic and engine-identical) and emit
        one EgressBilled trace event per provider; returns the $."""
        total = 0.0
        for base in sorted(self.pending):
            count = self.pending[base]
            if count <= 0:
                continue
            origin = self.plane.origin_for(base)
            # gb = size * int count, usd = gb * price: the exact scalar
            # float ops every engine shares (trace values byte-identical)
            gb = self.size_gb * count
            usd = gb * origin.egress_usd_per_gb
            if usd > 0.0 and ledger is not None:
                ledger.charge(base, usd, now, note="egress")
            if recorder is not None:
                recorder.egress_billed(now, base, gb, usd)
            self.egress_usd += usd
            total += usd
        self.pending.clear()
        return total

    # -- timeline ops ------------------------------------------------------
    def set_outage(self, provider: str, on: bool):
        self.down[self._base(provider)] = bool(on)

    def degrade_origin(self, provider: str, factor: float):
        base = self._base(provider)
        self.degrade[base] = self.degrade.get(base, 1.0) * float(factor)

    def flush_cache(self, provider: str):
        base = self._base(provider)
        self.epoch[base] = self.epoch.get(base, 0) + 1

    # -- results -----------------------------------------------------------
    def results(self) -> dict:
        """The three data-plane result columns (0-defaults when the
        campaign has no data plane), rounded like their $/hour peers."""
        attempts = self.hits + self.misses
        return {
            "egress_usd": round(self.egress_usd, 2),
            "stagein_hours": round(self.staged_ticks * self.dt_h, 1),
            "cache_hit_fraction": round(self.hits / attempts, 4)
            if attempts else 0.0,
        }

    @staticmethod
    def _base(provider: str) -> str:
        """Sliced pools (``azure/4``) share their base provider's
        origin, outage state and egress meter."""
        return provider.split("/", 1)[0]
