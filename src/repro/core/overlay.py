"""OSG Compute Element + glideinWMS-style overlay workload management.

Federation principle (paper §II): resources — wherever provisioned — run a
standard pilot that registers with a single Compute Element; user jobs only
ever see the CE. The CE matchmaker hands queued jobs to idle pilots holding
a live lease.

Leases model the HTCondor TCP connections: a pilot renews its lease every
``lease_interval_s``; if the instance's provider NAT drops idle connections
sooner (Azure: 240 s) the pilot is disconnected and its job preempted — the
paper's one real operational bug, reproduced and regression-tested
(tests/test_overlay.py). The fix is the paper's fix: configure
``lease_interval_s`` below the provider NAT timeout.

Invariants (property-tested):
  * a job is never running on a pilot without a live lease
  * a pilot runs at most one job; a job runs on at most one pilot
  * every preempted job returns to the queue (nothing is lost silently)
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Job:
    id: int
    wall_h: float                     # remaining work (checkpoint-aware)
    policy: str = "icecube"           # CE access policy tag
    checkpoint_period_h: float = 1.0  # work is durable in these increments
    done_h: float = 0.0
    attempts: int = 0
    finished_at: Optional[float] = None

    @property
    def finished(self) -> bool:
        return self.finished_at is not None


@dataclass
class Pilot:
    id: int
    instance_id: int
    provider: str
    lease_interval_s: float
    nat_timeout_s: float
    registered_at: float = 0.0
    last_renew: float = 0.0
    job: Optional[Job] = None
    dead: bool = False
    # data-plane stage-in state: whole ticks left on the current
    # transfer, this pilot's cache-hit rotation counter, and the
    # CacheFlush epoch the counter belongs to (core/dataplane.py)
    stage_left: int = 0
    stage_k: int = 0
    stage_epoch: int = 0

    @property
    def connected(self) -> bool:
        """Registration always succeeds (the initial TCP handshake is not
        idle); the connection SURVIVES a running job only if lease renewals
        beat the NAT idle timeout — the drop manifests mid-job, exactly as
        the paper observed ('constant preemption of the user jobs')."""
        return self.lease_interval_s < self.nat_timeout_s

    @property
    def idle(self) -> bool:
        return not self.dead and self.job is None


class ComputeElement:
    """HTCondor-CE analogue with a single stated policy (paper §II:
    'registered it in OSG with the stated policy of only accepting IceCube
    jobs')."""

    def __init__(self, accept_policy: str = "icecube",
                 lease_interval_s: float = 120.0, recorder=None,
                 dataplane=None):
        self.accept_policy = accept_policy
        self.lease_interval_s = lease_interval_s
        # optional events.TraceRecorder; RNG-free, attaching it never
        # changes the campaign
        self.recorder = recorder
        # optional dataplane.DataPlaneRuntime: stage-in lengths, origin
        # outage gating and egress metering (None = pure compute)
        self.dataplane = dataplane
        self.queue: collections.deque = collections.deque()
        self.pilots: Dict[int, Pilot] = {}
        self.finished: List[Job] = []
        self.preemption_events = 0
        self.nat_drop_events = 0
        self._pilot_ids = 0
        self._job_ids = 0
        self.outage = False

    # -- job / pilot lifecycle -------------------------------------------
    def next_job_id(self) -> int:
        """Monotonic job-ID source. The CE owns the counter so IDs stay
        unique across re-queues: deriving IDs from queue+finished lengths
        (the seed formula) ignored jobs currently attached to pilots and
        could collide."""
        self._job_ids += 1
        return self._job_ids

    def submit(self, job: Job):
        if job.policy != self.accept_policy:
            raise PermissionError(
                f"CE policy {self.accept_policy!r} rejects {job.policy!r}")
        self._job_ids = max(self._job_ids, job.id)
        self.queue.append(job)

    def register_pilot(self, instance_id: int, provider: str,
                       nat_timeout_s: float, now_h: float) -> Pilot:
        self._pilot_ids += 1
        p = Pilot(self._pilot_ids, instance_id, provider,
                  self.lease_interval_s, nat_timeout_s,
                  registered_at=now_h, last_renew=now_h)
        self.pilots[p.id] = p
        if self.recorder is not None:
            self.recorder.pilot_registered(now_h, p.id, instance_id,
                                           provider)
        return p

    def pilot_lost(self, pilot_id: int, now_h: float):
        """Instance preempted / NAT dropped: job returns to queue; work since
        the last checkpoint is lost (graceful spot handling, paper §II)."""
        p = self.pilots.get(pilot_id)
        if p is None or p.dead:
            return
        p.dead = True
        if p.job is not None and not p.job.finished:
            j = p.job
            j.done_h = (j.done_h // j.checkpoint_period_h) \
                * j.checkpoint_period_h
            self.queue.appendleft(j)
            self.preemption_events += 1
        p.job = None
        p.stage_left = 0       # an abandoned transfer restarts on re-match

    # -- matchmaking / progress -------------------------------------------
    def match(self, now_h: float) -> int:
        """Assign queued jobs to idle connected pilots. Returns #matches."""
        if self.outage:
            return 0
        dp = self.dataplane
        gate = dp is not None and dp.active
        n = 0
        for p in self.pilots.values():
            if not self.queue:
                break
            if not p.idle:
                continue
            if gate and not dp.eligible(p.provider):
                continue         # origin outage: no NEW matches here
            job = self.queue.popleft()
            job.attempts += 1
            p.job = job
            n += 1
            if dp is not None and dp.staging:
                epoch = dp.current_epoch(p.provider)
                if p.stage_epoch != epoch:   # CacheFlush: rotation resets
                    p.stage_epoch = epoch
                    p.stage_k = 0
                ticks, hit = dp.decide(p.provider, p.stage_k)
                p.stage_k += 1
                p.stage_left = ticks
                if ticks > 0 and self.recorder is not None:
                    self.recorder.stagein_started(now_h, p.id, dp.size_gb,
                                                  hit, p.provider)
        return n

    def advance(self, dt_h: float, now_h: float):
        """Progress running jobs by dt; handle NAT-dropped pilots."""
        for p in list(self.pilots.values()):
            if p.dead:
                continue
            if not p.connected and p.job is not None:
                # idle TCP connection outlived the NAT timeout mid-job
                self.nat_drop_events += 1
                if self.recorder is not None:
                    self.recorder.nat_drop(now_h, p.id, p.instance_id,
                                           p.provider)
                self.pilot_lost(p.id, now_h)
                continue
            if p.job is not None and p.stage_left > 0:
                # stage-in burns the tick; the job starts after it
                p.stage_left -= 1
                if self.dataplane is not None:
                    self.dataplane.staged_ticks += 1
                if p.stage_left == 0 and self.recorder is not None:
                    self.recorder.stagein_finished(now_h, p.id)
                continue
            if p.job is not None:
                j = p.job
                j.done_h += dt_h
                if j.done_h >= j.wall_h:
                    j.finished_at = now_h
                    self.finished.append(j)
                    if self.recorder is not None:
                        self.recorder.job_finished(now_h, j.id, j.attempts)
                    p.job = None

    # -- views ---------------------------------------------------------------
    def busy_by_provider(self) -> Dict[str, int]:
        """#pilots currently running a job, per provider (feeds the
        heterogeneous-catalog EFLOP accounting)."""
        out: Dict[str, int] = {}
        for p in self.pilots.values():
            if not p.dead and p.job is not None:
                out[p.provider] = out.get(p.provider, 0) + 1
        return out

    def stats(self) -> dict:
        live = [p for p in self.pilots.values() if not p.dead]
        return {"pilots_live": len(live),
                "pilots_busy": sum(1 for p in live if p.job is not None),
                "queued": len(self.queue),
                "finished": len(self.finished),
                "preemptions": self.preemption_events,
                "nat_drops": self.nat_drop_events}
