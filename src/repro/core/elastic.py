"""Elastic pod-pool -> mesh management: the TPU adaptation of the paper's
elastic VM fleet (DESIGN.md §2).

The provisioning unit is a pod slice. Preemption granularity ==
provisioning granularity == the "pod" mesh axis, so synchronous SPMD
training survives fleet changes by:

  1. PodPool: membership ledger fed by the provisioner/pilots (join, leave,
     preemption-notice) with listener callbacks,
  2. ElasticRunner: on membership change — drain (finish current step),
     checkpoint (async copy already on host most of the time), rebuild the
     mesh for the new pod count, re-shard state (device_put with new
     shardings; checkpoints are sharding-agnostic), re-jit (compile cache
     keyed by pod count), resume at the same global batch size.

Goodput accounting mirrors the paper's operational stance: preempted work
since the last checkpoint is lost, everything else is durable.

The simulator side connects here through the typed event-trace API:
``drive_pool(trace, pool, runner)`` replays a campaign's
preemption/join stream (``api.run(spec, collect="trace")`` ->
``CampaignResult.trace``) into a :class:`PodPool` + runner, turning any
what-if spec from ``core/scenarios.py`` into an elastic-training
goodput study (:class:`GoodputReport`) with no new glue.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro import sharding as sh
from repro.launch.mesh import make_elastic_mesh


@dataclass
class PodPool:
    """Membership of healthy pods (slices). Thread-free; callers drive it."""
    min_pods: int = 1
    max_pods: int = 64
    pods: Dict[str, float] = field(default_factory=dict)  # id -> joined_at
    draining: Dict[str, float] = field(default_factory=dict)
    listeners: List[Callable[[int], None]] = field(default_factory=list)
    rejected_joins: int = 0      # joins refused because the pool was full

    def on_change(self, cb: Callable[[int], None]):
        self.listeners.append(cb)

    def _notify(self):
        n = self.size
        for cb in self.listeners:
            cb(n)

    @property
    def size(self) -> int:
        return len(self.pods)

    def join(self, pod_id: str, now: float = 0.0) -> bool:
        """Admit a pod; returns whether membership actually changed.
        A join refused at ``max_pods`` is observable (False +
        ``rejected_joins``) so capacity-bound provisioning loops can see
        the clip instead of silently over-offering."""
        if pod_id in self.pods:
            return False
        if len(self.pods) >= self.max_pods:
            self.rejected_joins += 1
            return False
        self.pods[pod_id] = now
        self._notify()
        return True

    def preemption_notice(self, pod_id: str, now: float = 0.0):
        """Cloud 30s-2min warning: mark draining; runner checkpoints before
        the pod disappears."""
        if pod_id in self.pods:
            self.draining[pod_id] = now

    def leave(self, pod_id: str, now: float = 0.0):
        self.draining.pop(pod_id, None)
        if self.pods.pop(pod_id, None) is not None:
            self._notify()


class ElasticRunner:
    """Owns sharded train state across pod-count changes."""

    def __init__(self, step_builder, params_host, opt_host, *,
                 pod_shape=(16, 16), checkpointer=None):
        """step_builder(mesh) -> jitted (params, opt, batch) -> (p', o', m).
        params_host/opt_host: host (numpy) trees — the sharding-agnostic
        source of truth at rebuild time."""
        self.step_builder = step_builder
        self.pod_shape = pod_shape
        self.checkpointer = checkpointer
        self._host = {"params": params_host, "opt": opt_host}
        self._jit_cache = {}
        self.mesh = None
        self.params = None
        self.opt = None
        self.n_pods = 0
        self.rebuilds = 0
        self.lost_steps = 0
        # last rebuild's wall time; initialized so reading it before the
        # first ensure() is 0.0, not an AttributeError
        self.rebuild_s = 0.0

    # -- (re)build ------------------------------------------------------------
    def ensure(self, n_pods: int, force: bool = False):
        """Drain/checkpoint/rebuild for ``n_pods`` pods; no-op when the
        count is unchanged.  ``force=True`` rebuilds even at the same
        count — a same-size member *swap* (pod preempted, replacement
        joined) changes the device set, so the mesh and its compiled
        step must re-form."""
        if not force and n_pods == self.n_pods and self.mesh is not None:
            return False
        # real-runner wall clock: rebuild_s measures the actual JAX
        # drain/reshard, not simulated time
        t0 = time.time()        # staticcheck: ignore[RNG003]
        if self.params is not None:
            # drain: pull current state to host before the fleet changes
            self._host = {"params": jax.device_get(self.params),
                          "opt": jax.device_get(self.opt)}
        self.mesh = make_elastic_mesh(n_pods, pod_shape=self.pod_shape)
        psh = sh.param_shardings(self._host["params"], self.mesh)
        osh = sh.opt_shardings(self._host["opt"], self.mesh)
        self.params = jax.device_put(self._host["params"], psh)
        self.opt = jax.device_put(self._host["opt"], osh)
        if force or n_pods not in self._jit_cache:
            # forced rebuilds mean a new device set: a cached step
            # compiled against the old mesh would be stale
            self._jit_cache[n_pods] = self.step_builder(self.mesh)
        self.n_pods = n_pods
        self.rebuilds += 1
        self.rebuild_s = time.time() - t0   # staticcheck: ignore[RNG003]
        return True

    def step(self, batch):
        fn = self._jit_cache[self.n_pods]
        self.params, self.opt, metrics = fn(self.params, self.opt, batch)
        return metrics

    def checkpoint(self, step):
        if self.checkpointer is not None:
            self.checkpointer.save_async(
                step, {"params": self.params, "opt": self.opt})

    def handle_preemption(self, step):
        """Preemption notice: durable state NOW (blocking — the pod may
        vanish in 30 s)."""
        if self.checkpointer is not None:
            self.checkpointer.save_blocking(
                step, {"params": self.params, "opt": self.opt})


class SimulatedElasticRunner:
    """Accounting-only stand-in for :class:`ElasticRunner`: the same
    counters and control surface ``drive_pool`` needs (``ensure`` /
    ``handle_preemption`` / ``rebuilds`` / ``rebuild_s`` /
    ``lost_steps``), with a fixed per-rebuild cost instead of real
    mesh/re-shard work — so campaign traces replay into elastic-training
    what-ifs without devices.  Swap in a real ``ElasticRunner`` and the
    same ``drive_pool`` call drives actual mesh rebuilds."""

    def __init__(self, *, rebuild_s: float = 30.0):
        self._fixed_rebuild_s = rebuild_s
        self.n_pods = 0
        self.rebuilds = 0
        self.lost_steps = 0
        self.rebuild_s = 0.0
        self.checkpoints = 0
        self.blocking_checkpoints = 0

    def ensure(self, n_pods: int, force: bool = False) -> bool:
        if not force and n_pods == self.n_pods:
            return False
        self.n_pods = n_pods
        self.rebuilds += 1
        self.rebuild_s = self._fixed_rebuild_s
        return True

    def checkpoint(self, step):
        self.checkpoints += 1

    def handle_preemption(self, step):
        """Preemption-notice response: one blocking checkpoint."""
        self.blocking_checkpoints += 1


@dataclass(frozen=True)
class GoodputReport:
    """Elastic-training accounting for one replayed campaign trace.

    Steps are global synchronous-SPMD steps; ``goodput_fraction``
    compares net completed steps against an ideal uninterrupted run of
    the same wall-clock length (so fleet-empty gaps — e.g. a CE outage
    — and rebuild downtime and lost work all show up as goodput)."""
    wall_h: float
    pod_hours: float
    steps_done: float
    steps_lost: float
    rebuilds: int
    rebuild_downtime_s: float
    preemptions: int
    graceful_leaves: int
    joins: int
    joins_rejected: int
    peak_pods: int
    goodput_fraction: float

    def to_dict(self) -> dict:
        return asdict(self)


def drive_pool(trace, pool: PodPool, runner, *, step_time_s: float = 2.0,
               checkpoint_period_s: float = 600.0, notice: bool = True,
               providers: Optional[tuple] = None) -> GoodputReport:
    """Replay a campaign's instance stream into an elastic pod pool.

    ``trace`` is a :class:`~repro.core.events.CampaignTrace`
    (``api.run(spec, collect="trace")``); every ``InstanceLaunched``
    offers a pod to ``pool`` (clips observably at ``max_pods``), every
    ``InstancePreempted`` runs the preemption-notice path
    (notice -> blocking checkpoint -> leave -> drain/rebuild via
    ``runner.ensure``), and every ``InstanceStopped`` is a graceful
    leave.  Between events the global training step advances whenever
    the pool holds at least ``pool.min_pods`` pods, minus pending
    rebuild downtime; async checkpoints land every
    ``checkpoint_period_s`` of progress.

    ``notice=True`` models the cloud's 30 s-2 min warning being honored
    (checkpoint completes, nothing is lost); ``notice=False`` models
    hard kills — work since the last periodic checkpoint is lost, the
    simulator's own ``checkpoint_floor`` stance.  ``providers``
    optionally restricts which trace instances become pods (e.g. only
    the on-demand carve-out).  Membership changes sharing one timestamp
    coalesce into a single drain -> rebuild (``runner.ensure(size,
    force=True)``), mirroring how a staged ramp joins hundreds of pods
    behind one mesh rebuild — and a same-size member *swap*
    (k preemptions + k replacement launches in one tick) still rebuilds:
    the device set changed even though the pod count did not.
    """
    from repro.core.events import (InstanceLaunched, InstancePreempted,
                                   InstanceStopped)
    from repro.core.fleet import checkpoint_floor

    ckpt_steps = max(checkpoint_period_s, step_time_s) / step_time_s
    min_active = max(1, pool.min_pods)
    steps = 0.0
    lost = 0.0
    last_ckpt = 0.0
    pod_hours = 0.0
    downtime_pending = 0.0
    downtime_total = 0.0
    joins = rejected = preempts = leaves = peak = rebuilds = 0
    t = 0.0

    def advance(to_h: float):
        nonlocal t, steps, last_ckpt, pod_hours, downtime_pending
        dt_h = to_h - t
        if dt_h <= 0:
            return
        pod_hours += pool.size * dt_h
        if pool.size >= min_active:
            active_s = dt_h * 3600.0
            used = min(downtime_pending, active_s)
            downtime_pending -= used
            steps += (active_s - used) / step_time_s
            last_ckpt = max(last_ckpt,
                            float(checkpoint_floor(steps, ckpt_steps)))
        t = to_h

    evs = trace.events
    i, n = 0, len(evs)
    while i < n:
        t_ev = evs[i].t
        advance(t_ev)
        changed = False            # any membership churn this timestamp
        while i < n and evs[i].t == t_ev:
            ev = evs[i]
            i += 1
            if isinstance(ev, InstanceLaunched):
                if providers is not None and ev.provider not in providers:
                    continue
                pod_id = f"i{ev.instance}"
                if pod_id in pool.pods:      # idempotent re-offer, not a
                    continue                 # capacity refusal
                if pool.join(pod_id, now=t_ev):
                    joins += 1
                    changed = True
                else:
                    rejected += 1
            elif isinstance(ev, InstancePreempted):
                pod_id = f"i{ev.instance}"
                if pod_id not in pool.pods:
                    continue
                preempts += 1
                changed = True
                pool.preemption_notice(pod_id, t_ev)
                if notice:
                    runner.handle_preemption(int(steps))
                    last_ckpt = steps
                else:
                    dropped = steps - last_ckpt
                    lost += dropped
                    steps = last_ckpt
                    runner.lost_steps += int(dropped)
                pool.leave(pod_id, t_ev)
            elif isinstance(ev, InstanceStopped):
                pod_id = f"i{ev.instance}"
                if pod_id in pool.pods:
                    leaves += 1
                    changed = True
                    pool.leave(pod_id, t_ev)
        peak = max(peak, pool.size)
        if changed and pool.size >= min_active:
            # any membership change re-forms the mesh — force covers the
            # same-size member swap, where the device set changed but
            # the pod count did not
            if runner.ensure(pool.size, force=True):
                rebuilds += 1
                downtime_pending += runner.rebuild_s
                downtime_total += runner.rebuild_s
    advance(trace.duration_h)
    ideal_steps = trace.duration_h * 3600.0 / step_time_s
    return GoodputReport(
        wall_h=round(trace.duration_h, 2),
        pod_hours=round(pod_hours, 1),
        steps_done=round(steps, 1),
        steps_lost=round(lost, 1),
        rebuilds=rebuilds,
        rebuild_downtime_s=round(downtime_total, 1),
        preemptions=preempts,
        graceful_leaves=leaves,
        joins=joins,
        joins_rejected=rejected,
        peak_pods=peak,
        goodput_fraction=round(steps / max(ideal_steps, 1e-9), 4))
