"""Elastic pod-pool -> mesh management: the TPU adaptation of the paper's
elastic VM fleet (DESIGN.md §2).

The provisioning unit is a pod slice. Preemption granularity ==
provisioning granularity == the "pod" mesh axis, so synchronous SPMD
training survives fleet changes by:

  1. PodPool: membership ledger fed by the provisioner/pilots (join, leave,
     preemption-notice) with listener callbacks,
  2. ElasticRunner: on membership change — drain (finish current step),
     checkpoint (async copy already on host most of the time), rebuild the
     mesh for the new pod count, re-shard state (device_put with new
     shardings; checkpoints are sharding-agnostic), re-jit (compile cache
     keyed by pod count), resume at the same global batch size.

Goodput accounting mirrors the paper's operational stance: preempted work
since the last checkpoint is lost, everything else is durable.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro import sharding as sh
from repro.launch.mesh import make_elastic_mesh


@dataclass
class PodPool:
    """Membership of healthy pods (slices). Thread-free; callers drive it."""
    min_pods: int = 1
    max_pods: int = 64
    pods: Dict[str, float] = field(default_factory=dict)  # id -> joined_at
    draining: Dict[str, float] = field(default_factory=dict)
    listeners: List[Callable[[int], None]] = field(default_factory=list)

    def on_change(self, cb: Callable[[int], None]):
        self.listeners.append(cb)

    def _notify(self):
        n = self.size
        for cb in self.listeners:
            cb(n)

    @property
    def size(self) -> int:
        return len(self.pods)

    def join(self, pod_id: str, now: float = 0.0):
        if pod_id not in self.pods and \
                len(self.pods) < self.max_pods:
            self.pods[pod_id] = now
            self._notify()

    def preemption_notice(self, pod_id: str, now: float = 0.0):
        """Cloud 30s-2min warning: mark draining; runner checkpoints before
        the pod disappears."""
        if pod_id in self.pods:
            self.draining[pod_id] = now

    def leave(self, pod_id: str, now: float = 0.0):
        self.draining.pop(pod_id, None)
        if self.pods.pop(pod_id, None) is not None:
            self._notify()


class ElasticRunner:
    """Owns sharded train state across pod-count changes."""

    def __init__(self, step_builder, params_host, opt_host, *,
                 pod_shape=(16, 16), checkpointer=None):
        """step_builder(mesh) -> jitted (params, opt, batch) -> (p', o', m).
        params_host/opt_host: host (numpy) trees — the sharding-agnostic
        source of truth at rebuild time."""
        self.step_builder = step_builder
        self.pod_shape = pod_shape
        self.checkpointer = checkpointer
        self._host = {"params": params_host, "opt": opt_host}
        self._jit_cache = {}
        self.mesh = None
        self.params = None
        self.opt = None
        self.n_pods = 0
        self.rebuilds = 0
        self.lost_steps = 0

    # -- (re)build ------------------------------------------------------------
    def ensure(self, n_pods: int):
        if n_pods == self.n_pods and self.mesh is not None:
            return False
        t0 = time.time()
        if self.params is not None:
            # drain: pull current state to host before the fleet changes
            self._host = {"params": jax.device_get(self.params),
                          "opt": jax.device_get(self.opt)}
        self.mesh = make_elastic_mesh(n_pods, pod_shape=self.pod_shape)
        psh = sh.param_shardings(self._host["params"], self.mesh)
        osh = sh.opt_shardings(self._host["opt"], self.mesh)
        self.params = jax.device_put(self._host["params"], psh)
        self.opt = jax.device_put(self._host["opt"], osh)
        if n_pods not in self._jit_cache:
            self._jit_cache[n_pods] = self.step_builder(self.mesh)
        self.n_pods = n_pods
        self.rebuilds += 1
        self.rebuild_s = time.time() - t0
        return True

    def step(self, batch):
        fn = self._jit_cache[self.n_pods]
        self.params, self.opt, metrics = fn(self.params, self.opt, batch)
        return metrics

    def checkpoint(self, step):
        if self.checkpointer is not None:
            self.checkpointer.save_async(
                step, {"params": self.params, "opt": self.opt})

    def handle_preemption(self, step):
        """Preemption notice: durable state NOW (blocking — the pod may
        vanish in 30 s)."""
        if self.checkpointer is not None:
            self.checkpointer.save_blocking(
                step, {"params": self.params, "opt": self.opt})
