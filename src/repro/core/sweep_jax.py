"""JAX-compiled Monte-Carlo sweep engine: B campaigns as one lax.scan.

``engine="jax"`` is the fourth engine behind :func:`repro.core.api.run`.
Where the numpy batched engine (core/sweep.py) mutates dynamic
per-instance row sets from Python each tick, this engine compiles the
whole campaign to one jitted ``lax.scan`` over ticks with lane-parallel
*count-plane* state.  Instances within a (lane, group, progress-step)
cell are exchangeable — same hazard, same hourly rate, same matcher
treatment — so the state is how many instances occupy each cell, not
which: ``idle``/``pilot-dead`` counts per (lane, group), ``busy`` job
counts per (lane, group, dt-progress-step), the CE queue as per-lane
checkpoint-level counts, and budgets/counters as lane columns.  That
makes every per-tick phase a fixed-shape integer reduction, which is
what lets one compiled scan replace ~1e6 Python-driven row updates and
makes 1024-lane planning grids routine.  Per-lane randomness is
``threefry`` (fold the tick index into each lane's key), not PCG64.

The hot per-tick ops — preemption fan-out, the queue->pilot matcher,
pilot progress sync, the billing/ledger reduction — are the Pallas
kernels in kernels/campaign_sweep.py (``use_pallas=True``, default on
TPU); on CPU the engine runs their jnp oracles from kernels/ref.py
directly (the kernels' interpret mode is pinned equal in
tests/test_kernels.py).

**The compiled-timeline segment splitter.**  ``lax.scan`` cannot branch
on Python timeline events mid-trace, so the spec timeline is compiled
(via the core/timeline.py registry) into *segments*: the union of all
lanes' event fire ticks splits the campaign into spans of constant
control parameters, and every per-segment parameter plane (rates, caps,
outage, floor arming, workload level, scale targets) is precomputed by
driving a :class:`JaxLaneOps` adapter — a full ``EngineOps``
implementation over planner state — through the registry's own
``apply_op`` bodies.  The scan then just gathers ``plane[seg_of_tick]``.
The one data-dependent event, the budget-floor cap, is handled in-scan:
each lane carries ``capped`` / ``cap_pending`` flags and its per-group
target vector, and scale targets come in *uncapped and capped* plane
pairs (the capped pair built with ``budget_capped=True``, so the
registry's own ``min(target, downscale)`` logic — and the
``outage_off`` exemption from it — is reused, not re-implemented).

**Equivalence tier: statistical, not bit-identical.**  The numpy
batched engine is pinned bit-identical to the solo engines; this engine
intentionally is not — per-group Poisson preemption totals with a
proportional systematic split replace per-instance PCG64 Bernoulli
draws, proportional allocation replaces row-age ordering for event
kills and pilot-order matching, and simultaneous same-tick scale chains
apply their net target.  The contract is
``tests/engine_equivalence.assert_statistically_equivalent``:
mean/p5/p95 bands on cost, GPU-days and jobs against the batched
engine over ``scenarios.default_suite`` (see README "Simulation
engines").  Event provenance is *not* statistical: ``events_fired`` is
reconstructed post-scan through the same registry records and matches
the other engines' schema exactly.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timeline as timeline_registry
from repro.core.spec import CampaignSpec
from repro.core.sweep import _Lane, _THRESHOLDS, _prepare

__all__ = ["JaxLaneOps", "JaxSweepEngine", "run_jax_detailed", "run_jax"]


class JaxLaneOps:
    """One lane's :class:`~repro.core.timeline.EngineOps` adapter over
    *planner* state (prices, caps, targets, floor arming) instead of a
    live fleet.  The segment splitter drives it through the registry's
    shared ``apply`` bodies to precompute per-segment parameter planes —
    once with ``budget_capped=False`` and once ``=True`` so the scan can
    select the right scale target after a lane's floor fires — and the
    post-scan provenance pass drives it again to reconstruct
    ``events_fired`` records identical to the other engines'."""

    budget_capped = False
    downscale_target = 0

    def __init__(self, spec: CampaignSpec, pairs,
                 budget_capped: bool = False):
        G = len(pairs)
        self.budget_capped = bool(budget_capped)
        self.downscale_target = int(spec.downscale_target)
        self.floor_fraction = float(spec.budget_floor_fraction)
        self.rate_base = np.array(
            [((p.spot_price_per_day if spec.spot
               else p.ondemand_price_per_day) / 24.0) for p, _ in pairs])
        self.price_scale = 1.0
        self.curve = np.ones(G)
        self.cap = np.array([r.capacity for _, r in pairs], dtype=np.int64)
        self.outage = False
        self.min_queue = int(spec.min_queue)
        self.min_queue_eff = int(spec.min_queue)
        # net scale target set during the current segment (None: keep)
        self.scale_n: Optional[int] = None
        self.g_provider = [p.name for p, _ in pairs]
        self._prov_groups = {}
        for g, name in enumerate(self.g_provider):
            self._prov_groups.setdefault(name, []).append(g)
        # data-plane planner state (spec.dataplane): per-group origin
        # up/down for match gating and the cumulative miss-bandwidth
        # degrade factor the per-segment stage lengths are derived from
        self.origin_up = np.ones(G, dtype=bool)
        self.dp_degrade = np.ones(G)
        self.flush_edge = np.zeros(G, dtype=bool)
        self._dp_groups_by_base = {}
        for g, name in enumerate(self.g_provider):
            self._dp_groups_by_base.setdefault(
                name.split("/", 1)[0], []).append(g)
        self._dp_groups_by_base = {
            k: np.array(v, dtype=np.int64)
            for k, v in self._dp_groups_by_base.items()}

    def rate_h(self) -> np.ndarray:
        """Effective $/h per group — the engines' shared expression
        ``(base * shift scalar) * curve factor``."""
        return self.rate_base * self.price_scale * self.curve

    # -- EngineOps ---------------------------------------------------------
    def scale_to(self, n: int):
        self.scale_n = max(0, int(n))

    def deprovision_all(self):
        self.scale_n = 0

    def set_outage(self, on: bool):
        self.outage = bool(on)

    def scale_prices(self, factor: float):
        self.price_scale *= factor

    def set_price_factor(self, provider, factor: float):
        if provider is None:
            self.curve[:] = factor
        else:
            gs = self._prov_groups.get(provider)
            if gs is not None:          # unknown provider: no-op (solo
                self.curve[gs] = factor  # semantics)

    def scale_capacity(self, factor: float):
        self.cap = np.maximum(1, (self.cap * factor).astype(np.int64))

    def arm_budget_floor(self, fraction: float, target: int):
        self.floor_fraction = float(fraction)
        self.downscale_target = int(target)

    def set_workload_factor(self, factor: float):
        self.min_queue_eff = int(self.min_queue * factor)

    # -- data-plane ops (spec.OriginOutage/OriginDegrade/CacheFlush).
    #    Outage and degrade become per-segment parameter planes; a
    #    CacheFlush becomes a per-segment edge flag the scan folds into
    #    the first-stage-miss ("virgin") pool: the row engines' lazy
    #    epoch reset makes every live pilot's NEXT stage-in a forced
    #    miss, which the mixture model reproduces by marking the whole
    #    live population of the flushed provider's groups virgin.
    def set_origin_outage(self, provider: str, on: bool):
        gs = self._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            self.origin_up[gs] = not bool(on)

    def degrade_origin(self, provider: str, factor: float):
        gs = self._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            self.dp_degrade[gs] *= float(factor)

    def flush_cache(self, provider: str):
        gs = self._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            self.flush_edge[gs] = True


# -- the jitted tick scan --------------------------------------------------

def _kernel_ops(use_pallas: bool, consts):
    """The four hot ops, bound to either the Pallas kernels (TPU) or
    their jnp oracles (CPU) — identical integer semantics either way
    (tests/test_kernels.py pins kernel == ref)."""
    if use_pallas:
        from repro.kernels import ops as k

        def preempt(cells, kk):
            return k.campaign_preempt(cells, kk)

        def match(idle, kk):
            return k.campaign_match(idle, kk)

        def advance(busy, fm):
            return k.campaign_advance(busy, fm)

        def bill(live, rate):
            return k.campaign_bill(live, rate, consts["prov_onehot"])
    else:
        from repro.kernels import ref as r

        def preempt(cells, kk):
            return r.campaign_preempt_ref(cells, kk)

        def match(idle, kk):
            return r.campaign_match_ref(idle, kk)

        def advance(busy, fm):
            return r.campaign_advance_ref(busy, fm)

        def bill(live, rate):
            return r.campaign_bill_ref(live, rate, consts["prov_onehot"])
    return preempt, match, advance, bill


def _poisson(u, lam):
    """Poisson(lam) quantile of the uniform draw ``u``: truncated
    inverse-CDF for small lam, a rounded normal approximation for large
    (statistical tier; per-tick per-group lam is O(1) in practice)."""
    from jax.scipy.special import ndtri
    K = 24
    p = jnp.exp(-jnp.minimum(lam, 30.0))
    cdf = p
    kk = (u > cdf).astype(jnp.int32)
    for j in range(1, K):
        p = p * lam / j
        cdf = cdf + p
        kk = kk + (u > cdf).astype(jnp.int32)
    z = ndtri(jnp.clip(u, 1e-7, 1.0 - 1e-7))
    k_norm = jnp.round(lam + jnp.sqrt(jnp.maximum(lam, 0.0)) * z)
    return jnp.where(lam > 8.0,
                     jnp.maximum(k_norm, 0.0).astype(jnp.int32), kk)


@functools.partial(jax.jit, static_argnames=("nat_any", "use_pallas",
                                             "dp_gating", "dp_staging"))
def _scan_campaigns(planes, consts, xs, *, nat_any, use_pallas,
                    dp_gating=False, dp_staging=False):
    """One jitted lax.scan over all N ticks of B lock-step lanes.

    The tick phases mirror ``BatchedFleetEngine.tick`` (see that
    module): events, kill-to-target, spawn, preemption, queue top-up,
    match, NAT drops, advance, billing, overhead, ledger thresholds,
    accumulation.  Billing charges the interval ending at this tick
    against the live set at the tick's *start*, which equals the numpy
    engine's ``live + died - created`` counter identity."""
    preempt_fn, match_fn, advance_fn, bill_fn = \
        _kernel_ops(use_pallas, consts)

    prov_onehot = consts["prov_onehot"]           # [G,P] f32
    pre_rate = consts["pre_rate_g"][None, :]      # [1,G] f32
    pre_scale = consts["pre_scale_g"][None, :]
    M_wl = consts["M_wl"]                         # [B,W,L] f32
    M_jw = consts["M_jw"]                         # [B,L+1,W] f32
    finmask_rg = consts["finmask_rg"]             # [B*G,W] i32
    nat_g = consts["nat_g"]                       # [B,G] i32
    overhead = consts["overhead"]                 # [B]
    budget = consts["budget"]                     # [B]
    dt = consts["dt"]                             # scalar f32
    thresholds = jnp.asarray(_THRESHOLDS, jnp.float32)
    B, G = nat_g.shape
    W = M_wl.shape[1]
    L = M_wl.shape[2]
    P = prov_onehot.shape[1]
    keys = jax.vmap(jax.random.PRNGKey)(consts["seeds"])

    def requeue_levels(kb):
        # busy cells [B,G,W] -> checkpoint-level counts [B,L]
        return jnp.matmul(kb.astype(jnp.float32), M_wl) \
            .sum(axis=1).astype(jnp.int32)

    def split_cells(idle, pdead, busy, k):
        # proportional fan-out of k removals per (lane, group) across
        # the group's occupancy cells (idle | pilot-dead | busy-at-w)
        cells = jnp.concatenate(
            [idle[..., None], pdead[..., None], busy], axis=2)
        killed = preempt_fn(cells.reshape(B * G, W + 2),
                            k.reshape(B * G)).reshape(B, G, W + 2)
        return killed[..., 0], killed[..., 1], killed[..., 2:]

    def step(c, x):
        i, seg, is_start = x
        idle, pdead, busy = c["idle"], c["pdead"], c["busy"]
        cap_g = planes["cap"][seg]                           # [B,G] i32
        rate_g = planes["rate"][seg]                         # [B,G] f32
        live0 = idle + pdead + busy.sum(axis=2)              # [B,G] i32
        live_g = live0
        virgin = c["virgin"]
        if dp_staging:
            # a CacheFlush edge marks the flushed provider's whole live
            # population virgin: the lazy epoch reset in the row engines
            # forces every pilot's next stage-in to miss
            virgin = jnp.where(
                jnp.logical_and(is_start, planes["dp_flush"][seg]),
                live0.astype(jnp.float32), virgin)

        # 1. events: the deferred budget cap first (solo at(now) order),
        # then this segment's net scale target (uncapped/capped pair)
        def greedy(n):                                       # [B] -> [B,G]
            cume = jnp.cumsum(cap_g, axis=1) - cap_g
            return jnp.clip(n[:, None] - cume, 0, cap_g)

        apply_cap = c["cap_pending"]
        target_g = jnp.where(apply_cap[:, None],
                             greedy(planes["downscale"][seg]),
                             c["target_g"])
        cap_tick = jnp.where(apply_cap, i, c["cap_tick"])
        n_eff = jnp.where(c["capped"], planes["n_cap"][seg],
                          planes["n_unc"][seg])
        do_scale = is_start & (n_eff >= 0)
        target_g = jnp.where(do_scale[:, None],
                             greedy(jnp.maximum(n_eff, 0)), target_g)

        # 2. kill down to target (event stops); busy kills requeue
        excess = jnp.clip(live_g - target_g, 0, None)
        ki, kp, kb = split_cells(idle, pdead, busy, excess)
        idle, pdead, busy = idle - ki, pdead - kp, busy - kb
        pre_ct = c["pre_ct"] + kb.sum(axis=(1, 2))
        lv = c["lv"] + requeue_levels(kb)
        live_g = live_g - ki - kp - kb.sum(axis=2)
        if dp_staging:                     # kills hit virgins pro rata
            virgin = virgin * live_g.astype(jnp.float32) \
                / jnp.maximum(1.0, live0.astype(jnp.float32))

        # 3. spawn to min(target, capacity); fresh pilots arrive idle
        deficit = jnp.clip(jnp.minimum(target_g, cap_g) - live_g,
                           0, None)
        idle = idle + deficit
        live_g = live_g + deficit
        if dp_staging:                     # fresh pilots stage cold
            virgin = virgin + deficit.astype(jnp.float32)
            live_sp = live_g

        # 4. preemption sampling: per-lane threefry keyed by the tick,
        # a Poisson total per (lane, group) from the shared fleet
        # hazard, fanned out across occupancy cells proportionally
        subkeys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, i)
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (G,)))(subkeys)
        util = live_g.astype(jnp.float32) \
            / jnp.maximum(1, cap_g).astype(jnp.float32)
        hazard = pre_rate * (1.0 + (pre_scale - 1.0) * util) * dt
        k_pre = _poisson(u, live_g.astype(jnp.float32) * hazard)
        ki, kp, kb = split_cells(idle, pdead, busy, k_pre)
        idle, pdead, busy = idle - ki, pdead - kp, busy - kb
        pre_ct = pre_ct + kb.sum(axis=(1, 2))
        lv = lv + requeue_levels(kb)
        live_g = live_g - ki - kp - kb.sum(axis=2)
        if dp_staging:
            virgin = virgin * live_g.astype(jnp.float32) \
                / jnp.maximum(1.0, live_sp.astype(jnp.float32))

        # 5/6. top the CE queue up to the workload level
        ring_tot = lv.sum(axis=1)
        fresh_q = c["fresh_q"] + jnp.clip(
            planes["minq"][seg] - (ring_tot + c["fresh_q"]), 0, None)

        # 7. match k = min(idle, queued) jobs: the requeued ring drains
        # first (highest checkpoint level first), then fresh jobs; the
        # matcher splits k across groups by idle-pilot counts and the
        # joint (group x queue-slice) pairing is the overlap of the two
        # cumulative partitions of [0, k).  Origin outages remove the
        # gated groups' idle pilots from the matcher's input (they stay
        # idle and billed, exactly like the row engines' skip).
        if dp_gating:
            idle_m = idle * planes["origin_up"][seg]
        else:
            idle_m = idle
        idle_tot = idle_m.sum(axis=1)
        k = jnp.minimum(idle_tot, ring_tot + fresh_q)
        k = jnp.where(planes["outage"][seg], 0, k)
        take_g = match_fn(idle_m, k)                         # [B,G]
        avail = jnp.concatenate([lv[:, ::-1], fresh_q[:, None]], axis=1)
        cumq = jnp.cumsum(avail, axis=1)
        take_j = jnp.clip(k[:, None] - (cumq - avail), 0, avail)
        cA = jnp.cumsum(take_g, axis=1)
        cB = jnp.cumsum(take_j, axis=1)
        lo = jnp.maximum((cA - take_g)[:, :, None],
                         (cB - take_j)[:, None, :])
        hi = jnp.minimum(cA[:, :, None], cB[:, None, :])
        joint = jnp.clip(hi - lo, 0, None).astype(jnp.float32)
        if dp_staging:
            # stage-in as a count-axis front extension: a matched job
            # enters at S_max + w0 - S and reaches its old entry step
            # after S staging ticks.  The hit/miss split is the
            # deterministic per-(lane, group) fractional accumulator —
            # the mixture analogue of the row engines' per-pilot
            # rotation (long-run hit frequency exactly r, no RNG).
            # Each virgin (freshly spawned or freshly flushed) pilot
            # restarts its rotation at k=0, losing the fractional hit
            # credit a mid-rotation pilot carries — expected deficit
            # E[frac(n*r)] per reset (dp_loss_g) — charged the tick the
            # virgin first matches.
            take_f = take_g.astype(jnp.float32)
            first_f = jnp.minimum(take_f, virgin)
            virgin = virgin - first_f
            acc = c["hit_acc"] + take_f * consts["dp_r_g"][None, :] \
                - first_f * consts["dp_loss_g"][None, :]
            th_f = jnp.clip(jnp.floor(acc), 0.0, take_f)
            hit_acc = acc - th_f
            cumj = jnp.cumsum(joint, axis=2)
            hit_j = jnp.clip(th_f[:, :, None] - (cumj - joint),
                             0.0, joint)
            miss_j = joint - hit_j
            inc = (hit_j[..., None] * consts["E_hit"]).sum(axis=2) \
                + (miss_j[..., None] * planes["E_miss"][seg]).sum(axis=2)
            busy = busy + inc.astype(jnp.int32)
            has = consts["dp_has_g"][None, :]
            miss_f = (take_f - th_f) * has
            hits = c["hits"] + (th_f * has).sum(axis=1)
            misses = c["misses"] + miss_f.sum(axis=1)
            stage_t = c["stage_t"] \
                + (th_f * consts["S_hit_g"][None, :]
                   + (take_f - th_f)
                   * planes["S_miss"][seg].astype(jnp.float32)) \
                .sum(axis=1)
            # cache-miss egress: usd/miss is precomputed (gb * price);
            # charged the tick the job matched, next to the GPU hours
            eg_g = (take_f - th_f) * consts["dp_usd_miss_g"][None, :]
            egress_g = c["egress_g"] + eg_g
        else:
            busy = busy + jnp.matmul(joint, M_jw).astype(jnp.int32)
            hit_acc, hits, misses = c["hit_acc"], c["hits"], c["misses"]
            stage_t, egress_g = c["stage_t"], c["egress_g"]
            eg_g = jnp.zeros_like(egress_g)
        idle = idle - take_g
        lv = lv - take_j[:, :L][:, ::-1]
        fresh_q = fresh_q - take_j[:, L]

        # 7.5 NAT drops: every busy pilot in a disconnected group
        # requeues its job (instance stays alive and billed, pilot dead)
        nat_ct = c["nat_ct"]
        if nat_any:
            drop = busy * nat_g[:, :, None]
            cnt = drop.sum(axis=(1, 2))
            lv = lv + requeue_levels(drop)
            nat_ct = nat_ct + cnt
            pre_ct = pre_ct + cnt
            busy = busy - drop
            pdead = pdead + drop.sum(axis=2)

        # 8. advance progress one dt step; finishes release the pilot
        adv, fin = advance_fn(busy.reshape(B * G, W), finmask_rg)
        busy = adv.reshape(B, G, W)
        fin_g = fin.reshape(B, G)
        fin_ct = c["fin_ct"] + fin_g.sum(axis=1)
        idle = idle + fin_g

        # 9. bill the interval ending at this tick against the tick's
        # starting live set, at post-event rates (numpy counter identity)
        dh = jnp.where(i > 0, dt, 0.0)
        spent_d, prov_d = bill_fn(live0, rate_g * dh)
        spent = c["spent"] + spent_d + eg_g.sum(axis=1)
        by_prov = c["by_prov"] + prov_d

        # 10. flat infra overhead
        oh = overhead * dt / 24.0
        chg = oh > 0
        spent = spent + jnp.where(chg, oh, 0.0)
        infra = c["infra"] + jnp.where(chg, oh, 0.0)

        # 11. ledger alert thresholds -> budget-floor tripwire (the cap
        # itself applies at the next tick's event phase)
        frac = jnp.maximum(0.0, budget - spent) / budget
        cross = (frac[:, None] <= thresholds[None, :]) & ~c["fired"]
        newly = cross.any(axis=1)
        fired = c["fired"] | cross
        trigger = newly & (frac <= planes["floor"][seg]) & ~c["capped"]
        capped = c["capped"] | trigger

        # 12. accumulate GPU-time totals at end-of-tick occupancy
        busy_g = busy.sum(axis=2).astype(jnp.float32)
        live_end = (idle + pdead).astype(jnp.float32) + busy_g
        accel = c["accel"] + live_end.sum(axis=1) * dt
        busy_h = c["busy_h"] + busy_g.sum(axis=1) * dt
        busy_prov = c["busy_prov"] + (busy_g @ prov_onehot) * dt

        return {"idle": idle, "pdead": pdead, "busy": busy,
                "target_g": target_g, "lv": lv, "fresh_q": fresh_q,
                "spent": spent, "by_prov": by_prov, "infra": infra,
                "fired": fired, "capped": capped, "cap_pending": trigger,
                "cap_tick": cap_tick, "pre_ct": pre_ct,
                "nat_ct": nat_ct, "fin_ct": fin_ct, "accel": accel,
                "busy_h": busy_h, "busy_prov": busy_prov,
                "hit_acc": hit_acc, "hits": hits, "misses": misses,
                "stage_t": stage_t, "egress_g": egress_g,
                "virgin": virgin}, None

    init = {
        "idle": jnp.zeros((B, G), jnp.int32),
        "pdead": jnp.zeros((B, G), jnp.int32),
        "busy": jnp.zeros((B, G, W), jnp.int32),
        "target_g": jnp.zeros((B, G), jnp.int32),
        "lv": jnp.zeros((B, L), jnp.int32),
        "fresh_q": jnp.zeros((B,), jnp.int32),
        "spent": jnp.zeros((B,), jnp.float32),
        "by_prov": jnp.zeros((B, P), jnp.float32),
        "infra": jnp.zeros((B,), jnp.float32),
        "fired": jnp.zeros((B, len(_THRESHOLDS)), bool),
        "capped": jnp.zeros((B,), bool),
        "cap_pending": jnp.zeros((B,), bool),
        "cap_tick": jnp.full((B,), -1, jnp.int32),
        "pre_ct": jnp.zeros((B,), jnp.int32),
        "nat_ct": jnp.zeros((B,), jnp.int32),
        "fin_ct": jnp.zeros((B,), jnp.int32),
        "accel": jnp.zeros((B,), jnp.float32),
        "busy_h": jnp.zeros((B,), jnp.float32),
        "busy_prov": jnp.zeros((B, P), jnp.float32),
        "hit_acc": jnp.zeros((B, G), jnp.float32),
        "virgin": jnp.zeros((B, G), jnp.float32),
        "hits": jnp.zeros((B,), jnp.float32),
        "misses": jnp.zeros((B,), jnp.float32),
        "stage_t": jnp.zeros((B,), jnp.float32),
        "egress_g": jnp.zeros((B, G), jnp.float32),
    }
    out, _ = jax.lax.scan(step, init, xs)

    # settle the final interval: one more dt at last-segment rates
    live_final = out["idle"] + out["pdead"] + out["busy"].sum(axis=2)
    amt = live_final.astype(jnp.float32) * planes["rate"][-1] * dt
    out["spent"] = out["spent"] + amt.sum(axis=1)
    out["by_prov"] = out["by_prov"] + amt @ prov_onehot
    out["live_g"] = live_final
    return out


# -- batch construction ----------------------------------------------------

class JaxSweepEngine:
    """One lock-step batch of lanes compiled to a single scan (the JAX
    analogue of ``BatchedFleetEngine`` — same batching key, so the two
    engines chunk a sweep identically)."""

    def __init__(self, lanes: Sequence[_Lane],
                 use_pallas: Optional[bool] = None):
        self.lanes = list(lanes)
        B = len(self.lanes)
        ref = self.lanes[0]
        pairs = ref.pairs
        G = len(pairs)
        self.B, self.G = B, G
        self.dt = float(ref.spec.dt_h)
        self.duration = float(ref.spec.duration_h)
        if use_pallas is None:
            from repro.sharding_ctx import on_tpu
            use_pallas = on_tpu()
        self.use_pallas = bool(use_pallas)

        # static per-group config (identical across lanes by batch key)
        self.g_provider = [p.name for p, _ in pairs]
        self.providers: List[str] = []
        for name in self.g_provider:
            if name not in self.providers:
                self.providers.append(name)
        self.Pn = len(self.providers)
        pi = np.array([self.providers.index(n) for n in self.g_provider])
        prov_onehot = np.zeros((G, self.Pn), np.float32)
        prov_onehot[np.arange(G), pi] = 1.0
        self.provider_tflops = {p.name: p.fp32_tflops for p, _r in pairs}
        self.homogeneous = all(t is None
                               for t in self.provider_tflops.values())
        g_pre_rate = np.array([r.preempt_rate_per_hour for _, r in pairs],
                              np.float32)
        g_pre_scale = np.array([r.preempt_scale_at_full for _, r in pairs],
                               np.float32)
        g_nat = np.array([p.nat_idle_timeout_s for p, _ in pairs])

        # the same float tick walk as the numpy engines
        times = []
        now = 0.0
        while now < self.duration:
            times.append(now)
            now += self.dt
        self.tick_times = np.array(times)
        N = len(times)
        self.N = N

        # compile timelines; segments = union of all lanes' fire ticks
        self._evs: List[List[tuple]] = []
        self._fts: List[np.ndarray] = []
        seg_set = {0}
        for ln in self.lanes:
            evs = timeline_registry.compile_timeline(ln.spec.timeline)
            ft = np.searchsorted(self.tick_times,
                                 np.array([e[0] for e in evs]), "left") \
                if evs else np.zeros(0, np.int64)
            self._evs.append(evs)
            self._fts.append(ft)
            seg_set.update(int(t) for t in ft if t < N)
        seg_ticks = np.array(sorted(seg_set), np.int64)
        n_seg = len(seg_ticks)
        seg_of_tick = (np.searchsorted(seg_ticks, np.arange(N), "right")
                       - 1).astype(np.int32)
        is_seg_start = np.zeros(N, bool)
        is_seg_start[seg_ticks] = True

        # drive the EngineOps adapter through every lane's events, once
        # uncapped and once capped, snapshotting planes per segment
        rate = np.zeros((n_seg, B, G), np.float32)
        cap = np.zeros((n_seg, B, G), np.int32)
        outage = np.zeros((n_seg, B), bool)
        floor = np.zeros((n_seg, B), np.float32)
        downscale = np.zeros((n_seg, B), np.int32)
        minq = np.zeros((n_seg, B), np.int32)
        n_unc = np.full((n_seg, B), -1, np.int32)
        n_cap = np.full((n_seg, B), -1, np.int32)
        origin_up = np.ones((n_seg, B, G), bool)
        dp_degrade_sbg = np.ones((n_seg, B, G))
        dp_flush_sbg = np.zeros((n_seg, B, G), bool)
        for b, ln in enumerate(self.lanes):
            ops_u = JaxLaneOps(ln.spec, ln.pairs, budget_capped=False)
            ops_c = JaxLaneOps(ln.spec, ln.pairs, budget_capped=True)
            by_tick: Dict[int, list] = {}
            for (t, kind, arg), ft in zip(self._evs[b], self._fts[b]):
                if ft < N:
                    by_tick.setdefault(int(ft), []).append((kind, arg))
            for s, st in enumerate(seg_ticks):
                ops_u.scale_n = None
                ops_c.scale_n = None
                ops_u.flush_edge[:] = False
                for kind, arg in by_tick.get(int(st), []):
                    timeline_registry.apply_op(ops_u, kind, arg, 0.0)
                    timeline_registry.apply_op(ops_c, kind, arg, 0.0)
                rate[s, b] = ops_u.rate_h()
                cap[s, b] = ops_u.cap
                outage[s, b] = ops_u.outage
                floor[s, b] = ops_u.floor_fraction
                downscale[s, b] = ops_u.downscale_target
                minq[s, b] = ops_u.min_queue_eff
                origin_up[s, b] = ops_u.origin_up
                dp_degrade_sbg[s, b] = ops_u.dp_degrade
                dp_flush_sbg[s, b] = ops_u.flush_edge
                if ops_u.scale_n is not None:
                    n_unc[s, b] = ops_u.scale_n
                if ops_c.scale_n is not None:
                    n_cap[s, b] = ops_c.scale_n
        self.planes = {"rate": rate, "cap": cap, "outage": outage,
                       "floor": floor, "downscale": downscale,
                       "minq": minq, "n_unc": n_unc, "n_cap": n_cap}
        self.seg_of_tick = seg_of_tick
        self.is_seg_start = is_seg_start

        # count-plane geometry: W progress steps (one per dt until the
        # job wall), L checkpoint levels, and the per-lane maps between
        # them (requeue level of a step; queue-drain start step)
        lease = np.array([ln.spec.lease_interval_s for ln in self.lanes])
        connected = lease[:, None] < g_nat[None, :]          # [B,G]
        nat_g = (~connected).astype(np.int32)
        self.nat_any = bool(nat_g.any())
        wall = np.array([ln.spec.job_wall_h for ln in self.lanes])
        ckpt = np.array([ln.spec.job_checkpoint_h for ln in self.lanes])
        self.L = L = max(1, int(np.max(np.floor(wall / ckpt)) + 1))
        wfin1 = np.maximum(
            0, np.ceil(wall / self.dt - 1e-9).astype(np.int64) - 1)
        self.W = W = int(wfin1.max()) + 1
        finmask = (np.arange(W)[None, :] >= wfin1[:, None]) \
            .astype(np.int32)                                # [B,W]
        lvl_of_w = np.minimum(np.floor(
            np.arange(W)[None, :] * self.dt / ckpt[:, None] + 1e-9)
            .astype(np.int64), L - 1)
        M_wl = np.zeros((B, W, L), np.float32)
        M_wl[np.arange(B)[:, None], np.arange(W)[None, :], lvl_of_w] = 1.0
        # queue drain order j: levels L-1..0 (highest checkpoint first),
        # then fresh (j = L) starting at step 0
        lev_of_j = np.concatenate([np.arange(L - 1, -1, -1), [0]])
        w0_of_j = np.minimum(np.rint(
            lev_of_j[None, :] * ckpt[:, None] / self.dt).astype(np.int64),
            W - 1)
        w0_of_j[:, L] = 0
        M_jw = np.zeros((B, L + 1, W), np.float32)
        M_jw[np.arange(B)[:, None], np.arange(L + 1)[None, :],
             w0_of_j] = 1.0

        # -- data plane: stage-in as a count-axis front extension.  A
        # matched job enters at ext position S_max + w0 - S and reaches
        # its old entry step after exactly S staging ticks (finish
        # thresholds shift by S_max, so stage + progress duration is
        # exact per job).  Killed staging cells requeue at the level of
        # their position past S_max — a statistical approximation (their
        # true pre-stage checkpoint level is not tracked per cell).
        dp = getattr(ref.spec, "dataplane", None)
        dp_size = float(getattr(ref.spec, "job_input_gb", 0.0))
        origins_g = [dp.origin_for(n) if dp is not None else None
                     for n in self.g_provider]
        self.dp_active = dp is not None and bool(dp.origins)
        self.dp_staging = self.dp_active and dp_size > 0.0
        self.dp_base_g = [n.split("/", 1)[0] for n in self.g_provider]
        dp_has_g = np.array([o is not None for o in origins_g],
                            np.float32)
        r_g = np.array([o.cache_hit_rate if o else 0.0
                        for o in origins_g], np.float32)
        usd_miss_g = np.array(
            [dp_size * o.egress_usd_per_gb if o else 0.0
             for o in origins_g], np.float32)
        if self.dp_staging:
            def _ticks(gbps):
                # vectorized dataplane.stage_ticks (0 where gbps <= 0)
                gbps = np.asarray(gbps, np.float64)
                hours = dp_size * 8.0 / np.where(gbps > 0.0, gbps, 1.0) \
                    / 3600.0
                t = np.maximum(1, np.ceil(hours / self.dt - 1e-9)
                               .astype(np.int64))
                return np.where(gbps > 0.0, t, 0)

            bw_g = np.array([o.bandwidth_gbps if o else 0.0
                             for o in origins_g])
            hbw_g = np.array(
                [(o.cache_bandwidth_gbps if o.cache_bandwidth_gbps > 0.0
                  else o.bandwidth_gbps) if o else 0.0
                 for o in origins_g])
            S_hit = _ticks(hbw_g)                            # [G]
            S_miss = _ticks(bw_g[None, None, :] * dp_degrade_sbg) \
                .astype(np.int32)                            # [S,B,G]
            S_max = int(max(S_hit.max(), S_miss.max()))
            W_ext = W + S_max
            finmask = (np.arange(W_ext)[None, :]
                       >= S_max + wfin1[:, None]).astype(np.int32)
            lvl_of_ext = np.minimum(np.floor(np.clip(
                np.arange(W_ext)[None, :] - S_max, 0, None)
                * self.dt / ckpt[:, None] + 1e-9)
                .astype(np.int64), L - 1)
            M_wl = np.zeros((B, W_ext, L), np.float32)
            M_wl[np.arange(B)[:, None], np.arange(W_ext)[None, :],
                 lvl_of_ext] = 1.0
            bi = np.arange(B)[:, None, None]
            gi = np.arange(G)[None, :, None]
            ji = np.arange(L + 1)[None, None, :]
            pos_hit = S_max + w0_of_j[:, None, :] \
                - S_hit[None, :, None]                       # [B,G,L+1]
            E_hit = np.zeros((B, G, L + 1, W_ext), np.float32)
            E_hit[bi, gi, ji, pos_hit] = 1.0
            E_miss = np.zeros((n_seg, B, G, L + 1, W_ext), np.float32)
            for s in range(n_seg):
                pos_miss = S_max + w0_of_j[:, None, :] \
                    - S_miss[s][:, :, None]
                E_miss[s][bi, gi, ji, pos_miss] = 1.0
            self.planes["S_miss"] = S_miss
            self.planes["E_miss"] = E_miss
            self.planes["dp_flush"] = dp_flush_sbg
            # expected hit-credit loss when a pilot's rotation resets:
            # over n stage-ins the rotation yields floor(n*r) hits, a
            # deficit of frac(n*r) vs the accumulator's exact n*r —
            # averaged over lifetimes (numerically, any float r)
            n_ = np.arange(1, 201)[:, None]
            loss_g = np.where(
                r_g > 0.0,
                np.modf(n_ * r_g[None, :].astype(np.float64))[0].mean(0),
                0.0).astype(np.float32)
            self._dp_consts = {"dp_r_g": r_g, "dp_has_g": dp_has_g,
                               "dp_usd_miss_g": usd_miss_g,
                               "dp_loss_g": loss_g,
                               "S_hit_g": S_hit.astype(np.float32),
                               "E_hit": E_hit}
        else:
            self._dp_consts = {}
        if self.dp_active:
            self.planes["origin_up"] = origin_up

        self.consts = {
            "prov_onehot": prov_onehot,
            "pre_rate_g": g_pre_rate,
            "pre_scale_g": g_pre_scale,
            "nat_g": nat_g,
            "finmask_rg": np.repeat(finmask, G, axis=0),     # [B*G,W]
            "M_wl": M_wl,
            "M_jw": M_jw,
            "overhead": np.array([ln.spec.overhead_per_day
                                  for ln in self.lanes], np.float32),
            "budget": np.array([ln.spec.budget for ln in self.lanes],
                               np.float32),
            "dt": np.float32(self.dt),
            "seeds": np.array([ln.seed for ln in self.lanes], np.uint32),
            **self._dp_consts,
        }
        assert (self.consts["budget"] > 0).all(), \
            "sweep lanes need a budget"
        self.out: Optional[dict] = None

    def run(self) -> "JaxSweepEngine":
        xs = (np.arange(self.N, dtype=np.int32),
              self.seg_of_tick,
              self.is_seg_start)
        out = _scan_campaigns(
            {k: jnp.asarray(v) for k, v in self.planes.items()},
            {k: jnp.asarray(v) for k, v in self.consts.items()},
            tuple(jnp.asarray(v) for v in xs),
            nat_any=self.nat_any, use_pallas=self.use_pallas,
            dp_gating=self.dp_active, dp_staging=self.dp_staging)
        self.out = {k: np.asarray(v) for k, v in out.items()}
        return self

    # -- per-lane provenance + results ------------------------------------
    def lane_events(self, b: int) -> List[dict]:
        """Reconstruct the lane's ``events_fired`` records through the
        registry's own ``apply_op`` bodies (schema-identical to the solo
        and batched engines; the budget cap is inserted at the tick the
        scan applied it)."""
        ln = self.lanes[b]
        ops = JaxLaneOps(ln.spec, ln.pairs)
        cap_tick = int(self.out["cap_tick"][b]) if self.out is not None \
            else -1
        by_tick: Dict[int, list] = {}
        for (t, kind, arg), ft in zip(self._evs[b], self._fts[b]):
            if ft < self.N:
                by_tick.setdefault(int(ft), []).append((kind, arg))
        ticks = sorted(set(by_tick)
                       | ({cap_tick} if cap_tick >= 0 else set()))
        recs: List[dict] = []
        for ft in ticks:
            now = float(self.tick_times[ft])
            ops.budget_capped = 0 <= cap_tick <= ft
            if ft == cap_tick:
                recs.append(timeline_registry.apply_budget_cap(ops, now))
            for kind, arg in by_tick.get(ft, []):
                recs.append(timeline_registry.apply_op(ops, kind, arg,
                                                       now))
        return recs

    def lane_results(self, b: int) -> dict:
        """Summary totals, schema-identical to the other engines'
        ``results()`` (same keys, grouping and rounding)."""
        out = self.out
        assert out is not None, "run() first"
        sc = self.lanes[b].spec
        busy_by_prov = {}
        for pidx, name in enumerate(self.providers):
            h = float(out["busy_prov"][b, pidx])
            if h > 0:
                busy_by_prov[name] = h
        if self.homogeneous:
            eflop = float(out["busy_h"][b]) * sc.accel_tflops * 1e12 / 1e18
        else:
            eflop = sum(
                h * (self.provider_tflops.get(name) or sc.accel_tflops)
                for name, h in busy_by_prov.items()) * 1e12 / 1e18
        spent = float(out["spent"][b])
        budget = float(self.consts["budget"][b])
        raw_by_prov: Dict[str, float] = {}
        for pidx, name in enumerate(self.providers):
            v = float(out["by_prov"][b, pidx])
            if v > 0:
                raw_by_prov[name] = v
        # egress lands under the BASE provider name, merged before
        # rounding (same grouping as the other engines' ledgers)
        for g, base in enumerate(self.dp_base_g):
            e = float(out["egress_g"][b, g])
            if e > 0:
                raw_by_prov[base] = raw_by_prov.get(base, 0.0) + e
        ledger_by_prov = {k: round(v, 2) for k, v in raw_by_prov.items()}
        infra = float(out["infra"][b])
        if infra > 0:
            ledger_by_prov["infra"] = round(infra, 2)
        by_provider: Dict[str, int] = {}
        for g, name in enumerate(self.g_provider):
            by_provider[name] = by_provider.get(name, 0) \
                + int(out["live_g"][b, g])
        accel = float(out["accel"][b])
        return {
            "accel_hours": round(accel, 1),
            "accel_days": round(accel / 24.0, 1),
            "busy_hours": round(float(out["busy_h"][b]), 1),
            "busy_hours_by_provider": {
                k: round(v, 1) for k, v in sorted(busy_by_prov.items())},
            "eflop_hours_fp32": round(eflop, 3),
            "cost": round(spent, 2),
            "cost_per_accel_day": round(
                spent / max(accel / 24.0, 1e-9), 2),
            "preemptions": int(out["pre_ct"][b]),
            "nat_drops": int(out["nat_ct"][b]),
            "jobs_finished": int(out["fin_ct"][b]),
            "egress_usd": round(float(out["egress_g"][b].sum()), 2),
            "stagein_hours": round(float(out["stage_t"][b]) * self.dt, 1),
            "cache_hit_fraction": round(
                float(out["hits"][b])
                / (float(out["hits"][b]) + float(out["misses"][b])), 4)
            if float(out["hits"][b]) + float(out["misses"][b]) else 0.0,
            "budget": {
                "total_spent": round(spent, 2),
                "by_provider": dict(sorted(ledger_by_prov.items())),
                "remaining": round(max(0.0, budget - spent), 2),
                "remaining_fraction": round(
                    max(0.0, budget - spent) / budget, 4),
                "overdraft": round(max(0.0, spent - budget), 2),
            },
            "by_provider": by_provider,
        }


def run_jax_detailed(lane_specs: Sequence[Tuple[CampaignSpec, int]],
                     use_pallas: Optional[bool] = None
                     ) -> List[Tuple[dict, List[dict], None]]:
    """Run every (spec, seed) lane on the compiled engine, batching by
    the same structural key as the numpy engine; returns per-lane
    ``(results, events_fired, None)`` in input order (the trace slot is
    always None — ``collect="trace"`` is a bit-identity surface the
    statistical engine does not implement)."""
    prepared = [_prepare(sc, seed) for sc, seed in lane_specs]
    batches: Dict[tuple, List[int]] = {}
    for i, (key, _lane) in enumerate(prepared):
        batches.setdefault(key, []).append(i)
    out: List[Optional[tuple]] = [None] * len(prepared)
    for idxs in batches.values():
        eng = JaxSweepEngine([prepared[i][1] for i in idxs],
                             use_pallas=use_pallas).run()
        for j, i in enumerate(idxs):
            out[i] = (eng.lane_results(j), eng.lane_events(j), None)
    return out


def run_jax(lane_specs: Sequence[Tuple[CampaignSpec, int]],
            use_pallas: Optional[bool] = None) -> List[dict]:
    """Like :func:`run_jax_detailed`, results only."""
    return [res for res, _events, _trace in
            run_jax_detailed(lane_specs, use_pallas=use_pallas)]
