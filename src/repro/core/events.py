"""Typed, replayable campaign event traces: the ``CampaignTrace`` API.

The paper's operational story is a *stream of events* — spot preemptions,
NAT-timeout drops, pilot joins, job completions, price changes — but the
results surface used to collapse every campaign into end-of-run scalar
aggregates.  This module makes the stream itself a first-class, frozen,
JSON-round-trippable artifact:

  * one frozen dataclass per event kind (:class:`InstanceLaunched`,
    :class:`InstancePreempted`, :class:`InstanceStopped`,
    :class:`PilotRegistered`, :class:`NatDrop`, :class:`JobFinished`,
    :class:`PriceChanged`, :class:`TimelineEventFired`), each with a
    stable ``kind`` tag and a stable field schema,
  * :class:`TraceRecorder` — the engine-side collection hook.  All three
    execution engines (solo object, solo array, batched sweep) call the
    same recorder methods at their instance/pilot/job choke points; the
    recorder consumes **no randomness**, so collecting a trace never
    changes the simulated campaign,
  * :class:`CampaignTrace` — the frozen result: every event of one
    (spec, seed) campaign in canonical order, serializable to JSONL
    (``python -m repro.campaigns trace`` writes it).

Cross-engine contract (tests/engine_equivalence.py): at matching
(spec, seed) all three engines produce **byte-identical** serialized
traces.  That holds because (a) instance/pilot/job identities are
already engine-identical (per-lane 0-based instance counters, 1-based
pilot registration order, submission-order job IDs), (b) timestamps are
the same float tick walk everywhere, and (c) event order *within* a
tick is canonicalized here — events sort by ``(t, kind rank, entity
id)``, with timeline events keeping their provenance order — so the
engines' differing intra-tick iteration orders can never leak into the
artifact.  The canonical kind rank mirrors the tick phase order:
timeline/price events, launches, stops, pilot registrations,
preemptions, NAT drops, job completions.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterator, List, Mapping, Optional, Tuple, Union

TRACE_SCHEMA_VERSION = 1


# -- the typed events ------------------------------------------------------

@dataclass(frozen=True)
class InstanceLaunched:
    """A cloud instance (one group-provisioned VM/slice) started."""
    t: float
    instance: int
    provider: str
    region: str

    kind = "launch"


@dataclass(frozen=True)
class InstanceStopped:
    """Graceful scale-down/deprovision stop (not a preemption): the
    instance was billed to ``t`` and its pilot drained normally."""
    t: float
    instance: int
    provider: str
    region: str

    kind = "stop"


@dataclass(frozen=True)
class InstancePreempted:
    """Spot preemption: the provider reclaimed the instance at ``t``
    (cloud notice semantics: 30 s - 2 min warning before the kill)."""
    t: float
    instance: int
    provider: str
    region: str

    kind = "preempt"


@dataclass(frozen=True)
class PilotRegistered:
    """A pilot on ``instance`` registered with the Compute Element.
    ``pilot`` is the 1-based global registration order — identical
    across engines."""
    t: float
    pilot: int
    instance: int
    provider: str

    kind = "pilot"


@dataclass(frozen=True)
class NatDrop:
    """The pilot's idle lease connection outlived the provider NAT
    timeout mid-job (the paper's Azure 240 s bug); its job re-queued."""
    t: float
    pilot: int
    instance: int
    provider: str

    kind = "nat_drop"


@dataclass(frozen=True)
class StageInStarted:
    """A matched pilot began staging its job's input: ``gb`` at the
    origin (``cache_hit=False``, billable egress) or the regional cache
    tier (``cache_hit=True``) — the data-plane provenance behind the
    ``cache_hit_fraction`` result column."""
    t: float
    pilot: int
    gb: float
    cache_hit: bool
    provider: str

    kind = "stagein"


@dataclass(frozen=True)
class StageInFinished:
    """The pilot's stage-in completed; its job starts progressing this
    tick."""
    t: float
    pilot: int

    kind = "stagein_done"


@dataclass(frozen=True)
class EgressBilled:
    """One tick's cache-miss egress for one provider, charged to the
    budget ledger next to the GPU-hour billing (``usd = gb *
    egress_usd_per_gb``, the engine-shared float contract)."""
    t: float
    provider: str
    gb: float
    usd: float

    kind = "egress"


@dataclass(frozen=True)
class JobFinished:
    """A job completed its wall hours at ``t`` (``attempts`` counts
    matches, i.e. 1 + re-queues survived)."""
    t: float
    job: int
    attempts: int

    kind = "job_done"


@dataclass(frozen=True)
class PriceChanged:
    """A billing-rate change fired from the spec timeline: cumulative
    ``PriceShift`` (``absolute=False``, uniform) or a ``PriceCurve``
    breakpoint (``absolute=True``, optionally per-provider)."""
    t: float
    factor: float
    provider: Optional[str] = None
    absolute: bool = False

    kind = "price"


@dataclass(frozen=True)
class TimelineEventFired:
    """Any other executed controller event (``scale`` / ``outage_on`` /
    ``outage_off`` / ``capacity`` / ``floor`` / ``budget_floor``) with
    its structured payload — the events_fired provenance, typed."""
    t: float
    event: str
    payload: Mapping = field(default_factory=dict)

    kind = "timeline"


TraceEvent = Union[InstanceLaunched, InstanceStopped, InstancePreempted,
                   PilotRegistered, NatDrop, StageInStarted,
                   StageInFinished, EgressBilled, JobFinished,
                   PriceChanged, TimelineEventFired]

TRACE_EVENT_KINDS: Dict[str, type] = {
    cls.kind: cls for cls in (InstanceLaunched, InstanceStopped,
                              InstancePreempted, PilotRegistered, NatDrop,
                              StageInStarted, StageInFinished, EgressBilled,
                              JobFinished, PriceChanged, TimelineEventFired)}

# canonical intra-tick order == the engines' tick phase order; entity ids
# (unique per kind per campaign — pilot ids for stage events, provider
# names for egress, which only compare within their own rank) break
# ties, so the sort is total and engine-iteration-order independent
_KIND_RANK = {"timeline": 0, "price": 0, "launch": 1, "stop": 2,
              "pilot": 3, "preempt": 4, "nat_drop": 5, "stagein": 6,
              "stagein_done": 7, "egress": 8, "job_done": 9}


def event_to_dict(ev: TraceEvent) -> dict:
    d = asdict(ev)
    if ev.kind == "timeline":
        d["payload"] = dict(d["payload"])
    return {"kind": ev.kind, **d}


def event_from_dict(d: Mapping) -> TraceEvent:
    d = dict(d)
    kind = d.pop("kind", None)
    cls = TRACE_EVENT_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown trace event kind {kind!r}")
    return cls(**d)


# -- canonical JSONL lines (shared by CampaignTrace.to_jsonl and the
#    streaming sinks in core/traceops.py, so streamed files are
#    byte-identical to in-memory serialization by construction) ------------

def dump_line(obj: Mapping) -> str:
    """One canonical compact JSON line: sorted keys, fixed separators,
    no NaN — equal dicts always serialize to equal bytes."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def trace_header(name: str, seed: int, duration_h: float, dt_h: float,
                 n_events: int) -> dict:
    """The JSONL meta header dict (first line of every serialized
    trace; carries the campaign identity, never the engine)."""
    return {"schema_version": TRACE_SCHEMA_VERSION,
            "kind": "campaign_trace", "name": name, "seed": int(seed),
            "duration_h": float(duration_h), "dt_h": float(dt_h),
            "events": int(n_events)}


# -- engine-side collection ------------------------------------------------

class TraceRecorder:
    """Collects raw entity events from one engine (or one batched lane).

    Methods cast every value to a native Python type at record time, so
    numpy scalars from the array engines can never leak into the frozen
    events (and JSON serialization stays byte-identical across engines).
    Recording consumes no RNG: a campaign run with a recorder attached is
    bit-identical to the same campaign without one.
    """

    __slots__ = ("_raw",)

    def __init__(self):
        # (t, kind rank, entity key, event) — presorted tuples
        self._raw: List[tuple] = []

    def _push(self, item: tuple):
        """Collection hook: every record method funnels its presorted
        (t, rank, key, event) tuple through here.  The base recorder
        accumulates in memory for :func:`build_trace`; the streaming
        recorder (core/traceops.py) overrides this to flush bounded
        windows into a :class:`~repro.core.traceops.TraceSink`."""
        self._raw.append(item)

    def timeline_fired(self, rec: Mapping):
        """Engines mirror every ``events_fired`` provenance append here.
        A no-op for in-memory collection (``build_trace`` folds the
        timeline provenance in at freeze time); the streaming recorder
        overrides it to emit the typed timeline event in-band."""

    def launched(self, t, instance, provider, region):
        t, i = float(t), int(instance)
        self._push((t, _KIND_RANK[InstanceLaunched.kind], i,
                    InstanceLaunched(t, i, provider, region)))

    def stopped(self, t, instance, provider, region):
        t, i = float(t), int(instance)
        self._push((t, _KIND_RANK[InstanceStopped.kind], i,
                    InstanceStopped(t, i, provider, region)))

    def preempted(self, t, instance, provider, region):
        t, i = float(t), int(instance)
        self._push((t, _KIND_RANK[InstancePreempted.kind], i,
                    InstancePreempted(t, i, provider, region)))

    def pilot_registered(self, t, pilot, instance, provider):
        t, p = float(t), int(pilot)
        self._push((t, _KIND_RANK[PilotRegistered.kind], p,
                    PilotRegistered(t, p, int(instance), provider)))

    def nat_drop(self, t, pilot, instance, provider):
        t, p = float(t), int(pilot)
        self._push((t, _KIND_RANK[NatDrop.kind], p,
                    NatDrop(t, p, int(instance), provider)))

    def stagein_started(self, t, pilot, gb, cache_hit, provider):
        t, p = float(t), int(pilot)
        self._push((t, _KIND_RANK[StageInStarted.kind], p,
                    StageInStarted(t, p, float(gb), bool(cache_hit),
                                   provider)))

    def stagein_finished(self, t, pilot):
        t, p = float(t), int(pilot)
        self._push((t, _KIND_RANK[StageInFinished.kind], p,
                    StageInFinished(t, p)))

    def egress_billed(self, t, provider, gb, usd):
        t = float(t)
        # provider names are the entity key: unique per tick within the
        # egress rank, so the canonical sort stays total
        self._push((t, _KIND_RANK[EgressBilled.kind], provider,
                    EgressBilled(t, provider, float(gb), float(usd))))

    def job_finished(self, t, job, attempts):
        t, j = float(t), int(job)
        self._push((t, _KIND_RANK[JobFinished.kind], j,
                    JobFinished(t, j, int(attempts))))


def _timeline_trace_event(rec: Mapping) -> TraceEvent:
    """One events_fired provenance record (already engine-identical) as
    a typed trace event."""
    d = dict(rec)
    t = float(d.pop("t"))
    ev = d.pop("event")
    if ev == "price":
        return PriceChanged(t, factor=float(d["factor"]))
    if ev == "price_curve":
        return PriceChanged(t, factor=float(d["factor"]),
                            provider=d.get("provider"), absolute=True)
    return TimelineEventFired(t, event=ev, payload=d)


def build_trace(name: str, seed: int, duration_h: float, dt_h: float,
                recorder: Optional[TraceRecorder],
                events_fired: List[Mapping]) -> "CampaignTrace":
    """Freeze one campaign's collected events into the canonical-order
    trace (entity events from the recorder + typed timeline events from
    the engine's events_fired provenance)."""
    items = list(recorder._raw) if recorder is not None else []
    for seq, rec in enumerate(events_fired):
        ev = _timeline_trace_event(rec)
        # timeline/price events share rank 0; the provenance sequence
        # number (engine-identical) breaks ties
        items.append((ev.t, _KIND_RANK[ev.kind], seq, ev))
    items.sort(key=lambda it: it[:3])
    return CampaignTrace(name=name, seed=int(seed),
                         duration_h=float(duration_h), dt_h=float(dt_h),
                         events=tuple(it[3] for it in items))


# -- the frozen artifact ---------------------------------------------------

@dataclass(frozen=True)
class CampaignTrace:
    """Every event of one (spec, seed) campaign, in canonical order.

    Deliberately engine-agnostic: the serialized form carries no engine
    tag, because all three engines emit the same bytes — that identity
    IS the API contract (tests/engine_equivalence.py pins it)."""
    name: str
    seed: int
    duration_h: float
    dt_h: float
    events: Tuple[TraceEvent, ...] = ()

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events)

    def filter(self, *kinds: str) -> Tuple[TraceEvent, ...]:
        """Events of the given kind tag(s), trace order preserved."""
        unknown = set(kinds) - set(TRACE_EVENT_KINDS)
        if unknown:
            raise ValueError(f"unknown trace event kinds {sorted(unknown)}")
        return tuple(ev for ev in self.events if ev.kind in kinds)

    def counts(self) -> Dict[str, int]:
        """{kind: occurrences}, every known kind present (0 included)."""
        out = {k: 0 for k in TRACE_EVENT_KINDS}
        for ev in self.events:
            out[ev.kind] += 1
        return out

    # -- serialization -----------------------------------------------------
    def to_jsonl(self) -> str:
        """One meta header line + one compact JSON object per event.
        ``sort_keys`` + fixed separators make the bytes canonical: equal
        traces serialize to equal strings, whichever engine emitted them."""
        lines = [dump_line(trace_header(self.name, self.seed,
                                        self.duration_h, self.dt_h,
                                        len(self.events)))]
        lines.extend(dump_line(event_to_dict(ev)) for ev in self.events)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "CampaignTrace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace stream")
        head = json.loads(lines[0])
        if head.get("kind") != "campaign_trace":
            raise ValueError("not a campaign trace (missing meta header)")
        version = head.get("schema_version")
        if version != TRACE_SCHEMA_VERSION:
            raise ValueError(f"unsupported trace schema_version {version!r}")
        events = tuple(event_from_dict(json.loads(ln)) for ln in lines[1:])
        if len(events) != head.get("events"):
            raise ValueError(
                f"truncated trace: header promises {head.get('events')} "
                f"events, stream has {len(events)}")
        return cls(name=head["name"], seed=head["seed"],
                   duration_h=head["duration_h"], dt_h=head["dt_h"],
                   events=events)
