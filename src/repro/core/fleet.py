"""Vectorized struct-of-arrays fleet engine for CloudSimulator.

The seed engine (provisioner.py + overlay.py) keeps every instance, pilot
and job as a dataclass and walks Python dicts on every 15-minute tick —
fine at the paper's 2k GPUs, hopeless at the 100k-instance campaigns that
HEPCloud-scale bursts imply.  This module keeps the *same tick semantics*
but stores the fleet as parallel numpy arrays (``started_at``,
``ended_at``, ``last_charged``, ``job_row``, ...) so preemption sampling,
billing, lease/NAT checks, matchmaking and job progress are per-tick array
ops.

Equivalence with the object engine is exact, not approximate: random draws
are consumed per group in instance-creation order (``rng.random(k)`` reads
the same PCG64 stream as ``k`` scalar draws), pilots are registered and
reaped in the same order, and re-queued jobs enter the queue in the same
positions — property-tested in tests/test_fleet_engine.py by replaying the
paper campaign on both engines at seed 2021.

Dead instances are compacted out of the arrays once fully billed (their
billed hours are folded into per-group aggregates), so billing cost tracks
the *live* fleet, not every instance ever created.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import replace
from typing import Dict, List, Optional

import numpy as np

from repro.core.budget import BudgetLedger
from repro.core.overlay import Job
from repro.core.provider import ProviderSpec
from repro.core.provisioner import Instance

# pilot lifecycle states (per instance row)
_NO_PILOT = 0      # instance created, pilot not yet registered (pre-sync)
_PILOT_LIVE = 1
_PILOT_DEAD = 2    # reaped (instance gone) or NAT-dropped (instance alive)


# -- tick-phase primitives, shared with the batched sweep engine ----------
# (core/sweep.py ticks B campaigns in lock-step; these are written to be
# shape-polymorphic so one formula serves the scalar object path, the
# per-group solo path and the [lanes x groups] batched path bit-identically)

def preemption_rate(pre_rate, pre_scale, live, capacity):
    """Per-instance preemption hazard at the group's current utilization
    (spot pools get tighter as they fill — ``preempt_scale_at_full``)."""
    util = live / np.maximum(1, capacity)
    return pre_rate * (1.0 + (pre_scale - 1.0) * util)


def checkpoint_floor(done, ckpt):
    """Work surviving a preemption: floored to the last durable
    checkpoint increment."""
    return np.floor_divide(done, ckpt) * ckpt


def segment_starts(counts: np.ndarray) -> np.ndarray:
    """Start offset of each segment in a segment-major packed array."""
    return np.cumsum(counts) - counts


def segment_ranks(seg_of: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Rank of each element within its segment, for segment-sorted
    ``seg_of`` (the workhorse behind per-lane ID assignment, queue
    placement and first-k selection in the batched engine)."""
    return np.arange(len(seg_of)) - np.repeat(segment_starts(counts),
                                              counts)


class ArrayFleetEngine:
    """The whole control plane — groups, instances, pilots, jobs — as
    struct-of-arrays with one vectorized pass per tick."""

    def __init__(self, catalog: Dict[str, ProviderSpec],
                 ledger: Optional[BudgetLedger], rng: np.random.Generator,
                 *, lease_interval_s: float = 120.0, spot: bool = True,
                 job_wall_h: float = 4.0, job_checkpoint_h: float = 1.0,
                 accept_policy: str = "icecube", recorder=None,
                 dataplane=None):
        self.catalog = catalog
        self.ledger = ledger
        self.rng = rng
        # optional events.TraceRecorder; consumes no RNG, so attaching it
        # never changes the campaign
        self.recorder = recorder
        # optional dataplane.DataPlaneRuntime: stage-in lengths, origin
        # outage gating and egress metering (None = pure compute)
        self.dataplane = dataplane
        self.lease_interval_s = lease_interval_s
        self._spot = spot
        self.job_wall_h = job_wall_h
        self.job_checkpoint_h = job_checkpoint_h
        self.accept_policy = accept_policy
        # per-engine: every simulator (and every sweep lane) numbers its
        # instances from 0, independent of how many sims ran earlier in
        # the process
        self._ids = itertools.count()

        # -- static per-group config, sorted exactly like the object
        #    provisioner (cheapest first, stable) --------------------------
        pairs = [(prov, region) for prov in catalog.values()
                 for region in prov.regions]
        pairs.sort(key=lambda pr: (
            pr[0].spot_price_per_day if spot else
            pr[0].ondemand_price_per_day, pr[0].name, pr[1].name))
        self.g_provider = [p for p, _ in pairs]
        self.g_region = [r for _, r in pairs]
        G = len(pairs)
        self.G = G
        self.g_capacity = np.array([r.capacity for _, r in pairs],
                                   dtype=np.int64)
        self.g_pre_rate = np.array([r.preempt_rate_per_hour
                                    for _, r in pairs])
        self.g_pre_scale = np.array([r.preempt_scale_at_full
                                     for _, r in pairs])
        self.g_connected = np.array(
            [lease_interval_s < p.nat_idle_timeout_s for p, _ in pairs])
        self.g_target = np.zeros(G, dtype=np.int64)
        self.global_target = 0
        # billed hours folded in at compaction time (conservation view)
        self.g_retired_hours = np.zeros(G)
        self.retired_count = 0
        # compacted rows, kept as cold append-only arrays so
        # all_instances() stays complete without the hot path rescanning
        # them (id, group, start, end, preempted, last_charged)
        self._retired_cols: List[np.ndarray] = []

        # -- instance/pilot SoA ------------------------------------------
        self.n = 0
        cap = 1024
        self.i_group = np.zeros(cap, dtype=np.int32)
        self.i_id = np.zeros(cap, dtype=np.int64)
        self.i_start = np.zeros(cap)
        self.i_end = np.full(cap, np.nan)          # nan == alive
        self.i_preempted = np.zeros(cap, dtype=bool)
        self.i_last_charged = np.zeros(cap)
        self.i_pilot = np.zeros(cap, dtype=np.int8)
        self.i_pilot_order = np.zeros(cap, dtype=np.int64)
        self.i_job = np.full(cap, -1, dtype=np.int64)
        # data-plane stage-in state per instance row: ticks left on the
        # current transfer, the pilot's cache-hit rotation counter, and
        # the CacheFlush epoch that counter belongs to
        self.i_stage = np.zeros(cap, dtype=np.int64)
        self.i_stage_k = np.zeros(cap, dtype=np.int64)
        self.i_stage_epoch = np.zeros(cap, dtype=np.int64)
        self._pilot_seq = 0

        # -- job SoA + queue ----------------------------------------------
        self.jn = 0
        jcap = 4096
        self.j_id = np.zeros(jcap, dtype=np.int64)
        self.j_wall = np.zeros(jcap)
        self.j_ckpt = np.zeros(jcap)
        self.j_done = np.zeros(jcap)
        self.j_attempts = np.zeros(jcap, dtype=np.int32)
        self.j_finished = np.full(jcap, np.nan)
        self._job_seq = 0
        self.queue: collections.deque = collections.deque()   # job rows
        self.finished: List[int] = []                         # job rows

        self.preemption_events = 0
        self.nat_drop_events = 0
        self.outage = False
        self._price_scale = 1.0
        # absolute per-provider curve factors (spec.PriceCurve)
        self._curve_factor: Dict[str, float] = {}
        self._busy_by_group = np.zeros(G, dtype=np.int64)

        self.prov = ArrayProvisionerView(self)
        self.ce = ArrayComputeElementView(self)

    # -- spot flag (settable like MultiCloudProvisioner.spot; does NOT
    #    re-sort groups — matches the object engine) ----------------------
    @property
    def spot(self) -> bool:
        return self._spot

    @spot.setter
    def spot(self, v: bool):
        self._spot = v

    def rate_h(self, gi: int) -> float:
        p = self.g_provider[gi]
        # ((price/24) * shift scalar) * curve factor — the shared billing
        # expression (see MultiCloudProvisioner.bill); x1.0 is exact
        return (p.spot_price_per_day if self._spot
                else p.ondemand_price_per_day) / 24.0 * self._price_scale \
            * self._curve_factor.get(p.name, 1.0)

    # -- timeline ops (spec.PriceShift/CapacityShift/PriceCurve) ----------
    def scale_prices(self, factor: float):
        """Uniform price shift from now on; one cumulative scalar so the
        price-priority group order is unaffected."""
        self._price_scale *= factor

    def set_price_factor(self, provider: Optional[str], factor: float):
        """Absolute per-provider curve factor (None = every provider) —
        the spec timeline's ``PriceCurve`` op; replaces, not compounds."""
        if provider is None:
            for name in self.catalog:
                self._curve_factor[name] = factor
        else:
            self._curve_factor[provider] = factor

    def scale_capacity(self, factor: float):
        """Multiply every group's capacity (floored at 1); shrinking
        below the live count does not evict running instances."""
        self.g_capacity = np.maximum(
            1, (self.g_capacity * factor).astype(np.int64))

    # -- growth helpers ---------------------------------------------------
    def _grow_instances(self, extra: int):
        need = self.n + extra
        cap = len(self.i_id)
        if need <= cap:
            return
        new = max(need, cap * 2)

        def g(a, fill=0):
            out = np.full(new, fill, dtype=a.dtype) if fill == fill else \
                np.full(new, np.nan)
            out[:self.n] = a[:self.n]
            return out

        self.i_group = g(self.i_group)
        self.i_id = g(self.i_id)
        self.i_start = g(self.i_start)
        self.i_end = g(self.i_end, np.nan)
        self.i_preempted = g(self.i_preempted)
        self.i_last_charged = g(self.i_last_charged)
        self.i_pilot = g(self.i_pilot)
        self.i_pilot_order = g(self.i_pilot_order)
        self.i_job = g(self.i_job, -1)
        self.i_stage = g(self.i_stage)
        self.i_stage_k = g(self.i_stage_k)
        self.i_stage_epoch = g(self.i_stage_epoch)

    def _grow_jobs(self, extra: int):
        need = self.jn + extra
        cap = len(self.j_id)
        if need <= cap:
            return
        new = max(need, cap * 2)

        def g(a, fill=0):
            out = np.full(new, fill, dtype=a.dtype) if fill == fill else \
                np.full(new, np.nan)
            out[:self.jn] = a[:self.jn]
            return out

        self.j_id = g(self.j_id)
        self.j_wall = g(self.j_wall)
        self.j_ckpt = g(self.j_ckpt)
        self.j_done = g(self.j_done)
        self.j_attempts = g(self.j_attempts)
        self.j_finished = g(self.j_finished, np.nan)

    # -- masks / counts ---------------------------------------------------
    def _alive(self) -> np.ndarray:
        return np.isnan(self.i_end[:self.n])

    def live_counts(self) -> np.ndarray:
        alive = self._alive()
        return np.bincount(self.i_group[:self.n][alive], minlength=self.G)

    def total_running(self) -> int:
        return int(self._alive().sum())

    def busy_count(self) -> int:
        return int(((self.i_pilot[:self.n] == _PILOT_LIVE)
                    & (self.i_job[:self.n] >= 0)).sum())

    def busy_by_provider(self) -> Dict[str, int]:
        busy = ((self.i_pilot[:self.n] == _PILOT_LIVE)
                & (self.i_job[:self.n] >= 0))
        counts = np.bincount(self.i_group[:self.n][busy], minlength=self.G)
        out: Dict[str, int] = {}
        for gi in range(self.G):
            if counts[gi]:
                name = self.g_provider[gi].name
                out[name] = out.get(name, 0) + int(counts[gi])
        return out

    # -- instance lifecycle ----------------------------------------------
    def _create(self, gi: int, k: int, now: float):
        if k <= 0:
            return
        self._grow_instances(k)
        s = slice(self.n, self.n + k)
        self.i_group[s] = gi
        self.i_id[s] = np.fromiter(itertools.islice(self._ids, k),
                                   dtype=np.int64, count=k)
        self.i_start[s] = now
        self.i_end[s] = np.nan
        self.i_preempted[s] = False
        self.i_last_charged[s] = now
        self.i_pilot[s] = _NO_PILOT
        self.i_pilot_order[s] = 0
        self.i_job[s] = -1
        self.i_stage[s] = 0
        self.i_stage_k[s] = 0
        self.i_stage_epoch[s] = 0
        self.n += k
        if self.recorder is not None:
            pname = self.g_provider[gi].name
            rname = self.g_region[gi].name
            for iid in self.i_id[s]:
                self.recorder.launched(now, iid, pname, rname)

    def set_group_target(self, gi: int, n: int, now: float):
        """Provider group semantics: fill to min(target, capacity)
        immediately; stop the newest extras when above target."""
        self.g_target[gi] = max(0, n)
        rows = np.nonzero(self._alive()
                          & (self.i_group[:self.n] == gi))[0]
        live = len(rows)
        fillable = int(min(self.g_target[gi], self.g_capacity[gi]))
        if live < fillable:
            self._create(gi, fillable - live, now)
        elif live > self.g_target[gi]:
            stop = rows[self.g_target[gi]:]
            self.i_end[stop] = now        # stopped (not preempted)
            if self.recorder is not None:
                pname = self.g_provider[gi].name
                rname = self.g_region[gi].name
                for iid in self.i_id[stop]:
                    self.recorder.stopped(now, iid, pname, rname)

    def scale_to(self, n: int, now: float) -> int:
        """Greedy cheapest-first fill, mirroring the object provisioner."""
        self.global_target = max(0, n)
        remaining = self.global_target
        for gi in range(self.G):
            want = min(remaining, int(self.g_capacity[gi]))
            self.set_group_target(gi, want, now)
            live = int((self._alive()
                        & (self.i_group[:self.n] == gi)).sum())
            remaining -= live
        return self.total_running()

    def deprovision_all(self, now: float):
        for gi in range(self.G):
            self.set_group_target(gi, 0, now)

    def preempt_instance(self, inst_id: int, now: float):
        """External preemption by instance id (group-view API)."""
        idx = np.searchsorted(self.i_id[:self.n], inst_id)
        if idx < self.n and self.i_id[idx] == inst_id \
                and np.isnan(self.i_end[idx]):
            self.i_end[idx] = now
            self.i_preempted[idx] = True
            if self.recorder is not None:
                gi = int(self.i_group[idx])
                self.recorder.preempted(now, inst_id,
                                        self.g_provider[gi].name,
                                        self.g_region[gi].name)

    # -- tick phases (ordering mirrors CloudSimulator.step exactly) -------
    def maintain_groups(self, now: float):
        counts = self.live_counts()
        fillable = np.minimum(self.g_target, self.g_capacity)
        for gi in np.nonzero(counts < fillable)[0]:
            self.set_group_target(gi, int(self.g_target[gi]), now)

    def _requeue(self, rows: np.ndarray):
        """Jobs of lost pilots return to the FRONT of the queue, work
        floored to the last checkpoint.  ``appendleft`` per pilot in pilot
        order — same final queue layout as the object engine."""
        jr = self.i_job[rows]
        has_job = jr >= 0
        jrows = jr[has_job]
        self.j_done[jrows] = checkpoint_floor(self.j_done[jrows],
                                              self.j_ckpt[jrows])
        for j in jrows:
            self.queue.appendleft(int(j))
        self.i_job[rows] = -1
        self.i_stage[rows] = 0   # an abandoned transfer restarts on re-match
        return int(has_job.sum())

    def sync_pilots(self, now: float):
        # register: one pilot per live, pilotless instance, visited in
        # group (price) order then creation order — the object engine's
        # live_instances() order
        alive = self._alive()
        fresh = alive & (self.i_pilot[:self.n] == _NO_PILOT)
        if fresh.any():
            for gi in range(self.G):
                rows = np.nonzero(fresh & (self.i_group[:self.n] == gi))[0]
                k = len(rows)
                if k:
                    self.i_pilot[rows] = _PILOT_LIVE
                    self.i_pilot_order[rows] = np.arange(
                        self._pilot_seq, self._pilot_seq + k)
                    self._pilot_seq += k
                    if self.recorder is not None:
                        pname = self.g_provider[gi].name
                        for r in rows:
                            # 1-based registration order: the object CE's
                            # pilot-id numbering
                            self.recorder.pilot_registered(
                                now, self.i_pilot_order[r] + 1,
                                self.i_id[r], pname)
        # reap: pilots whose instance is gone, in registration order
        lost = (~alive) & (self.i_pilot[:self.n] == _PILOT_LIVE)
        if lost.any():
            rows = np.nonzero(lost)[0]
            rows = rows[np.argsort(self.i_pilot_order[rows], kind="stable")]
            self.preemption_events += self._requeue(rows)
            self.i_pilot[rows] = _PILOT_DEAD

    def sample_preemptions(self, now: float, dt: float):
        alive = self._alive()
        counts = np.bincount(self.i_group[:self.n][alive], minlength=self.G)
        for gi in range(self.G):
            rows = np.nonzero(alive & (self.i_group[:self.n] == gi))[0]
            if not len(rows):
                continue
            rate = preemption_rate(self.g_pre_rate[gi], self.g_pre_scale[gi],
                                   counts[gi], int(self.g_capacity[gi]))
            hits = rows[self.rng.random(len(rows)) < rate * dt]
            if not len(hits):
                continue
            self.i_end[hits] = now
            self.i_preempted[hits] = True
            if self.recorder is not None:
                pname = self.g_provider[gi].name
                rname = self.g_region[gi].name
                for iid in self.i_id[hits]:
                    self.recorder.preempted(now, iid, pname, rname)
            piloted = hits[self.i_pilot[hits] == _PILOT_LIVE]
            self.preemption_events += self._requeue(piloted)
            self.i_pilot[piloted] = _PILOT_DEAD

    def next_job_id(self) -> int:
        self._job_seq += 1
        return self._job_seq

    def submit_jobs(self, k: int, *, wall_h=None, ckpt_h=None):
        """Batch-append k fresh jobs to the back of the queue."""
        if k <= 0:
            return
        self._grow_jobs(k)
        s = slice(self.jn, self.jn + k)
        self.j_id[s] = np.arange(self._job_seq + 1, self._job_seq + k + 1)
        self._job_seq += k
        self.j_wall[s] = self.job_wall_h if wall_h is None else wall_h
        self.j_ckpt[s] = self.job_checkpoint_h if ckpt_h is None else ckpt_h
        self.j_done[s] = 0.0
        self.j_attempts[s] = 0
        self.j_finished[s] = np.nan
        self.queue.extend(range(self.jn, self.jn + k))
        self.jn += k

    def submit_job(self, job: Job):
        """Append one externally-built Job, preserving its identity and
        checkpointed progress (the object CE's submit contract)."""
        self._grow_jobs(1)
        i = self.jn
        self.j_id[i] = job.id
        self._job_seq = max(self._job_seq, job.id)
        self.j_wall[i] = job.wall_h
        self.j_ckpt[i] = job.checkpoint_period_h
        self.j_done[i] = job.done_h
        self.j_attempts[i] = job.attempts
        self.j_finished[i] = np.nan
        self.queue.append(i)
        self.jn += 1

    def ensure_jobs(self, min_queue: int):
        self.submit_jobs(min_queue - len(self.queue))

    def match(self, now: float) -> int:
        if self.outage:
            return 0
        dp = self.dataplane
        idle_mask = ((self.i_pilot[:self.n] == _PILOT_LIVE)
                     & (self.i_job[:self.n] < 0))
        if dp is not None and dp.active:
            # origin outage gates NEW matches for affected providers
            elig_g = np.array([dp.eligible(p.name)
                               for p in self.g_provider])
            idle_mask &= elig_g[self.i_group[:self.n]]
        idle = np.nonzero(idle_mask)[0]
        k = min(len(idle), len(self.queue))
        if k <= 0:
            return 0
        idle = idle[np.argsort(self.i_pilot_order[idle],
                               kind="stable")][:k]
        jobs = np.fromiter((self.queue.popleft() for _ in range(k)),
                           dtype=np.int64, count=k)
        self.i_job[idle] = jobs
        self.j_attempts[jobs] += 1
        if dp is not None and dp.staging:
            for r in idle:
                gi = int(self.i_group[r])
                pname = self.g_provider[gi].name
                epoch = dp.current_epoch(pname)
                if self.i_stage_epoch[r] != epoch:  # CacheFlush reset
                    self.i_stage_epoch[r] = epoch
                    self.i_stage_k[r] = 0
                ticks, hit = dp.decide(pname, int(self.i_stage_k[r]))
                self.i_stage_k[r] += 1
                self.i_stage[r] = ticks
                if ticks > 0 and self.recorder is not None:
                    self.recorder.stagein_started(
                        now, self.i_pilot_order[r] + 1, dp.size_gb, hit,
                        pname)
        return k

    def advance(self, dt: float, now: float):
        busy = ((self.i_pilot[:self.n] == _PILOT_LIVE)
                & (self.i_job[:self.n] >= 0))
        # NAT drops: lease renewals lost to the provider's idle timeout
        dropped = busy & ~self.g_connected[self.i_group[:self.n]]
        if dropped.any():
            rows = np.nonzero(dropped)[0]
            rows = rows[np.argsort(self.i_pilot_order[rows], kind="stable")]
            self.nat_drop_events += len(rows)
            if self.recorder is not None:
                for r in rows:
                    gi = int(self.i_group[r])
                    self.recorder.nat_drop(now, self.i_pilot_order[r] + 1,
                                           self.i_id[r],
                                           self.g_provider[gi].name)
            # a NAT drop is a pilot loss: the job's return to queue counts
            # as a preemption, exactly like the object engine's pilot_lost
            self.preemption_events += self._requeue(rows)
            self.i_pilot[rows] = _PILOT_DEAD
            busy &= ~dropped
        # stage-in burns the tick before any job progress
        staging = busy & (self.i_stage[:self.n] > 0)
        if staging.any():
            srows = np.nonzero(staging)[0]
            self.i_stage[srows] -= 1
            if self.dataplane is not None:
                self.dataplane.staged_ticks += len(srows)
            done_stage = srows[self.i_stage[srows] == 0]
            if len(done_stage) and self.recorder is not None:
                order = np.argsort(self.i_pilot_order[done_stage],
                                   kind="stable")
                for r in done_stage[order]:
                    self.recorder.stagein_finished(
                        now, self.i_pilot_order[r] + 1)
            busy &= ~staging
        # job progress
        rows = np.nonzero(busy)[0]
        if len(rows):
            jr = self.i_job[rows]
            self.j_done[jr] += dt
            fin = self.j_done[jr] >= self.j_wall[jr]
            if fin.any():
                done_rows = rows[fin]
                done_jobs = jr[fin]
                order = np.argsort(self.i_pilot_order[done_rows],
                                   kind="stable")
                self.j_finished[done_jobs] = now
                self.finished.extend(int(j) for j in done_jobs[order])
                if self.recorder is not None:
                    for j in done_jobs[order]:
                        self.recorder.job_finished(now, self.j_id[j],
                                                   self.j_attempts[j])
                self.i_job[done_rows] = -1

    # -- billing + compaction ---------------------------------------------
    def bill(self, now: float) -> float:
        if self.ledger is None:
            return 0.0
        end_eff = np.where(np.isnan(self.i_end[:self.n]), now,
                           self.i_end[:self.n])
        dh = end_eff - self.i_last_charged[:self.n]
        total = 0.0
        for gi in range(self.G):
            sel = (self.i_group[:self.n] == gi) & (dh > 0)
            if not sel.any():
                continue
            hours = float(dh[sel].sum())
            amount = hours * self.rate_h(gi)
            self.ledger.charge(self.g_provider[gi].name, amount, now,
                               note=self.g_region[gi].name)
            self.i_last_charged[:self.n][sel] = end_eff[sel]
            total += amount
        self.compact()
        return total

    def compact(self):
        """Drop dead, fully-billed rows; fold their billed hours into
        per-group aggregates so conservation stays checkable."""
        dead = (~np.isnan(self.i_end[:self.n])
                & (self.i_pilot[:self.n] != _PILOT_LIVE)
                & (self.i_last_charged[:self.n] >= self.i_end[:self.n]))
        nd = int(dead.sum())
        if nd < 512 or nd * 4 < self.n:
            return
        rows = np.nonzero(dead)[0]
        hours = self.i_last_charged[rows] - self.i_start[rows]
        np.add.at(self.g_retired_hours, self.i_group[rows], hours)
        self.retired_count += nd
        self._retired_cols.append(np.stack([
            self.i_id[rows].astype(float), self.i_group[rows].astype(float),
            self.i_start[rows], self.i_end[rows],
            self.i_preempted[rows].astype(float),
            self.i_last_charged[rows]]))
        keep = np.nonzero(~dead)[0]
        for name in ("i_group", "i_id", "i_start", "i_end", "i_preempted",
                     "i_last_charged", "i_pilot", "i_pilot_order", "i_job",
                     "i_stage", "i_stage_k", "i_stage_epoch"):
            arr = getattr(self, name)
            arr[:len(keep)] = arr[keep]
            setattr(self, name, arr)
        self.n = len(keep)

    def billed_hours_by_group(self) -> np.ndarray:
        """Total instance-hours billed so far per group, including
        compacted-away instances (spent$ == sum(hours x rate))."""
        out = self.g_retired_hours.copy()
        hours = self.i_last_charged[:self.n] - self.i_start[:self.n]
        np.add.at(out, self.i_group[:self.n], hours)
        return out

    # -- the full tick, phase order identical to the object step ----------
    def tick(self, now: float, dt: float, min_queue: int):
        self.maintain_groups(now)
        self.sync_pilots(now)
        self.sample_preemptions(now, dt)
        self.sync_pilots(now)
        self.ensure_jobs(min_queue)
        self.match(now)
        self.advance(dt, now)
        self.bill(now)
        return self.total_running(), self.busy_count()

    # -- dataclass views --------------------------------------------------
    def instance_views(self, rows: np.ndarray) -> List[Instance]:
        out = []
        for r in rows:
            gi = int(self.i_group[r])
            end = float(self.i_end[r])
            pre = end if (end == end and self.i_preempted[r]) else None
            stop = end if (end == end and not self.i_preempted[r]) else None
            out.append(Instance(int(self.i_id[r]),
                                self.g_provider[gi].name,
                                self.g_region[gi].name,
                                float(self.i_start[r]),
                                preempted_at=pre, stopped_at=stop,
                                last_charged=float(
                                    self.i_last_charged[r])))
        return out


class ArrayGroupView:
    """InstanceGroup-shaped window onto one group's slice of the arrays."""

    def __init__(self, engine: ArrayFleetEngine, gi: int):
        self._e = engine
        self._gi = gi
        self.provider = engine.g_provider[gi]

    @property
    def region(self):
        """The group's RegionSpec at the engine's *current* capacity
        (CapacityShift events mutate it mid-run)."""
        e = self._e
        r = e.g_region[self._gi]
        cap = int(e.g_capacity[self._gi])
        return r if r.capacity == cap else replace(r, capacity=cap)

    @property
    def target(self) -> int:
        return int(self._e.g_target[self._gi])

    @property
    def running(self) -> List[Instance]:
        e = self._e
        rows = np.nonzero(e._alive() & (e.i_group[:e.n] == self._gi))[0]
        return e.instance_views(rows)

    def set_target(self, n: int, now: float):
        self._e.set_group_target(self._gi, n, now)

    def preempt(self, inst_id: int, now: float):
        self._e.preempt_instance(inst_id, now)

    def utilization(self) -> float:
        e = self._e
        live = int((e._alive() & (e.i_group[:e.n] == self._gi)).sum())
        return live / max(1, int(e.g_capacity[self._gi]))


class ArrayProvisionerView:
    """MultiCloudProvisioner-compatible facade over the array engine."""

    def __init__(self, engine: ArrayFleetEngine):
        self._e = engine
        self.catalog = engine.catalog
        self.groups = [ArrayGroupView(engine, gi)
                       for gi in range(engine.G)]

    @property
    def spot(self) -> bool:
        return self._e.spot

    @spot.setter
    def spot(self, v: bool):
        self._e.spot = v

    @property
    def global_target(self) -> int:
        return self._e.global_target

    def scale_to(self, n: int, now: float) -> int:
        return self._e.scale_to(n, now)

    def deprovision_all(self, now: float):
        self._e.deprovision_all(now)

    def scale_prices(self, factor: float):
        self._e.scale_prices(factor)

    def set_price_factor(self, provider, factor: float):
        self._e.set_price_factor(provider, factor)

    def scale_capacity(self, factor: float):
        self._e.scale_capacity(factor)

    def bill(self, now: float) -> float:
        return self._e.bill(now)

    def total_running(self) -> int:
        return self._e.total_running()

    def running_by_provider(self) -> Dict[str, int]:
        e = self._e
        counts = e.live_counts()
        out: Dict[str, int] = {}
        for gi in range(e.G):
            name = e.g_provider[gi].name
            out[name] = out.get(name, 0) + int(counts[gi])
        return out

    def live_instances(self):
        e = self._e
        yield from e.instance_views(np.nonzero(e._alive())[0])

    def all_instances(self):
        """Every instance ever created: compacted (retired) first, then
        the live arrays — mirrors the object provisioner's view."""
        e = self._e
        for cols in e._retired_cols:
            ids, groups, starts, ends, pres, charged = cols
            for j in range(cols.shape[1]):
                gi = int(groups[j])
                pre = float(ends[j]) if pres[j] else None
                stop = None if pres[j] else float(ends[j])
                yield Instance(int(ids[j]), e.g_provider[gi].name,
                               e.g_region[gi].name, float(starts[j]),
                               preempted_at=pre, stopped_at=stop,
                               last_charged=float(charged[j]))
        yield from e.instance_views(np.arange(e.n))


class ArrayComputeElementView:
    """ComputeElement-compatible facade (queue/finished hold job ROWS)."""

    def __init__(self, engine: ArrayFleetEngine):
        self._e = engine
        self.accept_policy = engine.accept_policy
        self.lease_interval_s = engine.lease_interval_s

    @property
    def queue(self):
        return self._e.queue

    @property
    def finished(self):
        return self._e.finished

    @property
    def outage(self) -> bool:
        return self._e.outage

    @outage.setter
    def outage(self, v: bool):
        self._e.outage = v

    @property
    def preemption_events(self) -> int:
        return self._e.preemption_events

    @property
    def nat_drop_events(self) -> int:
        return self._e.nat_drop_events

    def next_job_id(self) -> int:
        return self._e.next_job_id()

    def submit(self, job: Job):
        if job.policy != self.accept_policy:
            raise PermissionError(
                f"CE policy {self.accept_policy!r} rejects {job.policy!r}")
        self._e.submit_job(job)

    def match(self, now_h: float) -> int:
        return self._e.match(now_h)

    def busy_by_provider(self) -> Dict[str, int]:
        return self._e.busy_by_provider()

    def stats(self) -> dict:
        e = self._e
        live = int((e.i_pilot[:e.n] == _PILOT_LIVE).sum())
        return {"pilots_live": live,
                "pilots_busy": e.busy_count(),
                "queued": len(e.queue),
                "finished": len(e.finished),
                "preemptions": e.preemption_events,
                "nat_drops": e.nat_drop_events}
