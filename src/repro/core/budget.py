"""CloudBank analogue: multi-provider ledger, spend-rate, threshold alerts.

The paper (§III) used exactly two CloudBank services — this module provides
both:
  1. a "single window" aggregate view: total + per-provider spend, remaining
     budget, fraction of total (``BudgetLedger.report()``),
  2. threshold e-mails: callbacks fired as remaining fraction crosses
     configured levels, carrying the spend rate over the past few days
     (``on_threshold``). The campaign controller (campaign.py) wires the
     20 %-remaining alert to the paper's 2k->1k downscale decision.

Invariants (property-tested in tests/test_budget.py):
  * conservation: total spent == sum of per-provider spend == sum of events
  * remaining == budget - spent, never silently negative
  * each threshold fires exactly once, in descending order
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple


@dataclass
class SpendEvent:
    t: float                    # hours since campaign start
    provider: str
    amount: float
    note: str = ""


@dataclass
class BudgetLedger:
    total_budget: float
    thresholds: Tuple[float, ...] = (0.5, 0.25, 0.2, 0.1, 0.05)
    events: List[SpendEvent] = field(default_factory=list)
    by_provider: Dict[str, float] = field(default_factory=dict)
    spent: float = 0.0
    _fired: set = field(default_factory=set)
    _callbacks: List[Callable] = field(default_factory=list)
    overdraft: float = 0.0
    # prefix sums over events, parallel to `events`, for O(log n)
    # spend_rate (a two-week array-engine replay logs ~20k charge events;
    # the object engine, millions)
    _times: List[float] = field(default_factory=list)
    _cum: List[float] = field(default_factory=list)
    _monotonic: bool = True

    def on_threshold(self, cb: Callable[[float, float, float], None]):
        """cb(remaining_fraction, remaining_amount, spend_rate_per_day)."""
        self._callbacks.append(cb)

    def charge(self, provider: str, amount: float, t: float, note: str = ""):
        if amount < 0:
            raise ValueError("charges must be non-negative")
        self.events.append(SpendEvent(t, provider, amount, note))
        if self._times and t < self._times[-1]:
            self._monotonic = False
        self._times.append(t)
        self._cum.append((self._cum[-1] if self._cum else 0.0) + amount)
        self.by_provider[provider] = self.by_provider.get(provider, 0.) + amount
        self.spent += amount
        if self.spent > self.total_budget:
            self.overdraft = self.spent - self.total_budget
        frac = self.remaining_fraction()
        for th in sorted(self.thresholds, reverse=True):
            if frac <= th and th not in self._fired:
                self._fired.add(th)
                rate = self.spend_rate(t, window_h=72.0)
                for cb in self._callbacks:
                    cb(frac, self.remaining(), rate)

    def remaining(self) -> float:
        return max(0.0, self.total_budget - self.spent)

    def remaining_fraction(self) -> float:
        return self.remaining() / self.total_budget if self.total_budget else 0.

    def spend_rate(self, now_h: float, window_h: float = 72.0) -> float:
        """$/day over the past `window_h` hours (the periodic e-mail's
        'spending rate over the past few days')."""
        lo = now_h - window_h
        if self._monotonic:
            i = bisect.bisect_left(self._times, lo)
            recent = (self._cum[-1] if self._cum else 0.0) \
                - (self._cum[i - 1] if i else 0.0)
        else:   # charges arrived out of order: fall back to a scan
            recent = sum(e.amount for e in self.events if e.t >= lo)
        span_days = min(window_h, max(now_h, 1e-9)) / 24.0
        return recent / max(span_days, 1e-9)

    def report(self) -> dict:
        """The 'single window' web page."""
        return {
            "total_spent": round(self.spent, 2),
            "by_provider": {k: round(v, 2)
                            for k, v in sorted(self.by_provider.items())},
            "remaining": round(self.remaining(), 2),
            "remaining_fraction": round(self.remaining_fraction(), 4),
            "overdraft": round(self.overdraft, 2),
        }
