"""One declarative registry for every timed campaign event.

Historically each timed spec event (the paper's staged ramp and CE
outage, PR 3's price/capacity/floor shifts, PR 4's PriceCurve) was
dispatched by four hand-maintained ``if``-ladders that had to agree:
``spec.py`` per-event ``install`` closures (solo engines),
``sweep.py`` ``_compile_timeline`` + ``_run_events`` (batched engine),
``spec.lint_spec`` and the JSON (de)serialization — plus matching ops
on both provisioners.  Adding one event meant five-plus coordinated
edits, which is what kept serving-load and data-plane events off the
roadmap.

This module collapses all of it into data:

  * :class:`EngineOps` — the narrow protocol an engine exposes to the
    timeline (``scale_to`` / ``deprovision_all`` / ``set_outage`` /
    ``scale_prices`` / ``set_price_factor`` / ``scale_capacity`` /
    ``arm_budget_floor`` / ``set_workload_factor`` plus the
    ``budget_capped`` / ``downscale_target`` cap state).  The solo
    controller (``spec.TimelineController``, driving both the object
    and array engines through ``sim.prov``/``sim.ce``), the batched
    per-lane adapter (``sweep._LaneOps``) and the compiled engine's
    planner adapter (``sweep_jax.JaxLaneOps`` — driven ahead of time by
    the segment splitter to bake per-segment parameter planes, since a
    jitted scan cannot call back into Python at tick time) implement
    it.
  * :class:`OpSpec` — one compiled operation: how to apply it against
    ``EngineOps`` (returning the provenance record body), how to
    render the solo log line, and which EngineOps members it requires
    (the drift guard ``registry_findings`` checks).
  * :class:`EventType` — one registered event kind: its frozen
    dataclass, compile-to-``(t, op, arg)`` form, lint rules, JSON
    decode coercions, validation, and a hypothesis strategy so the
    differential harness sweeps it automatically.

**Adding a timed event is now one registration here plus (if needed)
new ``EngineOps`` method bodies on the two adapters** — serialization,
linting, solo installation, batched compilation, the lint CLI's
``--registry`` check and the property-test strategies all derive from
the registry entry.  ``WorkloadCurve`` (request-rate over time,
mirroring ``PriceCurve``) is the first event landed through this path.

Bit-identity contract: ``apply`` bodies must perform the exact float-op
sequence every engine shares (see the billing-rate discipline in
core/sweep.py); the shared ``apply`` *is* that single definition, so
the three engines cannot drift.  The statistical ``engine="jax"`` runs
the very same bodies — just ahead of time, against ``JaxLaneOps``
planner state during segment splitting — so its control parameters
(rates, caps, outages, floor arming) are float-identical even though
its per-instance randomness is not.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

try:                                       # typing.Protocol: py3.8+
    from typing import Protocol
except ImportError:                        # pragma: no cover
    Protocol = object


class EngineOps(Protocol):
    """What an engine must expose for the timeline to drive it.

    The solo adapter is ``spec.TimelineController`` (delegating fleet
    ops to ``sim.prov``/``sim.ce`` — identical facades on the object
    and array engines); the batched adapter is ``sweep._LaneOps`` (one
    lane's slice of the struct-of-arrays state).  ``registry_findings``
    hasattr-checks each op's ``requires`` against both."""

    budget_capped: bool       # has the budget floor fired?
    downscale_target: int     # cap applied to targets once fired

    def scale_to(self, n: int) -> None: ...
    def deprovision_all(self) -> None: ...
    def set_outage(self, on: bool) -> None: ...
    def scale_prices(self, factor: float) -> None: ...
    def set_price_factor(self, provider: Optional[str],
                         factor: float) -> None: ...
    def scale_capacity(self, factor: float) -> None: ...
    def arm_budget_floor(self, fraction: float, target: int) -> None: ...
    def set_workload_factor(self, factor: float) -> None: ...
    def set_origin_outage(self, provider: str, on: bool) -> None: ...
    def degrade_origin(self, provider: str, factor: float) -> None: ...
    def flush_cache(self, provider: str) -> None: ...


# -- the event dataclasses -------------------------------------------------

@dataclass(frozen=True)
class SetTarget:
    """Scale the global fleet target (staged-ramp step).  While the
    budget floor has fired, targets are capped at the downscale target —
    the controller semantics of the paper's staged ramp."""
    at_h: float
    target: int

    kind = "set_target"


@dataclass(frozen=True)
class CEOutage:
    """Total CE backend collapse at ``at_h``: instant fleet-wide
    deprovision ("minimal financial loss"), then resume at
    ``resume_target`` once the outage clears."""
    at_h: float
    duration_h: float = 2.0
    resume_target: int = 1000

    kind = "ce_outage"


@dataclass(frozen=True)
class PriceShift:
    """Uniform market drift at ``at_h``: every provider's $/day is
    multiplied by ``factor`` from then on (already-billed hours keep
    their old price).  Uniformity preserves the price-priority fill
    order, so provisioning decisions stay comparable."""
    at_h: float
    factor: float

    kind = "price_shift"


@dataclass(frozen=True)
class BudgetFloor:
    """(Re)arm the budget tripwire at ``at_h``: once remaining budget
    crosses ``fraction``, cap the fleet at ``downscale_target`` (the
    paper's "20% budget left -> resume at only 1k" decision).  A floor
    that already fired stays fired."""
    at_h: float
    fraction: float
    downscale_target: int

    kind = "budget_floor"


@dataclass(frozen=True)
class CapacityShift:
    """Capacity weather at ``at_h``: every region's spot capacity is
    multiplied by ``factor`` (floored at 1 instance).  Shrinking below
    the live count does not evict running instances — groups simply
    stop refilling (provider group semantics)."""
    at_h: float
    factor: float

    kind = "capacity_shift"


@dataclass(frozen=True)
class PriceCurve:
    """A piecewise-constant multi-day $/h curve: at each ``(t_h, factor)``
    breakpoint the price factor is *set* to ``factor`` (absolute, unlike
    the cumulative ``PriceShift`` multiplier), so a drifting spot market
    is declared as one curve instead of a chain of compensating shifts.
    ``provider=None`` drives every provider's rate; naming a provider
    drives that provider's groups only (per-provider curve factors stack
    multiplicatively on the uniform ``PriceShift`` scalar).  Already-
    billed hours keep their old price."""
    points: Tuple[Tuple[float, float], ...]
    provider: Optional[str] = None

    kind = "price_curve"

    @property
    def at_h(self) -> float:
        """First breakpoint time (lint/sorting anchor)."""
        return self.points[0][0] if self.points else 0.0


@dataclass(frozen=True)
class WorkloadCurve:
    """Request-rate over time (the serving-load mirror of PriceCurve):
    at each ``(t_h, factor)`` breakpoint the campaign's job-arrival
    factor is *set* to ``factor`` — the CE queue tops up to
    ``int(min_queue * factor)`` from then on.  Diurnal peaks, flash
    crowds and regional demand shifts become one declarative curve,
    interpreted bit-identically by all three engines (``int * float``
    is the same IEEE product everywhere, and the factor only changes
    at event time).  Factors below the fleet's drain rate starve
    pilots — the "what does it cost to serve N users through a
    spot-market week" question asked of load instead of price."""
    points: Tuple[Tuple[float, float], ...]

    kind = "workload_curve"

    @property
    def at_h(self) -> float:
        """First breakpoint time (lint/sorting anchor)."""
        return self.points[0][0] if self.points else 0.0


@dataclass(frozen=True)
class OriginOutage:
    """The ``provider``'s data origin goes dark at ``at_h`` for
    ``duration_h``: its pilots take no NEW jobs (a job cannot stage in)
    while in-flight transfers keep streaming; other providers' pilots
    keep matching.  The data-plane mirror of :class:`CEOutage` — the
    fleet itself stays up and billed."""
    at_h: float
    duration_h: float = 2.0
    provider: str = "azure"

    kind = "origin_outage"


@dataclass(frozen=True)
class OriginDegrade:
    """WAN weather: from ``at_h`` on, the ``provider`` origin's miss
    bandwidth is multiplied by ``factor`` (cumulative, like
    :class:`PriceShift`).  Cache hits keep streaming at the cache
    tier's bandwidth; in-flight stage-ins keep their locked rate."""
    at_h: float
    factor: float = 0.5
    provider: str = "azure"

    kind = "origin_degrade"


@dataclass(frozen=True)
class CacheFlush:
    """The ``provider``'s regional cache is flushed at ``at_h``: every
    pilot's deterministic hit rotation restarts (the first post-flush
    stage-ins re-miss and re-pay egress until the cache re-warms)."""
    at_h: float
    provider: str = "azure"

    kind = "cache_flush"


# -- registry plumbing -----------------------------------------------------

@dataclass(frozen=True)
class OpSpec:
    """One compiled timeline operation.

    ``apply(ops, arg)`` performs the op against an :class:`EngineOps`
    adapter and returns the provenance-record *body* (no ``"t"`` key —
    ``apply_op`` stamps it); ``describe(record)`` renders the solo
    controller's human log line; ``requires`` / ``prov_requires`` are
    the EngineOps / provisioner-facade members the op depends on (what
    ``registry_findings`` drift-checks)."""
    kind: str                              # compiled op tag
    event: str                             # record "event" field value
    requires: Tuple[str, ...]              # EngineOps members used
    apply: Callable[[Any, Any], dict]
    describe: Callable[[dict], str]
    prov_requires: Tuple[str, ...] = ()    # provisioner-facade members


@dataclass(frozen=True)
class EventType:
    """One registered timed-event kind — the single place an event
    declares everything every layer needs."""
    kind: str
    cls: type
    compile: Callable[[Any], List[tuple]]  # ev -> [(t, op_kind, arg)]
    ops: Tuple[str, ...]                   # op kinds compile may emit
    lint: Callable[[Any, str, Optional[set]], List[str]]
    lint_times: Callable[[Any], List[float]]   # dead-event check times
    decode: Callable[[dict], dict]         # JSON kwargs coercion
    validate: Callable[[Any], None]        # raises ValueError
    strategy: Callable[[Any], Any]         # hypothesis strategies module
    sample: Callable[[], Any]              # canonical example instance
    is_curve: bool = False                 # multi-point: exempt from the
    #                                        duplicate-anchor-time lint


REGISTRY: Dict[str, EventType] = {}
OPS: Dict[str, OpSpec] = {}
_DESCRIBE: Dict[str, OpSpec] = {}          # record "event" -> op

#: THE adapter roster — which class implements :class:`EngineOps` for
#: each engine, as ``"module:Class"`` strings.  Two consumers read this
#: single source of truth: ``campaigns lint --registry`` resolves the
#: classes at runtime (:func:`resolve_adapters` + hasattr drift checks)
#: and the static analyzer (``repro.analysis.staticcheck``, rule REG002)
#: reads the *literal* dict from this file's AST without importing
#: engine code — so keep the values plain string literals.  A new
#: engine's adapter is registered by adding one line here.
ENGINE_ADAPTERS: Dict[str, str] = {
    "solo": "repro.core.spec:TimelineController",
    "batched": "repro.core.sweep:_LaneOps",
    "jax": "repro.core.sweep_jax:JaxLaneOps",
}

#: the solo provisioner facades ops with ``prov_requires`` depend on
#: (same literal-string contract as :data:`ENGINE_ADAPTERS`; rule
#: REG003 reads it statically)
PROVISIONER_FACADES: Dict[str, str] = {
    "object": "repro.core.provisioner:MultiCloudProvisioner",
    "array": "repro.core.fleet:ArrayProvisionerView",
}


def resolve_adapters(refs: Mapping[str, str]) -> Dict[str, type]:
    """Import the ``"module:Class"`` values of an adapter roster —
    the runtime half of the metadata contract above."""
    import importlib
    out: Dict[str, type] = {}
    for name, ref in refs.items():
        module, _, cls = ref.partition(":")
        out[name] = getattr(importlib.import_module(module), cls)
    return out


def register_op(op: OpSpec) -> OpSpec:
    if op.kind in OPS:
        raise ValueError(f"duplicate op kind {op.kind!r}")
    OPS[op.kind] = op
    _DESCRIBE[op.event] = op
    return op


def register_event(et: EventType) -> EventType:
    if et.kind in REGISTRY:
        raise ValueError(f"duplicate event kind {et.kind!r}")
    unknown = set(et.ops) - set(OPS)
    if unknown:
        raise ValueError(f"event {et.kind!r} compiles to unregistered "
                         f"ops {sorted(unknown)}")
    REGISTRY[et.kind] = et
    return et


def _no_lint(ev, at, known_providers):
    return []


def _identity(d: dict) -> dict:
    return d


def _no_validate(ev):
    return None


def _anchor_times(ev) -> List[float]:
    return [ev.at_h]


def _point_times(ev) -> List[float]:
    return [t for t, _f in ev.points]


def _decode_points(d: dict) -> dict:
    d = dict(d)
    d["points"] = tuple((float(t), float(f)) for t, f in d["points"])
    return d


def _validate_points(ev):
    for p in ev.points:
        if len(p) != 2:
            raise ValueError(f"{type(ev).__name__} points must be "
                             f"(t_h, factor) pairs, got {p!r}")


# -- shared hypothesis sub-strategies (each takes the ``st`` module) -------

def _st_times(st):
    return st.integers(0, 120).map(lambda q: q * 0.25)


def _st_factors(st):
    return st.sampled_from([0.5, 0.8, 1.25, 2.0])


def _curve_points(ts, fs) -> Tuple[Tuple[float, float], ...]:
    # strictly increasing breakpoint times, one factor each
    ts = sorted(set(ts))
    return tuple(zip(ts, fs[:len(ts)]))


def _st_points(st, factors):
    return st.builds(_curve_points,
                     st.lists(_st_times(st), min_size=1, max_size=3),
                     st.lists(factors, min_size=3, max_size=3))


# -- the operations --------------------------------------------------------

def _apply_scale(ops, arg) -> dict:
    tgt = min(int(arg), int(ops.downscale_target)) \
        if ops.budget_capped else int(arg)
    ops.scale_to(tgt)
    return {"event": "scale", "target": int(tgt)}


def _apply_outage_on(ops, arg) -> dict:
    ops.set_outage(True)
    ops.deprovision_all()
    return {"event": "outage_on"}


def _apply_outage_off(ops, arg) -> dict:
    ops.set_outage(False)
    ops.scale_to(int(arg))
    return {"event": "outage_off", "target": int(arg)}


def _apply_price(ops, arg) -> dict:
    ops.scale_prices(arg)
    return {"event": "price", "factor": float(arg)}


def _apply_curve(ops, arg) -> dict:
    provider, f = arg
    ops.set_price_factor(provider, f)
    return {"event": "price_curve", "provider": provider,
            "factor": float(f)}


def _apply_capacity(ops, arg) -> dict:
    ops.scale_capacity(arg)
    return {"event": "capacity", "factor": float(arg)}


def _apply_floor(ops, arg) -> dict:
    fraction, tgt = arg
    ops.arm_budget_floor(fraction, tgt)
    return {"event": "floor", "fraction": float(fraction),
            "target": int(tgt)}


def _apply_workload(ops, arg) -> dict:
    ops.set_workload_factor(arg)
    return {"event": "workload", "factor": float(arg)}


register_op(OpSpec(
    kind="scale", event="scale",
    requires=("scale_to", "budget_capped", "downscale_target"),
    prov_requires=("scale_to",),
    apply=_apply_scale,
    describe=lambda r: f"scale_to({r['target']})"))
register_op(OpSpec(
    kind="outage_on", event="outage_on",
    requires=("set_outage", "deprovision_all"),
    prov_requires=("deprovision_all",),
    apply=_apply_outage_on,
    describe=lambda r: "CE OUTAGE -> deprovision all"))
register_op(OpSpec(
    kind="outage_off", event="outage_off",
    requires=("set_outage", "scale_to"),
    prov_requires=("scale_to",),
    apply=_apply_outage_off,
    describe=lambda r: f"CE recovered -> resume at {r['target']}"))
register_op(OpSpec(
    kind="price", event="price",
    requires=("scale_prices",), prov_requires=("scale_prices",),
    apply=_apply_price,
    describe=lambda r: f"price shift x{r['factor']}"))
register_op(OpSpec(
    kind="curve", event="price_curve",
    requires=("set_price_factor",), prov_requires=("set_price_factor",),
    apply=_apply_curve,
    describe=lambda r: (
        f"price curve "
        f"[{r['provider'] if r['provider'] is not None else 'all'}] "
        f"-> x{r['factor']}")))
register_op(OpSpec(
    kind="capacity", event="capacity",
    requires=("scale_capacity",), prov_requires=("scale_capacity",),
    apply=_apply_capacity,
    describe=lambda r: f"capacity shift x{r['factor']}"))
register_op(OpSpec(
    kind="floor", event="floor",
    requires=("arm_budget_floor",),
    apply=_apply_floor,
    describe=lambda r: (f"budget floor armed at {r['fraction']:.0%} "
                        f"-> {r['target']}")))
register_op(OpSpec(
    kind="workload", event="workload",
    requires=("set_workload_factor",),
    apply=_apply_workload,
    describe=lambda r: f"workload curve -> x{r['factor']}"))


def _apply_origin_on(ops, arg) -> dict:
    ops.set_origin_outage(arg, True)
    return {"event": "origin_outage_on", "provider": str(arg)}


def _apply_origin_off(ops, arg) -> dict:
    ops.set_origin_outage(arg, False)
    return {"event": "origin_outage_off", "provider": str(arg)}


def _apply_origin_degrade(ops, arg) -> dict:
    provider, f = arg
    ops.degrade_origin(provider, f)
    return {"event": "origin_degrade", "provider": str(provider),
            "factor": float(f)}


def _apply_cache_flush(ops, arg) -> dict:
    ops.flush_cache(arg)
    return {"event": "cache_flush", "provider": str(arg)}


register_op(OpSpec(
    kind="origin_on", event="origin_outage_on",
    requires=("set_origin_outage",),
    apply=_apply_origin_on,
    describe=lambda r: f"ORIGIN OUTAGE [{r['provider']}] -> "
                       "no new stage-ins"))
register_op(OpSpec(
    kind="origin_off", event="origin_outage_off",
    requires=("set_origin_outage",),
    apply=_apply_origin_off,
    describe=lambda r: f"origin recovered [{r['provider']}]"))
register_op(OpSpec(
    kind="origin_degrade", event="origin_degrade",
    requires=("degrade_origin",),
    apply=_apply_origin_degrade,
    describe=lambda r: (f"origin degrade [{r['provider']}] "
                        f"x{r['factor']}")))
register_op(OpSpec(
    kind="cache_flush", event="cache_flush",
    requires=("flush_cache",),
    apply=_apply_cache_flush,
    describe=lambda r: f"cache flush [{r['provider']}]"))


# -- the event registrations -----------------------------------------------

register_event(EventType(
    kind=SetTarget.kind, cls=SetTarget,
    compile=lambda ev: [(ev.at_h, "scale", ev.target)],
    ops=("scale",),
    lint=lambda ev, at, kp: (
        [f"SPEC110: {at}: negative target {ev.target}"]
        if ev.target < 0 else []),
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(SetTarget, at_h=_st_times(st),
                                  target=st.integers(0, 600)),
    sample=lambda: SetTarget(0.0, 100)))


def _lint_outage(ev, at, known_providers):
    out = []
    if ev.duration_h <= 0:
        out.append(f"SPEC111: {at}: outage duration must be positive")
    if ev.resume_target < 0:
        out.append(f"SPEC112: {at}: negative resume_target "
                   f"{ev.resume_target}")
    return out


register_event(EventType(
    kind=CEOutage.kind, cls=CEOutage,
    compile=lambda ev: [(ev.at_h, "outage_on", 0),
                        (ev.at_h + ev.duration_h, "outage_off",
                         ev.resume_target)],
    ops=("outage_on", "outage_off"),
    lint=_lint_outage,
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        CEOutage, at_h=_st_times(st),
        duration_h=st.sampled_from([1.0, 2.0, 6.0]),
        resume_target=st.integers(0, 400)),
    sample=lambda: CEOutage(10.0, 2.0, 50)))

register_event(EventType(
    kind=PriceShift.kind, cls=PriceShift,
    compile=lambda ev: [(ev.at_h, "price", ev.factor)],
    ops=("price",),
    lint=lambda ev, at, kp: (
        [f"SPEC113: {at}: factor must be positive, got {ev.factor}"]
        if ev.factor <= 0 else []),
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(PriceShift, at_h=_st_times(st),
                                  factor=_st_factors(st)),
    sample=lambda: PriceShift(5.0, 1.5)))


def _lint_floor(ev, at, known_providers):
    out = []
    if not 0.0 <= ev.fraction <= 1.0:
        out.append(f"SPEC114: {at}: fraction {ev.fraction} "
                   "outside [0, 1]")
    if ev.downscale_target < 0:
        out.append(f"SPEC115: {at}: negative downscale_target "
                   f"{ev.downscale_target}")
    return out


register_event(EventType(
    kind=BudgetFloor.kind, cls=BudgetFloor,
    compile=lambda ev: [(ev.at_h, "floor",
                         (ev.fraction, ev.downscale_target))],
    ops=("floor",),
    lint=_lint_floor,
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        BudgetFloor, at_h=_st_times(st),
        # ledger-threshold values only: the cap decision is then
        # charge-order independent
        fraction=st.sampled_from([0.05, 0.1, 0.2, 0.25, 0.5]),
        downscale_target=st.integers(0, 300)),
    sample=lambda: BudgetFloor(3.0, 0.25, 40)))

register_event(EventType(
    kind=CapacityShift.kind, cls=CapacityShift,
    compile=lambda ev: [(ev.at_h, "capacity", ev.factor)],
    ops=("capacity",),
    lint=lambda ev, at, kp: (
        [f"SPEC113: {at}: factor must be positive, got {ev.factor}"]
        if ev.factor <= 0 else []),
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        CapacityShift, at_h=_st_times(st),
        factor=st.sampled_from([0.25, 0.5, 1.5, 2.0])),
    sample=lambda: CapacityShift(7.0, 0.5)))


def _lint_price_curve(ev, at, known_providers):
    out = []
    if not ev.points:
        out.append(f"SPEC116: {at}: empty curve (no points)")
    pt = None
    for t, f in ev.points:
        if f <= 0:
            out.append(f"SPEC117: {at}: non-positive price factor {f} "
                       f"at t={t}")
        if pt is not None and t <= pt:
            out.append(f"SPEC118: {at}: curve points not strictly "
                       f"time-sorted ({t} after {pt})")
        pt = t
    if ev.provider is not None and known_providers is not None \
            and ev.provider not in known_providers:
        out.append(f"SPEC119: {at}: unknown provider {ev.provider!r} "
                   f"(catalog has {sorted(known_providers)})")
    return out


register_event(EventType(
    kind=PriceCurve.kind, cls=PriceCurve,
    # one op per breakpoint, at its own time (the solo controller
    # installs each point as its own one-shot)
    compile=lambda ev: [(t, "curve", (ev.provider, f))
                        for t, f in ev.points],
    ops=("curve",),
    lint=_lint_price_curve,
    lint_times=_point_times, decode=_decode_points,
    validate=_validate_points,
    strategy=lambda st: st.one_of(
        st.builds(PriceCurve, points=_st_points(st, _st_factors(st))),
        st.builds(PriceCurve, points=_st_points(st, _st_factors(st)),
                  provider=st.sampled_from(
                      ["azure", "gcp", "no-such-provider"]))),
    sample=lambda: PriceCurve(((2.0, 1.1), (4.0, 0.9))),
    is_curve=True))


def _lint_workload_curve(ev, at, known_providers):
    out = []
    if not ev.points:
        out.append(f"SPEC116: {at}: empty curve (no points)")
    pt = None
    for t, f in ev.points:
        if f < 0:
            out.append(f"SPEC117: {at}: negative request-rate factor "
                       f"{f} at t={t}")
        if pt is not None and t <= pt:
            out.append(f"SPEC118: {at}: curve points not strictly "
                       f"time-sorted ({t} after {pt})")
        pt = t
    return out


register_event(EventType(
    kind=WorkloadCurve.kind, cls=WorkloadCurve,
    compile=lambda ev: [(t, "workload", f) for t, f in ev.points],
    ops=("workload",),
    lint=_lint_workload_curve,
    lint_times=_point_times, decode=_decode_points,
    validate=_validate_points,
    strategy=lambda st: st.builds(
        WorkloadCurve,
        points=_st_points(st, st.sampled_from([0.0, 0.25, 0.5, 1.0,
                                               1.5]))),
    sample=lambda: WorkloadCurve(((2.0, 0.5), (4.0, 1.0))),
    is_curve=True))


def _lint_origin_provider(provider, at, known_providers) -> List[str]:
    """Unknown-provider check shared by the data-plane events: the
    name must match a catalog provider directly or as the base of a
    sliced pool (``azure`` covers ``azure/4``)."""
    if known_providers is None:
        return []
    bases = {p.split("/", 1)[0] for p in known_providers}
    if provider in known_providers or provider in bases:
        return []
    return [f"SPEC119: {at}: unknown provider {provider!r} "
            f"(catalog has {sorted(known_providers)})"]


def _lint_origin_outage(ev, at, known_providers):
    out = []
    if ev.duration_h <= 0:
        out.append(f"SPEC111: {at}: outage duration must be positive")
    out.extend(_lint_origin_provider(ev.provider, at, known_providers))
    return out


def _lint_origin_degrade(ev, at, known_providers):
    out = []
    if ev.factor <= 0:
        out.append(f"SPEC113: {at}: factor must be positive, "
                   f"got {ev.factor}")
    out.extend(_lint_origin_provider(ev.provider, at, known_providers))
    return out


_ST_ORIGIN_PROVIDERS = ("azure", "gcp", "aws")

register_event(EventType(
    kind=OriginOutage.kind, cls=OriginOutage,
    compile=lambda ev: [(ev.at_h, "origin_on", ev.provider),
                        (ev.at_h + ev.duration_h, "origin_off",
                         ev.provider)],
    ops=("origin_on", "origin_off"),
    lint=_lint_origin_outage,
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        OriginOutage, at_h=_st_times(st),
        duration_h=st.sampled_from([1.0, 2.0, 6.0]),
        provider=st.sampled_from(_ST_ORIGIN_PROVIDERS)),
    sample=lambda: OriginOutage(8.0, 2.0, "azure")))

register_event(EventType(
    kind=OriginDegrade.kind, cls=OriginDegrade,
    compile=lambda ev: [(ev.at_h, "origin_degrade",
                         (ev.provider, ev.factor))],
    ops=("origin_degrade",),
    lint=_lint_origin_degrade,
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        OriginDegrade, at_h=_st_times(st),
        factor=st.sampled_from([0.25, 0.5, 2.0]),
        provider=st.sampled_from(_ST_ORIGIN_PROVIDERS)),
    sample=lambda: OriginDegrade(6.0, 0.5, "azure")))

register_event(EventType(
    kind=CacheFlush.kind, cls=CacheFlush,
    compile=lambda ev: [(ev.at_h, "cache_flush", ev.provider)],
    ops=("cache_flush",),
    lint=lambda ev, at, kp: _lint_origin_provider(ev.provider, at, kp),
    lint_times=_anchor_times, decode=_identity, validate=_no_validate,
    strategy=lambda st: st.builds(
        CacheFlush, at_h=_st_times(st),
        provider=st.sampled_from(_ST_ORIGIN_PROVIDERS)),
    sample=lambda: CacheFlush(4.0, "azure")))


Event = Union[SetTarget, CEOutage, PriceShift, BudgetFloor, CapacityShift,
              PriceCurve, WorkloadCurve, OriginOutage, OriginDegrade,
              CacheFlush]
EVENT_KINDS: Dict[str, type] = {k: et.cls for k, et in REGISTRY.items()}


# -- registry-derived operations (what the engines/CLI/tests call) ---------

def compile_event(ev) -> List[tuple]:
    """One event's ``(t, op_kind, arg)`` expansion, in declaration
    order (CEOutage becomes on/off at its declaration point)."""
    et = REGISTRY.get(getattr(ev, "kind", None))
    if et is None or type(ev) is not et.cls:
        raise ValueError(f"unknown timeline event {ev!r}")
    return et.compile(ev)


def compile_timeline(timeline: Sequence) -> List[tuple]:
    """Flatten an event timeline into stably time-sorted
    ``(t, op_kind, arg)`` tuples — the same expansion order and
    tie-breaking (stable by timeline position) as the solo controller's
    one-shot installation."""
    evs: List[tuple] = []
    for ev in timeline:
        evs.extend(compile_event(ev))
    evs.sort(key=lambda e: e[0])
    return evs


def apply_op(ops: EngineOps, op_kind: str, arg, now: float) -> dict:
    """Execute one compiled op against an engine adapter; returns the
    provenance record (bit-identical across engines)."""
    body = OPS[op_kind].apply(ops, arg)
    return {"t": float(now), **body}


def apply_budget_cap(ops: EngineOps, now: float) -> dict:
    """The budget-floor tripwire's deferred cap (scheduled "at now" by
    the ledger alert, executed at the next tick's event phase): cap the
    fleet at the armed downscale target.  Shared so the solo controller
    and every batched lane record the identical provenance."""
    tgt = int(ops.downscale_target)
    ops.scale_to(tgt)
    return {"t": float(now), "event": "budget_floor", "target": tgt}


def describe_record(record: dict) -> str:
    """The solo controller's human log-line body for one provenance
    record (the ``t=...h`` prefix is the controller's)."""
    return _DESCRIBE[record["event"]].describe(record)


def event_to_dict(ev) -> dict:
    """JSON form: ``{"kind": ..., **fields}`` (round-trips via
    :func:`event_from_dict`)."""
    return {"kind": ev.kind, **asdict(ev)}


def event_from_dict(d: Mapping):
    d = dict(d)
    kind = d.pop("kind")
    et = REGISTRY.get(kind)
    if et is None:
        raise ValueError(f"unknown timeline event kind {kind!r}")
    return et.cls(**et.decode(d))


def validate_event(ev):
    """Raise ValueError on unregistered or malformed events (the
    fail-fast complement of :func:`lint_timeline`)."""
    et = REGISTRY.get(getattr(ev, "kind", None))
    if et is None or type(ev) is not et.cls:
        raise ValueError(f"unknown timeline event {ev!r}")
    et.validate(ev)


def lint_timeline(timeline: Sequence, duration_h: float,
                  known_providers: Optional[set]) -> List[str]:
    """Registry-derived static checks over a spec's event timeline:
    ordering/dead-time/duplicate-time checks plus every event kind's
    own lint rules.  Returns human-readable findings (empty == clean);
    never raises."""
    out: List[str] = []
    prev_t = None
    seen_times: Dict[float, int] = {}
    for i, ev in enumerate(timeline):
        at = f"timeline[{i}] {type(ev).__name__}"
        et = REGISTRY.get(getattr(ev, "kind", None))
        if et is None or type(ev) is not et.cls:
            out.append(f"SPEC101: {at}: unknown timeline event")
            continue
        t0 = ev.at_h
        if t0 < 0:
            out.append(f"SPEC102: {at}: negative event time {t0}")
        if prev_t is not None and t0 < prev_t:
            out.append(f"SPEC103: {at}: event times not sorted "
                       f"({t0} after {prev_t})")
        prev_t = max(t0, prev_t) if prev_t is not None else t0
        # dead events never execute: anchor for plain events, every
        # breakpoint for curves
        for t in et.lint_times(ev):
            if t >= duration_h:
                out.append(f"SPEC104: {at}: fires at t={t} h, at/after "
                           f"the campaign end ({duration_h} h) — never "
                           "executes")
        if not et.is_curve:
            seen_times[t0] = seen_times.get(t0, 0) + 1
        out.extend(et.lint(ev, at, known_providers))
    for t, n in seen_times.items():
        if n > 1:
            out.append(f"SPEC105: timeline: {n} events share t={t} h — "
                       "they execute in declaration order; split the "
                       "times if that overlap is unintended")
    return out


def event_strategies(st) -> List:
    """One hypothesis strategy per registered event kind (pass the
    ``hypothesis.strategies`` module) — the differential harness sweeps
    newly registered events with no hand edits."""
    return [et.strategy(st) for et in REGISTRY.values()]


def registry_findings(engines: Mapping[str, type],
                      provisioners: Optional[Mapping[str, type]] = None
                      ) -> List[str]:
    """The drift guard: every registered event must compile to handled
    ops, and every op's required members must exist on every engine
    adapter (and, where the op touches the fleet, on every provisioner
    facade).  Returns findings (empty == every event is registered for
    all engines) — surfaced by ``python -m repro.campaigns lint
    --registry`` and pinned by tests/test_timeline_registry.py."""
    out: List[str] = []
    for kind, et in sorted(REGISTRY.items()):
        for op_kind in et.ops:
            op = OPS.get(op_kind)
            if op is None:
                # rule ids shared with the static analyzer: this is the
                # runtime (hasattr) twin of staticcheck's REG family
                out.append(f"REG001: event {kind!r}: compiled op "
                           f"{op_kind!r} has no registered handler")
                continue
            for engine, cls in sorted(engines.items()):
                missing = sorted(a for a in op.requires
                                 if not hasattr(cls, a))
                if missing:
                    out.append(
                        f"REG002: event {kind!r}: op {op_kind!r} needs "
                        f"EngineOps member(s) {missing} missing on the "
                        f"{engine} adapter "
                        f"({cls.__module__}.{cls.__name__})")
            for prov, cls in sorted((provisioners or {}).items()):
                missing = sorted(a for a in op.prov_requires
                                 if not hasattr(cls, a))
                if missing:
                    out.append(
                        f"REG003: event {kind!r}: op {op_kind!r} needs "
                        f"provisioner member(s) {missing} missing on "
                        f"the {prov} facade "
                        f"({cls.__module__}.{cls.__name__})")
    return out
