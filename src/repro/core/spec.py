"""One declarative, serializable description of a campaign: CampaignSpec.

The paper's exercise was one hand-driven two-week run; sweep-scale
planning (HEPCloud-style pre-burst studies, per-scenario cost analyses)
wants campaign definitions that are *data*: storable, diffable,
sweepable, replayable in CI.  Historically a campaign's definition was
smeared across four layers — ``SimConfig``, the frozen ``Scenario``
dataclass, ``run_campaign()``'s keyword knobs and opaque
``sim.at(lambda sim: ...)`` callbacks inside ``CampaignController`` — so
adding one knob touched all four and nothing serialized.

``CampaignSpec`` subsumes all of it:

  * catalog choice (named ``"t4"``/``"heterogeneous"`` catalogs or an
    inline ``providers`` tuple) plus the catalog transforms
    (capacity/price scaling, spot/on-demand carve-out),
  * the fleet/billing knobs that used to live on ``SimConfig``,
  * the budget-floor tripwire that used to live on the controller, and
  * a **declarative event timeline** — ``SetTarget`` / ``CEOutage`` /
    ``PriceShift`` / ``BudgetFloor`` / ``CapacityShift`` frozen
    dataclasses with times — replacing the Python-callback idiom.  Every
    execution engine (solo object, solo array, batched sweep) interprets
    the same timeline, so a spec runs bit-identically everywhere.

Specs round-trip losslessly through JSON (``to_json``/``from_json``),
which unlocks the ``python -m repro.campaigns`` CLI and committed golden
specs in CI.  ``CampaignSpec()`` with no arguments IS the paper replay:
T4 catalog, $58k budget, staged ramp to 2k GPUs, the d10.5 CE outage,
the 20 %-budget-floor downscale.

Results come back typed: :class:`CampaignResult` (with paper-comparison
helpers for the ~$58k / ~16k GPU-days / ~3.1 EFLOP-h / doubling claims)
instead of string-keyed dicts — though it still quacks like the old
``results()`` Mapping for back-compat.
"""
from __future__ import annotations

import json
from collections.abc import Mapping as MappingABC
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Dict, List, Mapping, Optional, Tuple, Union

from repro.core.events import CampaignTrace, TraceRecorder, build_trace
from repro.core.provider import (T4_FP32_TFLOPS, ProviderSpec, RegionSpec,
                                 heterogeneous_catalog, slice_provider,
                                 t4_catalog)
from repro.core.simulator import CloudSimulator, SimConfig

SCHEMA_VERSION = 1

# IceCube baseline for the "approximate doubling" claim (abstract/Fig 2):
# cloud GPU-hours ~ IceCube's contemporaneous non-cloud GPU-hours. Paper §I
# gives 8M GPU-h/yr on OSG (IceCube >80%); with dedicated non-OSG resources
# IceCube's effective baseline is ~9M GPU-h/yr -> ~350k per 2 weeks.
ICECUBE_BASELINE_GPUH_PER_2W = 9e6 * (14 / 365.0)

# §V summary claims the benchmarks compare against
PAPER_CLAIMS = {"cost": 58000.0, "accel_days": 16000.0,
                "eflop_hours_fp32": 3.1, "doubling": 2.0}


# -- the declarative event timeline ---------------------------------------

@dataclass(frozen=True)
class SetTarget:
    """Scale the global fleet target (staged-ramp step).  While the
    budget floor has fired, targets are capped at the downscale target —
    the controller semantics of the paper's staged ramp."""
    at_h: float
    target: int

    kind = "set_target"

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        def fire(s):
            t = min(self.target, ctl.downscale_target) \
                if ctl.budget_capped else self.target
            s.prov.scale_to(t, s.now)
            ctl.record(f"t={s.now:6.1f}h scale_to({t})",
                       {"t": float(s.now), "event": "scale",
                        "target": int(t)})
        sim.at(self.at_h, fire)


@dataclass(frozen=True)
class CEOutage:
    """Total CE backend collapse at ``at_h``: instant fleet-wide
    deprovision ("minimal financial loss"), then resume at
    ``resume_target`` once the outage clears."""
    at_h: float
    duration_h: float = 2.0
    resume_target: int = 1000

    kind = "ce_outage"

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        def outage(s):
            s.ce.outage = True
            s.prov.deprovision_all(s.now)
            ctl.record(f"t={s.now:6.1f}h CE OUTAGE -> deprovision all",
                       {"t": float(s.now), "event": "outage_on"})

        def recover(s):
            s.ce.outage = False
            s.prov.scale_to(self.resume_target, s.now)
            ctl.record(f"t={s.now:6.1f}h CE recovered -> resume at "
                       f"{self.resume_target}",
                       {"t": float(s.now), "event": "outage_off",
                        "target": int(self.resume_target)})
        sim.at(self.at_h, outage)
        sim.at(self.at_h + self.duration_h, recover)


@dataclass(frozen=True)
class PriceShift:
    """Uniform market drift at ``at_h``: every provider's $/day is
    multiplied by ``factor`` from then on (already-billed hours keep
    their old price).  Uniformity preserves the price-priority fill
    order, so provisioning decisions stay comparable."""
    at_h: float
    factor: float

    kind = "price_shift"

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        def fire(s):
            s.prov.scale_prices(self.factor)
            ctl.record(f"t={s.now:6.1f}h price shift x{self.factor}",
                       {"t": float(s.now), "event": "price",
                        "factor": float(self.factor)})
        sim.at(self.at_h, fire)


@dataclass(frozen=True)
class BudgetFloor:
    """(Re)arm the budget tripwire at ``at_h``: once remaining budget
    crosses ``fraction``, cap the fleet at ``downscale_target`` (the
    paper's "20% budget left -> resume at only 1k" decision).  A floor
    that already fired stays fired."""
    at_h: float
    fraction: float
    downscale_target: int

    kind = "budget_floor"

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        def fire(s):
            ctl.floor_fraction = self.fraction
            ctl.downscale_target = self.downscale_target
            ctl.record(f"t={s.now:6.1f}h budget floor armed at "
                       f"{self.fraction:.0%} -> {self.downscale_target}",
                       {"t": float(s.now), "event": "floor",
                        "fraction": float(self.fraction),
                        "target": int(self.downscale_target)})
        sim.at(self.at_h, fire)


@dataclass(frozen=True)
class CapacityShift:
    """Capacity weather at ``at_h``: every region's spot capacity is
    multiplied by ``factor`` (floored at 1 instance).  Shrinking below
    the live count does not evict running instances — groups simply
    stop refilling (provider group semantics)."""
    at_h: float
    factor: float

    kind = "capacity_shift"

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        def fire(s):
            s.prov.scale_capacity(self.factor)
            ctl.record(f"t={s.now:6.1f}h capacity shift x{self.factor}",
                       {"t": float(s.now), "event": "capacity",
                        "factor": float(self.factor)})
        sim.at(self.at_h, fire)


@dataclass(frozen=True)
class PriceCurve:
    """A piecewise-constant multi-day $/h curve: at each ``(t_h, factor)``
    breakpoint the price factor is *set* to ``factor`` (absolute, unlike
    the cumulative ``PriceShift`` multiplier), so a drifting spot market
    is declared as one curve instead of a chain of compensating shifts.
    ``provider=None`` drives every provider's rate; naming a provider
    drives that provider's groups only (per-provider curve factors stack
    multiplicatively on the uniform ``PriceShift`` scalar).  Already-
    billed hours keep their old price."""
    points: Tuple[Tuple[float, float], ...]
    provider: Optional[str] = None

    kind = "price_curve"

    @property
    def at_h(self) -> float:
        """First breakpoint time (lint/sorting anchor)."""
        return self.points[0][0] if self.points else 0.0

    def install(self, sim: CloudSimulator, ctl: "TimelineController"):
        who = self.provider if self.provider is not None else "all"
        for t, f in self.points:
            def fire(s, f=f):
                s.prov.set_price_factor(self.provider, f)
                ctl.record(f"t={s.now:6.1f}h price curve [{who}] -> x{f}",
                           {"t": float(s.now), "event": "price_curve",
                            "provider": self.provider,
                            "factor": float(f)})
            sim.at(t, fire)


Event = Union[SetTarget, CEOutage, PriceShift, BudgetFloor, CapacityShift,
              PriceCurve]
EVENT_KINDS = {cls.kind: cls for cls in
               (SetTarget, CEOutage, PriceShift, BudgetFloor, CapacityShift,
                PriceCurve)}


@dataclass(frozen=True)
class GpuSlicing:
    """Sub-GPU slicing (Sfiligoi 2022, "The anachronism of whole-GPU
    accounting"): plan capacity in fractional-GPU slices instead of
    whole devices.  Applied as a catalog transform: each matched
    provider becomes a ``name/k`` variant whose regions hold ``k``
    slices per physical GPU, priced and rated at ``1/k`` of the device
    (times the overhead factors — slicing is rarely perfectly free).
    ``providers=None`` slices the whole catalog."""
    slices: int = 2
    providers: Optional[Tuple[str, ...]] = None
    price_factor: float = 1.0    # per-slice $ = price/slices * this
    tflops_factor: float = 1.0   # per-slice peak = tflops/slices * this

# the paper's staged ramp (§IV): small-scale validation, then
# 400 -> 900 -> 1.2k -> 1.6k -> 2k, each step sustained "for extended
# periods of time to validate the stability of the system"
PAPER_RAMP_EVENTS: Tuple[SetTarget, ...] = (
    SetTarget(0.0, 40), SetTarget(12.0, 400), SetTarget(48.0, 900),
    SetTarget(96.0, 1200), SetTarget(144.0, 1600), SetTarget(192.0, 2000))
# ... until the CE host's network outage at d10.5; resume lower (~20%
# budget left)
PAPER_TIMELINE: Tuple[Event, ...] = PAPER_RAMP_EVENTS + (
    CEOutage(252.0, 2.0, 1000),)


# -- the spec --------------------------------------------------------------

@dataclass(frozen=True)
class CampaignSpec:
    """One campaign, fully declared; defaults reproduce the paper replay."""
    name: str = "paper"
    # catalog: named ("t4" | "heterogeneous") or inline provider tuple
    catalog: str = "t4"
    providers: Optional[Tuple[ProviderSpec, ...]] = None
    capacity_scale: float = 1.0          # multiply every region's capacity
    spot: bool = True                    # spot (paper) vs on-demand pricing
    ondemand_fraction: float = 0.0       # carve this capacity share into
    #                                      preemption-free on-demand pools
    price_scale: float = 1.0             # static price perturbation
    budget: float = 58000.0
    budget_floor_fraction: float = 0.2   # initial tripwire arming ...
    downscale_target: int = 1000         # ... and its cap target
    duration_h: float = 14 * 24.0
    dt_h: float = 0.25                   # 15-minute ticks
    lease_interval_s: float = 120.0      # < Azure NAT 240 s (post-fix)
    job_wall_h: float = 4.0
    job_checkpoint_h: float = 1.0
    min_queue: int = 4000                # CE queue top-up level per tick
    overhead_per_day: float = 390.0      # CE VM, storage, egress
    accel_tflops: float = T4_FP32_TFLOPS
    # sub-GPU slicing transform applied to the chosen catalog (None =
    # whole-GPU accounting, the paper's mode)
    gpu_slicing: Optional[GpuSlicing] = None
    timeline: Tuple[Event, ...] = PAPER_TIMELINE

    def to_spec(self) -> "CampaignSpec":
        """Duck-typed coercion hook shared with the Scenario shim."""
        return self

    def validate(self) -> "CampaignSpec":
        if self.providers is None and self.catalog not in (
                "t4", "heterogeneous"):
            raise ValueError(f"unknown catalog {self.catalog!r}")
        if self.duration_h <= 0 or self.dt_h <= 0:
            raise ValueError("duration_h and dt_h must be positive")
        if self.budget <= 0:
            raise ValueError("campaigns need a positive budget")
        if self.gpu_slicing is not None:
            if not isinstance(self.gpu_slicing, GpuSlicing):
                raise ValueError(
                    f"gpu_slicing must be a GpuSlicing, "
                    f"got {self.gpu_slicing!r}")
            if self.gpu_slicing.slices < 1:
                raise ValueError("gpu_slicing.slices must be >= 1")
        for ev in self.timeline:
            if type(ev) not in EVENT_KINDS.values():
                raise ValueError(f"unknown timeline event {ev!r}")
            if isinstance(ev, PriceCurve):
                for p in ev.points:
                    if len(p) != 2:
                        raise ValueError(
                            f"PriceCurve points must be (t_h, factor) "
                            f"pairs, got {p!r}")
        return self

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        d = {"schema_version": SCHEMA_VERSION}
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name == "timeline":
                d[f.name] = [{"kind": ev.kind, **asdict(ev)}
                             for ev in v]
            elif f.name == "providers":
                # nat_idle_timeout_s defaults to float('inf'), which JSON
                # cannot represent (Python would emit the non-standard
                # token Infinity) — serialize it as null
                d[f.name] = None if v is None else [
                    {**asdict(p), "nat_idle_timeout_s":
                     None if p.nat_idle_timeout_s == float("inf")
                     else p.nat_idle_timeout_s} for p in v]
            elif f.name == "gpu_slicing":
                d[f.name] = None if v is None else asdict(v)
            else:
                d[f.name] = v
        return d

    def to_json(self, indent: int = 2) -> str:
        # allow_nan=False: fail loudly rather than emit invalid JSON
        return json.dumps(self.to_dict(), indent=indent,
                          allow_nan=False) + "\n"

    @classmethod
    def from_dict(cls, d: Mapping) -> "CampaignSpec":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(f"unsupported spec schema_version {version!r}")
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown CampaignSpec fields {sorted(unknown)}")
        if d.get("timeline") is not None:
            evs = []
            for ev in d["timeline"]:
                ev = dict(ev)
                kind = ev.pop("kind")
                if kind not in EVENT_KINDS:
                    raise ValueError(f"unknown timeline event kind {kind!r}")
                if kind == PriceCurve.kind:
                    ev["points"] = tuple(
                        (float(t), float(f)) for t, f in ev["points"])
                evs.append(EVENT_KINDS[kind](**ev))
            d["timeline"] = tuple(evs)
        if d.get("gpu_slicing") is not None:
            g = dict(d["gpu_slicing"])
            if g.get("providers") is not None:
                g["providers"] = tuple(g["providers"])
            d["gpu_slicing"] = GpuSlicing(**g)
        if d.get("providers") is not None:
            d["providers"] = tuple(
                ProviderSpec(**{
                    **p,
                    "nat_idle_timeout_s":
                        float("inf")
                        if p.get("nat_idle_timeout_s") is None
                        else p["nat_idle_timeout_s"],
                    "regions": tuple(RegionSpec(**r)
                                     for r in p["regions"])})
                for p in d["providers"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "CampaignSpec":
        return cls.from_dict(json.loads(s))


def paper_spec(**overrides) -> CampaignSpec:
    """The paper's two-week exercise as a spec; overrides replace fields."""
    return replace(CampaignSpec(), **overrides) if overrides \
        else CampaignSpec()


# -- catalog construction (shared by every execution path) -----------------

def _scale_capacity(cat: Dict[str, ProviderSpec],
                    f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, regions=tuple(
        replace(r, capacity=max(1, int(r.capacity * f)))
        for r in p.regions)) for name, p in cat.items()}


def _scale_prices(cat: Dict[str, ProviderSpec],
                  f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, spot_price_per_day=p.spot_price_per_day * f,
                          ondemand_price_per_day=p.ondemand_price_per_day * f)
            for name, p in cat.items()}


def _apply_slicing(cat: Dict[str, ProviderSpec], sl: Optional[GpuSlicing],
                   default_tflops: float) -> Dict[str, ProviderSpec]:
    """Replace each matched provider with its ``name/k`` sub-GPU-slice
    variant (k slices per device, ~1/k price and TFLOPS per slice).
    Unmatched providers keep offering whole GPUs, so mixed whole/sliced
    pools are expressible."""
    if sl is None or sl.slices == 1:
        return cat
    out: Dict[str, ProviderSpec] = {}
    for name, p in cat.items():
        if sl.providers is None or name in sl.providers:
            sp = slice_provider(p, sl.slices,
                                price_factor=sl.price_factor,
                                tflops_factor=sl.tflops_factor,
                                default_tflops=default_tflops)
            out[sp.name] = sp
        else:
            out[name] = p
    return out


def _split_ondemand(cat: Dict[str, ProviderSpec],
                    frac: float) -> Dict[str, ProviderSpec]:
    """Carve ``frac`` of every region's capacity into a preemption-free
    on-demand pool (priced at the on-demand rate) alongside the remaining
    spot capacity — the spot/on-demand *mix* what-if: how much preemption
    churn does a reliability floor buy off, and at what $."""
    if frac <= 0.0:
        return cat
    out: Dict[str, ProviderSpec] = {}
    for name, p in cat.items():
        spot_regions = []
        od_regions = []
        for r in p.regions:
            od_cap = max(1, int(r.capacity * frac))
            spot_cap = max(1, r.capacity - od_cap)
            spot_regions.append(replace(r, capacity=spot_cap))
            od_regions.append(RegionSpec(r.name, od_cap, 0.0, 1.0))
        out[name] = replace(p, regions=tuple(spot_regions))
        out[f"{name}-od"] = replace(
            p, name=f"{p.name}-od",
            spot_price_per_day=p.ondemand_price_per_day,
            regions=tuple(od_regions))
    return out


def build_catalog(spec) -> Dict[str, ProviderSpec]:
    """The spec's provider catalog with its static transforms applied."""
    spec = spec.to_spec()
    if spec.providers is not None:
        cat = {p.name: p for p in spec.providers}
    elif spec.catalog == "t4":
        cat = t4_catalog()
    elif spec.catalog == "heterogeneous":
        cat = heterogeneous_catalog()
    else:
        raise ValueError(f"unknown catalog {spec.catalog!r}")
    cat = _apply_slicing(cat, spec.gpu_slicing, spec.accel_tflops)
    cat = _scale_capacity(cat, spec.capacity_scale)
    cat = _scale_prices(cat, spec.price_scale)
    cat = _split_ondemand(cat, spec.ondemand_fraction)
    return cat


# -- spec-level lint (the `campaigns lint` CLI) ----------------------------

def lint_spec(spec: CampaignSpec) -> List[str]:
    """Static plausibility checks a spec author wants *before* burning a
    sweep on a typo'd campaign: unsorted/duplicate event times, negative
    prices/targets/factors, unknown catalog and provider names.  Returns
    human-readable findings (empty == clean); unlike ``validate()`` it
    reports everything at once and never raises."""
    out: List[str] = []
    if spec.providers is None and spec.catalog not in (
            "t4", "heterogeneous"):
        out.append(f"unknown catalog name {spec.catalog!r} "
                   "(known: 't4', 'heterogeneous')")
    if spec.duration_h <= 0:
        out.append(f"duration_h must be positive, got {spec.duration_h}")
    if spec.dt_h <= 0:
        out.append(f"dt_h must be positive, got {spec.dt_h}")
    if spec.budget <= 0:
        out.append(f"budget must be positive, got {spec.budget}")
    if spec.price_scale < 0:
        out.append(f"negative price_scale {spec.price_scale}")
    if not 0.0 <= spec.budget_floor_fraction <= 1.0:
        out.append(f"budget_floor_fraction {spec.budget_floor_fraction} "
                   "outside [0, 1]")
    if spec.downscale_target < 0:
        out.append(f"negative downscale_target {spec.downscale_target}")
    if spec.min_queue < 0:
        out.append(f"negative min_queue {spec.min_queue}")
    if spec.providers is not None:
        for p in spec.providers:
            if p.spot_price_per_day < 0 or p.ondemand_price_per_day < 0:
                out.append(f"provider {p.name!r} has a negative price")
            for r in p.regions:
                if r.capacity < 0:
                    out.append(f"provider {p.name!r} region {r.name!r} "
                               "has negative capacity")
    try:
        known_providers = set(build_catalog(spec))
    except (ValueError, ZeroDivisionError):
        known_providers = None           # catalog findings already queued
    sl = spec.gpu_slicing
    if sl is not None:
        if sl.slices < 1:
            out.append(f"gpu_slicing.slices must be >= 1, got {sl.slices}")
        if sl.price_factor <= 0 or sl.tflops_factor <= 0:
            out.append("gpu_slicing price/tflops factors must be positive")
        if sl.providers is not None:
            if spec.providers is not None:
                base = {p.name for p in spec.providers}
            elif spec.catalog == "t4":
                base = set(t4_catalog())
            elif spec.catalog == "heterogeneous":
                base = set(heterogeneous_catalog())
            else:
                base = None               # catalog finding already queued
            for name in sl.providers:
                if base is not None and name not in base:
                    out.append(f"gpu_slicing names unknown provider "
                               f"{name!r}")
    prev_t = None
    seen_times: Dict[float, int] = {}
    for i, ev in enumerate(spec.timeline):
        at = f"timeline[{i}] {type(ev).__name__}"
        t0 = ev.at_h
        if t0 < 0:
            out.append(f"{at}: negative event time {t0}")
        if prev_t is not None and t0 < prev_t:
            out.append(f"{at}: event times not sorted "
                       f"({t0} after {prev_t})")
        prev_t = max(t0, prev_t) if prev_t is not None else t0
        # dead events never execute: anchor for plain events, every
        # breakpoint for curves
        dead_ts = [t for t, _f in ev.points] if isinstance(ev, PriceCurve) \
            else [t0]
        for t in dead_ts:
            if t >= spec.duration_h:
                out.append(f"{at}: fires at t={t} h, at/after the "
                           f"campaign end ({spec.duration_h} h) — never "
                           "executes")
        if not isinstance(ev, PriceCurve):
            seen_times[t0] = seen_times.get(t0, 0) + 1
        if isinstance(ev, SetTarget) and ev.target < 0:
            out.append(f"{at}: negative target {ev.target}")
        elif isinstance(ev, CEOutage):
            if ev.duration_h <= 0:
                out.append(f"{at}: outage duration must be positive")
            if ev.resume_target < 0:
                out.append(f"{at}: negative resume_target "
                           f"{ev.resume_target}")
        elif isinstance(ev, (PriceShift, CapacityShift)) and ev.factor <= 0:
            out.append(f"{at}: factor must be positive, got {ev.factor}")
        elif isinstance(ev, BudgetFloor):
            if not 0.0 <= ev.fraction <= 1.0:
                out.append(f"{at}: fraction {ev.fraction} outside [0, 1]")
            if ev.downscale_target < 0:
                out.append(f"{at}: negative downscale_target "
                           f"{ev.downscale_target}")
        elif isinstance(ev, PriceCurve):
            if not ev.points:
                out.append(f"{at}: empty curve (no points)")
            pt = None
            for t, f in ev.points:
                if f <= 0:
                    out.append(f"{at}: non-positive price factor {f} "
                               f"at t={t}")
                if pt is not None and t <= pt:
                    out.append(f"{at}: curve points not strictly "
                               f"time-sorted ({t} after {pt})")
                pt = t
            if ev.provider is not None and known_providers is not None \
                    and ev.provider not in known_providers:
                out.append(f"{at}: unknown provider {ev.provider!r} "
                           f"(catalog has {sorted(known_providers)})")
    for t, n in seen_times.items():
        if n > 1:
            out.append(f"timeline: {n} events share t={t} h — they "
                       "execute in declaration order; split the times "
                       "if that overlap is unintended")
    return out


# -- solo execution --------------------------------------------------------

class TimelineController:
    """Interprets a spec's timeline against one solo ``CloudSimulator``:
    installs every event as a one-shot at its time, arms the budget-floor
    tripwire on the ledger's threshold alerts, and records operational
    provenance — human-readable ``log`` lines (the controller log the
    paper's operators kept) plus structured ``events_fired`` records that
    are bit-identical to the batched engine's per-lane provenance."""

    def __init__(self, sim: CloudSimulator, spec: CampaignSpec):
        self.sim = sim
        self.spec = spec
        self.log: List[str] = []
        self.events_fired: List[dict] = []
        self.floor_fraction = spec.budget_floor_fraction
        self.downscale_target = spec.downscale_target
        self.budget_capped = False
        sim.ledger.on_threshold(self._on_budget_alert)
        for ev in spec.timeline:
            ev.install(sim, self)

    def record(self, line: str, event: Optional[dict] = None):
        self.log.append(line)
        if event is not None:
            self.events_fired.append(event)

    def _on_budget_alert(self, frac, remaining, rate_per_day):
        self.log.append(
            f"BUDGET ALERT: {frac:.0%} remaining (${remaining:,.0f}), "
            f"rate ${rate_per_day:,.0f}/day")
        if frac <= self.floor_fraction and not self.budget_capped:
            self.budget_capped = True
            self.sim.at(self.sim.now, self._apply_cap)
            self.log.append(
                f"t={self.sim.now:6.1f}h budget floor hit -> "
                f"cap fleet at {self.downscale_target}")

    def _apply_cap(self, sim):
        tgt = int(self.downscale_target)
        sim.prov.scale_to(tgt, sim.now)
        self.events_fired.append({"t": float(sim.now),
                                  "event": "budget_floor", "target": tgt})


def check_collect(collect: str):
    """Shared validation for the ``collect=`` results knob."""
    if collect not in ("summary", "trace"):
        raise ValueError(f"unknown collect mode {collect!r} "
                         "(expected 'summary' or 'trace')")


def run_solo(spec, seed: int, engine: Optional[str] = None,
             collect: str = "summary"
             ) -> Tuple["CampaignResult", TimelineController]:
    """Reference execution of one (spec, seed) campaign on a solo
    ``CloudSimulator`` (array engine by default).  The batched sweep
    engine is pinned lane-by-lane against this path.  With
    ``collect="trace"`` the typed event stream is recorded (RNG-free —
    the campaign itself is unchanged) and returned as
    ``CampaignResult.trace``."""
    spec = spec.to_spec().validate()
    check_collect(collect)
    rec = TraceRecorder() if collect == "trace" else None
    sim = CloudSimulator.from_spec(spec, seed, engine=engine, recorder=rec)
    ctl = TimelineController(sim, spec)
    sim.run_until(spec.duration_h)
    results = sim.results()
    trace = None if rec is None else build_trace(
        spec.name, seed, spec.duration_h, spec.dt_h, rec, ctl.events_fired)
    res = CampaignResult.from_results(
        results, spec=spec, seed=seed, engine=sim.engine_kind,
        events_fired=tuple(ctl.events_fired), log=tuple(ctl.log),
        history=tuple(sim.history), trace=trace)
    return res, ctl


# -- typed results ---------------------------------------------------------

@dataclass(frozen=True)
class BudgetReport:
    """The CloudBank 'single window' totals."""
    total_spent: float
    by_provider: Mapping[str, float]
    remaining: float
    remaining_fraction: float
    overdraft: float

    def to_dict(self) -> dict:
        return {"total_spent": self.total_spent,
                "by_provider": dict(self.by_provider),
                "remaining": self.remaining,
                "remaining_fraction": self.remaining_fraction,
                "overdraft": self.overdraft}


_RESULT_KEYS = ("accel_hours", "accel_days", "busy_hours",
                "busy_hours_by_provider", "eflop_hours_fp32", "cost",
                "cost_per_accel_day", "preemptions", "nat_drops",
                "jobs_finished", "budget", "by_provider")


@dataclass(frozen=True)
class CampaignResult(MappingABC):
    """Typed campaign totals.  Also quacks like the legacy string-keyed
    ``CloudSimulator.results()`` dict (``res["cost"]`` etc.), so call
    sites migrate at their own pace."""
    accel_hours: float
    accel_days: float
    busy_hours: float
    busy_hours_by_provider: Mapping[str, float]
    eflop_hours_fp32: float
    cost: float
    cost_per_accel_day: float
    preemptions: int
    nat_drops: int
    jobs_finished: int
    budget: BudgetReport
    by_provider: Mapping[str, int]
    # provenance (not part of the legacy results mapping)
    spec: Optional[CampaignSpec] = None
    seed: Optional[int] = None
    engine: str = "array"
    events_fired: Tuple[dict, ...] = ()
    log: Tuple[str, ...] = ()
    history: Tuple = ()
    # the typed event stream; populated only by collect="trace" runs
    trace: Optional[CampaignTrace] = None

    @classmethod
    def from_results(cls, res: Mapping, *, spec=None, seed=None,
                     engine: str = "array", events_fired: Tuple[dict, ...]
                     = (), log: Tuple[str, ...] = (), history: Tuple = (),
                     trace: Optional[CampaignTrace] = None
                     ) -> "CampaignResult":
        """Wrap a legacy ``results()`` dict (engine output schema)."""
        return cls(budget=BudgetReport(**res["budget"]),
                   spec=spec, seed=seed, engine=engine,
                   events_fired=events_fired, log=log, history=history,
                   trace=trace,
                   **{k: res[k] for k in _RESULT_KEYS if k != "budget"})

    # -- legacy results() mapping ------------------------------------------
    def to_dict(self) -> dict:
        """Exactly the legacy ``CloudSimulator.results()`` schema."""
        d = {k: getattr(self, k) for k in _RESULT_KEYS}
        d["budget"] = self.budget.to_dict()
        d["busy_hours_by_provider"] = dict(self.busy_hours_by_provider)
        d["by_provider"] = dict(self.by_provider)
        return d

    def __getitem__(self, k):
        if k not in _RESULT_KEYS:
            raise KeyError(k)
        return self.budget.to_dict() if k == "budget" else getattr(self, k)

    def __iter__(self):
        return iter(_RESULT_KEYS)

    def __len__(self):
        return len(_RESULT_KEYS)

    # -- paper-comparison helpers (§V + Fig 2) -----------------------------
    def doubling_factor(self) -> float:
        """Cloud GPU-hours on top of IceCube's contemporaneous baseline
        ('approximate doubling', abstract/Fig 2)."""
        return 1 + self.busy_hours / ICECUBE_BASELINE_GPUH_PER_2W

    def compare_paper(self) -> Dict[str, dict]:
        """{claim: {sim, paper, err_pct}} for the §V summary numbers."""
        sims = {"cost": self.cost, "accel_days": self.accel_days,
                "eflop_hours_fp32": self.eflop_hours_fp32,
                "doubling": self.doubling_factor()}
        return {k: {"sim": sims[k], "paper": PAPER_CLAIMS[k],
                    "err_pct": round(
                        100 * (sims[k] - PAPER_CLAIMS[k]) / PAPER_CLAIMS[k],
                        2)}
                for k in PAPER_CLAIMS}

    def max_paper_err_pct(self, claims=("cost", "accel_days",
                                        "eflop_hours_fp32")) -> float:
        cmp = self.compare_paper()
        return max(abs(cmp[c]["err_pct"]) for c in claims)
