"""One declarative, serializable description of a campaign: CampaignSpec.

The paper's exercise was one hand-driven two-week run; sweep-scale
planning (HEPCloud-style pre-burst studies, per-scenario cost analyses)
wants campaign definitions that are *data*: storable, diffable,
sweepable, replayable in CI.  Historically a campaign's definition was
smeared across four layers — ``SimConfig``, the frozen ``Scenario``
dataclass, ``run_campaign()``'s keyword knobs and opaque
``sim.at(lambda sim: ...)`` callbacks inside ``CampaignController`` — so
adding one knob touched all four and nothing serialized.

``CampaignSpec`` subsumes all of it:

  * catalog choice (named ``"t4"``/``"heterogeneous"`` catalogs or an
    inline ``providers`` tuple) plus the catalog transforms
    (capacity/price scaling, spot/on-demand carve-out),
  * the fleet/billing knobs that used to live on ``SimConfig``,
  * the budget-floor tripwire that used to live on the controller, and
  * a **declarative event timeline** — ``SetTarget`` / ``CEOutage`` /
    ``PriceShift`` / ``BudgetFloor`` / ``CapacityShift`` frozen
    dataclasses with times — replacing the Python-callback idiom.  Every
    execution engine (solo object, solo array, batched sweep) interprets
    the same timeline, so a spec runs bit-identically everywhere.

Specs round-trip losslessly through JSON (``to_json``/``from_json``),
which unlocks the ``python -m repro.campaigns`` CLI and committed golden
specs in CI.  ``CampaignSpec()`` with no arguments IS the paper replay:
T4 catalog, $58k budget, staged ramp to 2k GPUs, the d10.5 CE outage,
the 20 %-budget-floor downscale.

Results come back typed: :class:`CampaignResult` (with paper-comparison
helpers for the ~$58k / ~16k GPU-days / ~3.1 EFLOP-h / doubling claims)
instead of string-keyed dicts — though it still quacks like the old
``results()`` Mapping for back-compat.
"""
from __future__ import annotations

import json
from collections.abc import Mapping as MappingABC
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core import timeline as timeline_registry
# the data-plane surface (PR 8): per-provider origins, stage-in, cache
# tiers, egress billing — re-exported because specs import them as spec.*
from repro.core.dataplane import DataOrigin, DataPlane  # noqa: F401
from repro.core.events import CampaignTrace, TraceRecorder, build_trace
from repro.core.provider import (T4_FP32_TFLOPS, ProviderSpec, RegionSpec,
                                 heterogeneous_catalog, slice_provider,
                                 t4_catalog)
from repro.core.simulator import CloudSimulator, SimConfig
# the timed-event dataclasses live in the core/timeline.py registry now
# (one registration covers serialization, lint, compile and apply);
# re-exported here because specs, goldens and tests import them as
# spec.* since PR 3
from repro.core.timeline import (EVENT_KINDS, BudgetFloor,  # noqa: F401
                                 CacheFlush, CapacityShift, CEOutage,
                                 Event, OriginDegrade, OriginOutage,
                                 PriceCurve, PriceShift, SetTarget,
                                 WorkloadCurve, event_from_dict,
                                 event_to_dict, lint_timeline,
                                 validate_event)

SCHEMA_VERSION = 1

# IceCube baseline for the "approximate doubling" claim (abstract/Fig 2):
# cloud GPU-hours ~ IceCube's contemporaneous non-cloud GPU-hours. Paper §I
# gives 8M GPU-h/yr on OSG (IceCube >80%); with dedicated non-OSG resources
# IceCube's effective baseline is ~9M GPU-h/yr -> ~350k per 2 weeks.
ICECUBE_BASELINE_GPUH_PER_2W = 9e6 * (14 / 365.0)

# §V summary claims the benchmarks compare against
PAPER_CLAIMS = {"cost": 58000.0, "accel_days": 16000.0,
                "eflop_hours_fp32": 3.1, "doubling": 2.0}


@dataclass(frozen=True)
class GpuSlicing:
    """Sub-GPU slicing (Sfiligoi 2022, "The anachronism of whole-GPU
    accounting"): plan capacity in fractional-GPU slices instead of
    whole devices.  Applied as a catalog transform: each matched
    provider becomes a ``name/k`` variant whose regions hold ``k``
    slices per physical GPU, priced and rated at ``1/k`` of the device
    (times the overhead factors — slicing is rarely perfectly free).
    ``providers=None`` slices the whole catalog."""
    slices: int = 2
    providers: Optional[Tuple[str, ...]] = None
    price_factor: float = 1.0    # per-slice $ = price/slices * this
    tflops_factor: float = 1.0   # per-slice peak = tflops/slices * this

# the paper's staged ramp (§IV): small-scale validation, then
# 400 -> 900 -> 1.2k -> 1.6k -> 2k, each step sustained "for extended
# periods of time to validate the stability of the system"
PAPER_RAMP_EVENTS: Tuple[SetTarget, ...] = (
    SetTarget(0.0, 40), SetTarget(12.0, 400), SetTarget(48.0, 900),
    SetTarget(96.0, 1200), SetTarget(144.0, 1600), SetTarget(192.0, 2000))
# ... until the CE host's network outage at d10.5; resume lower (~20%
# budget left)
PAPER_TIMELINE: Tuple[Event, ...] = PAPER_RAMP_EVENTS + (
    CEOutage(252.0, 2.0, 1000),)


# -- the spec --------------------------------------------------------------

@dataclass(frozen=True)
class CampaignSpec:
    """One campaign, fully declared; defaults reproduce the paper replay."""
    name: str = "paper"
    # catalog: named ("t4" | "heterogeneous") or inline provider tuple
    catalog: str = "t4"
    providers: Optional[Tuple[ProviderSpec, ...]] = None
    capacity_scale: float = 1.0          # multiply every region's capacity
    spot: bool = True                    # spot (paper) vs on-demand pricing
    ondemand_fraction: float = 0.0       # carve this capacity share into
    #                                      preemption-free on-demand pools
    price_scale: float = 1.0             # static price perturbation
    budget: float = 58000.0
    budget_floor_fraction: float = 0.2   # initial tripwire arming ...
    downscale_target: int = 1000         # ... and its cap target
    duration_h: float = 14 * 24.0
    dt_h: float = 0.25                   # 15-minute ticks
    lease_interval_s: float = 120.0      # < Azure NAT 240 s (post-fix)
    job_wall_h: float = 4.0
    job_checkpoint_h: float = 1.0
    min_queue: int = 4000                # CE queue top-up level per tick
    overhead_per_day: float = 390.0      # CE VM, storage, egress
    accel_tflops: float = T4_FP32_TFLOPS
    # sub-GPU slicing transform applied to the chosen catalog (None =
    # whole-GPU accounting, the paper's mode)
    gpu_slicing: Optional[GpuSlicing] = None
    timeline: Tuple[Event, ...] = PAPER_TIMELINE
    # data plane (PR 8): per-job input size staged in before compute
    # starts, against the per-provider origins declared below (None =
    # pure-compute jobs, the paper's mode)
    job_input_gb: float = 0.0
    dataplane: Optional[DataPlane] = None

    def to_spec(self) -> "CampaignSpec":
        """Duck-typed coercion hook shared with the Scenario shim."""
        return self

    def validate(self) -> "CampaignSpec":
        if self.providers is None and self.catalog not in (
                "t4", "heterogeneous"):
            raise ValueError(f"unknown catalog {self.catalog!r}")
        if self.duration_h <= 0 or self.dt_h <= 0:
            raise ValueError("duration_h and dt_h must be positive")
        if self.budget <= 0:
            raise ValueError("campaigns need a positive budget")
        if self.gpu_slicing is not None:
            if not isinstance(self.gpu_slicing, GpuSlicing):
                raise ValueError(
                    f"gpu_slicing must be a GpuSlicing, "
                    f"got {self.gpu_slicing!r}")
            if self.gpu_slicing.slices < 1:
                raise ValueError("gpu_slicing.slices must be >= 1")
        if self.job_input_gb < 0:
            raise ValueError("job_input_gb must be >= 0")
        if self.dataplane is not None:
            if not isinstance(self.dataplane, DataPlane):
                raise ValueError(
                    f"dataplane must be a DataPlane, got {self.dataplane!r}")
            for name, o in self.dataplane.origins:
                if o.bandwidth_gbps <= 0:
                    raise ValueError(
                        f"origin {name!r} needs a positive bandwidth_gbps")
                if o.egress_usd_per_gb < 0 or o.cache_bandwidth_gbps < 0:
                    raise ValueError(
                        f"origin {name!r} has a negative price/bandwidth")
                if not 0.0 <= o.cache_hit_rate <= 1.0:
                    raise ValueError(
                        f"origin {name!r} cache_hit_rate outside [0, 1]")
        for ev in self.timeline:
            validate_event(ev)
        return self

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        d = {"schema_version": SCHEMA_VERSION}
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name == "timeline":
                d[f.name] = [event_to_dict(ev) for ev in v]
            elif f.name == "providers":
                # nat_idle_timeout_s defaults to float('inf'), which JSON
                # cannot represent (Python would emit the non-standard
                # token Infinity) — serialize it as null
                d[f.name] = None if v is None else [
                    {**asdict(p), "nat_idle_timeout_s":
                     None if p.nat_idle_timeout_s == float("inf")
                     else p.nat_idle_timeout_s} for p in v]
            elif f.name == "gpu_slicing":
                d[f.name] = None if v is None else asdict(v)
            elif f.name == "dataplane":
                # omitted at default so pre-data-plane goldens stay
                # byte-identical
                if v is not None:
                    d[f.name] = v.to_dict()
            elif f.name == "job_input_gb":
                if v != 0.0:
                    d[f.name] = v
            else:
                d[f.name] = v
        return d

    def to_json(self, indent: int = 2) -> str:
        # allow_nan=False: fail loudly rather than emit invalid JSON
        return json.dumps(self.to_dict(), indent=indent,
                          allow_nan=False) + "\n"

    @classmethod
    def from_dict(cls, d: Mapping) -> "CampaignSpec":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(f"unsupported spec schema_version {version!r}")
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown CampaignSpec fields {sorted(unknown)}")
        if d.get("timeline") is not None:
            d["timeline"] = tuple(event_from_dict(ev)
                                  for ev in d["timeline"])
        if d.get("dataplane") is not None and not isinstance(
                d["dataplane"], DataPlane):
            d["dataplane"] = DataPlane.from_dict(d["dataplane"])
        if d.get("gpu_slicing") is not None:
            g = dict(d["gpu_slicing"])
            if g.get("providers") is not None:
                g["providers"] = tuple(g["providers"])
            d["gpu_slicing"] = GpuSlicing(**g)
        if d.get("providers") is not None:
            d["providers"] = tuple(
                ProviderSpec(**{
                    **p,
                    "nat_idle_timeout_s":
                        float("inf")
                        if p.get("nat_idle_timeout_s") is None
                        else p["nat_idle_timeout_s"],
                    "regions": tuple(RegionSpec(**r)
                                     for r in p["regions"])})
                for p in d["providers"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "CampaignSpec":
        return cls.from_dict(json.loads(s))


def paper_spec(**overrides) -> CampaignSpec:
    """The paper's two-week exercise as a spec; overrides replace fields."""
    return replace(CampaignSpec(), **overrides) if overrides \
        else CampaignSpec()


# -- catalog construction (shared by every execution path) -----------------

def _scale_capacity(cat: Dict[str, ProviderSpec],
                    f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, regions=tuple(
        replace(r, capacity=max(1, int(r.capacity * f)))
        for r in p.regions)) for name, p in cat.items()}


def _scale_prices(cat: Dict[str, ProviderSpec],
                  f: float) -> Dict[str, ProviderSpec]:
    if f == 1.0:
        return cat
    return {name: replace(p, spot_price_per_day=p.spot_price_per_day * f,
                          ondemand_price_per_day=p.ondemand_price_per_day * f)
            for name, p in cat.items()}


def _apply_slicing(cat: Dict[str, ProviderSpec], sl: Optional[GpuSlicing],
                   default_tflops: float) -> Dict[str, ProviderSpec]:
    """Replace each matched provider with its ``name/k`` sub-GPU-slice
    variant (k slices per device, ~1/k price and TFLOPS per slice).
    Unmatched providers keep offering whole GPUs, so mixed whole/sliced
    pools are expressible."""
    if sl is None or sl.slices == 1:
        return cat
    out: Dict[str, ProviderSpec] = {}
    for name, p in cat.items():
        if sl.providers is None or name in sl.providers:
            sp = slice_provider(p, sl.slices,
                                price_factor=sl.price_factor,
                                tflops_factor=sl.tflops_factor,
                                default_tflops=default_tflops)
            out[sp.name] = sp
        else:
            out[name] = p
    return out


def _split_ondemand(cat: Dict[str, ProviderSpec],
                    frac: float) -> Dict[str, ProviderSpec]:
    """Carve ``frac`` of every region's capacity into a preemption-free
    on-demand pool (priced at the on-demand rate) alongside the remaining
    spot capacity — the spot/on-demand *mix* what-if: how much preemption
    churn does a reliability floor buy off, and at what $."""
    if frac <= 0.0:
        return cat
    out: Dict[str, ProviderSpec] = {}
    for name, p in cat.items():
        spot_regions = []
        od_regions = []
        for r in p.regions:
            od_cap = max(1, int(r.capacity * frac))
            spot_cap = max(1, r.capacity - od_cap)
            spot_regions.append(replace(r, capacity=spot_cap))
            od_regions.append(RegionSpec(r.name, od_cap, 0.0, 1.0))
        out[name] = replace(p, regions=tuple(spot_regions))
        out[f"{name}-od"] = replace(
            p, name=f"{p.name}-od",
            spot_price_per_day=p.ondemand_price_per_day,
            regions=tuple(od_regions))
    return out


def build_catalog(spec) -> Dict[str, ProviderSpec]:
    """The spec's provider catalog with its static transforms applied."""
    spec = spec.to_spec()
    if spec.providers is not None:
        cat = {p.name: p for p in spec.providers}
    elif spec.catalog == "t4":
        cat = t4_catalog()
    elif spec.catalog == "heterogeneous":
        cat = heterogeneous_catalog()
    else:
        raise ValueError(f"unknown catalog {spec.catalog!r}")
    cat = _apply_slicing(cat, spec.gpu_slicing, spec.accel_tflops)
    cat = _scale_capacity(cat, spec.capacity_scale)
    cat = _scale_prices(cat, spec.price_scale)
    cat = _split_ondemand(cat, spec.ondemand_fraction)
    return cat


# -- spec-level lint (the `campaigns lint` CLI) ----------------------------

#: stable lint rule ids: every ``lint_spec``/``lint_timeline`` finding
#: is prefixed ``"SPECnnn: "`` (same ``ABC123`` id shape as the static
#: analyzer's REG/RNG/TRC/KRN rules, so ``campaigns lint --json`` and
#: ``campaigns check --json`` share one findings schema).  SPEC0xx are
#: spec-level checks here; SPEC10x are timeline-structure checks and
#: SPEC11x per-event checks, both in core/timeline.py.
SPEC_RULES: Dict[str, str] = {
    "SPEC001": "unknown catalog name",
    "SPEC002": "non-positive duration_h",
    "SPEC003": "non-positive dt_h",
    "SPEC004": "non-positive budget",
    "SPEC005": "negative price_scale",
    "SPEC006": "budget_floor_fraction outside [0, 1]",
    "SPEC007": "negative downscale_target",
    "SPEC008": "negative min_queue",
    "SPEC009": "provider with a negative price",
    "SPEC010": "region with negative capacity",
    "SPEC011": "gpu_slicing.slices < 1",
    "SPEC012": "non-positive gpu_slicing price/tflops factor",
    "SPEC013": "gpu_slicing names an unknown provider",
    "SPEC014": "negative job_input_gb",
    "SPEC015": "origin with non-positive bandwidth_gbps",
    "SPEC016": "origin with negative egress_usd_per_gb",
    "SPEC017": "origin with negative cache_bandwidth_gbps",
    "SPEC018": "origin cache_hit_rate outside [0, 1]",
    "SPEC019": "dataplane names an unknown provider",
    "SPEC020": "inert dataplane (no input bytes, no egress price)",
    "SPEC021": "dataplane timeline events without a dataplane",
    "SPEC100": "unloadable spec file",
    "SPEC101": "unknown timeline event",
    "SPEC102": "negative event time",
    "SPEC103": "event times not sorted",
    "SPEC104": "dead event: fires at/after the campaign end",
    "SPEC105": "events sharing an anchor time",
    "SPEC110": "negative scale target",
    "SPEC111": "non-positive outage duration",
    "SPEC112": "negative resume_target",
    "SPEC113": "non-positive factor",
    "SPEC114": "fraction outside [0, 1]",
    "SPEC115": "negative downscale_target",
    "SPEC116": "empty curve",
    "SPEC117": "out-of-range curve factor",
    "SPEC118": "curve points not time-sorted",
    "SPEC119": "unknown provider name",
}


def lint_spec(spec: CampaignSpec) -> List[str]:
    """Static plausibility checks a spec author wants *before* burning a
    sweep on a typo'd campaign: unsorted/duplicate event times, negative
    prices/targets/factors, unknown catalog and provider names.  Returns
    human-readable findings (empty == clean); unlike ``validate()`` it
    reports everything at once and never raises."""
    out: List[str] = []
    if spec.providers is None and spec.catalog not in (
            "t4", "heterogeneous"):
        out.append(f"SPEC001: unknown catalog name {spec.catalog!r} "
                   "(known: 't4', 'heterogeneous')")
    if spec.duration_h <= 0:
        out.append(f"SPEC002: duration_h must be positive, "
                   f"got {spec.duration_h}")
    if spec.dt_h <= 0:
        out.append(f"SPEC003: dt_h must be positive, got {spec.dt_h}")
    if spec.budget <= 0:
        out.append(f"SPEC004: budget must be positive, got {spec.budget}")
    if spec.price_scale < 0:
        out.append(f"SPEC005: negative price_scale {spec.price_scale}")
    if not 0.0 <= spec.budget_floor_fraction <= 1.0:
        out.append(f"SPEC006: budget_floor_fraction "
                   f"{spec.budget_floor_fraction} outside [0, 1]")
    if spec.downscale_target < 0:
        out.append(f"SPEC007: negative downscale_target "
                   f"{spec.downscale_target}")
    if spec.min_queue < 0:
        out.append(f"SPEC008: negative min_queue {spec.min_queue}")
    if spec.providers is not None:
        for p in spec.providers:
            if p.spot_price_per_day < 0 or p.ondemand_price_per_day < 0:
                out.append(f"SPEC009: provider {p.name!r} has a negative "
                           "price")
            for r in p.regions:
                if r.capacity < 0:
                    out.append(f"SPEC010: provider {p.name!r} region "
                               f"{r.name!r} has negative capacity")
    try:
        known_providers = set(build_catalog(spec))
    except (ValueError, ZeroDivisionError):
        known_providers = None           # catalog findings already queued
    sl = spec.gpu_slicing
    if sl is not None:
        if sl.slices < 1:
            out.append(f"SPEC011: gpu_slicing.slices must be >= 1, "
                       f"got {sl.slices}")
        if sl.price_factor <= 0 or sl.tflops_factor <= 0:
            out.append("SPEC012: gpu_slicing price/tflops factors must be "
                       "positive")
        if sl.providers is not None:
            if spec.providers is not None:
                base = {p.name for p in spec.providers}
            elif spec.catalog == "t4":
                base = set(t4_catalog())
            elif spec.catalog == "heterogeneous":
                base = set(heterogeneous_catalog())
            else:
                base = None               # catalog finding already queued
            for name in sl.providers:
                if base is not None and name not in base:
                    out.append(f"SPEC013: gpu_slicing names unknown "
                               f"provider {name!r}")
    if spec.job_input_gb < 0:
        out.append(f"SPEC014: negative job_input_gb {spec.job_input_gb}")
    dp = spec.dataplane
    if dp is not None:
        for name, o in dp.origins:
            if o.bandwidth_gbps <= 0:
                out.append(f"SPEC015: origin {name!r} bandwidth_gbps must "
                           f"be positive, got {o.bandwidth_gbps}")
            if o.egress_usd_per_gb < 0:
                out.append(f"SPEC016: origin {name!r} has a negative "
                           f"egress_usd_per_gb")
            if o.cache_bandwidth_gbps < 0:
                out.append(f"SPEC017: origin {name!r} has a negative "
                           f"cache_bandwidth_gbps")
            if not 0.0 <= o.cache_hit_rate <= 1.0:
                out.append(f"SPEC018: origin {name!r} cache_hit_rate "
                           f"{o.cache_hit_rate} outside [0, 1]")
            if known_providers is not None:
                bases = {p.split("/", 1)[0] for p in known_providers}
                if name not in known_providers and name not in bases:
                    out.append(f"SPEC019: dataplane names unknown "
                               f"provider {name!r}")
        if spec.job_input_gb == 0.0 and not any(
                o.egress_usd_per_gb > 0 for _, o in dp.origins):
            out.append("SPEC020: dataplane declared but job_input_gb is 0 "
                       "and no origin charges egress: the data plane "
                       "is inert")
    else:
        dead = sorted({type(ev).kind for ev in spec.timeline
                       if type(ev).kind in ("origin_outage",
                                            "origin_degrade",
                                            "cache_flush")})
        for kind in dead:
            out.append(f"SPEC021: timeline has {kind!r} events but the "
                       "spec declares no dataplane: they will never "
                       "matter")
    # per-event rules are registry-derived: every registered kind
    # declares its own lint in core/timeline.py
    out.extend(lint_timeline(spec.timeline, spec.duration_h,
                             known_providers))
    return out


# -- solo execution --------------------------------------------------------

class TimelineController:
    """Interprets a spec's timeline against one solo ``CloudSimulator``:
    the solo :class:`~repro.core.timeline.EngineOps` adapter.  Every
    event's compiled ops (``timeline.compile_event``) are installed as
    one-shots at their times, the budget-floor tripwire is armed on the
    ledger's threshold alerts, and operational provenance is recorded —
    human-readable ``log`` lines (the controller log the paper's
    operators kept) plus structured ``events_fired`` records that are
    bit-identical to the batched engine's per-lane provenance.  Fleet
    ops delegate to ``sim.prov``/``sim.ce``, which present the same
    facade on the object and array engines — one adapter covers both
    solo engines."""

    # class-level defaults so the ``registry_findings`` drift guard can
    # hasattr-check the EngineOps state members on the class itself
    budget_capped = False
    downscale_target = 0
    floor_fraction = 0.0

    def __init__(self, sim: CloudSimulator, spec: CampaignSpec):
        self.sim = sim
        self.spec = spec
        self.log: List[str] = []
        self.events_fired: List[dict] = []
        self.floor_fraction = spec.budget_floor_fraction
        self.downscale_target = spec.downscale_target
        self.budget_capped = False
        sim.ledger.on_threshold(self._on_budget_alert)
        for ev in spec.timeline:
            for t, op_kind, arg in timeline_registry.compile_event(ev):
                sim.at(t, self._fire(op_kind, arg))

    def _fire(self, op_kind: str, arg):
        def fire(s):
            rec = timeline_registry.apply_op(self, op_kind, arg, s.now)
            self.record(f"t={s.now:6.1f}h "
                        + timeline_registry.describe_record(rec), rec)
        return fire

    def record(self, line: str, event: Optional[dict] = None):
        self.log.append(line)
        if event is not None:
            self.events_fired.append(event)
            if self.sim.recorder is not None:
                # mirror timeline provenance into the trace recorder
                # in-band (a no-op for in-memory collection, where
                # build_trace folds events_fired in at freeze time;
                # the streaming recorder emits it immediately)
                self.sim.recorder.timeline_fired(event)

    # -- EngineOps (the registry's apply() targets) ------------------------
    def scale_to(self, n: int):
        self.sim.prov.scale_to(int(n), self.sim.now)

    def deprovision_all(self):
        self.sim.prov.deprovision_all(self.sim.now)

    def set_outage(self, on: bool):
        self.sim.ce.outage = bool(on)

    def scale_prices(self, factor: float):
        self.sim.prov.scale_prices(factor)

    def set_price_factor(self, provider: Optional[str], factor: float):
        self.sim.prov.set_price_factor(provider, factor)

    def scale_capacity(self, factor: float):
        self.sim.prov.scale_capacity(factor)

    def arm_budget_floor(self, fraction: float, target: int):
        self.floor_fraction = fraction
        self.downscale_target = target

    def set_workload_factor(self, factor: float):
        self.sim.workload_factor = factor

    def set_origin_outage(self, provider: str, on: bool):
        self.sim.dataplane.set_outage(provider, on)

    def degrade_origin(self, provider: str, factor: float):
        self.sim.dataplane.degrade_origin(provider, factor)

    def flush_cache(self, provider: str):
        self.sim.dataplane.flush_cache(provider)

    # -- the budget tripwire ----------------------------------------------
    def _on_budget_alert(self, frac, remaining, rate_per_day):
        self.log.append(
            f"BUDGET ALERT: {frac:.0%} remaining (${remaining:,.0f}), "
            f"rate ${rate_per_day:,.0f}/day")
        if frac <= self.floor_fraction and not self.budget_capped:
            self.budget_capped = True
            self.sim.at(self.sim.now, self._apply_cap)
            self.log.append(
                f"t={self.sim.now:6.1f}h budget floor hit -> "
                f"cap fleet at {self.downscale_target}")

    def _apply_cap(self, sim):
        rec = timeline_registry.apply_budget_cap(self, sim.now)
        self.events_fired.append(rec)
        if sim.recorder is not None:
            sim.recorder.timeline_fired(rec)


def check_collect(collect: str):
    """Shared validation for the ``collect=`` results knob."""
    if collect not in ("summary", "trace", "stream"):
        raise ValueError(f"unknown collect mode {collect!r} "
                         "(expected 'summary', 'trace' or 'stream')")


def run_solo(spec, seed: int, engine: Optional[str] = None,
             collect: str = "summary", sink=None
             ) -> Tuple["CampaignResult", TimelineController]:
    """Reference execution of one (spec, seed) campaign on a solo
    ``CloudSimulator`` (array engine by default).  The batched sweep
    engine is pinned lane-by-lane against this path.  With
    ``collect="trace"`` the typed event stream is recorded (RNG-free —
    the campaign itself is unchanged) and returned as
    ``CampaignResult.trace``; with ``collect="stream"`` it is fed
    through ``sink`` (a :class:`~repro.core.traceops.TraceSink`) in
    bounded tick-windows instead, and ``CampaignResult.trace`` stays
    ``None``."""
    spec = spec.to_spec().validate()
    check_collect(collect)
    if collect == "stream":
        if sink is None:
            raise ValueError('collect="stream" needs a sink= '
                             "(e.g. traceops.JsonlStreamSink)")
        from repro.core.traceops import StreamingRecorder
        rec = StreamingRecorder(sink)
    else:
        rec = TraceRecorder() if collect == "trace" else None
    sim = CloudSimulator.from_spec(spec, seed, engine=engine, recorder=rec)
    ctl = TimelineController(sim, spec)
    sim.run_until(spec.duration_h)
    results = sim.results()
    if collect == "stream":
        rec.finish(spec.name, seed, spec.duration_h, spec.dt_h)
        trace = None
    else:
        trace = None if rec is None else build_trace(
            spec.name, seed, spec.duration_h, spec.dt_h, rec,
            ctl.events_fired)
    res = CampaignResult.from_results(
        results, spec=spec, seed=seed, engine=sim.engine_kind,
        events_fired=tuple(ctl.events_fired), log=tuple(ctl.log),
        history=tuple(sim.history), trace=trace)
    return res, ctl


# -- typed results ---------------------------------------------------------

@dataclass(frozen=True)
class BudgetReport:
    """The CloudBank 'single window' totals."""
    total_spent: float
    by_provider: Mapping[str, float]
    remaining: float
    remaining_fraction: float
    overdraft: float

    def to_dict(self) -> dict:
        return {"total_spent": self.total_spent,
                "by_provider": dict(self.by_provider),
                "remaining": self.remaining,
                "remaining_fraction": self.remaining_fraction,
                "overdraft": self.overdraft}


_RESULT_KEYS = ("accel_hours", "accel_days", "busy_hours",
                "busy_hours_by_provider", "eflop_hours_fp32", "cost",
                "cost_per_accel_day", "preemptions", "nat_drops",
                "jobs_finished", "egress_usd", "stagein_hours",
                "cache_hit_fraction", "budget", "by_provider")


@dataclass(frozen=True)
class CampaignResult(MappingABC):
    """Typed campaign totals.  Also quacks like the legacy string-keyed
    ``CloudSimulator.results()`` dict (``res["cost"]`` etc.), so call
    sites migrate at their own pace."""
    accel_hours: float
    accel_days: float
    busy_hours: float
    busy_hours_by_provider: Mapping[str, float]
    eflop_hours_fp32: float
    cost: float
    cost_per_accel_day: float
    preemptions: int
    nat_drops: int
    jobs_finished: int
    egress_usd: float
    stagein_hours: float
    cache_hit_fraction: float
    budget: BudgetReport
    by_provider: Mapping[str, int]
    # provenance (not part of the legacy results mapping)
    spec: Optional[CampaignSpec] = None
    seed: Optional[int] = None
    engine: str = "array"
    events_fired: Tuple[dict, ...] = ()
    log: Tuple[str, ...] = ()
    history: Tuple = ()
    # the typed event stream; populated only by collect="trace" runs
    trace: Optional[CampaignTrace] = None

    @classmethod
    def from_results(cls, res: Mapping, *, spec=None, seed=None,
                     engine: str = "array", events_fired: Tuple[dict, ...]
                     = (), log: Tuple[str, ...] = (), history: Tuple = (),
                     trace: Optional[CampaignTrace] = None
                     ) -> "CampaignResult":
        """Wrap a legacy ``results()`` dict (engine output schema)."""
        return cls(budget=BudgetReport(**res["budget"]),
                   spec=spec, seed=seed, engine=engine,
                   events_fired=events_fired, log=log, history=history,
                   trace=trace,
                   **{k: res[k] for k in _RESULT_KEYS if k != "budget"})

    # -- legacy results() mapping ------------------------------------------
    def to_dict(self) -> dict:
        """Exactly the legacy ``CloudSimulator.results()`` schema."""
        d = {k: getattr(self, k) for k in _RESULT_KEYS}
        d["budget"] = self.budget.to_dict()
        d["busy_hours_by_provider"] = dict(self.busy_hours_by_provider)
        d["by_provider"] = dict(self.by_provider)
        return d

    def __getitem__(self, k):
        if k not in _RESULT_KEYS:
            raise KeyError(k)
        return self.budget.to_dict() if k == "budget" else getattr(self, k)

    def __iter__(self):
        return iter(_RESULT_KEYS)

    def __len__(self):
        return len(_RESULT_KEYS)

    # -- paper-comparison helpers (§V + Fig 2) -----------------------------
    def doubling_factor(self) -> float:
        """Cloud GPU-hours on top of IceCube's contemporaneous baseline
        ('approximate doubling', abstract/Fig 2)."""
        return 1 + self.busy_hours / ICECUBE_BASELINE_GPUH_PER_2W

    def compare_paper(self) -> Dict[str, dict]:
        """{claim: {sim, paper, err_pct}} for the §V summary numbers."""
        sims = {"cost": self.cost, "accel_days": self.accel_days,
                "eflop_hours_fp32": self.eflop_hours_fp32,
                "doubling": self.doubling_factor()}
        return {k: {"sim": sims[k], "paper": PAPER_CLAIMS[k],
                    "err_pct": round(
                        100 * (sims[k] - PAPER_CLAIMS[k]) / PAPER_CLAIMS[k],
                        2)}
                for k in PAPER_CLAIMS}

    def max_paper_err_pct(self, claims=("cost", "accel_days",
                                        "eflop_hours_fp32")) -> float:
        cmp = self.compare_paper()
        return max(abs(cmp[c]["err_pct"]) for c in claims)
