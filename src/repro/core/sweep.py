"""Batched multi-campaign sweep engine: B campaigns as one array program.

A Monte-Carlo planning sweep (seeds x what-if scenarios, see
core/scenarios.py) used to run one Python tick loop per campaign, paying
the fixed per-tick dispatch overhead B times.  ``BatchedFleetEngine``
ticks B independent campaigns in lock-step instead: instances, pilots and
jobs of *all* lanes live in one flat struct-of-arrays with a
``lane*G + group`` column, so preemption sampling, billing, lease/NAT
checks, matchmaking and job progress are single vectorized ops across
every campaign at once.  Per-campaign job queues are lanes of one ring
buffer; per-campaign budgets are columns of one vectorized ledger.

Reproducibility is exact, not statistical: lane b draws from its own
``np.random.default_rng(seed_b)`` — the same generator a solo
``CloudSimulator`` would build — and consumes it in the same order
(preemption draws per group in price order, creation order within a
group; ``rng.random(k1); rng.random(k2)`` reads the PCG64 stream exactly
like ``rng.random(k1+k2)``).  Every lane therefore reports ``results()``
totals matching a solo ``run_scenario()`` at the same (seed, scenario) —
pinned by tests/test_sweep.py, including the paper replay at seed 2021.

The hot loop never rescans or re-sorts the whole fleet: the engine
maintains an aliveness mask, per-(lane, group) live counts, a
lane-sorted row list (lazily compacted), and idle/busy pilot candidate
sets incrementally, so each tick touches O(rows that changed) plus a
handful of flat gathers.  Billing exploits lock-step: every billable row
accrues the same scalar interval, so a tick's charges are one bincount.

Lanes are grouped into lock-step batches by structural compatibility
(tick size, duration, and the price-ordered (provider, region) group
list); prices, budgets, timelines, lease intervals and queue depths
vary freely per lane within a batch.

Campaign control is the declarative ``CampaignSpec`` timeline
(core/spec.py): ``SetTarget`` / ``CEOutage`` / ``PriceShift`` /
``BudgetFloor`` / ``CapacityShift`` / ``PriceCurve`` events compile to
per-lane ``(t, kind, arg)`` tuples interpreted by ``_run_events`` — no
Python callbacks to special-case.  Effective billing rates follow the
engines' shared expression ``((base) * PriceShift scalar) * curve
factor``: the cumulative scalar is per-lane, the absolute curve factors
are per-(lane, group) (``curve_lg``), and both are only touched at
event time (``_refresh_rates``), so the hot loop never recomputes
prices.  Every executed event is recorded in a per-lane
``events_fired`` provenance log, bit-identical to the solo
``TimelineController``'s.

Tick-phase primitives (hazard model, checkpoint flooring, segmented
ranks) are shared with the solo array engine — see core/fleet.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import timeline as timeline_registry
from repro.core.budget import BudgetLedger
from repro.core.events import CampaignTrace, TraceRecorder, build_trace
from repro.core.fleet import (_NO_PILOT, _PILOT_DEAD, _PILOT_LIVE,
                              checkpoint_floor, preemption_rate,
                              segment_ranks)
from repro.core.spec import CampaignSpec, build_catalog

# ledger alert levels, descending — the solo controller reacts to these
# ledger callbacks, so both engines must cross the same set
_THRESHOLDS = tuple(sorted(
    BudgetLedger.__dataclass_fields__["thresholds"].default, reverse=True))


def _sorted_insert(a: np.ndarray, vs: np.ndarray) -> np.ndarray:
    """Merge sorted values ``vs`` into sorted array ``a`` in one pass
    (np.insert takes a slower generic path)."""
    if not len(vs):
        return a
    at = np.searchsorted(a, vs) + np.arange(len(vs))
    out = np.empty(len(a) + len(vs), dtype=a.dtype)
    mask = np.ones(len(out), dtype=bool)
    mask[at] = False
    out[at] = vs
    out[mask] = a
    return out


def _sorted_remove(a: np.ndarray, vs: np.ndarray) -> np.ndarray:
    """Remove present sorted values ``vs`` from sorted array ``a``."""
    if not len(vs):
        return a
    mask = np.ones(len(a), dtype=bool)
    mask[np.searchsorted(a, vs)] = False
    return a[mask]


@dataclass
class _Lane:
    """One (spec, seed) campaign prepared for batching."""
    spec: CampaignSpec
    seed: int
    pairs: list          # (ProviderSpec, RegionSpec), price-ordered


def _compile_timeline(spec: CampaignSpec) -> List[tuple]:
    """Flatten a spec's event timeline into stably time-sorted
    ``(t, op_kind, arg)`` tuples — registry-derived
    (``timeline.compile_timeline``), so the expansion order (CEOutage
    becomes on/off at its declaration point) and tie-breaking (stable by
    timeline position) are by construction the same one-shots the solo
    ``TimelineController`` installs."""
    return timeline_registry.compile_timeline(spec.timeline)


class _LaneOps:
    """One lane's :class:`~repro.core.timeline.EngineOps` adapter: the
    registry's shared ``apply`` bodies drive this to mutate lane ``b``'s
    slice of the struct-of-arrays state.  Each method mirrors the solo
    facade op exactly (same float-op order — see ``_refresh_rates``), so
    every lane stays bit-identical to a solo run."""

    __slots__ = ("eng", "b", "now")

    def __init__(self, eng: "BatchedFleetEngine", b: int, now: float):
        self.eng = eng
        self.b = b
        self.now = now

    @property
    def budget_capped(self) -> bool:
        return bool(self.eng.capped[self.b])

    @property
    def downscale_target(self) -> int:
        return int(self.eng.lane_downscale[self.b])

    def scale_to(self, n: int):
        self.eng._lane_scale_to(self.b, int(n), self.now)

    def deprovision_all(self):
        self.eng._lane_deprovision(self.b, self.now)

    def set_outage(self, on: bool):
        self.eng.outage[self.b] = bool(on)

    def scale_prices(self, factor: float):
        # cumulative per-lane scale on top of which curve factors stack
        # (solo: prov.scale_prices)
        self.eng.lane_price_scale[self.b] *= factor
        self.eng._refresh_rates(self.b)

    def set_price_factor(self, provider, factor: float):
        eng, b = self.eng, self.b
        if provider is None:
            eng.curve_lg[b * eng.G:(b + 1) * eng.G] = factor
        else:
            gs = eng._prov_groups.get(provider)
            if gs is not None:           # unknown provider: no-op (solo
                eng.curve_lg[b * eng.G + gs] = factor   # semantics)
        eng._refresh_rates(b)

    def scale_capacity(self, factor: float):
        eng, b = self.eng, self.b
        s = slice(b * eng.G, (b + 1) * eng.G)
        eng.g_cap_lg[s] = np.maximum(
            1, (eng.g_cap_lg[s] * factor).astype(np.int64))

    def arm_budget_floor(self, fraction: float, target: int):
        self.eng.lane_floor[self.b] = fraction
        self.eng.lane_downscale[self.b] = target

    def set_workload_factor(self, factor: float):
        eng, b = self.eng, self.b
        eng.lane_workload[b] = factor
        # cached at event time; int(int64 * float) is the same IEEE
        # product + truncation the solo sim computes per tick
        eng.lane_min_queue_eff[b] = int(eng.lane_min_queue[b] * factor)

    # -- data-plane ops (spec.OriginOutage/OriginDegrade/CacheFlush);
    #    keyed by base provider, exactly like DataPlaneRuntime ----------
    def set_origin_outage(self, provider: str, on: bool):
        eng, b = self.eng, self.b
        gs = eng._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            eng.stage_elig_lg[b * eng.G + gs] = not bool(on)

    def degrade_origin(self, provider: str, factor: float):
        eng, b = self.eng, self.b
        gs = eng._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            eng.dp_degrade_lg[b * eng.G + gs] *= float(factor)

    def flush_cache(self, provider: str):
        eng, b = self.eng, self.b
        gs = eng._dp_groups_by_base.get(str(provider).split("/", 1)[0])
        if gs is not None:
            eng.dp_epoch_lg[b * eng.G + gs] += 1


def _prepare(sc, seed: int) -> Tuple[tuple, _Lane]:
    sc = sc.to_spec().validate()      # CampaignSpec or Scenario shim
    cat = build_catalog(sc)
    pairs = [(p, r) for p in cat.values() for r in p.regions]
    pairs.sort(key=lambda pr: (
        pr[0].spot_price_per_day if sc.spot else
        pr[0].ondemand_price_per_day, pr[0].name, pr[1].name))
    key = (sc.dt_h, sc.duration_h, tuple(
        (p.name, r.name, r.capacity, r.preempt_rate_per_hour,
         r.preempt_scale_at_full, p.nat_idle_timeout_s, p.fp32_tflops)
        for p, r in pairs),
        # stage geometry must be lane-identical: ticks-per-transfer and
        # the per-group origin config are batch-level constants (the
        # per-lane outage/degrade/epoch *state* still varies freely)
        getattr(sc, "job_input_gb", 0.0), getattr(sc, "dataplane", None))
    return key, _Lane(sc, seed, pairs)


class BatchedFleetEngine:
    """B lock-step campaigns in one struct-of-arrays control plane."""

    def __init__(self, lanes: Sequence[_Lane], collect: bool = False,
                 sinks=None):
        self.lanes = list(lanes)
        B = len(self.lanes)
        assert B > 0
        self.B = B
        # per-lane typed event recorders (events.TraceRecorder); RNG-free,
        # so collecting traces never changes any lane.  ``sinks`` swaps
        # them for streaming recorders (traceops.StreamingRecorder) that
        # flush bounded tick-windows instead of accumulating.
        self._streaming = sinks is not None
        if self._streaming:
            if len(sinks) != B:
                raise ValueError(f"need one sink per lane: got "
                                 f"{len(sinks)} sinks for {B} lanes")
            from repro.core.traceops import StreamingRecorder
            self.recorders: Optional[List[TraceRecorder]] = \
                [StreamingRecorder(s) for s in sinks]
        else:
            self.recorders = \
                [TraceRecorder() for _ in range(B)] if collect else None
        ref = self.lanes[0]
        pairs = ref.pairs
        G = len(pairs)
        self.G = G
        self.LG = B * G
        self.dt = ref.spec.dt_h
        self.duration = ref.spec.duration_h

        # -- static per-group config (identical across lanes by batch key)
        self.g_provider = [p.name for p, _ in pairs]
        self.g_region = [r.name for _, r in pairs]
        self.g_capacity = np.array([r.capacity for _, r in pairs],
                                   dtype=np.int64)
        self.g_pre_rate = np.array([r.preempt_rate_per_hour
                                    for _, r in pairs])
        self.g_pre_scale = np.array([r.preempt_scale_at_full
                                     for _, r in pairs])
        g_nat = np.array([p.nat_idle_timeout_s for p, _ in pairs])
        # provider name -> column (order of first appearance + "infra")
        self.providers: List[str] = []
        for name in self.g_provider:
            if name not in self.providers:
                self.providers.append(name)
        self.Pn = len(self.providers)
        self.infra_col = self.Pn
        pi = np.array([self.providers.index(n) for n in self.g_provider])
        self.prov_onehot = np.zeros((G, self.Pn))
        self.prov_onehot[np.arange(G), pi] = 1.0
        self.provider_tflops = {p.name: p.fp32_tflops for p, _r in pairs}
        self.homogeneous = all(t is None
                               for t in self.provider_tflops.values())

        # flattened [LG] views used on the hot path; capacity is per-lane
        # state (CapacityShift events mutate a lane's slice mid-run)
        self.g_cap_lg = np.tile(self.g_capacity, B)
        self.g_pre_rate_lg = np.tile(self.g_pre_rate, B)
        self.g_pre_scale_lg = np.tile(self.g_pre_scale, B)

        # -- per-lane config columns -------------------------------------
        def col(f, dtype=np.float64):
            return np.array([f(ln.spec) for ln in self.lanes],
                            dtype=dtype)

        self.lane_budget = col(lambda s: s.budget)
        assert (self.lane_budget > 0).all(), "sweep lanes need a budget"
        self.lane_floor = col(lambda s: s.budget_floor_fraction)
        self.lane_downscale = col(lambda s: s.downscale_target, np.int64)
        self.lane_min_queue = col(lambda s: s.min_queue, np.int64)
        # request-rate factor (spec.WorkloadCurve) and the cached
        # effective top-up level it implies — refreshed at event time
        self.lane_workload = np.ones(B)
        self.lane_min_queue_eff = self.lane_min_queue.copy()
        self.lane_wall = col(lambda s: s.job_wall_h)
        self.lane_ckpt = col(lambda s: s.job_checkpoint_h)
        self.lane_overhead = col(lambda s: s.overhead_per_day)
        lease = col(lambda s: s.lease_interval_s)
        self.connected_lg = (lease[:, None] < g_nat[None, :]).ravel()
        self.nat_possible = not bool(self.connected_lg.all())
        # $/accel-hour per (lane, group): lane's spot/on-demand choice and
        # static price perturbation are baked into its built catalog;
        # PriceShift events multiply a per-lane cumulative scale on top
        # (effective = base * scale, the solo engines' exact expression)
        self._rate_base_lg = np.array(
            [((p.spot_price_per_day if ln.spec.spot
               else p.ondemand_price_per_day) / 24.0)
             for ln in self.lanes for p, _ in ln.pairs])
        self.rate_h_lg = self._rate_base_lg.copy()
        self.lane_price_scale = np.ones(B)
        # absolute per-(lane, group) curve factors (spec.PriceCurve);
        # group lists are identical across lanes (batch key), so one
        # name -> group-index map serves every lane
        self.curve_lg = np.ones(self.LG)
        self._prov_groups = {
            name: np.array([g for g, n in enumerate(self.g_provider)
                            if n == name], dtype=np.int64)
            for name in self.providers}

        # -- data plane (config is batch-identical by key; outage /
        #    degrade / epoch state varies per lane) ----------------------
        dp = getattr(ref.spec, "dataplane", None)
        self.dp_size = float(getattr(ref.spec, "job_input_gb", 0.0))
        self.dp_active = dp is not None and bool(dp.origins)
        self.dp_staging = self.dp_active and self.dp_size > 0.0
        base_g = [n.split("/", 1)[0] for n in self.g_provider]
        origins_g = [dp.origin_for(n) if dp is not None else None
                     for n in self.g_provider]
        self.dp_has_g = np.array([o is not None for o in origins_g])
        self.dp_rate_g = np.array([o.cache_hit_rate if o else 0.0
                                   for o in origins_g])
        self.dp_bw_g = np.array([o.bandwidth_gbps if o else 0.0
                                 for o in origins_g])
        self.dp_cbw_g = np.array([o.cache_bandwidth_gbps if o else 0.0
                                  for o in origins_g])
        # egress meters by BASE provider (sliced pools share their base's
        # origin), drained in sorted-name order like DataPlaneRuntime.bill
        self.dp_base_names = sorted(
            {base_g[g] for g in range(G) if origins_g[g] is not None})
        nb = max(1, len(self.dp_base_names))
        self.dp_price_base = np.array(
            [dp.origin_for(nm).egress_usd_per_gb
             for nm in self.dp_base_names]) if self.dp_base_names \
            else np.zeros(0)
        self.dp_baseidx_g = np.array(
            [self.dp_base_names.index(base_g[g])
             if origins_g[g] is not None else -1 for g in range(G)],
            dtype=np.int64)
        self._dp_groups_by_base = {}
        for g, bg in enumerate(base_g):
            self._dp_groups_by_base.setdefault(bg, []).append(g)
        self._dp_groups_by_base = {k: np.array(v, dtype=np.int64)
                                   for k, v in
                                   self._dp_groups_by_base.items()}
        self.stage_elig_lg = np.ones(self.LG, dtype=bool)
        self.dp_degrade_lg = np.ones(self.LG)
        self.dp_epoch_lg = np.zeros(self.LG, dtype=np.int64)
        self.dp_pending = np.zeros((B, nb), dtype=np.int64)
        self.dp_spent_by_base = np.zeros((B, nb))
        self.dp_egress_usd = np.zeros(B)
        self.dp_hits = np.zeros(B, dtype=np.int64)
        self.dp_misses = np.zeros(B, dtype=np.int64)
        self.staged_l = np.zeros(B, dtype=np.int64)

        # -- per-lane RNG/counters/state ---------------------------------
        self.rngs = [np.random.default_rng(ln.seed) for ln in self.lanes]
        self.inst_ctr = np.zeros(B, dtype=np.int64)
        self.pilot_seq = np.zeros(B, dtype=np.int64)
        self.job_seq = np.zeros(B, dtype=np.int64)
        self.g_target = np.zeros((B, G), dtype=np.int64)
        self.outage = np.zeros(B, dtype=bool)
        self.capped = np.zeros(B, dtype=bool)
        self.cap_pending = np.zeros(B, dtype=bool)

        # controller events: the spec timeline compiled to (t, kind, arg)
        # tuples, stably time-sorted per lane; every execution is logged
        # to the lane's events_fired provenance
        self.events: List[List[tuple]] = []
        self.events_fired: List[List[dict]] = [[] for _ in range(B)]
        self.ev_ptr = [0] * B
        self.next_event_t = np.full(B, np.inf)
        for b, ln in enumerate(self.lanes):
            evs = _compile_timeline(ln.spec)
            self.events.append(evs)
            if evs:
                self.next_event_t[b] = evs[0][0]
        # scalar fast-path guards so the per-tick event check is two
        # float/bool compares instead of two array reductions
        self._next_wake = float(self.next_event_t.min())
        self._cap_pending_any = False

        # -- vectorized ledger + totals ----------------------------------
        self.spent = np.zeros(B)
        self.by_provider = np.zeros((B, self.Pn + 1))
        self.fired = np.zeros((B, len(_THRESHOLDS)), dtype=bool)
        self.preemptions = np.zeros(B, dtype=np.int64)
        self.nat_drops = np.zeros(B, dtype=np.int64)
        self.finished = np.zeros(B, dtype=np.int64)
        self.accel_hours = np.zeros(B)
        self.busy_hours = np.zeros(B)
        self.busy_hours_by_provider = np.zeros((B, self.Pn))
        self.retired_hours_lg = np.zeros(self.LG)
        self.retired_count = np.zeros(B, dtype=np.int64)

        # -- instance SoA -------------------------------------------------
        self.n = 0
        cap = 4096
        self.i_lg = np.zeros(cap, dtype=np.int32)
        self.i_id = np.zeros(cap, dtype=np.int64)
        self.i_start = np.zeros(cap)
        self.i_end = np.full(cap, np.nan)          # nan == dead marker
        self.i_preempted = np.zeros(cap, dtype=bool)
        self.i_pilot = np.zeros(cap, dtype=np.int8)
        self.i_pilot_order = np.zeros(cap, dtype=np.int32)
        self.i_job = np.full(cap, -1, dtype=np.int32)
        # the running job's progress/wall/id, cached on the instance row
        # at match time: job-array gathers are random-access while busy
        # rows are walked in sorted order — advancing progress here is
        # ~9x cheaper (written back to a job row on requeue, where the
        # checkpoint floor is applied).  In scheduled-completion mode
        # progress is (now - i_match_t) since i_done0; the walk mode
        # advances i_done in place.  i_gen guards stale finish buckets.
        self.i_done = np.zeros(cap)
        self.i_done0 = np.zeros(cap)
        self.i_match_t = np.zeros(cap)
        self.i_gen = np.zeros(cap, dtype=np.int32)
        self.i_wall = np.zeros(cap)
        self.i_jid = np.zeros(cap, dtype=np.int64)
        self.alive = np.zeros(cap, dtype=bool)
        # data-plane stage-in state per row: ticks left on the current
        # transfer, the pilot's cache-hit rotation counter, and the
        # CacheFlush epoch that counter belongs to
        self.i_stage = np.zeros(cap, dtype=np.int64)
        self.i_stage_k = np.zeros(cap, dtype=np.int64)
        self.i_stage_epoch = np.zeros(cap, dtype=np.int64)

        # -- incremental hot-loop state -----------------------------------
        # live instance count per (lane, group); the single source the
        # hazard model, maintain deficit and results all read
        self.live_lg = np.zeros(self.LG, dtype=np.int64)
        # all rows ever alive, sorted by (lane, group, creation); dead
        # entries are filtered lazily, insertions go to segment ends
        self._cand_rows = np.empty(0, dtype=np.int32)
        self._cand_lg = np.empty(0, dtype=np.int32)
        self._pending_rows: List[np.ndarray] = []   # created, to cand-merge
        self._fresh_rows: List[np.ndarray] = []     # created, to register
        self._stopped_rows: List[np.ndarray] = []   # event stops this tick
        self._cand_dirty = False       # event stops left stale entries
        self._idle_cand = np.empty(0, dtype=np.int32)   # pilots sans job
        # busy pilots as an exact, row-sorted set: matches insert, requeues
        # and finishes delete, so _advance walks it with no validity scan
        self._busy_cand = np.empty(0, dtype=np.int32)
        self._busy_lg = np.zeros(self.LG, dtype=np.int64)
        self._created_lg = np.zeros(self.LG, dtype=np.int64)  # this tick
        self._died_lg = np.zeros(self.LG, dtype=np.int64)     # this tick
        self._billed_to = 0.0
        self._dead_unreaped = 0        # O(1) compaction triggers
        self._jobs_dead = 0
        # hot-path scratch buffers (preemption draws and thresholds)
        self._draws = np.empty(4096)
        self._thresh = np.empty(4096)
        self._hitbuf = np.empty(4096, dtype=bool)

        # -- scheduled job completion --------------------------------------
        # Progress advances uniformly by dt, so a job's finish tick is
        # known at match time; bucketing rows by completion tick lets
        # _advance touch only the rows due now instead of walking every
        # busy pilot.  Valid whenever the tick walk is float-exact (dt and
        # all tick times exactly representable — any binary dt like 0.25)
        # and no lane can NAT-drop mid-flight; otherwise fall back to the
        # per-tick walk over the sorted busy set.
        t_probe = 0.0
        exact = True
        for _ in range(int(np.ceil(self.duration / self.dt)) + 2):
            nxt = t_probe + self.dt
            if nxt - t_probe != self.dt:
                exact = False
                break
            t_probe = nxt
        # stage-in delays a matched job's start, so completion ticks are
        # no longer known at match time — staging batches take the walk
        self.scheduled_completion = exact and not self.nat_possible \
            and not self.dp_staging
        self._tick_idx = 0
        self._fin_buckets: Dict[int, list] = {}

        # -- jobs: anonymous fresh pool + materialized requeued rows ------
        # A fresh queued job is interchangeable with any other fresh job
        # of its lane (same wall/checkpoint, zero progress), so the CE's
        # 4000-deep top-up queue is just a per-lane counter; job rows are
        # materialized only when a preempted job returns to the queue
        # with checkpointed progress.  Requeues always re-enter at the
        # FRONT and fresh jobs only append at the BACK, so "requeued ring
        # then fresh pool" preserves the solo FIFO order exactly.
        self.fresh_q = np.zeros(B, dtype=np.int64)     # queued fresh jobs
        self.fresh_matched = np.zeros(B, dtype=np.int64)
        self.jn = 0
        jcap = 1 << 12
        self.j_id = np.zeros(jcap, dtype=np.int64)
        self.j_wall = np.zeros(jcap)
        self.j_ckpt = np.zeros(jcap)
        self.j_done = np.zeros(jcap)
        self.j_attempts = np.zeros(jcap, dtype=np.int32)
        self.j_state = np.zeros(jcap, dtype=np.int8)   # 0 live, 1 finished
        self.q_cap = 1 << 12                           # requeued ring only
        self.q_ring = np.zeros((B, self.q_cap), dtype=np.int64)
        self.q_head = np.zeros(B, dtype=np.int64)      # raw; slots mod q_cap
        self.q_len = np.zeros(B, dtype=np.int64)

    # -- growth -----------------------------------------------------------
    def _grow_instances(self, extra: int):
        need = self.n + extra
        cap = len(self.i_id)
        if need <= cap:
            return
        new = max(need, cap * 2)
        for name, fill in (("i_lg", 0), ("i_id", 0), ("i_start", 0),
                           ("i_end", np.nan), ("i_preempted", False),
                           ("i_pilot", 0), ("i_pilot_order", 0),
                           ("i_job", -1), ("i_done", 0), ("i_done0", 0),
                           ("i_match_t", 0), ("i_gen", 0), ("i_wall", 0),
                           ("i_jid", 0), ("alive", False), ("i_stage", 0),
                           ("i_stage_k", 0), ("i_stage_epoch", 0)):
            a = getattr(self, name)
            out = np.full(new, fill, dtype=a.dtype)
            out[:self.n] = a[:self.n]
            setattr(self, name, out)

    def _grow_jobs(self, extra: int):
        need = self.jn + extra
        cap = len(self.j_id)
        if need <= cap:
            return
        new = max(need, cap * 2)
        for name in ("j_id", "j_wall", "j_ckpt", "j_done",
                     "j_attempts", "j_state"):
            a = getattr(self, name)
            out = np.zeros(new, dtype=a.dtype)
            out[:self.jn] = a[:self.jn]
            setattr(self, name, out)

    def _grow_queue(self, incoming: np.ndarray):
        need = int((self.q_len + incoming).max())
        if need <= self.q_cap:
            return
        new_cap = self.q_cap
        while new_cap < need:
            new_cap *= 2
        new_ring = np.zeros((self.B, new_cap), dtype=np.int64)
        total = int(self.q_len.sum())
        if total:
            lanes = np.repeat(np.arange(self.B), self.q_len)
            rank = segment_ranks(lanes, self.q_len)
            old = self.q_ring[lanes, (self.q_head[lanes] + rank)
                              % self.q_cap]
            new_ring[lanes, rank] = old
        self.q_ring = new_ring
        self.q_cap = new_cap
        self.q_head[:] = 0

    # -- instance creation ------------------------------------------------
    def _append_rows(self, lg: np.ndarray, lanes: np.ndarray,
                     per_lane: np.ndarray, now: float):
        """Append created rows (lane-major, group-ascending ``lg``) with
        per-lane sequential IDs — the solo engine's creation order."""
        total = len(lg)
        if total == 0:
            return
        self._grow_instances(total)
        s = slice(self.n, self.n + total)
        self.i_lg[s] = lg
        self.i_id[s] = self.inst_ctr[lanes] + segment_ranks(lanes, per_lane)
        self.inst_ctr += per_lane
        self.i_start[s] = now
        self.i_end[s] = np.nan
        self.i_preempted[s] = False
        self.i_pilot[s] = _NO_PILOT
        self.i_pilot_order[s] = 0
        self.i_job[s] = -1
        self.i_stage[s] = 0
        self.i_stage_k[s] = 0
        self.i_stage_epoch[s] = 0
        self.alive[s] = True
        rows = np.arange(self.n, self.n + total,
                         dtype=np.int32)
        self.n += total
        if self.recorders is not None:
            ids = self.i_id[s]
            for j in range(total):
                b, g = divmod(int(lg[j]), self.G)
                self.recorders[b].launched(now, ids[j], self.g_provider[g],
                                           self.g_region[g])
        bc = np.bincount(lg, minlength=self.LG)
        self.live_lg += bc
        self._created_lg += bc
        self._pending_rows.append(rows)
        self._fresh_rows.append(rows)

    def _append_single(self, b: int, g: int, k: int, now: float):
        if k <= 0:
            return
        lg = np.full(k, b * self.G + g, dtype=np.int64)
        per_lane = np.zeros(self.B, dtype=np.int64)
        per_lane[b] = k
        self._append_rows(lg, np.full(k, b, dtype=np.int64), per_lane, now)

    # -- lane-scalar control (event-time only, mirrors the solo engine) ---
    def _lane_set_group_target(self, b: int, g: int, n: int, now: float):
        self.g_target[b, g] = max(0, n)
        lg = b * self.G + g
        live = int(self.live_lg[lg])
        fillable = int(min(self.g_target[b, g], self.g_cap_lg[lg]))
        if live < fillable:
            self._append_single(b, g, fillable - live, now)
        elif live > self.g_target[b, g]:
            rows = np.nonzero(self.alive[:self.n]
                              & (self.i_lg[:self.n] == lg))[0]
            stop = rows[self.g_target[b, g]:]     # newest extras stop
            self.i_end[stop] = now                # stopped, not preempted
            self.alive[stop] = False
            if self.recorders is not None:
                for iid in self.i_id[stop]:
                    self.recorders[b].stopped(now, iid,
                                              self.g_provider[g],
                                              self.g_region[g])
            self.live_lg[lg] -= len(stop)
            self._died_lg[lg] += len(stop)
            self._dead_unreaped += len(stop)
            self._cand_dirty = True
            self._stopped_rows.append(stop)

    def _lane_scale_to(self, b: int, n: int, now: float):
        remaining = max(0, int(n))
        for g in range(self.G):
            want = min(remaining, int(self.g_cap_lg[b * self.G + g]))
            self._lane_set_group_target(b, g, want, now)
            remaining -= int(self.live_lg[b * self.G + g])

    def _lane_deprovision(self, b: int, now: float):
        for g in range(self.G):
            self._lane_set_group_target(b, g, 0, now)

    def _refresh_rates(self, b: int):
        """Effective $/h for lane b: ((base) * shift scalar) * curve —
        the same float-op order as the solo engines' rate expression,
        so billing stays bit-identical."""
        s = slice(b * self.G, (b + 1) * self.G)
        self.rate_h_lg[s] = self._rate_base_lg[s] \
            * self.lane_price_scale[b] * self.curve_lg[s]

    # -- controller events ------------------------------------------------
    def _run_events(self, now: float):
        if not self._cap_pending_any and now < self._next_wake:
            return
        apply_op = timeline_registry.apply_op
        for b in range(self.B):
            fired = self.events_fired[b]
            ops = None
            # the budget-floor cap was scheduled "at now" during the
            # previous tick's billing — it sorts before any event due
            # this tick, exactly like the solo sim.at(now, ...) insertion
            if self.cap_pending[b]:
                ops = _LaneOps(self, b, now)
                rec = timeline_registry.apply_budget_cap(ops, now)
                fired.append(rec)
                if self.recorders is not None:
                    self.recorders[b].timeline_fired(rec)
                self.cap_pending[b] = False
            evs = self.events[b]
            while self.ev_ptr[b] < len(evs) \
                    and evs[self.ev_ptr[b]][0] <= now:
                _t, op_kind, arg = evs[self.ev_ptr[b]]
                self.ev_ptr[b] += 1
                if ops is None:
                    ops = _LaneOps(self, b, now)
                rec = apply_op(ops, op_kind, arg, now)
                fired.append(rec)
                if self.recorders is not None:
                    self.recorders[b].timeline_fired(rec)
            self.next_event_t[b] = evs[self.ev_ptr[b]][0] \
                if self.ev_ptr[b] < len(evs) else np.inf
        self._next_wake = float(self.next_event_t.min())
        self._cap_pending_any = False

    # -- vectorized tick phases ------------------------------------------
    def _maintain(self, now: float):
        """Group mechanisms refill to min(target, capacity) — pure
        arithmetic on the maintained live counts, no fleet scan."""
        fillable = np.minimum(self.g_target.ravel(), self.g_cap_lg)
        new = np.where(self.live_lg < fillable,
                       fillable - self.live_lg, 0)
        total = int(new.sum())
        if total == 0:
            return
        lg = np.repeat(np.arange(self.LG), new)     # lane-major, group-asc
        per_lane = new.reshape(self.B, self.G).sum(axis=1)
        self._append_rows(lg, lg // self.G, per_lane, now)

    def _requeue_front(self, rows: np.ndarray, lanes: np.ndarray,
                       now: float):
        """Jobs of lost pilots return to the FRONT of their lane's queue,
        work floored to the last checkpoint.  ``rows`` must be in the
        solo engine's appendleft order per lane (so the final queue
        layout — reversed within the batch — matches exactly)."""
        jr = self.i_job[rows]
        has = jr != -1
        rows, lanes, jr = rows[has], lanes[has], jr[has]
        if not len(rows):
            return
        anon = jr < 0                   # fresh jobs: materialize on first
        k = int(anon.sum())             # preemption, with their identity
        if k:
            self._grow_jobs(k)
            s = slice(self.jn, self.jn + k)
            arows = rows[anon]
            self.j_id[s] = self.i_jid[arows]
            self.j_wall[s] = self.i_wall[arows]
            self.j_ckpt[s] = self.lane_ckpt[lanes[anon]]
            self.j_done[s] = 0.0
            self.j_attempts[s] = 1      # matched once, as an anonymous job
            self.j_state[s] = 0
            jr[anon] = np.arange(self.jn, self.jn + k)
            self.jn += k
        if self.scheduled_completion:
            # progress since match is (now - match time): the tick walk
            # is float-exact here, so this equals the solo accumulation
            prog = self.i_done0[rows] + (now - self.i_match_t[rows])
        else:
            prog = self.i_done[rows]
            self._busy_cand = _sorted_remove(self._busy_cand,
                                             np.sort(rows))
        self.j_done[jr] = checkpoint_floor(prog, self.j_ckpt[jr])
        self.i_stage[rows] = 0   # an abandoned transfer restarts on re-match
        self._busy_lg -= np.bincount(self.i_lg[rows], minlength=self.LG)
        counts = np.bincount(lanes, minlength=self.B)
        rank = segment_ranks(lanes, counts)
        self._grow_queue(counts)
        new_head = self.q_head - counts
        pos = counts[lanes] - 1 - rank              # appendleft == reversed
        self.q_ring[lanes, (new_head[lanes] + pos) % self.q_cap] = jr
        self.q_head = new_head
        self.q_len += counts
        self.i_job[rows] = -1
        self.preemptions += counts

    def _sync_pilots(self, now: float):
        """Register pilots on rows created this tick; reap pilots of rows
        stopped this tick.  Both sets are tracked as they happen, so this
        touches only the changed rows (preemption hits reap themselves in
        _sample_preemptions, mirroring the solo phase order)."""
        if self._fresh_rows:
            rows = np.concatenate(self._fresh_rows) \
                if len(self._fresh_rows) > 1 else self._fresh_rows[0]
            self._fresh_rows = []
            rows = rows[self.alive[rows]]           # stopped-same-tick
            if len(rows):
                lgv = self.i_lg[rows]
                order = np.lexsort((rows, lgv))     # (lane, group, row)
                rows = rows[order]
                lanes = lgv[order] // self.G
                counts = np.bincount(lanes, minlength=self.B)
                self.i_pilot_order[rows] = self.pilot_seq[lanes] \
                    + segment_ranks(lanes, counts)
                self.pilot_seq += counts
                self.i_pilot[rows] = _PILOT_LIVE
                if self.recorders is not None:
                    for row in rows.tolist():
                        b, g = divmod(int(self.i_lg[row]), self.G)
                        # 1-based registration order == the object CE's
                        # pilot-id numbering
                        self.recorders[b].pilot_registered(
                            now, self.i_pilot_order[row] + 1,
                            self.i_id[row], self.g_provider[g])
                self._idle_cand = np.concatenate([self._idle_cand, rows])
        if self._stopped_rows:
            rows = np.concatenate(self._stopped_rows) \
                if len(self._stopped_rows) > 1 else self._stopped_rows[0]
            self._stopped_rows = []
            rows = rows[self.i_pilot[rows] == _PILOT_LIVE]
            if len(rows):
                lanes = self.i_lg[rows] // self.G
                order = np.lexsort((self.i_pilot_order[rows], lanes))
                rows, lanes = rows[order], lanes[order]
                self._requeue_front(rows, lanes, now)
                self.i_pilot[rows] = _PILOT_DEAD

    def _flush_cand(self):
        """Merge rows created this tick into the lane-sorted row list
        (segment-end insertion keeps creation order within a group)."""
        if not self._pending_rows:
            return
        rows = np.concatenate(self._pending_rows) \
            if len(self._pending_rows) > 1 else self._pending_rows[0]
        self._pending_rows = []
        lgv = self.i_lg[rows]
        order = np.argsort(lgv, kind="stable")      # row idx asc within lg
        rows, lgv = rows[order], lgv[order]
        at = np.searchsorted(self._cand_lg, lgv, side="right") \
            + np.arange(len(lgv))
        total = len(self._cand_rows) + len(rows)
        mask = np.ones(total, dtype=bool)
        mask[at] = False
        nr = np.empty(total, dtype=np.int32)
        nl = np.empty(total, dtype=np.int32)
        nr[at] = rows
        nr[mask] = self._cand_rows
        nl[at] = lgv
        nl[mask] = self._cand_lg
        self._cand_rows, self._cand_lg = nr, nl

    def _sample_preemptions(self, now: float, dt: float):
        self._flush_cand()
        if self._cand_dirty:                  # event stops this tick
            m = self.alive[self._cand_rows]
            self._cand_rows = self._cand_rows[m]
            self._cand_lg = self._cand_lg[m]
            self._cand_dirty = False
        rows = self._cand_rows
        lgv = self._cand_lg
        if not len(rows):
            return
        if len(rows) != int(self.live_lg.sum()):       # cheap invariant
            raise AssertionError("live-count bookkeeping diverged")
        lane_counts = self.live_lg.reshape(self.B, self.G).sum(axis=1)
        # one stream read per lane, written straight into the shared draw
        # buffer, consumed in the solo order (groups by price, creation
        # order within a group)
        if len(self._draws) < len(rows):
            self._draws = np.empty(max(len(rows), 2 * len(self._draws)))
        draws = self._draws[:len(rows)]
        rngs = self.rngs
        ofs = 0
        for b, c in enumerate(lane_counts.tolist()):
            if c:
                rngs[b].random(out=draws[ofs:ofs + c])
                ofs += c
        rate = preemption_rate(self.g_pre_rate_lg, self.g_pre_scale_lg,
                               self.live_lg, self.g_cap_lg)
        if len(self._thresh) < len(rows):
            self._thresh = np.empty(max(len(rows), 2 * len(self._thresh)))
            self._hitbuf = np.empty(len(self._thresh), dtype=bool)
        thresh = self._thresh[:len(rows)]
        np.take(rate * dt, lgv, out=thresh)
        hit = self._hitbuf[:len(rows)]
        np.less(draws, thresh, out=hit)
        if not hit.any():
            return
        hits = rows[hit]
        hit_lg = lgv[hit]
        keep = ~hit
        self._cand_rows = rows[keep]
        self._cand_lg = lgv[keep]
        self.i_end[hits] = now
        self.i_preempted[hits] = True
        self.alive[hits] = False
        if self.recorders is not None:
            for row, lgj in zip(hits.tolist(), hit_lg.tolist()):
                b, g = divmod(int(lgj), self.G)
                self.recorders[b].preempted(now, self.i_id[row],
                                            self.g_provider[g],
                                            self.g_region[g])
        hit_bc = np.bincount(hit_lg, minlength=self.LG)
        self.live_lg -= hit_bc
        self._died_lg += hit_bc
        self._dead_unreaped += len(hits)
        live_pilot = self.i_pilot[hits] == _PILOT_LIVE
        piloted = hits[live_pilot]
        self._requeue_front(piloted, hit_lg[live_pilot] // self.G, now)
        self.i_pilot[piloted] = _PILOT_DEAD

    def _ensure_jobs(self):
        """Top the CE queue up to min_queue — pure counter arithmetic:
        fresh jobs stay anonymous until matched (IDs are the submission
        order, which FIFO matching preserves)."""
        need = np.maximum(0, self.lane_min_queue_eff
                          - (self.q_len + self.fresh_q))
        self.fresh_q += need
        self.job_seq += need

    def _match(self, now: float):
        """Hand queued jobs to idle pilots in pilot-registration order.
        The idle set is maintained incrementally (registrations, finished
        jobs, unmatched leftovers) and validated by a point lookup here."""
        cand = self._idle_cand
        if not len(cand):
            return
        ok = self.alive[cand] & (self.i_pilot[cand] == _PILOT_LIVE) \
            & (self.i_job[cand] < 0)
        rows = cand[ok]
        if not len(rows):
            self._idle_cand = rows
            return
        lanes = self.i_lg[rows] // self.G
        # single-key sort on (lane << 32 | pilot_order) beats a 2-key
        # lexsort; pilot_order is per-lane and < 2^31
        key = lanes.astype(np.int64) << 32
        key |= self.i_pilot_order[rows].astype(np.int64)
        order = np.argsort(key, kind="stable")
        rows, lanes = rows[order], lanes[order]
        hold = None
        if self.dp_active:
            # origin outage gates NEW matches; gated pilots stay in the
            # idle set (the solo engines skip them in pilot order)
            em = self.stage_elig_lg[self.i_lg[rows]]
            if not em.all():
                hold = rows[~em]
                rows, lanes = rows[em], lanes[em]
                if not len(rows):
                    self._idle_cand = hold
                    return
        counts = np.bincount(lanes, minlength=self.B)
        k = np.minimum(counts, self.q_len + self.fresh_q)
        k[self.outage] = 0
        k1 = np.minimum(k, self.q_len)      # requeued ring drains first
        rank = segment_ranks(lanes, counts)
        sel = rank < k[lanes]
        ring_sel = rank < k1[lanes]
        mrows = rows[sel]
        r1 = rows[ring_sel]
        if len(r1):
            l1 = lanes[ring_sel]
            jobs = self.q_ring[l1, (self.q_head[l1] + rank[ring_sel])
                               % self.q_cap]
            self.i_job[r1] = jobs
            self.i_done0[r1] = self.j_done[jobs]
            self.i_wall[r1] = self.j_wall[jobs]
            self.i_jid[r1] = self.j_id[jobs]
            self.j_attempts[jobs] += 1
        self.q_head += k1
        self.q_len -= k1
        fresh_sel = sel & ~ring_sel
        r2 = rows[fresh_sel]
        if len(r2):
            l2 = lanes[fresh_sel]
            self.i_job[r2] = -2             # anonymous fresh job
            self.i_done0[r2] = 0.0
            self.i_wall[r2] = self.lane_wall[l2]
            self.i_jid[r2] = self.fresh_matched[l2] + 1 \
                + rank[fresh_sel] - k1[l2]
        k2 = k - k1
        self.fresh_matched += k2
        self.fresh_q -= k2
        self._busy_lg += np.bincount(self.i_lg[mrows], minlength=self.LG)
        self._idle_cand = rows[~sel] if hold is None \
            else np.concatenate([rows[~sel], hold])
        self.i_match_t[mrows] = now
        if self.dp_staging and len(mrows):
            self._stage_matches(mrows, now)
        if self.scheduled_completion:
            self._schedule_finish(mrows)
        else:
            self.i_done[mrows] = self.i_done0[mrows]
            self._busy_cand = _sorted_insert(self._busy_cand,
                                             np.sort(mrows))

    def _stage_matches(self, mrows: np.ndarray, now: float):
        """Vectorized ``DataPlaneRuntime.decide`` for this tick's
        matches: per-pilot cache-hit rotation, stage length in whole
        ticks, and per-(lane, base) egress-miss metering — the same
        scalar float expressions as core/dataplane.py, elementwise."""
        lgm = self.i_lg[mrows]
        g = lgm % self.G
        has = self.dp_has_g[g]          # groups without an origin: no-op
        if not has.any():
            return
        rws = mrows[has]
        lgs = lgm[has]
        gs = g[has]
        bs = lgs // self.G
        ep = self.dp_epoch_lg[lgs]
        reset = self.i_stage_epoch[rws] != ep     # CacheFlush: k resets
        if reset.any():
            self.i_stage_k[rws[reset]] = 0
            self.i_stage_epoch[rws[reset]] = ep[reset]
        k = self.i_stage_k[rws].astype(np.float64)
        r = self.dp_rate_g[gs]
        # int((k+1)*r) > int(k*r) with k, r >= 0: floor == trunc
        hit = np.floor((k + 1.0) * r) > np.floor(k * r)
        self.i_stage_k[rws] += 1
        gbps = np.where(hit,
                        np.where(self.dp_cbw_g[gs] > 0.0,
                                 self.dp_cbw_g[gs], self.dp_bw_g[gs]),
                        self.dp_bw_g[gs] * self.dp_degrade_lg[lgs])
        # stage_ticks(): 0 when gbps <= 0 (a fully-degraded origin)
        hours = self.dp_size * 8.0 / np.where(gbps > 0.0, gbps, 1.0) \
            / 3600.0
        ticks = np.where(
            gbps > 0.0,
            np.maximum(1, np.ceil(hours / self.dt - 1e-9)
                       .astype(np.int64)), 0)
        self.i_stage[rws] = ticks
        self.dp_hits += np.bincount(bs[hit], minlength=self.B)
        miss = ~hit
        self.dp_misses += np.bincount(bs[miss], minlength=self.B)
        np.add.at(self.dp_pending,
                  (bs[miss], self.dp_baseidx_g[gs[miss]]), 1)
        if self.recorders is not None:
            hitl = hit.tolist()
            tickl = ticks.tolist()
            for j, row in enumerate(rws.tolist()):
                if tickl[j] > 0:      # zero-tick stages are not events
                    self.recorders[int(bs[j])].stagein_started(
                        now, self.i_pilot_order[row] + 1, self.dp_size,
                        hitl[j], self.g_provider[int(gs[j])])

    def _schedule_finish(self, mrows: np.ndarray):
        """Bucket matched rows by their (known) completion tick.  The
        floor+correction computes the smallest m with done0 + m*dt >=
        wall using the exact product, so it lands on the same tick as
        the solo engine's accumulate-and-compare."""
        done0 = self.i_done0[mrows]
        wall = self.i_wall[mrows]
        m = np.floor((wall - done0) / self.dt)
        m += (done0 + m * self.dt) < wall
        m += (done0 + m * self.dt) < wall
        f = self._tick_idx + m.astype(np.int64) - 1
        gen = self.i_gen[mrows] + 1
        self.i_gen[mrows] = gen
        for fv in np.unique(f):
            msk = f == fv
            self._fin_buckets.setdefault(int(fv), []).append(
                (mrows[msk], gen[msk]))

    def _advance(self, dt: float, now: float):
        if self.scheduled_completion:
            bucket = self._fin_buckets.pop(self._tick_idx, None)
            if bucket is None:
                return
            if len(bucket) > 1:
                rows = np.concatenate([r for r, _ in bucket])
                gens = np.concatenate([g for _, g in bucket])
            else:
                rows, gens = bucket[0]
            # stale entries: requeued (i_job cleared) or re-matched
            # (generation bumped) since this bucket was scheduled
            valid = (self.i_gen[rows] == gens) & (self.i_job[rows] != -1)
            done_rows = rows[valid]
            if len(done_rows):
                self._finish_rows(done_rows, now)
            return
        self._advance_walk(dt, now)

    def _finish_rows(self, done_rows: np.ndarray, now: float):
        done_jobs = self.i_job[done_rows]
        done_lg = np.bincount(self.i_lg[done_rows], minlength=self.LG)
        self._busy_lg -= done_lg
        self.finished += done_lg.reshape(self.B, self.G).sum(axis=1)
        if self.recorders is not None:
            for row in done_rows.tolist():
                b = int(self.i_lg[row]) // self.G
                jrow = int(self.i_job[row])
                # anonymous fresh jobs (-2) were matched exactly once
                attempts = self.j_attempts[jrow] if jrow >= 0 else 1
                self.recorders[b].job_finished(now, self.i_jid[row],
                                               attempts)
        mat = done_jobs >= 0                   # anonymous jobs have no row
        if mat.any():
            dj = done_jobs[mat]
            self.j_state[dj] = 1
            self._jobs_dead += len(dj)
        self.i_job[done_rows] = -1
        self._idle_cand = np.concatenate([self._idle_cand, done_rows])

    def _advance_walk(self, dt: float, now: float):
        """Per-tick walk over the sorted busy set — the fallback for NAT
        batches (mid-flight drops) and non-binary tick sizes."""
        if self.nat_possible and len(self._busy_cand):
            rows = self._busy_cand
            lgv = self.i_lg[rows]
            dropped = ~self.connected_lg[lgv]
            if dropped.any():
                drop = rows[dropped]
                lanes = lgv[dropped] // self.G
                order = np.lexsort((self.i_pilot_order[drop], lanes))
                drop, lanes = drop[order], lanes[order]
                self.nat_drops += np.bincount(lanes, minlength=self.B)
                if self.recorders is not None:
                    for row in drop.tolist():
                        b, g = divmod(int(self.i_lg[row]), self.G)
                        self.recorders[b].nat_drop(
                            now, self.i_pilot_order[row] + 1,
                            self.i_id[row], self.g_provider[g])
                self._requeue_front(drop, lanes, now)  # deletes from busy
                self.i_pilot[drop] = _PILOT_DEAD
        rows = self._busy_cand
        if not len(rows):
            return
        if len(rows) != int(self._busy_lg.sum()):     # cheap invariant
            raise AssertionError("busy-count bookkeeping diverged")
        prows = rows
        if self.dp_staging:
            # stage-in burns the tick; the job progresses from the next
            staging = self.i_stage[rows] > 0
            if staging.any():
                srows = rows[staging]
                self.i_stage[srows] -= 1
                self.staged_l += np.bincount(
                    self.i_lg[srows] // self.G, minlength=self.B)
                if self.recorders is not None:
                    done_s = srows[self.i_stage[srows] == 0]
                    if len(done_s):
                        lanes = self.i_lg[done_s] // self.G
                        order = np.lexsort(
                            (self.i_pilot_order[done_s], lanes))
                        for row in done_s[order].tolist():
                            b = int(self.i_lg[row]) // self.G
                            self.recorders[b].stagein_finished(
                                now, self.i_pilot_order[row] + 1)
                prows = rows[~staging]
                if not len(prows):
                    return
        done = self.i_done[prows] + dt
        self.i_done[prows] = done
        fin = done >= self.i_wall[prows]
        if fin.any():
            self._finish_rows(prows[fin], now)
            # staging rows must stay busy: remove only the finished rows
            self._busy_cand = _sorted_remove(rows, prows[fin])

    def _bill(self, now: float):
        """Lock-step billing: every billable row accrued the same scalar
        interval since the last charge (rows created at ``now`` have
        nothing billable yet; rows that died this tick died at ``now``
        and owe the full interval), so a tick's charges are pure counter
        arithmetic — no fleet scan at all."""
        dh = now - self._billed_to
        if dh > 0:
            counts = self.live_lg + self._died_lg - self._created_lg
            amt_bg = (counts * dh * self.rate_h_lg).reshape(self.B, self.G)
            self.by_provider[:, :self.Pn] += amt_bg @ self.prov_onehot
            self.spent += amt_bg.sum(axis=1)
        if self.dp_active and self.dp_pending.any():
            # drain this tick's cache-miss egress right after the
            # GPU-hour charges, per base provider in sorted-name order —
            # the solo DataPlaneRuntime.bill contract, vectorized
            for j, base in enumerate(self.dp_base_names):
                cnt = self.dp_pending[:, j]
                if not cnt.any():
                    continue
                gb = self.dp_size * cnt
                usd = gb * self.dp_price_base[j]
                self.dp_egress_usd += usd
                chg = usd > 0.0
                if chg.any():
                    self.spent += np.where(chg, usd, 0.0)
                    self.dp_spent_by_base[:, j] += np.where(chg, usd, 0.0)
                if self.recorders is not None:
                    for b in np.nonzero(cnt > 0)[0].tolist():
                        self.recorders[b].egress_billed(
                            now, base, float(gb[b]), float(usd[b]))
            self.dp_pending[:] = 0
        self._billed_to = now
        self._died_lg[:] = 0
        self._created_lg[:] = 0
        self._compact_instances()
        self._compact_jobs()

    def _compact_instances(self):
        # every dead row is fully billed once its death tick's _bill ran
        # (this runs right after the charge step), so dead == compactable;
        # the running death counter makes the trigger O(1) per tick
        if self._dead_unreaped < 4096 or self._dead_unreaped * 4 < self.n:
            return
        dead = ~self.alive[:self.n] \
            & (self.i_pilot[:self.n] != _PILOT_LIVE)
        self._dead_unreaped = 0
        rows = np.nonzero(dead)[0]
        self.retired_hours_lg += np.bincount(
            self.i_lg[rows], minlength=self.LG,
            weights=self.i_end[rows] - self.i_start[rows])
        self.retired_count += np.bincount(
            self.i_lg[rows].astype(np.int64) // self.G, minlength=self.B)
        keep = np.nonzero(~dead)[0]
        newidx = np.full(self.n, -1, dtype=np.int32)
        newidx[keep] = np.arange(len(keep), dtype=np.int32)
        for name in ("i_lg", "i_id", "i_start", "i_end", "i_preempted",
                     "i_pilot", "i_pilot_order", "i_job", "i_done",
                     "i_done0", "i_match_t", "i_gen", "i_wall", "i_jid",
                     "alive", "i_stage", "i_stage_k", "i_stage_epoch"):
            arr = getattr(self, name)
            arr[:len(keep)] = arr[keep]
        self.n = len(keep)
        # remap candidate sets (drop stale dead entries first; remapping
        # is monotone, so lane-sorted order is preserved)
        m = newidx[self._cand_rows] >= 0
        self._cand_rows = newidx[self._cand_rows[m]]
        self._cand_lg = self._cand_lg[m]
        for attr in ("_idle_cand", "_busy_cand"):
            c = getattr(self, attr)
            nc = newidx[c]
            setattr(self, attr, nc[nc >= 0])
        # pending finish buckets hold row indices too; preempted entries
        # map to -1 and drop (their generation guard is then moot)
        for fv, lst in self._fin_buckets.items():
            newlst = []
            for r, g in lst:
                nr = newidx[r]
                mm = nr >= 0
                newlst.append((nr[mm], g[mm]))
            self._fin_buckets[fv] = newlst

    def _compact_jobs(self):
        """Finished materialized (once-requeued) jobs are dead weight;
        drop them and remap the row indices held by pilots and queues."""
        if self.jn < (1 << 14) or self._jobs_dead * 2 < self.jn:
            return
        dead = self.j_state[:self.jn] == 1
        self._jobs_dead = 0
        keep = np.nonzero(~dead)[0]
        newidx = np.full(self.jn, -1, dtype=np.int64)
        newidx[keep] = np.arange(len(keep))
        ij = self.i_job[:self.n]
        ref = ij >= 0
        ij[ref] = newidx[ij[ref]]
        total_q = int(self.q_len.sum())
        if total_q:
            lanes = np.repeat(np.arange(self.B), self.q_len)
            rank = segment_ranks(lanes, self.q_len)
            slot = (self.q_head[lanes] + rank) % self.q_cap
            self.q_ring[lanes, slot] = newidx[self.q_ring[lanes, slot]]
        for name in ("j_id", "j_wall", "j_ckpt", "j_done",
                     "j_attempts", "j_state"):
            arr = getattr(self, name)
            arr[:len(keep)] = arr[keep]
        self.jn = len(keep)

    def _charge_overhead(self, dt: float):
        amt = self.lane_overhead * dt / 24.0
        chg = amt > 0
        if chg.any():
            self.by_provider[chg, self.infra_col] += amt[chg]
            self.spent += np.where(chg, amt, 0.0)

    def _check_thresholds(self, now: float):
        """End-of-tick sweep over the ledger alert levels.  The solo
        ledger fires mid-charge, but every response is scheduled
        ``at(now)`` and so lands at the next tick either way; checking
        once after all of a tick's charges crosses the same levels."""
        frac = np.maximum(0.0, self.lane_budget - self.spent) \
            / self.lane_budget
        newly = np.zeros(self.B, dtype=bool)
        for i, th in enumerate(_THRESHOLDS):
            cross = (frac <= th) & ~self.fired[:, i]
            self.fired[:, i] |= cross
            newly |= cross
        trigger = newly & (frac <= self.lane_floor) & ~self.capped
        if trigger.any():
            self.capped |= trigger
            self.cap_pending |= trigger
            self._cap_pending_any = True

    def _accumulate(self, dt: float):
        running = self.live_lg.reshape(self.B, self.G).sum(axis=1)
        busy_bg = self._busy_lg.reshape(self.B, self.G)
        self.accel_hours += running * dt
        self.busy_hours += busy_bg.sum(axis=1) * dt
        self.busy_hours_by_provider += (busy_bg @ self.prov_onehot) * dt

    # -- the lock-step driver --------------------------------------------
    def tick(self, now: float, dt: float):
        self._run_events(now)
        self._maintain(now)
        self._sync_pilots(now)
        self._sample_preemptions(now, dt)
        self._sync_pilots(now)       # solo phase order (no-op here: both
        #                              death paths reap where they happen)
        self._ensure_jobs()
        self._match(now)
        self._advance(dt, now)
        self._bill(now)
        self._charge_overhead(dt)
        self._check_thresholds(now)
        self._accumulate(dt)

    def run(self) -> "BatchedFleetEngine":
        now = 0.0
        while now < self.duration:        # same float walk as the solo sim
            self.tick(now, self.dt)
            self._tick_idx += 1
            now += self.dt
        self._bill(now)                   # settle the final interval
        self.now = now
        return self

    # -- conservation view (tests) ---------------------------------------
    def billed_hours_by_lg(self) -> np.ndarray:
        out = self.retired_hours_lg.copy()
        end = np.where(self.alive[:self.n], self._billed_to,
                       self.i_end[:self.n])
        out += np.bincount(self.i_lg[:self.n], minlength=self.LG,
                           weights=np.maximum(
                               0.0, end - self.i_start[:self.n]))
        return out

    # -- per-lane results, schema-identical to CloudSimulator.results() --
    def lane_events(self, b: int) -> List[dict]:
        """The lane's executed-event provenance (timeline events plus
        budget-floor caps), bit-identical to the solo controller's
        ``events_fired``."""
        return list(self.events_fired[b])

    def lane_trace(self, b: int) -> Optional[CampaignTrace]:
        """The lane's typed event trace (``collect`` engines only) —
        byte-identical to the solo engines' trace at the same
        (spec, seed).  Streaming lanes fed their events to a sink and
        hold nothing to build from."""
        if self.recorders is None or self._streaming:
            return None
        ln = self.lanes[b]
        return build_trace(ln.spec.name, ln.seed, self.duration, self.dt,
                           self.recorders[b], self.events_fired[b])

    def lane_results(self, b: int) -> dict:
        sc = self.lanes[b].spec
        busy_by_prov = {}
        for pidx, name in enumerate(self.providers):
            h = float(self.busy_hours_by_provider[b, pidx])
            if h > 0:
                busy_by_prov[name] = h
        if self.homogeneous:
            eflop = float(self.busy_hours[b]) * sc.accel_tflops * 1e12 / 1e18
        else:
            eflop = sum(
                h * (self.provider_tflops.get(name) or sc.accel_tflops)
                for name, h in busy_by_prov.items()) * 1e12 / 1e18
        spent = float(self.spent[b])
        budget = float(self.lane_budget[b])
        raw_by_prov: Dict[str, float] = {}
        for pidx, name in enumerate(self.providers + ["infra"]):
            v = float(self.by_provider[b, pidx])
            if v > 0:
                raw_by_prov[name] = v
        # egress lands under the BASE provider name, merged before
        # rounding — matching the solo ledger's per-provider totals
        for j, base in enumerate(self.dp_base_names):
            e = float(self.dp_spent_by_base[b, j])
            if e > 0:
                raw_by_prov[base] = raw_by_prov.get(base, 0.0) + e
        ledger_by_prov = {k: round(v, 2) for k, v in raw_by_prov.items()}
        running = self.live_lg.reshape(self.B, self.G)[b]
        by_provider: Dict[str, int] = {}
        for g, name in enumerate(self.g_provider):
            by_provider[name] = by_provider.get(name, 0) + int(running[g])
        accel = float(self.accel_hours[b])
        return {
            "accel_hours": round(accel, 1),
            "accel_days": round(accel / 24.0, 1),
            "busy_hours": round(float(self.busy_hours[b]), 1),
            "busy_hours_by_provider": {
                k: round(v, 1) for k, v in sorted(busy_by_prov.items())},
            "eflop_hours_fp32": round(eflop, 3),
            "cost": round(spent, 2),
            "cost_per_accel_day": round(
                spent / max(accel / 24.0, 1e-9), 2),
            "preemptions": int(self.preemptions[b]),
            "nat_drops": int(self.nat_drops[b]),
            "jobs_finished": int(self.finished[b]),
            "egress_usd": round(float(self.dp_egress_usd[b]), 2),
            "stagein_hours": round(int(self.staged_l[b]) * self.dt, 1),
            "cache_hit_fraction": round(
                int(self.dp_hits[b])
                / (int(self.dp_hits[b]) + int(self.dp_misses[b])), 4)
            if int(self.dp_hits[b]) + int(self.dp_misses[b]) else 0.0,
            "budget": {
                "total_spent": round(spent, 2),
                "by_provider": dict(sorted(ledger_by_prov.items())),
                "remaining": round(max(0.0, budget - spent), 2),
                "remaining_fraction": round(
                    max(0.0, budget - spent) / budget, 4),
                "overdraft": round(max(0.0, spent - budget), 2),
            },
            "by_provider": by_provider,
        }


# lanes per engine: wider amortizes more Python dispatch, but the flat
# arrays must stay cache-resident — 64 paper-scale lanes (~130k
# instances, ~20 MB hot) is the empirical sweet spot on a laptop-class
# cache; chunking kicks in for wider sweeps
_MAX_LANES_PER_ENGINE = 64


def run_batched_detailed(lane_specs: Sequence[Tuple[CampaignSpec, int]],
                         max_lanes: int = _MAX_LANES_PER_ENGINE,
                         collect: str = "summary", sinks=None
                         ) -> List[Tuple[dict, List[dict],
                                         Optional[CampaignTrace]]]:
    """Run every (spec, seed) lane, batching lock-step-compatible lanes
    into shared engines (chunked to keep the working set in cache);
    returns per-lane ``(results, events_fired, trace)`` in input order
    (``trace`` is None unless ``collect="trace"``).  With
    ``collect="stream"`` each lane's canonical event stream goes to the
    matching entry of ``sinks`` (one traceops.TraceSink per lane, input
    order) instead of being held in memory — ``trace`` stays None."""
    if collect == "stream":
        if sinks is None or len(sinks) != len(lane_specs):
            raise ValueError(
                'collect="stream" needs sinks= with one '
                "traceops.TraceSink per lane")
    elif sinks is not None:
        raise ValueError('sinks= is only meaningful with '
                         'collect="stream"')
    prepared = [_prepare(sc, seed) for sc, seed in lane_specs]
    batches: Dict[tuple, List[int]] = {}
    for i, (key, _lane) in enumerate(prepared):
        batches.setdefault(key, []).append(i)
    out: List[Optional[tuple]] = [None] * len(prepared)
    for idxs in batches.values():
        for c in range(0, len(idxs), max_lanes):
            chunk = idxs[c:c + max_lanes]
            chunk_sinks = [sinks[i] for i in chunk] \
                if sinks is not None else None
            eng = BatchedFleetEngine([prepared[i][1] for i in chunk],
                                     collect=(collect == "trace"),
                                     sinks=chunk_sinks).run()
            if chunk_sinks is not None:
                for j in range(len(chunk)):
                    ln = eng.lanes[j]
                    eng.recorders[j].finish(ln.spec.name, ln.seed,
                                            eng.duration, eng.dt)
            for j, i in enumerate(chunk):
                out[i] = (eng.lane_results(j), eng.lane_events(j),
                          eng.lane_trace(j))
    return out


def run_batched(lane_specs: Sequence[Tuple[CampaignSpec, int]],
                max_lanes: int = _MAX_LANES_PER_ENGINE) -> List[dict]:
    """Like :func:`run_batched_detailed`, results only."""
    return [res for res, _events, _trace in
            run_batched_detailed(lane_specs, max_lanes)]


# -- sweep result table ---------------------------------------------------

_BAND_METRICS = ("cost", "accel_days", "eflop_hours_fp32", "preemptions",
                 "jobs_finished")


def _flatten_row(row: dict) -> dict:
    """Dotted-key flattening for CSV export; events_fired is serialized
    as one compact deterministic cell."""
    out: dict = {}

    def walk(prefix, v):
        if isinstance(v, dict):
            for k in sorted(v):
                walk(f"{prefix}.{k}" if prefix else str(k), v[k])
        else:
            out[prefix] = v

    for k, v in row.items():
        if k == "events_fired":
            out[k] = "|".join(
                ";".join(f"{kk}={ev[kk]}" for kk in sorted(ev))
                for ev in v)
        else:
            walk(k, v)
    return out


@dataclass
class SweepResult:
    """Per-lane campaign totals plus per-scenario summary bands.

    Rows are legacy ``results()`` dicts extended with ``scenario`` /
    ``seed`` / ``events_fired`` (the executed-event provenance both the
    batched and sequential engines record identically).  Sweeps run
    with ``collect="trace"`` additionally carry one
    :class:`~repro.core.events.CampaignTrace` per lane in ``traces``
    (row-aligned; reachable by name via :meth:`trace_for`) — rows stay
    plain dicts so CSV export and back-compat consumers are unaffected."""
    rows: List[dict]
    traces: Optional[List[Optional[CampaignTrace]]] = None

    def trace_for(self, scenario: str, seed: int) -> CampaignTrace:
        """The (scenario, seed) lane's typed event trace."""
        if self.traces is None:
            raise ValueError(
                "this sweep ran with collect='summary'; re-run with "
                "collect='trace' to record per-lane event traces")
        for row, tr in zip(self.rows, self.traces):
            if row["scenario"] == scenario and row["seed"] == seed:
                return tr
        raise KeyError((scenario, seed))

    def to_csv(self, path: Optional[str] = None) -> str:
        """Deterministic CSV of the per-lane rows: rows sorted by
        (scenario, seed), columns sorted by dotted key — byte-identical
        across runs of the same sweep, so CI artifacts diff cleanly."""
        import csv
        import io
        flat = sorted((_flatten_row(r) for r in self.rows),
                      key=lambda r: (str(r.get("scenario", "")),
                                     r.get("seed", 0)))
        cols = ["scenario", "seed"] + sorted(
            {k for r in flat for k in r} - {"scenario", "seed"})
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=cols, restval="",
                           lineterminator="\n")
        w.writeheader()
        w.writerows(flat)
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def scenario_names(self) -> List[str]:
        seen: List[str] = []
        for r in self.rows:
            if r["scenario"] not in seen:
                seen.append(r["scenario"])
        return seen

    def summary(self, metrics: Sequence[str] = _BAND_METRICS
                ) -> Dict[str, dict]:
        """Per-scenario {metric: {mean, p5, p95}} across seeds."""
        out: Dict[str, dict] = {}
        for name in self.scenario_names():
            vals = {m: np.array([r[m] for r in self.rows
                                 if r["scenario"] == name])
                    for m in metrics}
            out[name] = {
                "seeds": int(len(next(iter(vals.values())))),
                **{m: {"mean": float(np.mean(v)),
                       "p5": float(np.percentile(v, 5)),
                       "p95": float(np.percentile(v, 95))}
                   for m, v in vals.items()}}
        return out

    def table(self, metrics: Sequence[str] = ("cost", "accel_days",
                                              "preemptions")) -> str:
        """Plain-text planning table: one row per scenario, mean [p5, p95]
        bands per metric."""
        summ = self.summary(metrics)
        if not summ:
            return "(no sweep rows)"
        width = max(len(n) for n in summ) + 2
        cols = [f"{m} mean [p5, p95]" for m in metrics]
        lines = ["scenario".ljust(width) + "  ".join(c.rjust(30)
                                                     for c in cols)]
        for name, stats in summ.items():
            cells = []
            for m in metrics:
                s = stats[m]
                cells.append(f"{s['mean']:,.1f} "
                             f"[{s['p5']:,.1f}, {s['p95']:,.1f}]".rjust(30))
            lines.append(name.ljust(width) + "  ".join(cells))
        return "\n".join(lines)
