"""Cloud provider models: capacity, spot pricing, preemption, NAT quirks.

Catalog defaults reproduce the paper's observations:
  * Azure: cheapest spot T4 ($2.9/day), "plenty of spare capacity with very
    low preemption rates" -> favored by the price-priority provisioner.
  * Azure NAT drops idle TCP connections after 4 minutes — the paper's one
    operational bug (OSG default keepalive was 5 min -> constant preemption
    until tuned). Modeled via ``nat_idle_timeout_s``; the overlay's lease
    interval must stay below it (tests/test_overlay.py pins this).
  * GCP / AWS: pricier spot T4s, moderate preemption.
  * TPU v5e entries drive the adapted (pod-granular) workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class RegionSpec:
    name: str
    capacity: int                 # max accelerators fillable in this region
    preempt_rate_per_hour: float  # per-instance hazard at low utilization
    # hazard multiplier at full capacity utilization (spot gets tighter)
    preempt_scale_at_full: float = 3.0


@dataclass(frozen=True)
class ProviderSpec:
    name: str
    accel: str                    # "t4" | "v100" | ... | "v5e-slice"
    spot_price_per_day: float     # $ per accelerator-day (spot)
    ondemand_price_per_day: float
    regions: Tuple[RegionSpec, ...]
    nat_idle_timeout_s: float = float("inf")
    group_mechanism: str = ""     # VMSS / InstanceGroups / SpotFleet
    # fp32 peak of this provider's accelerator; None -> use the simulator's
    # homogeneous SimConfig.accel_tflops (keeps the T4-only replay's EFLOP
    # accounting bit-identical to the seed engine)
    fp32_tflops: Optional[float] = None

    @property
    def total_capacity(self) -> int:
        return sum(r.capacity for r in self.regions)


def t4_catalog() -> Dict[str, ProviderSpec]:
    """The paper's three providers (T4 spot). Prices: Azure $2.9/T4-day is
    the paper's number; AWS/GCP set from contemporaneous public spot prices
    (~$0.16-0.19/h)."""
    return {
        "azure": ProviderSpec(
            "azure", "t4", spot_price_per_day=2.9,
            ondemand_price_per_day=12.7,
            regions=(RegionSpec("eastus", 500, 0.0008),
                     RegionSpec("westus2", 300, 0.0010),
                     RegionSpec("westeurope", 250, 0.0010),
                     RegionSpec("southcentralus", 150, 0.0015)),
            nat_idle_timeout_s=240.0,          # the 4-minute NAT quirk
            group_mechanism="VMSS"),
        "gcp": ProviderSpec(
            "gcp", "t4", spot_price_per_day=4.3,
            ondemand_price_per_day=16.8,
            regions=(RegionSpec("us-central1", 500, 0.008),
                     RegionSpec("us-east1", 300, 0.010),
                     RegionSpec("europe-west1", 250, 0.012)),
            group_mechanism="InstanceGroups"),
        "aws": ProviderSpec(
            "aws", "t4", spot_price_per_day=4.8,
            ondemand_price_per_day=18.9,
            regions=(RegionSpec("us-east-1", 450, 0.012),
                     RegionSpec("us-west-2", 350, 0.015),
                     RegionSpec("eu-west-1", 250, 0.018)),
            group_mechanism="SpotFleet"),
    }


def tpu_catalog() -> Dict[str, ProviderSpec]:
    """Adapted workload: the provisioning unit is a v5e pod slice (the
    elastic `pod` mesh axis member). Prices scaled per-slice."""
    return {
        "cloud-a": ProviderSpec(
            "cloud-a", "v5e-slice", spot_price_per_day=1060.0,
            ondemand_price_per_day=2470.0,
            regions=(RegionSpec("a-east", 8, 0.004),
                     RegionSpec("a-west", 4, 0.006)),
            nat_idle_timeout_s=240.0, group_mechanism="VMSS"),
        "cloud-b": ProviderSpec(
            "cloud-b", "v5e-slice", spot_price_per_day=1420.0,
            ondemand_price_per_day=2900.0,
            regions=(RegionSpec("b-central", 6, 0.012),),
            group_mechanism="InstanceGroups"),
        "cloud-c": ProviderSpec(
            "cloud-c", "v5e-slice", spot_price_per_day=1510.0,
            ondemand_price_per_day=3100.0,
            regions=(RegionSpec("c-east", 6, 0.015),),
            group_mechanism="SpotFleet"),
    }


def slice_provider(p: ProviderSpec, slices: int, *,
                   price_factor: float = 1.0, tflops_factor: float = 1.0,
                   default_tflops: Optional[float] = None) -> ProviderSpec:
    """The provider's sub-GPU-slice variant (Sfiligoi 2022): each region
    offers ``slices`` fractional-GPU slots per physical device, priced
    and rated at ``1/slices`` of the whole GPU times the overhead
    factors (MIG-style partitions are rarely perfectly proportional).
    ``default_tflops`` supplies the device peak where the catalog leaves
    ``fp32_tflops`` unset (the homogeneous T4 replay; defaults to the T4
    peak) — a slice must always carry an explicit sliced peak, else the
    simulator's homogeneous EFLOP path would count each slice as a
    whole device."""
    if slices < 1:
        raise ValueError(f"slices must be >= 1, got {slices}")
    full = p.fp32_tflops if p.fp32_tflops is not None else \
        (default_tflops if default_tflops is not None else T4_FP32_TFLOPS)
    return replace(
        p, name=f"{p.name}/{slices}", accel=f"{p.accel}/{slices}",
        spot_price_per_day=p.spot_price_per_day / slices * price_factor,
        ondemand_price_per_day=(p.ondemand_price_per_day / slices
                                * price_factor),
        fp32_tflops=full / slices * tflops_factor,
        regions=tuple(replace(r, capacity=r.capacity * slices)
                      for r in p.regions))


def sliced_catalog(slices: int = 2, capacity_scale: float = 1.0,
                   **slice_kwargs) -> Dict[str, ProviderSpec]:
    """The §III heterogeneous T4/V100/P100/M60 pool planned in 1/k-GPU
    slices instead of whole devices — the Sfiligoi 2022 what-if: same
    physical fleet, k-fold finer-grained capacity accounting."""
    return {p.name: p for p in (
        slice_provider(spec, slices, **slice_kwargs)
        for spec in heterogeneous_catalog(capacity_scale).values())}


# fp32 peaks (paper's EFLOP accounting; §III GPU generations): TFLOP/s
T4_FP32_TFLOPS = 8.141
V100_FP32_TFLOPS = 14.13
P100_FP32_TFLOPS = 9.3
M60_FP32_TFLOPS = 4.825          # per GPU (half a Tesla M60 board)


def heterogeneous_catalog(capacity_scale: float = 1.0
                          ) -> Dict[str, ProviderSpec]:
    """The paper's §III heterogeneous pool: alongside the T4 workhorses,
    the providers offered V100 / P100 / M60 spot (and on-demand) SKUs —
    the mix the earlier pre-exascale burst actually ran on. One
    ProviderSpec per (cloud, GPU) pair so the price-priority provisioner
    can trade $/day against delivered fp32 TFLOPS.

    ``capacity_scale`` multiplies every region's capacity, letting the
    fleet-scale benchmark express 100k-instance campaigns."""
    def _cap(n: int) -> int:
        return max(1, int(n * capacity_scale))

    def _regions(*specs) -> Tuple[RegionSpec, ...]:
        return tuple(replace(r, capacity=_cap(r.capacity)) for r in specs)

    cat: Dict[str, ProviderSpec] = {}
    for name, spec in t4_catalog().items():
        cat[f"{name}-t4"] = replace(
            spec, name=f"{name}-t4", regions=_regions(*spec.regions),
            fp32_tflops=T4_FP32_TFLOPS)
    cat.update({
        "azure-v100": ProviderSpec(
            "azure-v100", "v100", spot_price_per_day=13.2,
            ondemand_price_per_day=73.4, fp32_tflops=V100_FP32_TFLOPS,
            regions=_regions(RegionSpec("eastus", 150, 0.0020),
                             RegionSpec("westeurope", 100, 0.0025)),
            nat_idle_timeout_s=240.0, group_mechanism="VMSS"),
        "azure-m60": ProviderSpec(
            "azure-m60", "m60", spot_price_per_day=2.7,
            ondemand_price_per_day=27.4, fp32_tflops=M60_FP32_TFLOPS,
            regions=_regions(RegionSpec("eastus", 200, 0.0012),
                             RegionSpec("southcentralus", 120, 0.0018)),
            nat_idle_timeout_s=240.0, group_mechanism="VMSS"),
        "gcp-v100": ProviderSpec(
            "gcp-v100", "v100", spot_price_per_day=17.8,
            ondemand_price_per_day=59.5, fp32_tflops=V100_FP32_TFLOPS,
            regions=_regions(RegionSpec("us-central1", 200, 0.015),
                             RegionSpec("europe-west4", 100, 0.018)),
            group_mechanism="InstanceGroups"),
        "gcp-p100": ProviderSpec(
            "gcp-p100", "p100", spot_price_per_day=10.3,
            ondemand_price_per_day=35.0, fp32_tflops=P100_FP32_TFLOPS,
            regions=_regions(RegionSpec("us-east1", 250, 0.012),
                             RegionSpec("europe-west1", 150, 0.014)),
            group_mechanism="InstanceGroups"),
        "aws-v100": ProviderSpec(
            "aws-v100", "v100", spot_price_per_day=22.0,
            ondemand_price_per_day=73.4, fp32_tflops=V100_FP32_TFLOPS,
            regions=_regions(RegionSpec("us-east-1", 200, 0.018),
                             RegionSpec("us-west-2", 150, 0.020)),
            group_mechanism="SpotFleet"),
        "aws-m60": ProviderSpec(
            "aws-m60", "m60", spot_price_per_day=3.4,
            ondemand_price_per_day=15.6, fp32_tflops=M60_FP32_TFLOPS,
            regions=_regions(RegionSpec("us-east-1", 250, 0.014),
                             RegionSpec("eu-west-1", 150, 0.016)),
            group_mechanism="SpotFleet"),
    })
    return cat
