"""Cloud provider models: capacity, spot pricing, preemption, NAT quirks.

Catalog defaults reproduce the paper's observations:
  * Azure: cheapest spot T4 ($2.9/day), "plenty of spare capacity with very
    low preemption rates" -> favored by the price-priority provisioner.
  * Azure NAT drops idle TCP connections after 4 minutes — the paper's one
    operational bug (OSG default keepalive was 5 min -> constant preemption
    until tuned). Modeled via ``nat_idle_timeout_s``; the overlay's lease
    interval must stay below it (tests/test_overlay.py pins this).
  * GCP / AWS: pricier spot T4s, moderate preemption.
  * TPU v5e entries drive the adapted (pod-granular) workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class RegionSpec:
    name: str
    capacity: int                 # max accelerators fillable in this region
    preempt_rate_per_hour: float  # per-instance hazard at low utilization
    # hazard multiplier at full capacity utilization (spot gets tighter)
    preempt_scale_at_full: float = 3.0


@dataclass(frozen=True)
class ProviderSpec:
    name: str
    accel: str                    # "t4" | "v5e-slice"
    spot_price_per_day: float     # $ per accelerator-day (spot)
    ondemand_price_per_day: float
    regions: Tuple[RegionSpec, ...]
    nat_idle_timeout_s: float = float("inf")
    group_mechanism: str = ""     # VMSS / InstanceGroups / SpotFleet

    @property
    def total_capacity(self) -> int:
        return sum(r.capacity for r in self.regions)


def t4_catalog() -> Dict[str, ProviderSpec]:
    """The paper's three providers (T4 spot). Prices: Azure $2.9/T4-day is
    the paper's number; AWS/GCP set from contemporaneous public spot prices
    (~$0.16-0.19/h)."""
    return {
        "azure": ProviderSpec(
            "azure", "t4", spot_price_per_day=2.9,
            ondemand_price_per_day=12.7,
            regions=(RegionSpec("eastus", 500, 0.0008),
                     RegionSpec("westus2", 300, 0.0010),
                     RegionSpec("westeurope", 250, 0.0010),
                     RegionSpec("southcentralus", 150, 0.0015)),
            nat_idle_timeout_s=240.0,          # the 4-minute NAT quirk
            group_mechanism="VMSS"),
        "gcp": ProviderSpec(
            "gcp", "t4", spot_price_per_day=4.3,
            ondemand_price_per_day=16.8,
            regions=(RegionSpec("us-central1", 500, 0.008),
                     RegionSpec("us-east1", 300, 0.010),
                     RegionSpec("europe-west1", 250, 0.012)),
            group_mechanism="InstanceGroups"),
        "aws": ProviderSpec(
            "aws", "t4", spot_price_per_day=4.8,
            ondemand_price_per_day=18.9,
            regions=(RegionSpec("us-east-1", 450, 0.012),
                     RegionSpec("us-west-2", 350, 0.015),
                     RegionSpec("eu-west-1", 250, 0.018)),
            group_mechanism="SpotFleet"),
    }


def tpu_catalog() -> Dict[str, ProviderSpec]:
    """Adapted workload: the provisioning unit is a v5e pod slice (the
    elastic `pod` mesh axis member). Prices scaled per-slice."""
    return {
        "cloud-a": ProviderSpec(
            "cloud-a", "v5e-slice", spot_price_per_day=1060.0,
            ondemand_price_per_day=2470.0,
            regions=(RegionSpec("a-east", 8, 0.004),
                     RegionSpec("a-west", 4, 0.006)),
            nat_idle_timeout_s=240.0, group_mechanism="VMSS"),
        "cloud-b": ProviderSpec(
            "cloud-b", "v5e-slice", spot_price_per_day=1420.0,
            ondemand_price_per_day=2900.0,
            regions=(RegionSpec("b-central", 6, 0.012),),
            group_mechanism="InstanceGroups"),
        "cloud-c": ProviderSpec(
            "cloud-c", "v5e-slice", spot_price_per_day=1510.0,
            ondemand_price_per_day=3100.0,
            regions=(RegionSpec("c-east", 6, 0.015),),
            group_mechanism="SpotFleet"),
    }


# T4 fp32 peak (paper's EFLOP accounting): 8.141 TFLOP/s
T4_FP32_TFLOPS = 8.141
