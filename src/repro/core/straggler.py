"""Straggler detection & mitigation.

Two consumers:
  * the job overlay (IceCube-style independent tasks): speculative
    re-execution — if a job's elapsed time exceeds ``spec_factor`` x the
    running median of completed jobs, clone it onto an idle pilot and let
    the first copy win (classic backup tasks),
  * synchronous training (the TPU adaptation): per-pod step-time EWMA; a pod
    persistently slower than ``evict_factor`` x the fleet median is evicted
    from the PodPool (elastic shrink beats a permanently slow step, since
    SPMD speed == slowest pod).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class SpeculativeScheduler:
    spec_factor: float = 2.0
    min_samples: int = 5
    completed_times: List[float] = field(default_factory=list)
    speculated: int = 0

    def record_completion(self, wall_h: float):
        self.completed_times.append(wall_h)

    def should_speculate(self, elapsed_h: float) -> bool:
        if len(self.completed_times) < self.min_samples:
            return False
        med = statistics.median(self.completed_times)
        if elapsed_h > self.spec_factor * med:
            self.speculated += 1
            return True
        return False


@dataclass
class StragglerMonitor:
    """Per-pod step-time EWMA for synchronous training.

    ``min_pods`` is the eviction floor: shrinking below it would stall
    the whole SPMD job, so :meth:`stragglers` proposes at most
    ``active - min_pods`` evictions (slowest first) and :meth:`evict`
    refuses (returns False) rather than cross the floor."""
    evict_factor: float = 1.5
    ewma_alpha: float = 0.2
    min_steps: int = 10
    min_pods: int = 1
    times: Dict[str, float] = field(default_factory=dict)   # pod -> ewma
    counts: Dict[str, int] = field(default_factory=dict)
    evicted: List[str] = field(default_factory=list)

    def record(self, pod_id: str, step_s: float):
        prev = self.times.get(pod_id)
        self.times[pod_id] = step_s if prev is None else \
            (1 - self.ewma_alpha) * prev + self.ewma_alpha * step_s
        self.counts[pod_id] = self.counts.get(pod_id, 0) + 1

    def active_pods(self) -> List[str]:
        return [p for p in self.times if p not in self.evicted]

    def fleet_median(self) -> Optional[float]:
        vals = [v for k, v in self.times.items() if k not in self.evicted]
        return statistics.median(vals) if vals else None

    def stragglers(self) -> List[str]:
        med = self.fleet_median()
        if med is None:
            return []
        out = []
        for pod, t in self.times.items():
            if pod in self.evicted or self.counts.get(pod, 0) < self.min_steps:
                continue
            if t > self.evict_factor * med:
                out.append(pod)
        # never propose shrinking below the floor: slowest first, at
        # most (active - min_pods) of them
        room = max(0, len(self.active_pods()) - self.min_pods)
        out.sort(key=lambda p: self.times[p], reverse=True)
        return out[:room]

    def evict(self, pod_id: str) -> bool:
        """Evict ``pod_id`` unless already evicted, unknown, or the
        active fleet is at the ``min_pods`` floor; True if evicted."""
        if pod_id in self.evicted or pod_id not in self.times:
            return False
        if len(self.active_pods()) <= self.min_pods:
            return False
        self.evicted.append(pod_id)
        return True
