"""Trace analytics: streaming collection and campaign diffing.

The typed :class:`~repro.core.events.CampaignTrace` (byte-identical
across the solo object, solo array and batched engines) is the repo's
operational record of a campaign — but until this module it could only
be held whole in memory and compared by eyeball.  Two new surfaces fix
that:

**Streaming collection** (``api.run(..., collect="stream", sink=...)``)
feeds canonicalized events through a bounded-window
:class:`StreamingRecorder` into a :class:`TraceSink` as the campaign
runs, so a 100k-instance multi-week trace never exists as one Python
list.  The stream is *byte-identical* to the in-memory
``events.build_trace`` path: every engine records all of a tick's
events before any later tick's (each carries the tick's ``now``), so
the recorder sees a non-decreasing time stream and can close one
tick-window at a time; sorting each window by the canonical
``(t, kind rank, entity id)`` key with a stable sort and concatenating
windows reproduces ``build_trace``'s single stable global sort exactly
(equal-keyed events always share a window).  Any out-of-order record is
an engine bug and raises rather than silently reordering.

**Trace diffing** (:func:`diff_traces`, ``python -m repro.campaigns
diff a.jsonl b.jsonl.gz``) aligns two traces' entity timelines —
instances, pilots, jobs — and reports the first divergence point in
the canonical stream, per-kind added/removed/changed counts, and
deltas of the trace-derived digests (jobs, accel-hours from integrated
instance lifetimes — the goodput axis — and the metered egress GB/$,
the data-plane cost axis; per-GPU-hour billing is priced outside the
trace).  ``diff_traces(t, t)`` is empty; the CLI exits 1 on any
divergence, which makes committed traces a CI equivalence gate.
"""
from __future__ import annotations

import gzip
import os
import shutil
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.events import (CampaignTrace, TraceRecorder, _KIND_RANK,
                               _timeline_trace_event, dump_line,
                               event_to_dict, trace_header)

DIFF_SCHEMA_VERSION = 1


# -- sinks -----------------------------------------------------------------

class TraceSink:
    """Receives one campaign's canonical event stream.

    ``emit(ev)`` is called once per trace event, in exactly the order
    ``CampaignTrace.events`` would hold; ``close(header)`` is called
    once at end-of-campaign with the JSONL meta header dict (it carries
    the final event count, which is only known then)."""

    def emit(self, ev):
        raise NotImplementedError

    def close(self, header: dict):
        """Finalize the sink; default is a no-op."""


class CallbackSink(TraceSink):
    """Adapter: every event to ``fn(event)``; optional ``on_close``
    receives the meta header dict."""

    def __init__(self, fn: Callable, on_close: Optional[Callable] = None):
        self.fn = fn
        self.on_close = on_close
        self.events_seen = 0

    def emit(self, ev):
        self.events_seen += 1
        self.fn(ev)

    def close(self, header: dict):
        if self.on_close is not None:
            self.on_close(header)


class JsonlStreamSink(TraceSink):
    """Streams canonical JSONL trace bytes to ``path`` (a ``.gz``
    suffix gzips transparently, ``mtime=0`` for byte-reproducible
    archives — the same convention as ``campaigns trace --out``).

    The JSONL header line carries the total event count, which is only
    known at end-of-campaign, so event lines are spooled to
    ``path + ".spool"`` during the run and the final file is assembled
    at ``close()`` (header + streamed spool copy).  Memory stays
    O(one tick window) regardless of campaign size; the finished bytes
    are identical to ``CampaignTrace.to_jsonl()`` by construction —
    both go through ``events.dump_line`` / ``events.trace_header``."""

    def __init__(self, path: str):
        self.path = str(path)
        self._spool_path = self.path + ".spool"
        self._spool = None
        self.events_written = 0
        self.closed = False

    def emit(self, ev):
        if self.closed:
            raise ValueError(f"sink {self.path!r} is already closed")
        if self._spool is None:
            self._spool = open(self._spool_path, "w", newline="\n")
        self._spool.write(dump_line(event_to_dict(ev)) + "\n")
        self.events_written += 1

    def close(self, header: dict):
        if self.closed:
            raise ValueError(f"sink {self.path!r} is already closed")
        if self._spool is not None:
            self._spool.close()
            self._spool = None
        if self.path.endswith(".gz"):
            out = gzip.GzipFile(self.path, "wb", mtime=0)
        else:
            out = open(self.path, "wb")
        try:
            out.write((dump_line(header) + "\n").encode("utf-8"))
            if os.path.exists(self._spool_path):
                with open(self._spool_path, "rb") as spool:
                    shutil.copyfileobj(spool, out, 1 << 20)
        finally:
            out.close()
        if os.path.exists(self._spool_path):
            os.remove(self._spool_path)
        self.closed = True


# -- the streaming recorder ------------------------------------------------

class StreamingRecorder(TraceRecorder):
    """Drop-in :class:`~repro.core.events.TraceRecorder` that forwards
    canonicalized events to a :class:`TraceSink` one tick-window at a
    time instead of accumulating them.

    Correctness rests on the engines' recording discipline (pinned by
    the differential stream tests): every event is recorded with the
    tick's ``now``, and ticks advance monotonically, so the recorder
    sees a non-decreasing ``t`` stream.  Each window holds one ``t``'s
    events; closing a window stable-sorts it by the canonical
    ``(t, kind rank, entity id)`` key and emits — the concatenation of
    sorted windows equals ``build_trace``'s global stable sort because
    equal-keyed events always land in the same window.  A record with
    ``t`` earlier than the open window is an engine bug and raises.

    Timeline provenance arrives through :meth:`timeline_fired` (engines
    mirror every ``events_fired`` append there); the arrival sequence
    number is the rank-0 tie-break key, matching ``build_trace``'s
    ``enumerate(events_fired)`` order."""

    __slots__ = ("sink", "_window", "_window_t", "_seq", "count",
                 "finished")

    def __init__(self, sink: TraceSink):
        super().__init__()
        self.sink = sink
        self._window: List[tuple] = []
        self._window_t: Optional[float] = None
        self._seq = 0                   # timeline provenance tie-break
        self.count = 0                  # events emitted so far
        self.finished = False

    def _push(self, item: tuple):
        t = item[0]
        if self.finished:
            raise ValueError("StreamingRecorder already finished")
        if self._window_t is None:
            self._window_t = t
        elif t != self._window_t:
            if t < self._window_t:
                raise ValueError(
                    f"out-of-order trace event at t={t} after window "
                    f"t={self._window_t}: engines must record each "
                    f"event with its tick's now")
            self._flush_window()
            self._window_t = t
        self._window.append(item)

    def timeline_fired(self, rec: Mapping):
        ev = _timeline_trace_event(rec)
        self._push((ev.t, _KIND_RANK[ev.kind], self._seq, ev))
        self._seq += 1

    def _flush_window(self):
        w = self._window
        w.sort(key=lambda it: it[:3])
        emit = self.sink.emit
        for it in w:
            emit(it[3])
        self.count += len(w)
        self._window = []

    def finish(self, name: str, seed: int, duration_h: float,
               dt_h: float) -> int:
        """Flush the open window and close the sink with the meta
        header; returns the total event count."""
        if self.finished:
            raise ValueError("StreamingRecorder already finished")
        self._flush_window()
        self.finished = True
        self.sink.close(trace_header(name, seed, duration_h, dt_h,
                                     self.count))
        return self.count


# -- file loading ----------------------------------------------------------

def load_trace(path: str) -> CampaignTrace:
    """Read a serialized trace from ``path`` (``.gz`` transparently)."""
    if str(path).endswith(".gz"):
        with gzip.open(path, "rt", encoding="utf-8") as f:
            text = f.read()
    else:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    return CampaignTrace.from_jsonl(text)


# -- trace digests ---------------------------------------------------------

@dataclass(frozen=True)
class TraceDigest:
    """Campaign totals derivable from the trace alone.  ``accel_hours``
    integrates instance lifetimes (launch -> stop/preempt, still-up
    instances billed to ``duration_h``) — the trace-side goodput axis;
    ``egress_usd`` is the only dollar figure a trace carries (per-
    GPU-hour billing rates are priced outside the event stream)."""
    events: int
    launches: int
    preemptions: int
    nat_drops: int
    jobs_finished: int
    accel_hours: float
    egress_gb: float
    egress_usd: float
    cache_hit_fraction: float

    def to_dict(self) -> dict:
        return {"events": self.events, "launches": self.launches,
                "preemptions": self.preemptions,
                "nat_drops": self.nat_drops,
                "jobs_finished": self.jobs_finished,
                "accel_hours": self.accel_hours,
                "egress_gb": self.egress_gb,
                "egress_usd": self.egress_usd,
                "cache_hit_fraction": self.cache_hit_fraction}


def trace_digest(trace: CampaignTrace) -> TraceDigest:
    """Compute the :class:`TraceDigest` of one trace."""
    launches = preempts = drops = jobs = hits = misses = 0
    egress_gb = egress_usd = 0.0
    start: Dict[int, float] = {}
    lifetime = 0.0
    for ev in trace.events:
        k = ev.kind
        if k == "launch":
            launches += 1
            start[ev.instance] = ev.t
        elif k in ("stop", "preempt"):
            if k == "preempt":
                preempts += 1
            t0 = start.pop(ev.instance, None)
            if t0 is not None:
                lifetime += ev.t - t0
        elif k == "nat_drop":
            drops += 1
        elif k == "job_done":
            jobs += 1
        elif k == "stagein":
            if ev.cache_hit:
                hits += 1
            else:
                misses += 1
        elif k == "egress":
            egress_gb += ev.gb
            egress_usd += ev.usd
    # instances still up at end-of-campaign billed to the horizon
    for t0 in start.values():
        lifetime += trace.duration_h - t0
    return TraceDigest(
        events=len(trace.events), launches=launches,
        preemptions=preempts, nat_drops=drops, jobs_finished=jobs,
        accel_hours=round(lifetime, 3), egress_gb=round(egress_gb, 3),
        egress_usd=round(egress_usd, 3),
        cache_hit_fraction=round(hits / (hits + misses), 4)
        if hits + misses else 0.0)


# -- the diff engine -------------------------------------------------------

#: kind -> (entity domain, id attribute); price/timeline have no entity
#: identity and align by their provenance sequence position instead
_ENTITY_ATTR = {"launch": ("instances", "instance"),
                "stop": ("instances", "instance"),
                "preempt": ("instances", "instance"),
                "pilot": ("pilots", "pilot"),
                "nat_drop": ("pilots", "pilot"),
                "stagein": ("pilots", "pilot"),
                "stagein_done": ("pilots", "pilot"),
                "job_done": ("jobs", "job"),
                "egress": ("egress", "provider"),
                "price": (None, None), "timeline": (None, None)}

_HEADER_FIELDS = ("name", "seed", "duration_h", "dt_h")


@dataclass(frozen=True)
class Divergence:
    """First canonical-stream position where the traces disagree.
    ``a`` / ``b`` are the differing events as dicts (None where one
    stream has already ended); ``t`` is the earlier of the two sides'
    timestamps — the first simulated moment the campaigns differ."""
    index: int
    t: float
    a: Optional[dict]
    b: Optional[dict]

    def to_dict(self) -> dict:
        return {"index": self.index, "t": self.t, "a": self.a,
                "b": self.b}


def _group_by(events, attr: Optional[str]) -> Dict:
    g: Dict = {}
    for ev in events:
        g.setdefault(getattr(ev, attr) if attr else 0, []).append(ev)
    return g


def _aligned_event_counts(ga: Dict, gb: Dict) -> Tuple[int, int, int]:
    """Per-entity positional alignment: (added, removed, changed) event
    counts.  A retimed/retargeted event on a shared entity counts as
    changed; surplus events count as added (b-only) / removed (a-only)."""
    added = removed = changed = 0
    for k in sorted(set(ga) | set(gb), key=repr):
        ea, eb = ga.get(k, ()), gb.get(k, ())
        n = min(len(ea), len(eb))
        changed += sum(1 for i in range(n) if ea[i] != eb[i])
        removed += len(ea) - n
        added += len(eb) - n
    return added, removed, changed


def _entity_counts(ga: Dict, gb: Dict) -> Tuple[int, int, int]:
    """(added, removed, changed) at entity granularity: ids only in b,
    only in a, and shared ids whose timelines differ."""
    sa, sb = set(ga), set(gb)
    changed = sum(1 for k in sa & sb if ga[k] != gb[k])
    return len(sb - sa), len(sa - sb), changed


@dataclass(frozen=True)
class TraceDiff:
    """Structured comparison of two campaign traces (see
    :func:`diff_traces`)."""
    a_meta: dict
    b_meta: dict
    header_changes: Dict[str, Tuple]
    divergence: Optional[Divergence]
    by_kind: Dict[str, Dict[str, int]]
    entities: Dict[str, Dict[str, int]]
    digest_a: TraceDigest = field(repr=False, default=None)
    digest_b: TraceDigest = field(repr=False, default=None)

    @property
    def identical(self) -> bool:
        return self.divergence is None and not self.header_changes

    def deltas(self) -> Dict[str, float]:
        """b - a per numeric digest field (jobs, accel-hours, egress)."""
        da, db = self.digest_a.to_dict(), self.digest_b.to_dict()
        return {k: round(db[k] - da[k], 6) for k in da}

    def to_dict(self) -> dict:
        """Stable machine-readable form (the ``campaigns diff --json``
        payload and the committed golden-diff schema)."""
        return {"schema_version": DIFF_SCHEMA_VERSION,
                "kind": "trace_diff",
                "identical": self.identical,
                "a": dict(self.a_meta), "b": dict(self.b_meta),
                "header_changes": {k: list(v) for k, v in
                                   sorted(self.header_changes.items())},
                "divergence": None if self.divergence is None
                else self.divergence.to_dict(),
                "by_kind": {k: dict(v) for k, v in
                            sorted(self.by_kind.items())},
                "entities": {k: dict(v) for k, v in
                             sorted(self.entities.items())},
                "digest_a": self.digest_a.to_dict(),
                "digest_b": self.digest_b.to_dict(),
                "deltas": self.deltas()}

    def summary(self) -> str:
        """Human-readable report (the ``campaigns diff`` stdout)."""
        am, bm = self.a_meta, self.b_meta
        lines = [f"trace a: {am['name']!r} seed={am['seed']} "
                 f"({am['events']} events)",
                 f"trace b: {bm['name']!r} seed={bm['seed']} "
                 f"({bm['events']} events)"]
        if self.identical:
            lines.append("traces are identical")
            return "\n".join(lines)
        for k, (va, vb) in sorted(self.header_changes.items()):
            lines.append(f"header {k}: {va!r} -> {vb!r}")
        if self.divergence is not None:
            d = self.divergence
            lines.append(f"first divergence at t={d.t:g}h "
                         f"(event #{d.index}):")
            lines.append(f"  a: {d.a}")
            lines.append(f"  b: {d.b}")
        if self.by_kind:
            lines.append("events by kind (+added / -removed / ~changed):")
            for k, c in sorted(self.by_kind.items()):
                lines.append(f"  {k:12s} +{c['added']} -{c['removed']} "
                             f"~{c['changed']}")
        if self.entities:
            lines.append("entities (+added / -removed / ~changed):")
            for k, c in sorted(self.entities.items()):
                lines.append(f"  {k:12s} +{c['added']} -{c['removed']} "
                             f"~{c['changed']}")
        lines.append("digest deltas (b - a): " + ", ".join(
            f"{k}={v:+g}" for k, v in self.deltas().items() if v))
        return "\n".join(lines)


def diff_traces(a: CampaignTrace, b: CampaignTrace) -> TraceDiff:
    """Compare two campaign traces.

    Reports (1) the first divergence point in the canonical event
    stream, (2) per-kind added/removed/changed event counts under
    per-entity positional alignment (instances by instance id, pilots
    by pilot id, jobs by job id, egress by provider; price/timeline by
    provenance order), (3) entity-level added/removed/changed counts
    per domain, and (4) deltas of the trace-derived digests.
    ``diff_traces(t, t)`` returns an empty (``identical``) diff."""
    header_changes = {f: (getattr(a, f), getattr(b, f))
                      for f in _HEADER_FIELDS
                      if getattr(a, f) != getattr(b, f)}

    divergence = None
    n = min(len(a.events), len(b.events))
    for i in range(n):
        if a.events[i] != b.events[i]:
            ea, eb = a.events[i], b.events[i]
            divergence = Divergence(i, min(ea.t, eb.t),
                                    event_to_dict(ea), event_to_dict(eb))
            break
    if divergence is None and len(a.events) != len(b.events):
        if len(a.events) > n:
            ev, d_a, d_b = a.events[n], event_to_dict(a.events[n]), None
        else:
            ev, d_a, d_b = b.events[n], None, event_to_dict(b.events[n])
        divergence = Divergence(n, ev.t, d_a, d_b)

    # partition once per trace, then align per kind
    part_a: Dict[str, List] = {}
    part_b: Dict[str, List] = {}
    for ev in a.events:
        part_a.setdefault(ev.kind, []).append(ev)
    for ev in b.events:
        part_b.setdefault(ev.kind, []).append(ev)

    by_kind: Dict[str, Dict[str, int]] = {}
    domain_a: Dict[str, Dict] = {}
    domain_b: Dict[str, Dict] = {}
    for kind in sorted(set(part_a) | set(part_b)):
        domain, attr = _ENTITY_ATTR[kind]
        ga = _group_by(part_a.get(kind, ()), attr)
        gb = _group_by(part_b.get(kind, ()), attr)
        added, removed, changed = _aligned_event_counts(ga, gb)
        if added or removed or changed:
            by_kind[kind] = {"added": added, "removed": removed,
                             "changed": changed}
        if domain in ("instances", "pilots", "jobs"):
            for gid, evs in ga.items():
                domain_a.setdefault(domain, {}).setdefault(
                    gid, []).extend(evs)
            for gid, evs in gb.items():
                domain_b.setdefault(domain, {}).setdefault(
                    gid, []).extend(evs)

    entities: Dict[str, Dict[str, int]] = {}
    for domain in sorted(set(domain_a) | set(domain_b)):
        # merged-domain per-entity timelines in canonical trace order
        ga = {k: sorted(v, key=lambda e: (e.t, _KIND_RANK[e.kind]))
              for k, v in domain_a.get(domain, {}).items()}
        gb = {k: sorted(v, key=lambda e: (e.t, _KIND_RANK[e.kind]))
              for k, v in domain_b.get(domain, {}).items()}
        added, removed, changed = _entity_counts(ga, gb)
        if added or removed or changed:
            entities[domain] = {"added": added, "removed": removed,
                                "changed": changed}

    meta = {tr: {"name": t.name, "seed": t.seed,
                 "duration_h": t.duration_h, "dt_h": t.dt_h,
                 "events": len(t.events)}
            for tr, t in (("a", a), ("b", b))}
    return TraceDiff(a_meta=meta["a"], b_meta=meta["b"],
                     header_changes=header_changes,
                     divergence=divergence, by_kind=by_kind,
                     entities=entities, digest_a=trace_digest(a),
                     digest_b=trace_digest(b))
