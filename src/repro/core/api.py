"""The campaign front door: ``run(spec_or_specs, seeds=..., engine=...)``.

One entry point executes any declarative :class:`~repro.core.spec.
CampaignSpec` — solo or sweep — and returns typed results:

  * one spec, one seed  -> a solo simulation, returned as a
    :class:`~repro.core.spec.CampaignResult`,
  * one spec x many seeds, or many specs -> a (seed x spec) sweep on the
    batched lock-step engine, returned as a
    :class:`~repro.core.sweep.SweepResult`.

``engine`` selects the execution path:

  * ``"auto"`` (default): solo array engine for a single (spec, seed),
    the batched sweep engine otherwise,
  * ``"array"`` / ``"object"``: force solo engines (sweeps loop them
    sequentially — the reference semantics),
  * ``"batched"``: force the lock-step sweep engine,
  * ``"sequential"``: alias for a sequential solo-array loop,
  * ``"jax"``: the jit-compiled sweep engine (core/sweep_jax.py) —
    statistically equivalent, not bit-identical (see below).

Every batched lane is bit-reproducible against its solo run at the same
(spec, seed) — pinned by tests/test_sweep.py and tests/test_spec.py.
``engine="jax"`` sits in a separate **statistical-equivalence tier**:
it replaces per-instance PCG64 draws with per-group threefry Poisson
totals, so results match the bit-identical engines in distribution
(mean/p5/p95 bands on cost, GPU-days and jobs — pinned by
tests/test_sweep_jax.py via
``engine_equivalence.assert_statistically_equivalent``), never
byte-for-byte.  The allowed-engine sets below (:data:`SOLO_ENGINES`,
:data:`SWEEP_ENGINES`, :data:`ENGINES`) are the single source of truth
for ``run``/``sweep`` validation and the ``campaigns`` CLI choices.
The deprecated ``Scenario`` shim is accepted anywhere a spec is.
"""
from __future__ import annotations

import numbers
from typing import Iterable, List, Sequence, Tuple, Union

import numpy as np

from repro.core.spec import (CampaignResult, CampaignSpec, check_collect,
                             paper_spec, run_solo)
from repro.core.sweep import SweepResult, run_batched_detailed

__all__ = ["run", "sweep", "paper_spec", "CampaignResult", "SweepResult",
           "SOLO_ENGINES", "SWEEP_ENGINES", "ENGINES", "TRACE_ENGINES"]

#: the allowed-engine sets — the one place the names live.  ``run``,
#: ``sweep`` and the ``campaigns`` CLI ``--engine`` choices all read
#: these; adding an engine here is the whole registration step.
SOLO_ENGINES = frozenset({"array", "object"})
SWEEP_ENGINES = SOLO_ENGINES | {"batched", "sequential", "jax"}
ENGINES = SWEEP_ENGINES | {"auto"}

#: engines with a per-instance trace surface (``collect="trace"``):
#: every bit-identical engine; the statistical jax tier is excluded
TRACE_ENGINES = frozenset(SWEEP_ENGINES - {"jax"})

_SOLO_ENGINES = SOLO_ENGINES          # backwards-compat alias


def _no_trace_error() -> ValueError:
    """The one error both ``run`` and ``sweep`` raise for
    ``engine="jax", collect="trace"`` — it names the engines that DO
    have a trace surface so the fix is in the message."""
    return ValueError(
        'engine="jax" is statistical — it has no per-instance event '
        'stream to trace; use collect="summary", or pick a '
        "trace-capable engine: " + ", ".join(sorted(TRACE_ENGINES)))


def _check_engine(engine: str, allowed: frozenset, what: str) -> str:
    """The shared engine validation (both ``run`` layers used to raise
    their own, differently-worded errors)."""
    if engine not in allowed:
        raise ValueError(
            f"unknown {what} engine {engine!r}; choose one of "
            f"{', '.join(sorted(allowed))}")
    return engine


def _as_seed(s) -> int:
    """Seeds are exact campaign identities: a float like 3.7 used to
    truncate to 3 via ``int()`` and silently run a different campaign,
    and ``True`` (an ``Integral`` subclass; ``np.bool_`` registers with
    neither ABC) would silently run seed 1 — all are rejected outright."""
    if isinstance(s, (bool, np.bool_)):
        raise TypeError(
            f"seeds must be integers, got {s!r} (bool); a bool seed "
            f"would silently run seed {int(s)} — pass an int")
    if isinstance(s, numbers.Real) and not isinstance(s, numbers.Integral):
        raise TypeError(
            f"seeds must be integers, got {s!r} ({type(s).__name__}); "
            "float seeds would be silently truncated — pass an int")
    return int(s)


def sweep(specs: Sequence[CampaignSpec], seeds: Sequence[int],
          engine: str = "batched", collect: str = "summary") -> SweepResult:
    """Run every (spec x seed) lane and always return a SweepResult
    (``run()`` delegates here for multi-lane inputs).  ``engine``:
    "batched" (lock-step array program), "jax" (compiled scan —
    statistical tier, no trace surface) or "sequential" / "array" /
    "object" (solo reference loop).  ``collect="trace"`` additionally
    records one typed ``CampaignTrace`` per lane (``SweepResult.traces``
    / ``trace_for``)."""
    check_collect(collect)
    if collect == "stream":
        raise ValueError(
            'collect="stream" feeds ONE campaign through one sink — '
            'sweeps record per-lane traces with collect="trace" '
            "(SweepResult.traces) instead")
    _check_engine(engine, SWEEP_ENGINES, "sweep")
    specs = list(specs)
    if not specs:
        raise ValueError("sweep() needs at least one spec")
    seeds = [_as_seed(seed) for seed in seeds]
    if not seeds:
        raise ValueError("sweep() needs at least one seed")
    lanes = [(spec.to_spec(), seed) for spec in specs for seed in seeds]
    if engine == "batched":
        detailed = run_batched_detailed(lanes, collect=collect)
    elif engine == "jax":
        if collect == "trace":
            raise _no_trace_error()
        from repro.core.sweep_jax import run_jax_detailed
        detailed = run_jax_detailed(lanes)
    else:
        eng = engine if engine in SOLO_ENGINES else None
        detailed = []
        for spec, seed in lanes:
            res, ctl = run_solo(spec, seed, engine=eng, collect=collect)
            detailed.append((res.to_dict(), list(ctl.events_fired),
                             res.trace))
    rows = [{"scenario": spec.name, "seed": seed, **res,
             "events_fired": events}
            for (spec, seed), (res, events, _tr) in zip(lanes, detailed)]
    traces = [tr for _res, _ev, tr in detailed] \
        if collect == "trace" else None
    return SweepResult(rows, traces=traces)


def _coerce_specs(spec_or_specs) -> Tuple[List[CampaignSpec], bool]:
    if hasattr(spec_or_specs, "to_spec"):
        return [spec_or_specs.to_spec()], True
    specs = [s.to_spec() for s in spec_or_specs]
    if not specs:
        raise ValueError("run() needs at least one spec")
    return specs, False


def _coerce_seeds(seeds) -> Tuple[List[int], bool]:
    if isinstance(seeds, str):
        # a string is iterable per-character: "2021" would silently
        # become the 4-seed sweep [2, 0, 2, 1] — treat it as one seed
        return [int(seeds)], True
    if not isinstance(seeds, Iterable):
        return [_as_seed(seeds)], True
    seeds = [_as_seed(s) for s in seeds]
    if not seeds:
        raise ValueError("run() needs at least one seed")
    return seeds, False


def run(spec_or_specs: Union[CampaignSpec, Sequence[CampaignSpec]],
        seeds: Union[int, Sequence[int]] = 2021,
        engine: str = "auto",
        collect: str = "summary",
        sink=None) -> Union[CampaignResult, SweepResult]:
    """Execute campaign spec(s); see module docstring for dispatch.

    ``collect`` selects the results surface: ``"summary"`` (default —
    end-of-run totals only, the historical behavior), ``"trace"``,
    which additionally records the typed event stream (every launch /
    stop / preemption / pilot / NAT drop / job completion / timeline
    firing) as a :class:`~repro.core.events.CampaignTrace` on
    ``CampaignResult.trace`` (solo) or ``SweepResult.traces`` (sweeps),
    or ``"stream"``, which feeds that same canonical event stream
    through ``sink`` (a :class:`~repro.core.traceops.TraceSink` — JSONL
    /gzip file or callback) in bounded tick-windows so the full event
    list never exists in memory; the streamed bytes are identical to
    ``collect="trace"`` serialization.  ``"stream"`` is one campaign
    into one sink: solo-shaped input only.  Collection is RNG-free:
    summary numbers are identical either way, and all trace-capable
    engines emit byte-identical serialized traces."""
    check_collect(collect)
    specs, single_spec = _coerce_specs(spec_or_specs)
    seed_list, single_seed = _coerce_seeds(seeds)
    solo = single_spec and len(specs) == 1 and len(seed_list) == 1
    _check_engine(engine, ENGINES, "run")
    if collect == "stream":
        if not solo:
            raise ValueError(
                'collect="stream" feeds ONE campaign through one sink; '
                "pass one spec and one seed (for sweeps, use "
                'collect="trace" and SweepResult.traces)')
        if sink is None:
            raise ValueError(
                'collect="stream" needs a sink= (e.g. '
                "repro.core.traceops.JsonlStreamSink)")
    elif sink is not None:
        raise ValueError('sink= is only meaningful with collect="stream"')

    if solo and engine == "batched":     # forced single-lane batched run
        (res, events, trace), = run_batched_detailed(
            [(specs[0], seed_list[0])], collect=collect,
            sinks=None if sink is None else [sink])
        return CampaignResult.from_results(
            res, spec=specs[0], seed=seed_list[0], engine="batched",
            events_fired=tuple(events), trace=trace)
    if solo and engine == "jax":         # forced single-lane compiled run
        if collect in ("trace", "stream"):
            raise _no_trace_error()
        from repro.core.sweep_jax import run_jax_detailed
        (res, events, trace), = run_jax_detailed(
            [(specs[0], seed_list[0])])
        return CampaignResult.from_results(
            res, spec=specs[0], seed=seed_list[0], engine="jax",
            events_fired=tuple(events), trace=trace)
    if solo:
        eng = None if engine in ("auto", "sequential") else engine
        result, _ctl = run_solo(specs[0], seed_list[0], engine=eng,
                                collect=collect, sink=sink)
        return result

    return sweep(specs, seed_list,
                 engine="batched" if engine == "auto" else engine,
                 collect=collect)
