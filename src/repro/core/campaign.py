"""The paper's two-week campaign as a reusable controller (§IV/§V):

  * initial small-scale validation in every region,
  * staged ramp 400 -> 900 -> 1.2k -> 1.6k -> 2k GPUs, sustaining each step
    "for extended periods of time to validate the stability of the system",
  * the CE-outage incident at 2k GPUs: total backend collapse -> instant
    fleet-wide deprovision ("minimal financial loss") -> ~2 h outage ->
    resume at 1k GPUs,
  * budget-driven downscale: resume at only 1k because "at that point in
    time we had only about 20% of the budget left" — wired to the
    CloudBank 20 %-remaining threshold alert.

``replay_paper_campaign()`` reproduces the exercise end-to-end and returns
simulated totals for the benchmark to compare with the published ones
(~$58k, ~16k GPU-days, ~3.1 fp32 EFLOP-hours, a >=2x boost of IceCube's
GPU wall-hours).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.provider import ProviderSpec, t4_catalog
from repro.core.simulator import CloudSimulator, SimConfig


@dataclass
class RampStage:
    start_h: float
    target: int


PAPER_RAMP: Tuple[RampStage, ...] = (
    RampStage(0.0, 40),        # small-scale validation in each region
    RampStage(12.0, 400),
    RampStage(48.0, 900),
    RampStage(96.0, 1200),
    RampStage(144.0, 1600),
    RampStage(192.0, 2000),    # sustained at 2k ...
)
OUTAGE_AT_H = 252.0            # ... until the CE host's network outage (d10.5)
OUTAGE_DURATION_H = 2.0
POST_OUTAGE_TARGET = 1000      # resume lower: ~20% budget left


@dataclass
class CampaignController:
    """Budget-aware staged-ramp controller driving a CloudSimulator."""
    sim: CloudSimulator
    ramp: Tuple[RampStage, ...] = PAPER_RAMP
    budget_floor_fraction: float = 0.2
    downscale_target: int = POST_OUTAGE_TARGET
    log: List[str] = field(default_factory=list)
    _budget_capped: bool = False

    def __post_init__(self):
        self.sim.ledger.on_threshold(self._on_budget_alert)
        for stage in self.ramp:
            self.sim.at(stage.start_h, self._make_setter(stage.target))

    def _make_setter(self, target):
        def set_target(sim):
            t = min(target, self.downscale_target) if self._budget_capped \
                else target
            sim.prov.scale_to(t, sim.now)
            self.log.append(f"t={sim.now:6.1f}h scale_to({t})")
        return set_target

    def _on_budget_alert(self, frac, remaining, rate_per_day):
        self.log.append(
            f"BUDGET ALERT: {frac:.0%} remaining (${remaining:,.0f}), "
            f"rate ${rate_per_day:,.0f}/day")
        if frac <= self.budget_floor_fraction and not self._budget_capped:
            self._budget_capped = True
            self.sim.at(self.sim.now, lambda sim: sim.prov.scale_to(
                self.downscale_target, sim.now))
            self.log.append(
                f"t={self.sim.now:6.1f}h budget floor hit -> "
                f"cap fleet at {self.downscale_target}")

    def inject_ce_outage(self, at_h: float = OUTAGE_AT_H,
                         duration_h: float = OUTAGE_DURATION_H,
                         resume_target: int = POST_OUTAGE_TARGET):
        def outage(sim):
            sim.ce.outage = True
            sim.prov.deprovision_all(sim.now)
            self.log.append(f"t={sim.now:6.1f}h CE OUTAGE -> deprovision all")

        def recover(sim):
            sim.ce.outage = False
            sim.prov.scale_to(resume_target, sim.now)
            self.log.append(
                f"t={sim.now:6.1f}h CE recovered -> resume at "
                f"{resume_target}")
        self.sim.at(at_h, outage)
        self.sim.at(at_h + duration_h, recover)


def replay_paper_campaign(budget: float = 58000.0, seed: int = 2021,
                          sim_cfg: Optional[SimConfig] = None,
                          engine: Optional[str] = None):
    """Run the full two-week exercise; returns (results, controller).

    ``engine`` selects the simulation engine ("array" | "object"); both
    produce matching totals (tests/test_fleet_engine.py)."""
    cfg = sim_cfg or SimConfig(seed=seed)
    sim = CloudSimulator(t4_catalog(), budget, cfg, engine=engine)
    ctl = CampaignController(sim)
    ctl.inject_ce_outage()
    sim.run_until(cfg.duration_h)
    return sim.results(), ctl


def run_campaign(catalog: Dict[str, ProviderSpec], budget: float,
                 ramp: Tuple[RampStage, ...] = PAPER_RAMP,
                 sim_cfg: Optional[SimConfig] = None,
                 engine: Optional[str] = None,
                 outage: bool = False, *,
                 outage_at_h: float = OUTAGE_AT_H,
                 outage_duration_h: float = OUTAGE_DURATION_H,
                 resume_target: int = POST_OUTAGE_TARGET,
                 budget_floor_fraction: float = 0.2,
                 downscale_target: int = POST_OUTAGE_TARGET):
    """Campaign runner for catalogs beyond the T4-only replay — e.g. the
    §III heterogeneous pool (``provider.heterogeneous_catalog()``) or a
    capacity-scaled one for 100k-instance studies.  The keyword-only
    knobs expose the controller's outage timing and budget tripwire for
    what-if scenarios (core/scenarios.py).  Returns
    (results, controller)."""
    cfg = sim_cfg or SimConfig()
    sim = CloudSimulator(catalog, budget, cfg, engine=engine)
    ctl = CampaignController(sim, ramp=ramp,
                             budget_floor_fraction=budget_floor_fraction,
                             downscale_target=downscale_target)
    if outage:
        ctl.inject_ce_outage(outage_at_h, outage_duration_h, resume_target)
    sim.run_until(cfg.duration_h)
    return sim.results(), ctl


def sweep_campaigns(scenarios, seeds, *, engine: str = "batched"):
    """Run every (scenario x seed) campaign and return a
    ``sweep.SweepResult`` (per-lane results rows plus mean/p5/p95 summary
    bands on the paper totals).

    ``engine="batched"`` (default) ticks all lanes in lock-step on the
    batched struct-of-arrays engine (core/sweep.py) — a 256-point sweep
    pays the per-tick dispatch overhead once, not 256 times.
    ``engine="sequential"`` loops solo ``CloudSimulator`` campaigns (the
    reference semantics; every batched lane is bit-reproducible against
    it at the same (seed, scenario))."""
    from repro.core import sweep as sweep_mod
    from repro.core.scenarios import run_scenario
    scenarios = list(scenarios)          # tolerate one-shot iterators
    seeds = [int(s) for s in seeds]
    lanes = [(sc, seed) for sc in scenarios for seed in seeds]
    if engine == "batched":
        results = sweep_mod.run_batched(lanes)
    elif engine == "sequential":
        results = [run_scenario(sc, seed)[0] for sc, seed in lanes]
    else:
        raise ValueError(f"unknown sweep engine {engine!r}")
    rows = [{"scenario": sc.name, "seed": seed, **res}
            for (sc, seed), res in zip(lanes, results)]
    return sweep_mod.SweepResult(rows)


# IceCube baseline for the "approximate doubling" claim (abstract/Fig 2):
# cloud GPU-hours ~ IceCube's contemporaneous non-cloud GPU-hours. Paper §I
# gives 8M GPU-h/yr on OSG (IceCube >80%); with dedicated non-OSG resources
# IceCube's effective baseline is ~9M GPU-h/yr -> ~350k per 2 weeks.
ICECUBE_BASELINE_GPUH_PER_2W = 9e6 * (14 / 365.0)
