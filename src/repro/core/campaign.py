"""Back-compat shims for the legacy campaign API (pre-CampaignSpec).

The paper's two-week exercise (§IV/§V) — staged ramp 400 -> 900 -> 1.2k
-> 1.6k -> 2k GPUs, the CE-outage incident at 2k, the budget-driven
2k -> 1k downscale — is now declared once as data:
``repro.core.spec.CampaignSpec`` (whose defaults ARE the paper replay)
executed through the ``repro.core.api.run`` front door.

This module keeps the historical entry points importable and
bit-identical, as deprecation-warned shims over specs:

  * ``replay_paper_campaign()``  -> ``run(paper_spec(), seeds=...)``
  * ``run_campaign(catalog, ...)`` -> an inline-``providers`` spec
  * ``sweep_campaigns(...)``       -> the sweep path of ``api.run``
  * ``CampaignController``         -> ``spec.TimelineController``

``spec.PAPER_TIMELINE`` holds the canonical ramp/outage numbers;
``RampStage``/``PAPER_RAMP`` and the ``OUTAGE_*`` constants here are
derived from it for legacy importers.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.provider import ProviderSpec, t4_catalog
from repro.core.simulator import CloudSimulator, SimConfig
from repro.core.spec import (CEOutage, CampaignSpec, PAPER_RAMP_EVENTS,
                             PAPER_TIMELINE, SetTarget,
                             ICECUBE_BASELINE_GPUH_PER_2W,  # noqa: F401
                             paper_spec, run_solo)


@dataclass
class RampStage:
    start_h: float
    target: int


# the legacy constants, derived from the single source of truth
# (spec.PAPER_TIMELINE) so the numbers can never desynchronize
PAPER_RAMP: Tuple[RampStage, ...] = tuple(
    RampStage(ev.at_h, ev.target) for ev in PAPER_RAMP_EVENTS)
_PAPER_OUTAGE: CEOutage = PAPER_TIMELINE[-1]
OUTAGE_AT_H = _PAPER_OUTAGE.at_h           # the CE host outage (d10.5)
OUTAGE_DURATION_H = _PAPER_OUTAGE.duration_h
POST_OUTAGE_TARGET = _PAPER_OUTAGE.resume_target   # ~20% budget left


def _timeline(ramp: Tuple[RampStage, ...], outage: bool, *,
              outage_at_h: float = OUTAGE_AT_H,
              outage_duration_h: float = OUTAGE_DURATION_H,
              resume_target: int = POST_OUTAGE_TARGET):
    events = tuple(SetTarget(st.start_h, st.target) for st in ramp)
    if outage:
        events += (CEOutage(outage_at_h, outage_duration_h, resume_target),)
    return events


def _deprecated(old: str, new: str):
    warnings.warn(f"{old} is deprecated; use {new} "
                  "(see repro.core.spec / repro.core.api)",
                  DeprecationWarning, stacklevel=3)


@dataclass
class CampaignController:
    """Deprecated: the staged-ramp/outage/budget-cap controller as
    Python callbacks.  Superseded by the declarative CampaignSpec
    timeline interpreted by ``spec.TimelineController`` (which every
    engine — solo object, solo array, batched sweep — understands)."""
    sim: CloudSimulator
    ramp: Tuple[RampStage, ...] = PAPER_RAMP
    budget_floor_fraction: float = 0.2
    downscale_target: int = POST_OUTAGE_TARGET
    log: List[str] = field(default_factory=list)
    _budget_capped: bool = False

    def __post_init__(self):
        _deprecated("CampaignController", "CampaignSpec timelines")
        self.sim.ledger.on_threshold(self._on_budget_alert)
        for stage in self.ramp:
            self.sim.at(stage.start_h, self._make_setter(stage.target))

    def _make_setter(self, target):
        def set_target(sim):
            t = min(target, self.downscale_target) if self._budget_capped \
                else target
            sim.prov.scale_to(t, sim.now)
            self.log.append(f"t={sim.now:6.1f}h scale_to({t})")
        return set_target

    def _on_budget_alert(self, frac, remaining, rate_per_day):
        self.log.append(
            f"BUDGET ALERT: {frac:.0%} remaining (${remaining:,.0f}), "
            f"rate ${rate_per_day:,.0f}/day")
        if frac <= self.budget_floor_fraction and not self._budget_capped:
            self._budget_capped = True
            self.sim.at(self.sim.now, lambda sim: sim.prov.scale_to(
                self.downscale_target, sim.now))
            self.log.append(
                f"t={self.sim.now:6.1f}h budget floor hit -> "
                f"cap fleet at {self.downscale_target}")

    def inject_ce_outage(self, at_h: float = OUTAGE_AT_H,
                         duration_h: float = OUTAGE_DURATION_H,
                         resume_target: int = POST_OUTAGE_TARGET):
        def outage(sim):
            sim.ce.outage = True
            sim.prov.deprovision_all(sim.now)
            self.log.append(f"t={sim.now:6.1f}h CE OUTAGE -> deprovision all")

        def recover(sim):
            sim.ce.outage = False
            sim.prov.scale_to(resume_target, sim.now)
            self.log.append(
                f"t={sim.now:6.1f}h CE recovered -> resume at "
                f"{resume_target}")
        self.sim.at(at_h, outage)
        self.sim.at(at_h + duration_h, recover)


def replay_paper_campaign(budget: float = 58000.0, seed: int = 2021,
                          sim_cfg: Optional[SimConfig] = None,
                          engine: Optional[str] = None):
    """Deprecated shim: run the full two-week exercise; returns
    (results dict, controller).  Equivalent to
    ``api.run(paper_spec(budget=...), seeds=seed)`` — which returns the
    typed ``CampaignResult`` instead."""
    _deprecated("replay_paper_campaign()", "api.run(paper_spec())")
    cfg = sim_cfg or SimConfig(seed=seed)
    spec = paper_spec(
        budget=budget, duration_h=cfg.duration_h, dt_h=cfg.dt_h,
        lease_interval_s=cfg.lease_interval_s, job_wall_h=cfg.job_wall_h,
        job_checkpoint_h=cfg.job_checkpoint_h,
        accel_tflops=cfg.accel_tflops,
        overhead_per_day=cfg.overhead_per_day, min_queue=cfg.min_queue,
        spot=cfg.spot)
    res, ctl = run_solo(spec, cfg.seed, engine=engine or cfg.engine)
    return res.to_dict(), ctl


def run_campaign(catalog: Dict[str, ProviderSpec], budget: float,
                 ramp: Tuple[RampStage, ...] = PAPER_RAMP,
                 sim_cfg: Optional[SimConfig] = None,
                 engine: Optional[str] = None,
                 outage: bool = False, *,
                 outage_at_h: float = OUTAGE_AT_H,
                 outage_duration_h: float = OUTAGE_DURATION_H,
                 resume_target: int = POST_OUTAGE_TARGET,
                 budget_floor_fraction: float = 0.2,
                 downscale_target: int = POST_OUTAGE_TARGET):
    """Deprecated shim: the ten-knob campaign runner.  The knobs are now
    CampaignSpec fields (catalog -> ``providers``, ramp/outage ->
    ``timeline`` events); returns (results dict, controller)."""
    _deprecated("run_campaign()", "api.run(CampaignSpec(...))")
    cfg = sim_cfg or SimConfig()
    spec = CampaignSpec(
        name="campaign", providers=tuple(catalog.values()),
        budget=budget, budget_floor_fraction=budget_floor_fraction,
        downscale_target=downscale_target, duration_h=cfg.duration_h,
        dt_h=cfg.dt_h, lease_interval_s=cfg.lease_interval_s,
        job_wall_h=cfg.job_wall_h, job_checkpoint_h=cfg.job_checkpoint_h,
        accel_tflops=cfg.accel_tflops,
        overhead_per_day=cfg.overhead_per_day, min_queue=cfg.min_queue,
        spot=cfg.spot,
        timeline=_timeline(ramp, outage, outage_at_h=outage_at_h,
                           outage_duration_h=outage_duration_h,
                           resume_target=resume_target))
    res, ctl = run_solo(spec, cfg.seed, engine=engine or cfg.engine)
    return res.to_dict(), ctl


def sweep_campaigns(scenarios, seeds, *, engine: str = "batched"):
    """Run every (scenario x seed) campaign and return a
    ``sweep.SweepResult`` (per-lane results rows plus mean/p5/p95 summary
    bands on the paper totals; each row carries its ``events_fired``
    provenance).  Accepts CampaignSpecs or deprecated Scenario shims.

    ``engine="batched"`` (default) ticks all lanes in lock-step on the
    batched struct-of-arrays engine (core/sweep.py) — a 256-point sweep
    pays the per-tick dispatch overhead once, not 256 times.
    ``engine="sequential"`` loops solo campaigns (the reference
    semantics; every batched lane is bit-reproducible against it at the
    same (seed, scenario))."""
    from repro.core.api import sweep as api_sweep
    if engine not in ("batched", "sequential"):
        raise ValueError(f"unknown sweep engine {engine!r}")
    # seed coercion/validation happens in api.sweep (floats rejected)
    return api_sweep([s.to_spec() for s in scenarios], list(seeds),
                     engine=engine)
