"""The paper's contribution (control plane) as composable modules.

spec         — CampaignSpec: one declarative, JSON-serializable campaign
               description (catalog, fleet/budget policy, event timeline)
               + typed CampaignResult with paper-claim helpers
api          — the front door: run(spec_or_specs, seeds, engine) ->
               CampaignResult | SweepResult (solo vs batched dispatch)
provider     — cloud catalogs: capacity, spot pricing, preemption, NAT quirks
provisioner  — VMSS/InstanceGroups/SpotFleet-style group provisioning
budget       — CloudBank analogue: ledger, spend-rate, threshold alerts
overlay      — OSG CE + glideinWMS analogue: pilots, leases, matchmaking
simulator    — discrete-event cloud simulator binding the above
events       — typed, replayable CampaignTrace event stream (emitted
               byte-identically by every engine via collect="trace")
campaign     — deprecated shims (run_campaign/replay_paper_campaign/
               CampaignController) over specs
scenarios    — what-if spec library (spot mixes, outages, budgets) +
               the deprecated Scenario shim
sweep        — batched multi-campaign engine: B campaigns, one array program
elastic      — pod-pool -> mesh manager for synchronous SPMD training (TPU)
straggler    — speculative re-execution + slow-pod eviction

The CLI lives one level up: ``python -m repro.campaigns run spec.json``.
"""
from repro.core.api import run, sweep as run_sweep  # noqa: F401
from repro.core.budget import BudgetLedger  # noqa: F401
from repro.core.campaign import (CampaignController, PAPER_RAMP,  # noqa: F401
                                 replay_paper_campaign, run_campaign,
                                 sweep_campaigns)
from repro.core.scenarios import Scenario, default_suite  # noqa: F401
from repro.core.spec import (BudgetFloor, CampaignResult,  # noqa: F401
                             CampaignSpec, CapacityShift, CEOutage,
                             PriceCurve, PriceShift, SetTarget,
                             WorkloadCurve, paper_spec)
from repro.core.sweep import SweepResult  # noqa: F401
from repro.core.events import CampaignTrace, TraceRecorder  # noqa: F401
from repro.core.elastic import (ElasticRunner, GoodputReport,  # noqa: F401
                                PodPool, SimulatedElasticRunner,
                                drive_pool)
from repro.core.overlay import ComputeElement, Job, Pilot  # noqa: F401
from repro.core.provider import t4_catalog, tpu_catalog  # noqa: F401
from repro.core.provisioner import MultiCloudProvisioner  # noqa: F401
from repro.core.simulator import CloudSimulator, SimConfig  # noqa: F401
from repro.core.straggler import SpeculativeScheduler, StragglerMonitor  # noqa: F401
