"""The paper's contribution (control plane) as composable modules.

provider     — cloud catalogs: capacity, spot pricing, preemption, NAT quirks
provisioner  — VMSS/InstanceGroups/SpotFleet-style group provisioning
budget       — CloudBank analogue: ledger, spend-rate, threshold alerts
overlay      — OSG CE + glideinWMS analogue: pilots, leases, matchmaking
simulator    — discrete-event cloud simulator binding the above
campaign     — the paper's staged-ramp / outage / budget-cap controller
scenarios    — what-if scenario library (spot mixes, outages, budgets)
sweep        — batched multi-campaign engine: B campaigns, one array program
elastic      — pod-pool -> mesh manager for synchronous SPMD training (TPU)
straggler    — speculative re-execution + slow-pod eviction
"""
from repro.core.budget import BudgetLedger  # noqa: F401
from repro.core.campaign import (CampaignController, PAPER_RAMP,  # noqa: F401
                                 replay_paper_campaign, run_campaign,
                                 sweep_campaigns)
from repro.core.scenarios import Scenario, default_suite  # noqa: F401
from repro.core.elastic import ElasticRunner, PodPool  # noqa: F401
from repro.core.overlay import ComputeElement, Job, Pilot  # noqa: F401
from repro.core.provider import t4_catalog, tpu_catalog  # noqa: F401
from repro.core.provisioner import MultiCloudProvisioner  # noqa: F401
from repro.core.simulator import CloudSimulator, SimConfig  # noqa: F401
from repro.core.straggler import SpeculativeScheduler, StragglerMonitor  # noqa: F401
