"""Discrete-event simulator for the cloud pool: preemptions, billing,
pilots, jobs — deterministic (seeded numpy), hour-granular.

Drives provisioner + overlay + budget together so campaign.py can replay
the paper's two-week exercise and the benchmarks can compare simulated
totals (GPU-days, $, EFLOP-hours, preemption counts) against the paper's
published numbers (§IV/§V).

Two interchangeable engines drive the tick:

  * ``engine="array"`` (default): the vectorized struct-of-arrays engine
    (core/fleet.py) — instances/pilots/jobs live in parallel numpy arrays
    and every phase of the tick is an array op.  This is what makes
    100k-instance campaigns tractable (benchmarks/fleet_scale.py).
  * ``engine="object"``: the seed dataclass engine (one Python object per
    instance/pilot/job).  Kept as the executable specification; the two
    engines consume the RNG identically and produce matching results
    (tests/test_fleet_engine.py).

``sim.prov`` and ``sim.ce`` expose the same API either way (the array
engine provides thin dataclass view layers), so campaign.py, the examples
and the tests are engine-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.budget import BudgetLedger
from repro.core.dataplane import DataPlane, DataPlaneRuntime
from repro.core.overlay import ComputeElement, Job
from repro.core.provider import T4_FP32_TFLOPS, ProviderSpec
from repro.core.provisioner import MultiCloudProvisioner


@dataclass
class SimConfig:
    duration_h: float = 14 * 24.0
    dt_h: float = 0.25                  # 15-minute ticks
    seed: int = 2021
    lease_interval_s: float = 120.0     # < Azure NAT 240 s (post-fix default)
    job_wall_h: float = 4.0             # typical IceCube GPU task length
    job_checkpoint_h: float = 1.0
    accel_tflops: float = T4_FP32_TFLOPS
    overhead_per_day: float = 390.0     # CE VM, storage, egress ("all
    #                                     included" in the paper's $58k)
    min_queue: int = 4000               # CE queue top-up level per tick
    engine: str = "array"               # "array" (vectorized) | "object"
    spot: bool = True                   # spot (default) vs on-demand pricing
    job_input_gb: float = 0.0           # staged in before a job starts ...
    dataplane: Optional[DataPlane] = None  # ... against these origins

    @classmethod
    def from_spec(cls, spec, seed: int,
                  engine: Optional[str] = None) -> "SimConfig":
        """Engine knobs of a ``repro.core.spec.CampaignSpec`` (duck-typed
        so the deprecated Scenario shim also works).  ``seed`` must be an
        integer: a float like 3.7 would previously truncate to 3 via
        ``int()`` and silently run a different campaign, and a bool
        (``True`` is an ``int`` subclass) would silently run seed 1."""
        if isinstance(seed, bool) or not isinstance(
                seed, (int, np.integer)):
            raise TypeError(
                f"seed must be an integer, got {seed!r} "
                f"({type(seed).__name__}); float/bool seeds would be "
                "silently coerced to a different campaign")
        return cls(duration_h=spec.duration_h, dt_h=spec.dt_h,
                   seed=seed, lease_interval_s=spec.lease_interval_s,
                   job_wall_h=spec.job_wall_h,
                   job_checkpoint_h=spec.job_checkpoint_h,
                   accel_tflops=spec.accel_tflops,
                   overhead_per_day=spec.overhead_per_day,
                   min_queue=spec.min_queue, spot=spec.spot,
                   job_input_gb=getattr(spec, "job_input_gb", 0.0),
                   dataplane=getattr(spec, "dataplane", None),
                   engine=engine or cls.engine)


@dataclass
class TickStats:
    t_h: float
    running: int
    busy: int
    queued: int
    spent: float
    preemptions: int


class CloudSimulator:
    def __init__(self, catalog: Dict[str, ProviderSpec], budget: float,
                 cfg: SimConfig = SimConfig(),
                 engine: Optional[str] = None, recorder=None):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.ledger = BudgetLedger(budget)
        self.engine_kind = engine or cfg.engine
        # recorder: optional events.TraceRecorder collecting the typed
        # instance/pilot/job event stream (spec.run_solo(collect="trace"))
        self.recorder = recorder
        # always constructed (empty plane when the spec has none) so the
        # OriginOutage/OriginDegrade/CacheFlush timeline ops land
        # identically — as no-ops — on dataplane-less campaigns too
        self.dataplane = DataPlaneRuntime(cfg.dataplane, cfg.job_input_gb,
                                          cfg.dt_h)
        if self.engine_kind == "array":
            from repro.core.fleet import ArrayFleetEngine
            self.fleet = ArrayFleetEngine(
                catalog, self.ledger, self.rng,
                lease_interval_s=cfg.lease_interval_s, spot=cfg.spot,
                job_wall_h=cfg.job_wall_h,
                job_checkpoint_h=cfg.job_checkpoint_h, recorder=recorder,
                dataplane=self.dataplane)
            self.prov = self.fleet.prov
            self.ce = self.fleet.ce
        elif self.engine_kind == "object":
            self.fleet = None
            self.prov = MultiCloudProvisioner(catalog, self.ledger,
                                              spot=cfg.spot,
                                              recorder=recorder)
            self.ce = ComputeElement(lease_interval_s=cfg.lease_interval_s,
                                     recorder=recorder,
                                     dataplane=self.dataplane)
        else:
            raise ValueError(f"unknown engine {self.engine_kind!r}")
        self.now = 0.0
        self.history: List[TickStats] = []
        self._pilot_by_instance: Dict[int, int] = {}
        self._events: List[tuple] = []   # (t_h, callable) one-shots
        # request-rate factor (spec.WorkloadCurve): the CE queue tops up
        # to int(min_queue * factor).  Set only at event time, so the
        # per-tick int(int * float) product matches the batched engine's
        # event-time cache bit-for-bit.
        self.workload_factor = 1.0
        self.accel_hours = 0.0           # delivered accelerator wall hours
        self.busy_hours = 0.0            # hours with a job attached
        self.busy_hours_by_provider: Dict[str, float] = {}

    @classmethod
    def from_spec(cls, spec, seed: int, engine: Optional[str] = None,
                  recorder=None) -> "CloudSimulator":
        """Build a simulator straight from a declarative
        ``repro.core.spec.CampaignSpec`` (catalog + engine knobs); the
        spec's *timeline* is installed by ``spec.TimelineController``."""
        from repro.core.spec import build_catalog
        cfg = SimConfig.from_spec(spec, seed)
        return cls(build_catalog(spec), spec.budget, cfg, engine=engine,
                   recorder=recorder)

    # -- scheduling ---------------------------------------------------------
    def at(self, t_h: float, fn: Callable[["CloudSimulator"], None]):
        self._events.append((t_h, fn))
        self._events.sort(key=lambda e: e[0])

    def effective_min_queue(self) -> int:
        """The CE queue top-up level under the current request-rate
        factor (1.0 unless a ``WorkloadCurve`` event changed it)."""
        return int(self.cfg.min_queue * self.workload_factor)

    def ensure_jobs(self, min_queue: Optional[int] = None):
        """IceCube's queue was effectively infinite; keep it topped up."""
        mq = self.effective_min_queue() if min_queue is None else min_queue
        if self.fleet is not None:
            self.fleet.ensure_jobs(mq)
            return
        need = mq - len(self.ce.queue)
        for _ in range(max(0, need)):
            self.ce.submit(Job(id=self.ce.next_job_id(),
                               wall_h=self.cfg.job_wall_h,
                               checkpoint_period_h=self.cfg.job_checkpoint_h))

    # -- object-engine tick phases -----------------------------------------
    def _sync_pilots(self):
        """Every live instance runs exactly one registered pilot; pilots on
        stopped/preempted instances are reaped (their jobs re-queue)."""
        live_ids = set()
        for inst in self.prov.live_instances():
            live_ids.add(inst.id)
            if inst.id not in self._pilot_by_instance:
                nat = self.prov.catalog[inst.provider].nat_idle_timeout_s
                p = self.ce.register_pilot(inst.id, inst.provider, nat,
                                           self.now)
                self._pilot_by_instance[inst.id] = p.id
        for iid in list(self._pilot_by_instance):
            if iid not in live_ids:
                self.ce.pilot_lost(self._pilot_by_instance.pop(iid),
                                   self.now)

    def _maintain_groups(self):
        """Group mechanisms keep their desired count: replacements for
        preempted instances are provisioned automatically (paper §II: 'no
        further operator intervention was needed')."""
        for g in self.prov.groups:
            if len(g.running) < min(g.target, g.region.capacity):
                g.set_target(g.target, self.now)

    def _sample_preemptions(self, dt_h: float):
        from repro.core.fleet import preemption_rate
        for g in self.prov.groups:
            rate = preemption_rate(g.region.preempt_rate_per_hour,
                                   g.region.preempt_scale_at_full,
                                   len(g.running), g.region.capacity)
            for inst in g.running:
                if self.rng.random() < rate * dt_h:
                    g.preempt(inst.id, self.now)
                    pid = self._pilot_by_instance.pop(inst.id, None)
                    if pid is not None:
                        self.ce.pilot_lost(pid, self.now)

    def step(self):
        dt = self.cfg.dt_h
        # one-shot events
        while self._events and self._events[0][0] <= self.now:
            _, fn = self._events.pop(0)
            fn(self)
        if self.fleet is not None:
            running, busy = self.fleet.tick(self.now, dt,
                                            self.effective_min_queue())
            busy_by_prov = self.fleet.busy_by_provider()
        else:
            self._maintain_groups()
            self._sync_pilots()
            self._sample_preemptions(dt)
            self._sync_pilots()
            self.ensure_jobs()
            self.ce.match(self.now)
            self.ce.advance(dt, self.now)
            self.prov.bill(self.now)
            running = self.prov.total_running()
            busy = self.ce.stats()["pilots_busy"]
            busy_by_prov = self.ce.busy_by_provider()
        # cache-miss egress lands right after the GPU-hour charges and
        # before the overhead line — the engine-shared billing order
        self.dataplane.bill(self.ledger, self.now, self.recorder)
        if self.cfg.overhead_per_day > 0:
            self.ledger.charge("infra", self.cfg.overhead_per_day * dt / 24.0,
                               self.now, note="CE VM, storage, egress")
        self.accel_hours += running * dt
        self.busy_hours += busy * dt
        for prov_name, n in busy_by_prov.items():
            self.busy_hours_by_provider[prov_name] = \
                self.busy_hours_by_provider.get(prov_name, 0.0) + n * dt
        self.history.append(TickStats(self.now, running, busy,
                                      len(self.ce.queue),
                                      self.ledger.spent,
                                      self.ce.preemption_events))
        self.now += dt

    def run_until(self, t_h: float):
        while self.now < min(t_h, self.cfg.duration_h):
            self.step()

    # -- results ---------------------------------------------------------------
    def settle(self):
        """Bill any instance-hours accrued since the last tick (found by
        tests/test_sim_properties.py::test_sim_conservation: the final
        tick's interval was never charged)."""
        self.prov.bill(self.now)

    def _eflop_hours(self) -> float:
        """fp32 EFLOP-hours delivered.  Homogeneous catalogs (no
        per-provider fp32_tflops) use the seed formula; heterogeneous
        catalogs weight each provider's busy hours by its GPU's peak.
        Sub-GPU slices (spec.GpuSlicing) flow through the heterogeneous
        path: a ``name/k`` provider carries a 1/k-scaled fp32_tflops, so
        slice-hours aggregate to the same device-hours of compute."""
        specs = self.prov.catalog.values()
        if not any(p.fp32_tflops is not None for p in specs):
            return self.busy_hours * self.cfg.accel_tflops * 1e12 / 1e18
        tflops = {p.name: (p.fp32_tflops if p.fp32_tflops is not None
                           else self.cfg.accel_tflops) for p in specs}
        return sum(h * tflops.get(name, self.cfg.accel_tflops)
                   for name, h in self.busy_hours_by_provider.items()
                   ) * 1e12 / 1e18

    def results(self) -> dict:
        self.settle()
        return {
            "accel_hours": round(self.accel_hours, 1),
            "accel_days": round(self.accel_hours / 24.0, 1),
            "busy_hours": round(self.busy_hours, 1),
            "busy_hours_by_provider": {
                k: round(v, 1)
                for k, v in sorted(self.busy_hours_by_provider.items())},
            "eflop_hours_fp32": round(self._eflop_hours(), 3),
            "cost": round(self.ledger.spent, 2),
            "cost_per_accel_day": round(
                self.ledger.spent / max(self.accel_hours / 24.0, 1e-9), 2),
            "preemptions": self.ce.preemption_events,
            "nat_drops": self.ce.nat_drop_events,
            "jobs_finished": len(self.ce.finished),
            "budget": self.ledger.report(),
            "by_provider": self.prov.running_by_provider(),
            **self.dataplane.results(),
        }
