"""Multi-cloud group provisioning with the paper's semantics (§II):

  "All three Cloud providers offer group provisioning mechanisms with very
   similar semantics. [...] All three allowed us to set the desired number
   of instances in a specific region, and they would provision as many as
   available at that point in time; no further operator intervention was
   needed. [...] we would typically instantiate one group mechanism per
   region."

``InstanceGroup`` is that uniform abstraction (VMSS / InstanceGroups /
SpotFleet behind one interface); ``MultiCloudProvisioner`` spreads a global
target across groups by price priority (the paper "heavily favored Azure" —
cheapest spot T4 with spare capacity), charges the budget ledger per
instance-hour, and supports instant fleet-wide de-provisioning ("instructing
the various Cloud-native group mechanisms to keep zero active instances" —
the paper's CE-outage response).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, Iterator, List, Optional

from repro.core.budget import BudgetLedger
from repro.core.provider import ProviderSpec, RegionSpec


@dataclass
class Instance:
    id: int
    provider: str
    region: str
    started_at: float            # hours
    preempted_at: Optional[float] = None
    stopped_at: Optional[float] = None
    last_charged: float = 0.0    # hours already billed

    @property
    def alive(self) -> bool:
        return self.preempted_at is None and self.stopped_at is None

    def runtime_h(self, now: float) -> float:
        end = self.preempted_at if self.preempted_at is not None else \
            (self.stopped_at if self.stopped_at is not None else now)
        return max(0.0, end - self.started_at)


@dataclass
class InstanceGroup:
    """One Cloud-native group mechanism in one region."""
    provider: ProviderSpec
    region: RegionSpec
    target: int = 0
    instances: Dict[int, Instance] = field(default_factory=dict)
    retired: List[Instance] = field(default_factory=list)
    # ID source; a standalone group numbers from 0, a provisioner hands
    # every group one shared counter so IDs are engine-unique and each
    # sim starts from 0 regardless of process history
    ids: Iterator[int] = field(default_factory=itertools.count)
    # optional events.TraceRecorder (shared across groups by the
    # provisioner); RNG-free, so attaching it never changes the campaign
    recorder: Optional[object] = None

    @property
    def running(self) -> List[Instance]:
        return [i for i in self.instances.values() if i.alive]

    def compact(self):
        """Move dead, fully-billed instances out of the live dict so
        ``bill()``/``running`` stop rescanning every instance ever
        created (a two-week replay creates ~100k of them)."""
        dead = [i for i in self.instances.values()
                if not i.alive and i.last_charged >= (
                    i.preempted_at if i.preempted_at is not None
                    else i.stopped_at)]
        if len(dead) * 4 > len(self.instances):
            for i in dead:
                del self.instances[i.id]
            self.retired.extend(dead)

    def set_target(self, n: int, now: float):
        """Provider semantics: fill to min(target, capacity available),
        immediately, no operator intervention."""
        self.target = max(0, n)
        live = self.running
        fillable = min(self.target, self.region.capacity)
        if len(live) < fillable:
            for _ in range(fillable - len(live)):
                inst = Instance(next(self.ids), self.provider.name,
                                self.region.name, now, last_charged=now)
                self.instances[inst.id] = inst
                if self.recorder is not None:
                    self.recorder.launched(now, inst.id,
                                           self.provider.name,
                                           self.region.name)
        elif len(live) > self.target:
            for inst in live[self.target:]:
                inst.stopped_at = now
                if self.recorder is not None:
                    self.recorder.stopped(now, inst.id,
                                          self.provider.name,
                                          self.region.name)

    def preempt(self, inst_id: int, now: float):
        inst = self.instances.get(inst_id)
        if inst is not None and inst.alive:
            inst.preempted_at = now
            if self.recorder is not None:
                self.recorder.preempted(now, inst.id, self.provider.name,
                                        self.region.name)

    def utilization(self) -> float:
        return len(self.running) / max(1, self.region.capacity)


class MultiCloudProvisioner:
    """Price-priority distribution of a global instance target across all
    (provider, region) groups, with per-hour spot billing into the ledger."""

    def __init__(self, catalog: Dict[str, ProviderSpec],
                 ledger: Optional[BudgetLedger] = None,
                 spot: bool = True, recorder=None):
        self.catalog = catalog
        self.ledger = ledger
        self.spot = spot
        ids = itertools.count()
        self.groups: List[InstanceGroup] = [
            InstanceGroup(prov, region, ids=ids, recorder=recorder)
            for prov in catalog.values() for region in prov.regions]
        # cheapest first; stable for determinism
        self.groups.sort(key=lambda g: (self._price(g.provider),
                                        g.provider.name, g.region.name))
        self.global_target = 0
        # cumulative uniform market drift (spec.PriceShift events); kept
        # as one scalar so the price-priority group order is unaffected
        self.price_scale = 1.0
        # absolute per-provider curve factors (spec.PriceCurve events);
        # stack multiplicatively on the uniform scalar
        self.curve_factor: Dict[str, float] = {}

    def _price(self, prov: ProviderSpec) -> float:
        return (prov.spot_price_per_day if self.spot
                else prov.ondemand_price_per_day)

    def scale_prices(self, factor: float):
        """Uniform price shift from now on (already-billed hours keep
        their old price) — the spec timeline's ``PriceShift`` op."""
        self.price_scale *= factor

    def set_price_factor(self, provider: Optional[str], factor: float):
        """Set the absolute curve factor for one provider (or all, when
        ``provider`` is None) — the spec timeline's ``PriceCurve`` op.
        Unlike ``scale_prices`` this *replaces* the previous curve value
        rather than compounding on it."""
        if provider is None:
            for name in self.catalog:
                self.curve_factor[name] = factor
        else:
            self.curve_factor[provider] = factor

    def scale_capacity(self, factor: float):
        """Multiply every region's capacity (floored at 1 instance);
        shrinking below the live count does not evict running instances —
        the spec timeline's ``CapacityShift`` op."""
        for g in self.groups:
            g.region = replace(
                g.region,
                capacity=max(1, int(g.region.capacity * factor)))

    # -- control ------------------------------------------------------------
    def scale_to(self, n: int, now: float):
        """Greedy fill cheapest regions first (the paper's Azure bias is an
        emergent consequence of its price)."""
        self.global_target = max(0, n)
        remaining = self.global_target
        for g in self.groups:
            want = min(remaining, g.region.capacity)
            g.set_target(want, now)
            remaining -= len(g.running)
        return self.total_running()

    def deprovision_all(self, now: float):
        """The CE-outage response: zero instances everywhere, instantly."""
        for g in self.groups:
            g.set_target(0, now)

    # -- accounting ----------------------------------------------------------
    def bill(self, now: float):
        """Charge the ledger for instance-hours since the last billing."""
        if self.ledger is None:
            return 0.0
        total = 0.0
        for g in self.groups:
            # ((price/24) * shift scalar) * curve factor — the exact
            # float expression every engine must share for bit-identical
            # billing (curve defaults to x1.0, an exact no-op)
            rate_h = self._price(g.provider) / 24.0 * self.price_scale \
                * self.curve_factor.get(g.provider.name, 1.0)
            for inst in g.instances.values():
                end = now
                if inst.preempted_at is not None:
                    end = inst.preempted_at
                elif inst.stopped_at is not None:
                    end = inst.stopped_at
                dh = max(0.0, end - inst.last_charged)
                if dh > 0:
                    amount = dh * rate_h
                    self.ledger.charge(g.provider.name, amount, now,
                                       note=f"{g.region.name}")
                    inst.last_charged = end
                    total += amount
            g.compact()
        return total

    # -- views ---------------------------------------------------------------
    def total_running(self) -> int:
        return sum(len(g.running) for g in self.groups)

    def running_by_provider(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for g in self.groups:
            out[g.provider.name] = out.get(g.provider.name, 0) \
                + len(g.running)
        return out

    def all_instances(self):
        for g in self.groups:
            yield from g.retired
            yield from g.instances.values()

    def live_instances(self):
        for g in self.groups:
            yield from g.running
