"""What-if planning sweep: hundreds of (seed x spec) campaigns as one
array program, through the ``run()`` front door.

    PYTHONPATH=src python -m examples.whatif_sweep
    PYTHONPATH=src python -m examples.whatif_sweep --seeds 32
    PYTHONPATH=src python -m examples.whatif_sweep --scenarios paper,hetero
    PYTHONPATH=src python -m examples.whatif_sweep --csv sweep.csv

Runs the default pre-burst spec suite (paper baseline, on-demand
fallback, spot/on-demand mix, heterogeneous §III pool, outage grid,
budget-floor and price-curve variants — all declarative CampaignSpecs,
core/scenarios.py) over N seeds on the batched sweep engine
(core/sweep.py) and prints the planning table: mean [p5, p95] bands on
cost, GPU-days and preemptions per spec.  Every lane is bit-reproducible
against a solo ``run(spec, seeds=seed)`` at the same (seed, spec);
``--csv`` writes the deterministic per-lane row artifact (including each
lane's ``events_fired`` provenance)."""
from __future__ import annotations

import argparse
import time

from repro.core.api import run
from repro.core.scenarios import default_suite


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=8,
                    help="seeds per scenario spec")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated spec-name filter")
    ap.add_argument("--csv", default=None,
                    help="write the per-lane row CSV here")
    args = ap.parse_args()

    suite = default_suite()
    if args.scenarios:
        want = {s.strip() for s in args.scenarios.split(",")}
        suite = [s for s in suite if s.name in want]
        if not suite:
            raise SystemExit(f"no spec matches {sorted(want)}; "
                             f"have {[s.name for s in default_suite()]}")
    seeds = list(range(2021, 2021 + args.seeds))
    n = len(suite) * len(seeds)
    print(f"sweeping {len(suite)} specs x {len(seeds)} seeds "
          f"= {n} two-week campaigns (batched engine) ...")
    t0 = time.perf_counter()
    sw = run(suite, seeds=seeds)
    dt = time.perf_counter() - t0
    print(f"done in {dt:.1f}s ({n / dt:.1f} campaigns/s)\n")
    print(sw.table())
    if args.csv:
        sw.to_csv(args.csv)
        print(f"\nwrote {args.csv}")
    print("\n(paper single-run reference: ~$58k, ~16k GPU-days)")


if __name__ == "__main__":
    main()
