"""What-if planning sweep: hundreds of (seed x scenario) campaigns as
one array program.

    PYTHONPATH=src python -m examples.whatif_sweep
    PYTHONPATH=src python -m examples.whatif_sweep --seeds 32
    PYTHONPATH=src python -m examples.whatif_sweep --scenarios paper,hetero

Runs the default pre-burst scenario suite (paper baseline, on-demand
fallback, spot/on-demand mix, heterogeneous §III pool, outage grid,
budget-floor and price-curve variants) over N seeds on the batched sweep
engine (core/sweep.py) and prints the planning table: mean [p5, p95]
bands on cost, GPU-days and preemptions per scenario.  Every lane is
bit-reproducible against a solo ``run_scenario()`` at the same
(seed, scenario)."""
from __future__ import annotations

import argparse
import time

from repro.core.campaign import sweep_campaigns
from repro.core.scenarios import default_suite


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=8,
                    help="seeds per scenario")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario-name filter")
    args = ap.parse_args()

    suite = default_suite()
    if args.scenarios:
        want = {s.strip() for s in args.scenarios.split(",")}
        suite = [s for s in suite if s.name in want]
        if not suite:
            raise SystemExit(f"no scenario matches {sorted(want)}; "
                             f"have {[s.name for s in default_suite()]}")
    seeds = list(range(2021, 2021 + args.seeds))
    n = len(suite) * len(seeds)
    print(f"sweeping {len(suite)} scenarios x {len(seeds)} seeds "
          f"= {n} two-week campaigns (batched engine) ...")
    t0 = time.perf_counter()
    sw = sweep_campaigns(suite, seeds)
    dt = time.perf_counter() - t0
    print(f"done in {dt:.1f}s ({n / dt:.1f} campaigns/s)\n")
    print(sw.table())
    print("\n(paper single-run reference: ~$58k, ~16k GPU-days)")


if __name__ == "__main__":
    main()
