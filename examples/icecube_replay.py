"""Replay the paper's two-week multi-cloud campaign end-to-end and compare
every published number (eScience'21 §IV/§V, Figs 1-2) — through the
declarative front door: the whole campaign is one ``CampaignSpec`` (the
same JSON as tests/data/paper_replay.spec.json) and one ``run()`` call
returning a typed ``CampaignResult``.

    PYTHONPATH=src python examples/icecube_replay.py
"""
from repro.core.api import paper_spec, run


def main():
    spec = paper_spec(budget=58000.0)
    res = run(spec, seeds=2021)

    print("=== the campaign as data (CampaignSpec timeline) ===")
    for ev in spec.timeline:
        print(f"  {ev}")

    print("\n=== operational log (timeline controller) ===")
    for line in res.log:
        print(" ", line)

    print("\n=== fleet timeline (Fig 1 analogue) ===")
    hist = res.history
    for t in hist[:: max(1, len(hist) // 14)]:
        bar = "#" * (t.running // 50)
        print(f"  d{t.t_h / 24:5.1f} {t.running:5d} {bar}")

    print("\n=== published-claim comparison (§V) ===")
    units = {"cost": "$", "accel_days": " GPU-days",
             "eflop_hours_fp32": " fp32 EFLOP-h", "doubling": "x"}
    for claim, row in res.compare_paper().items():
        print(f"  {claim:18s} sim {row['sim']:>12,.2f}{units[claim]:<14s}"
              f" paper ~{row['paper']:,.1f}  err {row['err_pct']:+6.1f}%")
    print(f"  preemptions handled {res.preemptions:>10,} (spot)")
    print(f"  jobs completed      {res.jobs_finished:>10,}")


if __name__ == "__main__":
    main()
