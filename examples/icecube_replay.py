"""Replay the paper's two-week multi-cloud campaign end-to-end and compare
every published number (eScience'21 §IV/§V, Figs 1-2).

    PYTHONPATH=src python examples/icecube_replay.py
"""
from repro.core.campaign import (ICECUBE_BASELINE_GPUH_PER_2W,
                                 replay_paper_campaign)


def main():
    res, ctl = replay_paper_campaign(budget=58000.0)

    print("=== operational log (controller) ===")
    for line in ctl.log:
        print(" ", line)

    print("\n=== fleet timeline (Fig 1 analogue) ===")
    hist = ctl.sim.history
    for t in hist[::  max(1, len(hist) // 14)]:
        bar = "#" * (t.running // 50)
        print(f"  d{t.t_h / 24:5.1f} {t.running:5d} {bar}")

    print("\n=== published-claim comparison (§V) ===")
    rows = [
        ("total cost            ", f"${res['cost']:>9,.0f}", "~$58,000"),
        ("GPU-days delivered    ", f"{res['accel_days']:>10,.0f}", "~16,000"),
        ("fp32 EFLOP-hours      ", f"{res['eflop_hours_fp32']:>10.2f}",
         "~3.1"),
        ("$ / GPU-day           ", f"{res['cost_per_accel_day']:>10.2f}",
         "~3.6 blended"),
        ("preemptions handled   ", f"{res['preemptions']:>10,}", "(spot)"),
        ("jobs completed        ", f"{res['jobs_finished']:>10,}", ""),
    ]
    for name, sim, paper in rows:
        print(f"  {name} sim {sim}   paper {paper}")
    doubling = 1 + res["busy_hours"] / ICECUBE_BASELINE_GPUH_PER_2W
    print(f"  GPU-hours vs baseline  {doubling:10.2f}x  paper ~2x "
          "(\"approximate doubling\")")


if __name__ == "__main__":
    main()
