"""Elastic-training goodput from a campaign trace, end to end.

The typed event-trace API turns any what-if campaign from
``repro.core.scenarios`` into an elastic-training study with no new
glue:

  1. run the campaign with ``collect="trace"`` — every spot preemption,
     graceful stop and instance launch lands in a typed, replayable
     ``CampaignTrace``,
  2. replay the stream into an elastic pod pool
     (``elastic.drive_pool``): launches join pods, preemptions run the
     notice -> checkpoint -> rebuild path, CE outages drain the pool,
  3. read the ``GoodputReport``: net steps, lost steps, rebuild
     downtime, pool clipping.

Here: the paper burst with its CE outage moved to day 2.5
(``scenarios.outage_burst()``, a ``default_suite`` member), replayed
twice — honoring the cloud's preemption notice vs hard kills.

Run:  PYTHONPATH=src python examples/elastic_goodput.py
"""
from repro.core import scenarios
from repro.core.api import run
from repro.core.elastic import PodPool, SimulatedElasticRunner, drive_pool


def main():
    spec = scenarios.outage_burst()
    print(f"campaign {spec.name!r}: collecting the event trace ...")
    res = run(spec, seeds=2021, collect="trace")
    trace = res.trace
    counts = {k: v for k, v in sorted(trace.counts().items()) if v}
    print(f"  {len(trace)} events: "
          + " ".join(f"{k}={v}" for k, v in counts.items()))

    reports = {}
    for label, notice in (("notice honored", True), ("hard kills", False)):
        pool = PodPool(min_pods=1, max_pods=128)
        runner = SimulatedElasticRunner(rebuild_s=45.0)
        reports[label] = drive_pool(trace, pool, runner,
                                    step_time_s=2.0,
                                    checkpoint_period_s=600.0,
                                    notice=notice)

    fields = ("steps_done", "steps_lost", "rebuilds",
              "rebuild_downtime_s", "preemptions", "graceful_leaves",
              "joins_rejected", "peak_pods", "goodput_fraction")
    width = max(len(f) for f in fields) + 2
    print(f"\n{'':{width}}" + "".join(f"{k:>18}" for k in reports))
    for f in fields:
        cells = "".join(f"{getattr(r, f):>18,}" for r in reports.values())
        print(f"{f:{width}}" + cells)
    soft = reports["notice honored"]
    hard = reports["hard kills"]
    print(f"\npreemption notices buy "
          f"{soft.steps_done - hard.steps_done:,.0f} steps "
          f"({100 * (soft.goodput_fraction - hard.goodput_fraction):.1f} "
          "pp of goodput) over hard kills on this campaign.")


if __name__ == "__main__":
    main()
