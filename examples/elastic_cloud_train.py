"""End-to-end elastic cloud training: the paper's scenario applied to
synchronous SPMD training (the TPU adaptation, DESIGN.md §2).

A simulated multi-provider spot fleet provisions pod slices; pilots join
the PodPool; the ElasticRunner reshapes the mesh as pods come and go
(spot preemption + the CE-outage-style full collapse), restarting from
async checkpoints. Budget thresholds drive the fleet size, exactly like
the paper's 20 %-left -> downscale decision.

Runs on CPU with 4 faked devices (pods of shape (2,1)):
    PYTHONPATH=src python examples/elastic_cloud_train.py
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import sharding as sh  # noqa: E402
from repro.checkpoint import Checkpointer  # noqa: E402
from repro.configs import REDUCED_SHAPE, RunConfig, get_reduced  # noqa: E402
from repro.core.budget import BudgetLedger  # noqa: E402
from repro.core.elastic import ElasticRunner, PodPool  # noqa: E402
from repro.core.provider import tpu_catalog  # noqa: E402
from repro.core.provisioner import MultiCloudProvisioner  # noqa: E402
from repro.data import make_batch  # noqa: E402
from repro.launch import steps as st  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.optim import adamw_init  # noqa: E402
from repro.sharding_ctx import use_mesh  # noqa: E402

CKPT = "/tmp/repro_elastic_ckpt"


def main():
    cfg = get_reduced("yi-9b")
    run = RunConfig(model=cfg, shape=REDUCED_SHAPE,
                    compute_dtype="float32", remat=False)
    params = jax.device_get(init_params(cfg, jax.random.PRNGKey(0)))
    opt = jax.device_get(adamw_init(params))

    def builder(mesh):
        fn = st.make_train_step(cfg, run)
        psh = sh.param_shardings(params, mesh)
        osh = sh.opt_shardings(opt, mesh)
        jf = jax.jit(fn, in_shardings=(psh, osh, None),
                     out_shardings=(psh, osh, None))

        def wrapped(p, o, b):
            with use_mesh(mesh):
                return jf(p, o, b)
        return wrapped

    # --- control plane: budget-managed multi-cloud slice provisioning ------
    ledger = BudgetLedger(total_budget=50000.0)
    prov = MultiCloudProvisioner(tpu_catalog(), ledger)
    pool = PodPool(max_pods=2)
    runner = ElasticRunner(builder, params, opt, pod_shape=(2, 1),
                           checkpointer=Checkpointer(CKPT, keep=2))
    pool.on_change(lambda n: runner.ensure(max(n, 1)))

    # hour 0: provision 2 slices (cheapest provider fills first)
    prov.scale_to(2, now=0.0)
    for inst in prov.live_instances():
        pool.join(f"slice-{inst.id}")
    print(f"fleet: {prov.running_by_provider()}  -> {runner.n_pods} pods")

    step, losses = 0, []
    for step in range(10):
        losses.append(float(runner.step(make_batch(cfg, REDUCED_SHAPE,
                                                   step))["loss"]))
    runner.checkpoint(step)

    # hour 6: spot preemption takes one slice (30 s notice honored)
    victim = next(iter(pool.pods))
    pool.preemption_notice(victim)
    runner.handle_preemption(step)           # durable state, blocking
    pool.leave(victim)
    prov.bill(now=6.0)
    print(f"preempted {victim}; now {runner.n_pods} pod(s); "
          f"spent ${ledger.spent:,.0f}")

    for step in range(10, 20):
        losses.append(float(runner.step(make_batch(cfg, REDUCED_SHAPE,
                                                   step))["loss"]))

    # hour 12: capacity returns -> grow back, same global batch throughout
    prov.scale_to(2, now=12.0)
    pool.join("slice-replacement")
    for step in range(20, 30):
        losses.append(float(runner.step(make_batch(cfg, REDUCED_SHAPE,
                                                   step))["loss"]))
    prov.bill(now=12.5)

    assert all(np.isfinite(losses))
    print(f"30 elastic steps, {runner.rebuilds} mesh rebuilds, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"ledger: {ledger.report()}")


if __name__ == "__main__":
    main()
