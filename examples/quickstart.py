"""Quickstart: declare and run a cloud campaign as data, then train a
small LM for a few hundred steps on CPU, checkpoint, restore, and serve
a few batched requests — the whole public API in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.checkpoint import restore
from repro.core.api import run
from repro.core.spec import CampaignSpec, CEOutage, PriceShift, SetTarget
from repro.launch.serve import BatchServer, Request
from repro.launch.train import Trainer, build

CKPT = "/tmp/repro_quickstart_ckpt"


def campaign_quickstart():
    # -- a two-day burst campaign, declared as data --------------------------
    spec = CampaignSpec(
        name="quickstart", budget=4000.0, duration_h=48.0,
        downscale_target=150,                # budget tripwire cap
        timeline=(SetTarget(0.0, 100),       # small-scale validation ...
                  SetTarget(6.0, 500),       # ... then burst
                  PriceShift(24.0, 1.3),     # spot market drifts up
                  CEOutage(36.0, 2.0, 250)))  # backend dies; resume lower
    print(f"spec round-trips to JSON: "
          f"{len(spec.to_json().splitlines())} lines")
    res = run(spec, seeds=2021)              # typed CampaignResult
    print(f"campaign {spec.name!r}: ${res.cost:,.0f} for "
          f"{res.accel_days:,.1f} GPU-days "
          f"({res.preemptions} preemptions, "
          f"{res.jobs_finished:,} jobs)")
    for ev in res.events_fired:
        print(f"  fired: {ev}")

    # the same spec across seeds = one batched Monte-Carlo sweep
    sw = run(spec, seeds=range(2021, 2025))
    band = sw.summary()[spec.name]["cost"]
    print(f"cost across 4 seeds: mean ${band['mean']:,.0f} "
          f"[p5 ${band['p5']:,.0f}, p95 ${band['p95']:,.0f}]")


def main():
    campaign_quickstart()
    # -- train a ~300k-param yi-family model for 200 steps -------------------
    # start from scratch: a leftover checkpoint at step >= 200 would make
    # train(200) a silent no-op (the Trainer auto-resumes from ckpt_dir)
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg, shape, run = build("yi-9b", reduced=True, batch=8, seq=64)
    trainer = Trainer(cfg, shape, run, ckpt_dir=CKPT, seed=0)
    trainer.install_signal_handlers()        # SIGTERM = preemption notice
    losses = trainer.train(200, ckpt_every=50, log_every=25)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over 200 steps")
    assert losses[-1] < losses[0]

    # -- restart from the durable checkpoint ---------------------------------
    step, _ = restore(CKPT, {"params": trainer.params, "opt": trainer.opt})
    print(f"latest durable checkpoint: step {step}")

    # -- serve a few batched requests against the same config ----------------
    import numpy as np
    server = BatchServer(cfg, slots=4)
    server.params = jax.device_get(trainer.params)   # hand over the weights
    rng = np.random.default_rng(0)
    for i in range(6):
        server.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                              .astype(np.int32), max_new=12))
    done = server.run()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out) for r in done)} tokens")


if __name__ == "__main__":
    main()
