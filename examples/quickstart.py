"""Quickstart: train a small LM for a few hundred steps on CPU, checkpoint,
restore, and sample a few tokens — the whole public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.checkpoint import restore
from repro.launch.serve import BatchServer, Request
from repro.launch.train import Trainer, build

CKPT = "/tmp/repro_quickstart_ckpt"


def main():
    # -- train a ~300k-param yi-family model for 200 steps -------------------
    cfg, shape, run = build("yi-9b", reduced=True, batch=8, seq=64)
    trainer = Trainer(cfg, shape, run, ckpt_dir=CKPT, seed=0)
    trainer.install_signal_handlers()        # SIGTERM = preemption notice
    losses = trainer.train(200, ckpt_every=50, log_every=25)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over 200 steps")
    assert losses[-1] < losses[0]

    # -- restart from the durable checkpoint ---------------------------------
    step, _ = restore(CKPT, {"params": trainer.params, "opt": trainer.opt})
    print(f"latest durable checkpoint: step {step}")

    # -- serve a few batched requests against the same config ----------------
    import numpy as np
    server = BatchServer(cfg, slots=4)
    server.params = jax.device_get(trainer.params)   # hand over the weights
    rng = np.random.default_rng(0)
    for i in range(6):
        server.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                              .astype(np.int32), max_new=12))
    done = server.run()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out) for r in done)} tokens")


if __name__ == "__main__":
    main()
