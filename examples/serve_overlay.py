"""Serving through the overlay: inference requests as CE "jobs", decode
slots as "pilots" — the paper's federation principle applied to a model
server, with straggler-aware speculative re-execution.

    PYTHONPATH=src python examples/serve_overlay.py
"""
import numpy as np

from repro.configs import get_reduced
from repro.core.overlay import ComputeElement, Job
from repro.core.straggler import SpeculativeScheduler
from repro.launch.serve import BatchServer, Request


def main():
    cfg = get_reduced("qwen3-moe-30b-a3b")     # MoE decode path
    server = BatchServer(cfg, slots=4, max_len=64)
    ce = ComputeElement(accept_policy="icecube", lease_interval_s=120.0)
    spec = SpeculativeScheduler(spec_factor=2.5, min_samples=3)

    rng = np.random.default_rng(1)
    n_requests = 10
    for i in range(n_requests):
        ce.submit(Job(i, wall_h=float(rng.integers(8, 24))))  # wall == tokens
    for slot in range(4):
        ce.register_pilot(slot, "cloud-a", nat_timeout_s=240.0, now_h=0.0)

    served = 0
    t = 0.0
    while served < n_requests:
        ce.match(t)
        for pilot in ce.pilots.values():
            if pilot.job is None or pilot.job.finished:
                continue
            job = pilot.job
            req = Request(job.id, rng.integers(0, cfg.vocab_size, 6)
                          .astype(np.int32), max_new=int(job.wall_h))
            server.submit(req)
            done = server.run()
            job.done_h = job.wall_h            # tokens delivered
            spec.record_completion(len(done[-1].out))
            served += 1
        ce.advance(1.0, t)
        t += 1.0

    print(f"served {served} requests via the CE overlay "
          f"({len(server.done)} batches), "
          f"speculative re-executions: {spec.speculated}")
    print("CE stats:", ce.stats())


if __name__ == "__main__":
    main()
