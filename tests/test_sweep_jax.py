"""The compiled sweep engine (``engine="jax"``, core/sweep_jax.py).

Four contracts:

  * **statistical equivalence** (the acceptance bar): over the full
    ``scenarios.default_suite`` at 8 seeds, per-scenario mean and
    [p5, p95] bands on cost, GPU-days and jobs must sit inside the
    batched numpy engine's bands
    (``engine_equivalence.assert_statistically_equivalent``),
  * **event provenance is not statistical**: ``events_fired`` is
    reconstructed through the same timeline registry and must match the
    bit-identical engines record-for-record,
  * **one front door**: ``api.run/sweep(engine="jax")`` dispatch,
    the solo forced path, the no-trace-surface error, and the
    centralized allowed-engine sets the CLI shares,
  * **planning-grid scale**: every ``scenarios.planning_grid`` member
    shares one structural batch key, so the whole grid compiles into a
    single scan.
"""
import pytest

pytest.importorskip("jax")

from engine_equivalence import assert_statistically_equivalent
from repro.core import scenarios
from repro.core.api import (ENGINES, SOLO_ENGINES, SWEEP_ENGINES, run,
                            sweep)
from repro.core.spec import CampaignResult, paper_spec
from repro.core.sweep_jax import _prepare, run_jax


def _short(name="paper", **kw):
    from dataclasses import replace
    sc = next(s for s in scenarios.default_suite() if s.name == name)
    return replace(sc, **kw) if kw else sc


# -- the acceptance bar ----------------------------------------------------

@pytest.mark.slow
def test_jax_statistically_equivalent_full_suite():
    """ISSUE 7 acceptance: full default_suite, 8 seeds, mean/p5/p95
    bands on cost, GPU-days and jobs vs the batched numpy engine."""
    assert_statistically_equivalent(scenarios.default_suite(),
                                    list(range(8)))


def test_jax_statistically_equivalent_smoke():
    """The same contract at pytest-friendly cost: three suite members
    covering the budget-floor cap, a CE outage and a workload curve at
    reduced duration."""
    specs = [_short("paper", duration_h=96.0),
             _short("floor30", duration_h=96.0, budget=16000.0),
             _short("load-diurnal", duration_h=96.0)]
    assert_statistically_equivalent(specs, list(range(6)))


# -- event provenance ------------------------------------------------------

def test_jax_events_fired_match_batched():
    """events_fired is reconstructed through the registry's own apply
    bodies — schema- and value-identical to the bit-exact engines (the
    paper timeline: staged ramp + CE outage + budget-floor arming)."""
    sc = paper_spec()
    got = sweep([sc], [0], engine="jax")
    ref = sweep([sc], [0], engine="batched")
    assert got.rows[0]["events_fired"] == ref.rows[0]["events_fired"]


def test_jax_budget_floor_cap_event_recorded():
    """The in-scan budget-floor cap surfaces as the same budget_floor
    provenance record the other engines emit (its tick is data-driven,
    so only the schema and bounded timing are pinned)."""
    sc = _short("floor30", duration_h=168.0, budget=20000.0)
    res = run(sc, seeds=3, engine="jax")
    kinds = [e["event"] for e in res.events_fired]
    assert "budget_floor" in kinds
    cap = next(e for e in res.events_fired
               if e["event"] == "budget_floor")
    assert 0.0 <= cap["t"] <= sc.duration_h
    assert cap["target"] == sc.downscale_target


# -- the front door --------------------------------------------------------

def test_engine_sets_are_single_source():
    assert "jax" in SWEEP_ENGINES and "jax" in ENGINES
    assert "jax" not in SOLO_ENGINES
    assert "auto" in ENGINES and "auto" not in SWEEP_ENGINES


def test_unknown_engine_errors_share_one_message():
    sc = _short(duration_h=24.0)
    with pytest.raises(ValueError, match="unknown run engine 'nope'"):
        run(sc, seeds=1, engine="nope")
    with pytest.raises(ValueError, match="unknown sweep engine 'nope'"):
        sweep([sc], [1, 2], engine="nope")
    # "auto" dispatches in run() but is not a sweep engine
    with pytest.raises(ValueError, match="unknown sweep engine 'auto'"):
        sweep([sc], [1, 2], engine="auto")


def test_cli_engine_choices_track_api():
    """The campaigns CLI --engine choices derive from api.ENGINES (the
    drift this satellite closes)."""
    from repro.campaigns import main as cli_main
    try:
        cli_main(["run", "/nonexistent.spec.json", "--engine", "jax"])
    except FileNotFoundError:
        pass  # engine choice accepted; the spec path (deliberately) not
    with pytest.raises(SystemExit):
        cli_main(["run", "/nonexistent.spec.json", "--engine", "nope"])


def test_jax_solo_forced_run_returns_campaign_result():
    sc = _short(duration_h=48.0)
    res = run(sc, seeds=11, engine="jax")
    assert isinstance(res, CampaignResult)
    assert res.engine == "jax" and res.seed == 11
    assert res.cost > 0 and res.accel_days > 0


def test_jax_has_no_trace_surface():
    sc = _short(duration_h=24.0)
    with pytest.raises(ValueError, match="statistical"):
        run(sc, seeds=1, engine="jax", collect="trace")
    with pytest.raises(ValueError, match="statistical"):
        sweep([sc], [1, 2], engine="jax", collect="trace")


# -- planning-grid scale ---------------------------------------------------

def test_planning_grid_shares_one_batch_key():
    grid = scenarios.planning_grid()
    assert len(grid) == 60
    assert len({s.name for s in grid}) == 60
    keys = {_prepare(s, 0)[0] for s in grid}
    assert len(keys) == 1, "grid members must compile into one scan"


def test_jax_grid_slice_runs_in_one_engine_batch():
    from dataclasses import replace
    grid = [replace(s, duration_h=24.0)
            for s in scenarios.planning_grid((0.9, 1.1), (0.2,),
                                             (58000.0,))]
    sw = sweep(grid, [0, 1], engine="jax")
    assert len(sw.rows) == len(grid) * 2
    costs = {r["scenario"]: r["cost"] for r in sw.rows}
    assert costs["grid-p090-f20-b58k"] < costs["grid-p110-f20-b58k"]


# -- engine internals ------------------------------------------------------

def test_jax_batches_by_structural_key():
    """Lanes with different catalogs land in different compiled batches;
    lanes differing only in price/budget share one."""
    a = _short(duration_h=24.0)
    b = _short("hetero", duration_h=24.0)
    out = run_jax([(a, 0), (b, 0), (a, 1)])
    assert len(out) == 3
    assert out[0]["cost"] != out[1]["cost"]


def test_jax_engine_is_deterministic():
    lanes = [(_short(duration_h=48.0), s) for s in (0, 1)]
    r1 = run_jax(lanes)
    r2 = run_jax(lanes)
    assert r1 == r2


def test_jax_results_schema_matches_batched():
    sc = _short(duration_h=48.0)
    gj = sweep([sc], [5], engine="jax").rows[0]
    gb = sweep([sc], [5], engine="batched").rows[0]
    assert set(gj) == set(gb)
    assert set(gj["budget"]) == set(gb["budget"])
    assert set(gj["by_provider"]) == set(gb["by_provider"])


def test_jax_pallas_interpret_path_matches_ref_path():
    """use_pallas=True on CPU runs every tick op through the Pallas
    kernels in interpret mode; integer semantics must match the jnp
    oracle path exactly (same seeds, same scan)."""
    lanes = [(_short(duration_h=24.0), s) for s in (0, 1)]
    ref = run_jax(lanes, use_pallas=False)
    pal = run_jax(lanes, use_pallas=True)
    assert ref == pal
