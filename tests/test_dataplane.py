"""The data-plane subsystem (core/dataplane.py) end to end.

Covers the PR-8 tentpole:

  * the shared stage math (``stage_ticks`` / ``cache_hit`` /
    ``stage_decision``) — the ONE-expression contract every bit-exact
    engine reuses,
  * the frozen ``DataPlane`` spec surface: normalization, base-provider
    lookup for sliced pools, serialization round trips, and the
    ``CampaignSpec`` omit-at-default rule that keeps the three
    pre-data-plane goldens byte-identical,
  * ``DataPlaneRuntime`` semantics: outage gating, cumulative degrade,
    cache-flush epochs, per-tick egress metering drained by the bill
    phase in sorted provider order,
  * lint findings for inert or dangling data-plane declarations,
  * engine equivalence: byte-identical traces and results across the
    solo-array, solo-object and batched engines on a campaign using
    every data-plane surface; the compiled jax engine statistically
    equivalent with ``egress_usd`` inside its band,
  * the committed golden data-plane campaign
    (tests/data/dataplane.spec.json) pinned bit-for-bit at seed 2021.
"""
import json
import os

import pytest

from repro.core.dataplane import (DataOrigin, DataPlane, DataPlaneRuntime,
                                  cache_hit, stage_decision, stage_ticks)
from repro.core.api import run
from repro.core.scenarios import (DATA_PLANES, data_heavy_mix,
                                  dataplane_burst, default_suite,
                                  egress_cost_scenarios, origin_outage_grid)
from repro.core.spec import (CacheFlush, CampaignSpec, OriginDegrade,
                             OriginOutage, SetTarget, lint_spec, paper_spec)
from tests.engine_equivalence import (STAT_BANDS, assert_engines_equivalent,
                                      assert_statistically_equivalent,
                                      assert_traces_equivalent)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "dataplane.spec.json")

# seed-2021 dataplane-burst totals (pinned; must never drift)
DATAPLANE_BURST_2021 = {"cost": 98360.63, "accel_days": 13188.0,
                        "eflop_hours_fp32": 2.336, "preemptions": 2557,
                        "jobs_finished": 70734, "egress_usd": 54226.65,
                        "stagein_hours": 18570.0,
                        "cache_hit_fraction": 0.6709}

FEDERATED = DATA_PLANES["federated"]


def _dp_spec(**kw):
    """A short campaign exercising every data-plane surface on the t4
    catalog (whose providers carry the azure/gcp/aws base names the
    origin maps bind to)."""
    dp = DataPlane({
        "azure": DataOrigin(bandwidth_gbps=2.0, egress_usd_per_gb=0.09,
                            cache_hit_rate=0.6, cache_bandwidth_gbps=8.0),
        "aws": DataOrigin(bandwidth_gbps=1.0, egress_usd_per_gb=0.05),
    })
    base = dict(name="dp-short", catalog="t4", duration_h=30.0, dt_h=0.05,
                budget=4000.0, job_wall_h=1.0, min_queue=500,
                job_input_gb=25.0, dataplane=dp,
                timeline=(SetTarget(at_h=0.0, target=120),
                          OriginOutage(at_h=6.0, duration_h=3.0,
                                       provider="azure"),
                          OriginDegrade(at_h=12.0, factor=0.5,
                                        provider="aws"),
                          CacheFlush(at_h=18.0, provider="azure")))
    base.update(kw)
    return CampaignSpec(**base)


# -- the shared stage math -------------------------------------------------

def test_stage_ticks_rounds_up_to_whole_ticks():
    # 100 GB at 1 Gbit/s = 800/3600 h = 0.2222 h -> 5 ticks of 0.05 h
    assert stage_ticks(100.0, 1.0, 0.05) == 5
    # any positive transfer costs at least one tick
    assert stage_ticks(0.001, 100.0, 0.1) == 1
    # exact multiples don't round up an extra tick
    assert stage_ticks(45.0, 1.0, 0.05) == 2      # 0.1 h exactly
    # degenerate inputs stage nothing
    assert stage_ticks(0.0, 1.0, 0.05) == 0
    assert stage_ticks(25.0, 0.0, 0.05) == 0
    assert stage_ticks(25.0, 1.0, 0.0) == 0


def test_cache_hit_rotation_is_deterministic_and_converges():
    assert not any(cache_hit(k, 0.0) for k in range(10))
    assert all(cache_hit(k, 1.0) for k in range(10))
    # long-run frequency is exactly the rate (floor-rotation property)
    for rate in (0.25, 0.5, 0.6, 0.9):
        hits = sum(cache_hit(k, rate) for k in range(1000))
        assert hits == int(round(1000 * rate)), rate
    # and the sequence is a fixed rotation, not RNG
    assert [cache_hit(k, 0.5) for k in range(4)] == [False, True] * 2


def test_stage_decision_picks_cache_or_degraded_origin_bandwidth():
    origin = DataOrigin(bandwidth_gbps=1.0, cache_hit_rate=0.5,
                        cache_bandwidth_gbps=8.0)
    miss = stage_decision(origin, 1.0, 100.0, 0.05, k=0)
    hit = stage_decision(origin, 1.0, 100.0, 0.05, k=1)
    assert miss == (5, False)                     # origin at 1 Gbit/s
    assert hit == (1, True)                       # cache at 8 Gbit/s
    # degrade only slows misses; a halved origin doubles the ticks
    assert stage_decision(origin, 0.5, 100.0, 0.05, k=0) == (9, False)
    assert stage_decision(origin, 0.5, 100.0, 0.05, k=1) == (1, True)
    # a cache with no bandwidth of its own still skips the degrade
    eg_only = DataOrigin(bandwidth_gbps=1.0, cache_hit_rate=1.0)
    assert stage_decision(eg_only, 0.5, 100.0, 0.05, k=0) == (5, True)


# -- the frozen spec surface -----------------------------------------------

def test_dataplane_normalizes_and_resolves_base_providers():
    a = DataPlane({"gcp": DataOrigin(1.0), "azure": DataOrigin(2.0)})
    b = DataPlane((("azure", DataOrigin(2.0)), ("gcp", DataOrigin(1.0))))
    assert a == b
    assert a.providers() == ("azure", "gcp")
    assert a.origin_for("azure/4") == DataOrigin(2.0)   # sliced pool
    assert a.origin_for("azure-v100") is None           # not a base match
    assert a.origin_for("aws") is None


def test_dataplane_serialization_round_trips():
    d = FEDERATED.to_dict()
    assert DataPlane.from_dict(json.loads(json.dumps(d))) == FEDERATED
    with pytest.raises(ValueError):
        DataPlane.from_dict({"origins": {}, "bogus": 1})


def test_spec_omits_dataplane_fields_at_defaults():
    """The omit-at-default rule: pre-data-plane specs serialize to the
    exact same dict as before PR 8 (the three committed goldens stay
    byte-identical)."""
    d = paper_spec().to_dict()
    assert "dataplane" not in d
    assert "job_input_gb" not in d
    full = dataplane_burst().to_dict()
    assert full["job_input_gb"] == 25.0
    assert set(full["dataplane"]["origins"]) == {"azure", "gcp", "aws"}
    assert CampaignSpec.from_dict(full) == dataplane_burst()


def test_spec_validate_rejects_bad_origins():
    with pytest.raises(ValueError):
        paper_spec(job_input_gb=-1.0).validate()
    with pytest.raises(ValueError):
        paper_spec(dataplane=DataPlane(
            {"azure": DataOrigin(bandwidth_gbps=0.0)})).validate()
    with pytest.raises(ValueError):
        paper_spec(dataplane=DataPlane(
            {"azure": DataOrigin(1.0, cache_hit_rate=1.5)})).validate()


def test_lint_flags_inert_and_dangling_dataplanes():
    inert = paper_spec(dataplane=DataPlane({"azure": DataOrigin(1.0)}))
    assert any("inert" in f for f in lint_spec(inert))
    dangling = paper_spec(timeline=(SetTarget(0.0, 100),
                                    OriginOutage(6.0, 2.0, "azure")))
    assert any("never matter" in f for f in lint_spec(dangling))
    unknown = paper_spec(job_input_gb=5.0, dataplane=DataPlane(
        {"ibm": DataOrigin(1.0)}))
    assert any("unknown provider" in f for f in lint_spec(unknown))
    assert lint_spec(dataplane_burst()) == []


# -- runtime semantics ------------------------------------------------------

class _Ledger:
    def __init__(self):
        self.charges = []

    def charge(self, provider, amount, t, note=""):
        self.charges.append((provider, amount, t, note))


def test_runtime_meters_misses_and_bills_in_sorted_order():
    dp = DataPlaneRuntime(FEDERATED, job_input_gb=10.0, dt_h=0.1)
    assert dp.active and dp.staging
    # gcp origin: r=0.5 -> k=0 misses, k=1 hits; sliced pools share the
    # base provider's meter
    assert dp.decide("gcp", 0)[1] is False
    assert dp.decide("gcp/4", 1)[1] is True
    assert dp.decide("aws", 0)[1] is False        # no cache: always miss
    led = _Ledger()
    total = dp.bill(led, now=1.0)
    # aws 10 GB * 0.09 + gcp 10 GB * 0.12, charged aws first (sorted)
    assert [c[0] for c in led.charges] == ["aws", "gcp"]
    assert total == pytest.approx(10.0 * 0.09 + 10.0 * 0.12)
    assert dp.pending == {}                       # drained
    assert dp.bill(led, now=2.0) == 0.0           # idempotent when empty
    assert dp.results()["cache_hit_fraction"] == pytest.approx(1 / 3, 4)


def test_runtime_outage_degrade_and_flush():
    dp = DataPlaneRuntime(FEDERATED, job_input_gb=10.0, dt_h=0.1)
    assert dp.eligible("azure") and dp.eligible("azure/2")
    dp.set_outage("azure", True)
    assert not dp.eligible("azure") and not dp.eligible("azure/2")
    assert dp.eligible("gcp")                     # others unaffected
    dp.set_outage("azure", False)
    assert dp.eligible("azure")
    dp.degrade_origin("aws", 0.5)
    dp.degrade_origin("aws", 0.5)                 # cumulative: 0.25
    assert dp.degrade["aws"] == pytest.approx(0.25)
    assert dp.current_epoch("azure") == 0
    dp.flush_cache("azure/4")                     # base-provider epoch
    assert dp.current_epoch("azure") == 1


def test_runtime_without_a_plane_is_inert():
    dp = DataPlaneRuntime(None, job_input_gb=25.0, dt_h=0.1)
    assert not dp.active and not dp.staging
    assert dp.eligible("azure")
    assert dp.decide("azure", 0) == (0, False)
    assert dp.bill(_Ledger(), 0.0) == 0.0
    assert dp.results() == {"egress_usd": 0.0, "stagein_hours": 0.0,
                            "cache_hit_fraction": 0.0}


# -- engine equivalence -----------------------------------------------------

def test_dataplane_engines_bit_identical():
    """Results AND canonical trace bytes identical across the
    solo-array reference, the solo-object engine and the batched
    engine on a campaign using outage + degrade + flush + caches."""
    spec = _dp_spec()
    res = assert_engines_equivalent(spec, 2021,
                                    engines=("object", "batched"))
    assert res.egress_usd > 0 and res.stagein_hours > 0
    assert 0.0 < res.cache_hit_fraction < 1.0
    jsonl = assert_traces_equivalent(spec, 2021,
                                     engines=("object", "batched"))
    kinds = [json.loads(l)["kind"] for l in jsonl.splitlines()]
    for kind in ("stagein", "stagein_done", "egress", "job_done"):
        assert kind in kinds, kind


def test_dataplane_timeline_events_fire_into_the_trace():
    res = run(_dp_spec(), seeds=2021, collect="trace")
    fired = [(e.event, e.payload.get("provider")) for e in res.trace.events
             if e.kind == "timeline" and "origin" in e.event
             or e.kind == "timeline" and e.event == "cache_flush"]
    assert fired == [("origin_outage_on", "azure"),
                     ("origin_outage_off", "azure"),
                     ("origin_degrade", "aws"),
                     ("cache_flush", "azure")]


def test_gate_only_and_zero_input_specs_stay_identical():
    """origins declared but job_input_gb=0: outage gating only, still
    bit-identical; egress accrues nothing."""
    spec = _dp_spec(name="dp-gate", job_input_gb=0.0, duration_h=20.0,
                    dt_h=0.1)
    res = assert_engines_equivalent(spec, 7, engines=("object", "batched"))
    assert_traces_equivalent(spec, 7, engines=("object", "batched"))
    assert res.egress_usd == 0.0 and res.stagein_hours == 0.0


def test_jax_dataplane_statistically_equivalent():
    """The compiled engine's staged-occupancy mixture stays inside the
    statistical bands — egress dollars included (STAT_BANDS gained
    ``egress_usd`` in PR 8)."""
    pytest.importorskip("jax")
    assert "egress_usd" in STAT_BANDS
    spec = paper_spec(name="dp-jax", duration_h=168.0, job_input_gb=25.0,
                      dataplane=FEDERATED)
    assert_statistically_equivalent([spec], list(range(6)))


# -- scenario library -------------------------------------------------------

def test_dataplane_scenarios_are_wellformed():
    specs = (data_heavy_mix() + origin_outage_grid()
             + egress_cost_scenarios() + [dataplane_burst()])
    assert len({s.name for s in specs}) == len(specs)
    for s in specs:
        assert lint_spec(s) == [], s.name
        s.validate()
    suite = {s.name for s in default_suite()}
    assert {"data025gb", "origin-azure-t60-d6", "egress-cached",
            "egress-nocache", "egress-flushed"} <= suite


# -- the committed golden campaign -----------------------------------------

def test_golden_dataplane_spec_file_is_current():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    assert spec == dataplane_burst()
    assert lint_spec(spec) == []


@pytest.fixture(scope="module")
def golden_result():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    return run(spec, seeds=2021)


def test_golden_dataplane_reproduces_pinned_totals(golden_result):
    res = golden_result
    for k, v in DATAPLANE_BURST_2021.items():
        assert res[k] == v, k
    # the data-plane events actually fired
    fired = [e["event"] for e in res.events_fired]
    for ev in ("origin_outage_on", "origin_outage_off", "origin_degrade",
               "cache_flush"):
        assert ev in fired, ev


def test_golden_dataplane_batched_lane_is_identical(golden_result):
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    batched = run(spec, seeds=2021, engine="batched")
    assert batched.to_dict() == golden_result.to_dict()
    assert list(batched.events_fired) == list(golden_result.events_fired)
