"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True on CPU; assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D,causal", [
    (1, 128, 128, 2, 2, 64, True),
    (2, 256, 256, 4, 2, 64, True),      # GQA
    (1, 128, 384, 2, 1, 128, False),    # cross-ish, MQA
    (2, 96, 160, 2, 2, 80, True),       #非-128-aligned (padding path)
])
def test_flash_attention(B, Sq, Skv, H, Hkv, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D)).astype(dtype)
    o = ops.flash_attention(q, k, v, causal=causal)
    G = H // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    orf = ref.flash_attention_ref(qr, kr, vr, causal=causal)
    orf = orf.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,di,N,bd,bs", [
    (1, 64, 32, 8, 32, 32),
    (2, 128, 64, 16, 32, 64),
    (1, 96, 48, 8, 16, 32),             # padding path
])
def test_mamba_scan(B, S, di, N, bd, bs, dtype):
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (B, S, di)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))).astype(dtype)
    bm = jax.random.normal(ks[2], (B, S, N)).astype(dtype)
    cm = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (di, N)))
    y = ops.mamba_scan(xc, dt, bm, cm, a, block_d=bd, block_s=bs)
    yr = ref.mamba_scan_ref(xc, dt, bm, cm, a)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,dqk,dv,bs", [
    (2, 128, 32, 32, 64),
    (4, 256, 64, 64, 128),
    (1, 128, 16, 48, 32),               # dqk != dv
])
def test_mlstm_chunk(BH, S, dqk, dv, bs, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (BH, S, dqk)).astype(dtype)
    k = jax.random.normal(ks[1], (BH, S, dqk)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, S, dv)).astype(dtype)
    li = (jax.random.normal(ks[3], (BH, S, 1)) - 5.0).astype(dtype)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (BH, S, 1))
                            + 3.0).astype(dtype)
    h = ops.mlstm_chunk(q, k, v, li, lf, block_s=bs)
    hr = ref.mlstm_ref(q, k, v, li, lf)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [
    (2, 64, 32, 64),
    (4, 128, 64, 96),
    (3, 72, 40, 56),                    # all-unaligned (padding path)
])
def test_moe_gmm(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, D)).astype(dtype)
    w = jax.random.normal(ks[1], (E, D, F)).astype(dtype)
    o = ops.moe_gmm(x, w, block_c=32, block_f=32, block_k=16)
    orf = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


def test_flash_matches_model_attention():
    """The kernel agrees with the model's chunked reference attention."""
    from repro.models.attention import chunked_attention
    B, S, H, D = 2, 128, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    o_kernel = ops.flash_attention(q, k, v, causal=True)
    pos = jnp.arange(S)
    o_model = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-5, atol=2e-5)


# -- campaign-sweep tick ops (core/sweep_jax.py) ---------------------------
# Integer semantics, so the wrappers (Pallas, interpret=True on CPU)
# must match the ref.py oracles *exactly* — these are the per-tick ops
# the jitted engine dispatches through kernels when on TPU.

def _counts(key, shape, hi=30):
    return jax.random.randint(key, shape, 0, hi, dtype=jnp.int32)


@pytest.mark.parametrize("R,C", [(8, 5), (20, 10), (3, 16), (64, 7)])
def test_campaign_preempt(R, C):
    ks = jax.random.split(KEY, 2)
    counts = _counts(ks[0], (R, C))
    tot = counts.sum(-1)
    # k spans the edge cases: 0, everything, and beyond-everything
    # (the allocator must clip; rows keep counts >= 0)
    k = jnp.concatenate([jnp.zeros(1, jnp.int32), tot[1:2],
                         tot[2:3] + 7,
                         jax.random.randint(ks[1], (R - 3,), 0, 40)
                         .astype(jnp.int32)]) if R >= 3 else tot
    killed = ops.campaign_preempt(counts, k, interpret=True)
    killed_ref = ref.campaign_preempt_ref(counts, k)
    np.testing.assert_array_equal(np.asarray(killed),
                                  np.asarray(killed_ref))
    kil = np.asarray(killed)
    cnt = np.asarray(counts)
    assert (kil >= 0).all() and (kil <= cnt).all()
    np.testing.assert_array_equal(
        kil.sum(-1), np.minimum(np.asarray(k), cnt.sum(-1)))


@pytest.mark.parametrize("B,G", [(4, 3), (16, 10), (9, 12)])
def test_campaign_match(B, G):
    ks = jax.random.split(KEY, 2)
    idle = _counts(ks[0], (B, G))
    k = jax.random.randint(ks[1], (B,), 0, 60).astype(jnp.int32)
    take = ops.campaign_match(idle, k, interpret=True)
    take_ref = ref.campaign_match_ref(idle, k)
    np.testing.assert_array_equal(np.asarray(take), np.asarray(take_ref))


@pytest.mark.parametrize("R,W", [(8, 16), (20, 16), (5, 9)])
def test_campaign_advance(R, W):
    ks = jax.random.split(KEY, 2)
    busy = _counts(ks[0], (R, W))
    wfin1 = jax.random.randint(ks[1], (R, 1), 1, W)
    fin_mask = jnp.arange(W)[None, :] >= wfin1     # suffix, like finmask
    adv, fin = ops.campaign_advance(busy, fin_mask, interpret=True)
    adv_ref, fin_ref = ref.campaign_advance_ref(busy, fin_mask)
    np.testing.assert_array_equal(np.asarray(adv), np.asarray(adv_ref))
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(fin_ref))
    # conservation: finished + surviving == starting population, minus
    # whatever sat unfinished at w = W-1 (the engine sizes W so that
    # column is always finished; here we account for it explicitly)
    lost = np.where(np.asarray(fin_mask)[:, -1], 0,
                    np.asarray(busy)[:, -1])
    np.testing.assert_array_equal(
        np.asarray(fin) + np.asarray(adv).sum(-1) + lost,
        np.asarray(busy).sum(-1))


@pytest.mark.parametrize("B,G,P", [(4, 3, 2), (16, 10, 3), (7, 12, 5)])
def test_campaign_bill(B, G, P):
    ks = jax.random.split(KEY, 3)
    live = _counts(ks[0], (B, G))
    rate = jax.random.uniform(ks[1], (B, G), minval=0.1, maxval=5.0)
    prov = jax.random.randint(ks[2], (G,), 0, P)
    onehot = jax.nn.one_hot(prov, P, dtype=jnp.float32)
    spent, by_prov = ops.campaign_bill(live, rate, onehot,
                                       interpret=True)
    spent_ref, by_prov_ref = ref.campaign_bill_ref(live, rate, onehot)
    np.testing.assert_allclose(np.asarray(spent), np.asarray(spent_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(by_prov),
                               np.asarray(by_prov_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(by_prov).sum(-1),
                               np.asarray(spent), rtol=1e-6, atol=1e-6)
