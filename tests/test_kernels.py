"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True on CPU; assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D,causal", [
    (1, 128, 128, 2, 2, 64, True),
    (2, 256, 256, 4, 2, 64, True),      # GQA
    (1, 128, 384, 2, 1, 128, False),    # cross-ish, MQA
    (2, 96, 160, 2, 2, 80, True),       #非-128-aligned (padding path)
])
def test_flash_attention(B, Sq, Skv, H, Hkv, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D)).astype(dtype)
    o = ops.flash_attention(q, k, v, causal=causal)
    G = H // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    orf = ref.flash_attention_ref(qr, kr, vr, causal=causal)
    orf = orf.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,di,N,bd,bs", [
    (1, 64, 32, 8, 32, 32),
    (2, 128, 64, 16, 32, 64),
    (1, 96, 48, 8, 16, 32),             # padding path
])
def test_mamba_scan(B, S, di, N, bd, bs, dtype):
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (B, S, di)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))).astype(dtype)
    bm = jax.random.normal(ks[2], (B, S, N)).astype(dtype)
    cm = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (di, N)))
    y = ops.mamba_scan(xc, dt, bm, cm, a, block_d=bd, block_s=bs)
    yr = ref.mamba_scan_ref(xc, dt, bm, cm, a)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,dqk,dv,bs", [
    (2, 128, 32, 32, 64),
    (4, 256, 64, 64, 128),
    (1, 128, 16, 48, 32),               # dqk != dv
])
def test_mlstm_chunk(BH, S, dqk, dv, bs, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (BH, S, dqk)).astype(dtype)
    k = jax.random.normal(ks[1], (BH, S, dqk)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, S, dv)).astype(dtype)
    li = (jax.random.normal(ks[3], (BH, S, 1)) - 5.0).astype(dtype)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (BH, S, 1))
                            + 3.0).astype(dtype)
    h = ops.mlstm_chunk(q, k, v, li, lf, block_s=bs)
    hr = ref.mlstm_ref(q, k, v, li, lf)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [
    (2, 64, 32, 64),
    (4, 128, 64, 96),
    (3, 72, 40, 56),                    # all-unaligned (padding path)
])
def test_moe_gmm(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, D)).astype(dtype)
    w = jax.random.normal(ks[1], (E, D, F)).astype(dtype)
    o = ops.moe_gmm(x, w, block_c=32, block_f=32, block_k=16)
    orf = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


def test_flash_matches_model_attention():
    """The kernel agrees with the model's chunked reference attention."""
    from repro.models.attention import chunked_attention
    B, S, H, D = 2, 128, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    o_kernel = ops.flash_attention(q, k, v, causal=True)
    pos = jnp.arange(S)
    o_model = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-5, atol=2e-5)
