"""Sharding-rule tests on an AbstractMesh (no devices needed): divisibility
fallback, megatron pairing, EP layout, cache rules."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.configs import get_config, get_reduced
from repro.launch import steps as st
from repro.sharding_ctx import abstract_mesh

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _specs(tree, mesh=MESH):
    return jax.tree.map(lambda s: s.spec, sh.param_shardings(tree, mesh))


def test_dense_megatron_pairing():
    cfg = get_config("yi-9b")
    ps = st.params_struct(cfg, jnp.bfloat16)
    specs = _specs(ps)
    blk = specs["stack"]["b0"]
    assert blk["ffn"]["wi"] == P(None, "data", "model")   # column-parallel
    assert blk["ffn"]["wo"] == P(None, "model", "data")   # row-parallel
    assert blk["mixer"]["wq"] == P(None, "data", "model")  # heads 32/16
    assert blk["mixer"]["wk"] == P(None, "data")           # kv=4: fallback
    assert blk["mixer"]["wo"] == P(None, "model", None, "data")


def test_whisper_head_fallback():
    cfg = get_config("whisper-large-v3")            # 20 heads % 16 != 0
    ps = st.params_struct(cfg, jnp.bfloat16)
    specs = _specs(ps)
    assert specs["stack"]["b0"]["mixer"]["wq"] == P(None, "data")
    # d_ff = 5120 still TP-shardable
    assert specs["stack"]["b0"]["ffn"]["wi"] == P(None, "data", "model")


def test_moe_expert_layout():
    cfg = get_config("kimi-k2-1t-a32b")
    ps = st.params_struct(cfg, jnp.bfloat16)
    specs = _specs(ps)
    blk = specs["stack"]["b0"]["ffn"]
    assert blk["wi"] == P(None, "data", None, "model")    # EP x TP-in-expert
    assert blk["wo"] == P(None, "data", "model")
    # router storage is FSDP/TP-sharded (tiny; gathered at use by GSPMD to
    # satisfy the shard_map's replicated in_spec)
    assert blk["router"] == P(None, "data", "model")


def test_embed_no_vocab_sharding():
    cfg = get_config("nemotron-4-15b")
    specs = _specs(st.params_struct(cfg, jnp.bfloat16))
    emb = specs["embed"]["table"]
    assert emb[0] is None                   # vocab gather stays local
    assert specs["lm_head"]["w"] == P("data", "model")


def test_opt_state_mirrors_params():
    cfg = get_reduced("yi-9b")
    ps = st.params_struct(cfg, jnp.bfloat16)
    os_ = st.opt_struct(cfg, ps)
    ospecs = jax.tree.map(lambda s: s.spec, sh.opt_shardings(os_, MESH))
    pspecs = _specs(ps)
    assert ospecs["mu"]["lm_head"]["w"] == pspecs["lm_head"]["w"]
    assert ospecs["master"]["lm_head"]["w"] == pspecs["lm_head"]["w"]
    assert ospecs["step"] == P()


def test_cache_rules_decode():
    cfg = get_config("kimi-k2-1t-a32b")
    cs = st.cache_struct(cfg, 128, 32768)
    specs = jax.tree.map(lambda s: s.spec, sh.cache_shardings(cs, MESH))
    k = specs["b0"]["k"]
    assert k[1] == "data" and k[2] == "model"   # batch->data, seq->model


def test_cache_rules_batch1_long():
    cfg = get_config("jamba-v0.1-52b")
    cs = st.cache_struct(cfg, 1, 524288)
    specs = jax.tree.map(lambda s: s.spec, sh.cache_shardings(cs, MESH))
    k = specs["b4"]["k"]                        # the attention sub-block
    assert k[1] is None                         # batch 1: unshardable
    assert k[2] == ("model", "data")            # seq over both axes


def test_batch_sharding_multipod():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = jax.tree.map(lambda s: s.spec,
                         sh.batch_shardings(batch, MESH3))
    assert specs["tokens"] == P(("pod", "data"))


def test_divisibility_fallback_never_crashes():
    """Every arch x both meshes: spec building must always succeed."""
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        ps = st.params_struct(get_config(arch), jnp.bfloat16)
        for mesh in (MESH, MESH3):
            sh.param_shardings(ps, mesh)
