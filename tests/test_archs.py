"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus prefill/decode agreement for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, REDUCED_SHAPE, RunConfig, get_reduced
from repro.data import make_batch
from repro.launch import steps as st
from repro.models import (decode_step, forward_loss, init_cache, init_params,
                          param_count, prefill)
from repro.optim import adamw_init


def _batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "targets": tok}
    if cfg.is_encdec:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.frontend is not None:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.frontend.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_no_nan(arch):
    cfg = get_reduced(arch)
    p = init_params(cfg, jax.random.PRNGKey(0))
    loss, parts = forward_loss(p, cfg, _batch(cfg), compute_dtype=jnp.float32)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(parts["ce"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_reduced(arch)
    run = RunConfig(model=cfg, shape=REDUCED_SHAPE,
                    compute_dtype="float32", remat=False)
    p = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(p)
    step = jax.jit(st.make_train_step(cfg, run))
    batch = _batch(cfg, B=REDUCED_SHAPE.global_batch,
                   S=REDUCED_SHAPE.seq_len)
    p1, opt1, m = step(p, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    assert int(opt1["step"]) == 1
    # params must actually move
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p1)))
    assert d > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_shapes(arch):
    cfg = get_reduced(arch)
    B = 2
    caches = init_cache(cfg, B, 16, jnp.float32)
    p = init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.ones((B, 1), jnp.int32)
    logits, caches1 = decode_step(p, cfg, caches, tok, jnp.int32(0),
                                  compute_dtype=jnp.float32)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size])).all()
    # cache trees keep structure and shapes
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches1)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", ["yi-9b", "xlstm-350m", "jamba-v0.1-52b",
                                  "minicpm3-4b"])
def test_prefill_decode_agree(arch):
    """logits(prefill of t0..tn) == logits(decode token-by-token)."""
    cfg = get_reduced(arch)
    B, S = 2, 8
    p = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.frontend is not None:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.frontend.num_patches, cfg.d_model))
        pytest.skip("vlm prefill prepends patches; decode-only path is "
                    "covered by test_decode_shapes")
    logits_pre, _ = prefill(p, cfg, batch, compute_dtype=jnp.float32)

    # token-by-token decode over a fresh cache
    caches = init_cache(cfg, B, S + 1, jnp.float32)
    lg = None
    for t in range(S):
        lg, caches = decode_step(p, cfg, caches, tok[:, t:t + 1],
                                 jnp.int32(t), compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_pre[:, 0]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_analytic(arch):
    """roofline.count_params (analytic) vs actual init — keeps the roofline's
    MODEL_FLOPS denominator honest."""
    from repro.analysis.roofline import count_params
    cfg = get_reduced(arch)
    p = init_params(cfg, jax.random.PRNGKey(0))
    actual = param_count(p)
    analytic, active = count_params(cfg)
    assert active <= analytic
    assert abs(actual - analytic) / actual < 0.06, \
        f"{arch}: analytic {analytic} vs actual {actual}"


def test_data_pipeline_deterministic():
    cfg = get_reduced("yi-9b")
    b1 = make_batch(cfg, REDUCED_SHAPE, 7, seed=3)
    b2 = make_batch(cfg, REDUCED_SHAPE, 7, seed=3)
    b3 = make_batch(cfg, REDUCED_SHAPE, 8, seed=3)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert not (b1["tokens"] == b3["tokens"]).all()
    assert int(b1["tokens"].max()) < cfg.vocab_size
