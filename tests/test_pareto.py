"""analysis/pareto.py — frontier correctness on hand-built dominance
fixtures, seed aggregation, measured-goodput augmentation, the
``scenarios.pareto_grid()`` candidate set, and the ``campaigns pareto``
CLI happy path (argument-error regressions live in test_traceops.py
with the other CLI coverage).
"""
import json

import pytest

from repro.analysis.pareto import (ParetoFrontier, ParetoPoint, frontier,
                                   goodput_rows)
from repro.campaigns import main as campaigns_main
from repro.core import scenarios
from repro.core.api import run, sweep as api_sweep
from repro.core.spec import lint_spec
from tests.test_events import SMALL_SPEC


def _row(scenario, cost, value, seed=2021, metric="accel_days"):
    return {"scenario": scenario, "seed": seed, "cost": cost,
            metric: value}


# -- dominance fixtures: the exact non-dominated set -----------------------

def test_frontier_exact_non_dominated_set():
    rows = [
        _row("cheap-slow", 100.0, 10.0),     # frontier
        _row("mid", 200.0, 30.0),            # frontier
        _row("dear-fast", 400.0, 45.0),      # frontier
        _row("dominated-1", 250.0, 25.0),    # mid beats it on both
        _row("dominated-2", 400.0, 30.0),    # mid: cheaper, same value
        _row("dominated-3", 200.0, 20.0),    # mid: same cost, more value
    ]
    front = frontier(rows)
    assert [p.scenario for p in front.frontier] \
        == ["cheap-slow", "mid", "dear-fast"]
    assert {p.scenario for p in front.dominated} \
        == {"dominated-1", "dominated-2", "dominated-3"}
    assert len(front.points) == 6            # dominated points are kept
    assert [p.scenario for p in front.points] \
        == sorted((p.scenario for p in front.points),
                  key=lambda n: next(q.cost for q in front.points
                                     if q.scenario == n))


def test_frontier_single_point_and_duplicates():
    assert frontier([_row("only", 10.0, 1.0)]).frontier[0].on_frontier
    # exact ties dominate nothing: both stay on the frontier
    front = frontier([_row("a", 10.0, 5.0), _row("b", 10.0, 5.0)])
    assert all(p.on_frontier for p in front.points)


def test_frontier_strictly_better_point_dominates_everything():
    rows = [_row("best", 1.0, 100.0)] \
        + [_row(f"w{i}", 1.0 + i, 100.0 - i) for i in range(1, 5)]
    front = frontier(rows)
    assert [p.scenario for p in front.frontier] == ["best"]
    assert len(front.dominated) == 4


def test_frontier_aggregates_seeds_by_mean():
    rows = [_row("a", 100.0, 10.0, seed=1), _row("a", 300.0, 30.0, seed=2),
            _row("b", 150.0, 15.0, seed=1), _row("b", 250.0, 35.0, seed=2)]
    front = frontier(rows)
    pa = next(p for p in front.points if p.scenario == "a")
    pb = next(p for p in front.points if p.scenario == "b")
    assert (pa.cost, pa.value, pa.seeds) == (200.0, 20.0, 2)
    assert (pb.cost, pb.value, pb.seeds) == (200.0, 25.0, 2)
    assert pb.on_frontier and not pa.on_frontier    # same cost, more value


def test_frontier_axis_selection_and_errors():
    rows = [_row("a", 10.0, 5.0, metric="jobs_finished")]
    front = frontier(rows, y="jobs_finished")
    assert front.y == "jobs_finished" and front.points[0].value == 5.0
    with pytest.raises(ValueError, match="no 'accel_days'"):
        frontier(rows)                       # default y missing from rows
    with pytest.raises(ValueError, match="at least one"):
        frontier([])


def test_frontier_serialization_and_table():
    front = frontier([_row("a", 10.0, 5.0), _row("b", 20.0, 1.0)])
    d = front.to_dict()
    assert json.loads(json.dumps(d)) == d
    assert d["points"][0] == {"scenario": "a", "cost": 10.0, "value": 5.0,
                              "seeds": 1, "on_frontier": True}
    table = front.table()
    assert "| * | a" in table and "|   | b" in table
    assert isinstance(front, ParetoFrontier)
    assert all(isinstance(p, ParetoPoint) for p in front.points)


def test_frontier_accepts_sweep_result():
    res = run([SMALL_SPEC], seeds=[2021, 2022])
    front = frontier(res)
    assert front.points[0].scenario == "small"
    assert front.points[0].seeds == 2
    assert front.points[0].on_frontier


# -- measured goodput from collected traces --------------------------------

def test_goodput_rows_augments_trace_sweeps():
    res = api_sweep([SMALL_SPEC], [2021], collect="trace")
    rows = goodput_rows(res)
    assert len(rows) == 1
    g = rows[0]["goodput_fraction"]
    assert 0.0 < g <= 1.0
    assert res.rows[0] is not rows[0]        # copied, not mutated
    assert "goodput_fraction" not in res.rows[0]
    front = frontier(rows, y="goodput_fraction")
    assert front.points[0].value == round(g, 6)


def test_goodput_rows_requires_traces():
    res = api_sweep([SMALL_SPEC], [2021])
    with pytest.raises(ValueError, match="collect"):
        goodput_rows(res)


# -- the candidate grid ----------------------------------------------------

def test_pareto_grid_composes_the_three_axes():
    grid = scenarios.pareto_grid()
    assert len(grid) == 12                   # 3 curves x 2 slices x 2 planes
    names = [s.name for s in grid]
    assert len(set(names)) == 12
    assert "par-flat-s1-nodata" in names     # the paper baseline corner
    assert "par-azure-squeeze-s4-federated" in names
    by_name = {s.name: s for s in grid}
    assert by_name["par-flat-s1-nodata"].gpu_slicing is None
    assert by_name["par-flat-s1-nodata"].dataplane is None
    assert by_name["par-drift-up-s4-federated"].gpu_slicing.slices == 4
    assert by_name["par-drift-up-s4-federated"].job_input_gb == 25.0
    for s in grid:
        assert lint_spec(s) == []            # every candidate lint-clean


def test_pareto_grid_axes_are_parameterizable():
    grid = scenarios.pareto_grid(curves=(None,), slices=(1,),
                                 planes=(None, "federated"))
    assert [s.name for s in grid] \
        == ["par-flat-s1-nodata", "par-flat-s1-federated"]


# -- CLI happy path --------------------------------------------------------

def test_cli_pareto_renders_frontier_and_json(tmp_path, capsys):
    a = tmp_path / "a.spec.json"
    b = tmp_path / "b.spec.json"
    a.write_text(SMALL_SPEC.to_json())
    import dataclasses
    b.write_text(dataclasses.replace(
        SMALL_SPEC, name="pricier", price_scale=1.5).to_json())
    out_json = str(tmp_path / "front.json")
    rc = campaigns_main(["pareto", str(a), str(b), "--seeds", "2021",
                         "--json", out_json])
    assert rc == 0
    out = capsys.readouterr().out
    assert "pareto frontier over 2 scenarios" in out
    assert "non-dominated: small" in out
    with open(out_json) as f:
        payload = json.load(f)
    assert payload["x"] == "cost" and payload["y"] == "accel_days"
    scen = {p["scenario"]: p for p in payload["points"]}
    # same campaign at 1.5x prices: strictly dominated
    assert scen["small"]["on_frontier"] is True
    assert scen["pricier"]["on_frontier"] is False
