"""The typed event-trace API (core/events.py) and its cross-engine
contract:

  * every trace event kind round-trips through dict and JSONL forms,
  * traces are in canonical (t, kind rank, entity id) order and their
    counts reconcile with the summary totals,
  * all three engines — solo object, solo array, batched sweep — emit
    BYTE-identical serialized traces at matching (spec, seed), pinned on
    hand-built specs (scheduled-completion and NAT walk modes) and on
    the golden paper replay at seed 2021 (sha256-pinned),
  * ``collect="trace"`` never changes the summary results (collection
    is RNG-free),
  * sweeps carry row-aligned per-lane trace handles,
  * the ``python -m repro.campaigns trace`` subcommand streams JSONL,
  * seed hygiene satellites: bool seeds are rejected everywhere float
    seeds already were, and empty sweeps raise instead of silently
    returning no rows.
"""
import hashlib
import json
import os

import pytest

from repro.core.api import run, sweep as api_sweep
from repro.core.events import (CampaignTrace, EgressBilled,
                               InstanceLaunched, InstancePreempted,
                               InstanceStopped, JobFinished, NatDrop,
                               PilotRegistered, PriceChanged,
                               StageInFinished, StageInStarted,
                               TimelineEventFired, TRACE_EVENT_KINDS,
                               _KIND_RANK, event_from_dict, event_to_dict)
from repro.core.simulator import SimConfig
from repro.core.spec import (CampaignSpec, CEOutage, PriceCurve,
                             PriceShift, SetTarget, paper_spec, run_solo)
from repro.campaigns import main as campaigns_main
from tests.engine_equivalence import (assert_traces_equivalent,
                                      serialized_trace)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "paper_replay.spec.json")

# sha256 of the canonical JSONL trace of the golden paper replay at seed
# 2021 — pinned so the trace schema (and the campaign it describes) can
# never drift silently; regenerate via
#   python -m repro.campaigns trace tests/data/paper_replay.spec.json
PAPER_TRACE_SHA256 = \
    "b547c83685583eeadb1c62e0e2d2ccfc9123e01dd6b9c4192e784a1ee1820ce6"

# a small campaign exercising scheduled-completion mode (lease 120 <
# every NAT timeout) with scale-downs, an outage, price events and
# preemptions — fast enough to run on all three engines
SMALL_SPEC = CampaignSpec(
    name="small", duration_h=24.0, budget=8000.0, min_queue=500,
    timeline=(SetTarget(0.0, 150), PriceShift(6.0, 1.2),
              CEOutage(10.0, 2.0, 80),
              PriceCurve(((14.0, 0.9), (20.0, 1.3)))))

# lease 300 s > Azure's 240 s NAT timeout: constant mid-job drops, which
# force the batched engine onto its per-tick walk path
NAT_SPEC = CampaignSpec(
    name="nat", duration_h=12.0, budget=5000.0, min_queue=400,
    lease_interval_s=300.0, timeline=(SetTarget(0.0, 120),))


@pytest.fixture(scope="module")
def small_trace():
    res, _ctl = run_solo(SMALL_SPEC, 7, collect="trace")
    return res


# -- schema + serialization ------------------------------------------------

def test_every_event_kind_roundtrips_through_dicts():
    events = [
        InstanceLaunched(0.25, 3, "azure", "eastus"),
        InstanceStopped(1.0, 3, "azure", "eastus"),
        InstancePreempted(2.5, 4, "gcp", "us-central1"),
        PilotRegistered(0.5, 1, 3, "azure"),
        NatDrop(0.75, 1, 3, "azure"),
        JobFinished(4.0, 17, 2),
        PriceChanged(6.0, 1.2),
        PriceChanged(6.0, 0.9, provider="azure", absolute=True),
        TimelineEventFired(0.0, "scale", {"target": 2000}),
        StageInStarted(0.5, 1, 25.0, False, "azure"),
        StageInFinished(0.75, 1),
        EgressBilled(1.0, "azure", 250.0, 21.75),
    ]
    assert {type(e).kind for e in events} == set(TRACE_EVENT_KINDS)
    for ev in events:
        d = event_to_dict(ev)
        assert d["kind"] == ev.kind
        json.dumps(d)                          # JSON-safe payloads only
        assert event_from_dict(d) == ev
    with pytest.raises(ValueError, match="unknown trace event kind"):
        event_from_dict({"kind": "nope", "t": 0.0})


def test_trace_jsonl_roundtrip_is_identity(small_trace):
    tr = small_trace.trace
    text = tr.to_jsonl()
    back = CampaignTrace.from_jsonl(text)
    assert back == tr
    assert back.to_jsonl() == text            # canonical bytes are stable
    # header carries the campaign identity, never the engine
    head = json.loads(text.splitlines()[0])
    assert head["name"] == "small" and head["seed"] == 7
    assert "engine" not in head


def test_trace_jsonl_rejects_malformed_streams(small_trace):
    text = small_trace.trace.to_jsonl()
    with pytest.raises(ValueError, match="empty trace"):
        CampaignTrace.from_jsonl("")
    with pytest.raises(ValueError, match="not a campaign trace"):
        CampaignTrace.from_jsonl('{"foo": 1}\n')
    bad = text.replace('"schema_version":1', '"schema_version":99')
    with pytest.raises(ValueError, match="schema_version"):
        CampaignTrace.from_jsonl(bad)
    truncated = "\n".join(text.splitlines()[:-10]) + "\n"
    with pytest.raises(ValueError, match="truncated"):
        CampaignTrace.from_jsonl(truncated)


def test_trace_canonical_order_and_filter(small_trace):
    tr = small_trace.trace
    keys = [(ev.t, _KIND_RANK[ev.kind]) for ev in tr]
    assert keys == sorted(keys)
    launches = tr.filter("launch")
    assert launches and all(isinstance(e, InstanceLaunched)
                            for e in launches)
    assert len(tr.filter("launch", "stop", "preempt", "pilot", "nat_drop",
                         "job_done", "price", "timeline")) == len(tr)
    with pytest.raises(ValueError, match="unknown trace event kinds"):
        tr.filter("bogus")


# -- trace <-> summary reconciliation --------------------------------------

def test_trace_counts_reconcile_with_summary(small_trace):
    res = small_trace
    c = res.trace.counts()
    assert c["job_done"] == res.jobs_finished
    assert c["nat_drop"] == res.nat_drops
    assert c["pilot"] == c["launch"]          # one pilot per instance
    # instance conservation: launched == stopped + preempted + still up
    still_up = sum(res["by_provider"].values())
    assert c["launch"] == c["stop"] + c["preempt"] + still_up
    # timeline-derived events mirror the events_fired provenance 1:1
    assert c["price"] + c["timeline"] == len(res.events_fired)


def test_collect_trace_never_changes_summary_results():
    plain, _ = run_solo(SMALL_SPEC, 7)
    traced, _ = run_solo(SMALL_SPEC, 7, collect="trace")
    assert plain.to_dict() == traced.to_dict()
    assert plain.trace is None and traced.trace is not None
    with pytest.raises(ValueError, match="unknown collect mode"):
        run(SMALL_SPEC, seeds=7, collect="everything")


# -- the cross-engine byte-identity contract -------------------------------

def test_three_engines_emit_identical_trace_bytes_scheduled_mode():
    assert_traces_equivalent(SMALL_SPEC, 7, engines=("object", "batched"))


def test_three_engines_emit_identical_trace_bytes_nat_mode():
    ref = assert_traces_equivalent(NAT_SPEC, 3,
                                   engines=("object", "batched"))
    tr = CampaignTrace.from_jsonl(ref)
    assert tr.counts()["nat_drop"] > 0        # the walk path actually ran


def test_paper_replay_trace_three_engines_and_sha_pinned():
    """The acceptance pin: at (golden paper spec, seed 2021) all three
    engines serialize the identical trace, and its digest never drifts."""
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    ref = assert_traces_equivalent(spec, 2021,
                                   engines=("batched", "object"))
    assert hashlib.sha256(ref.encode()).hexdigest() == PAPER_TRACE_SHA256
    tr = CampaignTrace.from_jsonl(ref)
    assert tr.counts()["job_done"] == 97852   # == PAPER_2021 pinned total


# -- sweeps carry per-lane trace handles -----------------------------------

def test_sweep_traces_row_aligned_and_lane_identical():
    specs = [SMALL_SPEC, paper_spec(name="tiny", duration_h=18.0,
                                    budget=6000.0, min_queue=500,
                                    timeline=(SetTarget(0.0, 100),))]
    sw = api_sweep(specs, [7, 8], collect="trace")
    assert sw.traces is not None and len(sw.traces) == len(sw.rows) == 4
    for row, tr in zip(sw.rows, sw.traces):
        assert (tr.name, tr.seed) == (row["scenario"], row["seed"])
        assert tr.counts()["job_done"] == row["jobs_finished"]
    # lane handle lookup, and lane bytes == solo bytes at the same pair
    tr = sw.trace_for("tiny", 8)
    assert tr.to_jsonl() == serialized_trace(specs[1], 8)
    with pytest.raises(KeyError):
        sw.trace_for("tiny", 99)
    # summary sweeps keep rows unchanged and refuse trace lookups
    plain = api_sweep(specs, [7])
    assert plain.traces is None
    with pytest.raises(ValueError, match="collect='summary'"):
        plain.trace_for("tiny", 7)


# -- the campaigns CLI ------------------------------------------------------

def test_campaigns_cli_trace_writes_jsonl(tmp_path, capsys):
    spec_path = tmp_path / "small.spec.json"
    spec_path.write_text(SMALL_SPEC.to_json())
    out_path = tmp_path / "trace.jsonl"
    rc = campaigns_main(["trace", str(spec_path), "--seed", "7",
                         "--out", str(out_path)])
    assert rc == 0
    tr = CampaignTrace.from_jsonl(out_path.read_text())
    assert tr.to_jsonl() == serialized_trace(SMALL_SPEC, 7)
    # no --out: the JSONL streams to stdout
    rc = campaigns_main(["trace", str(spec_path), "--seed", "7",
                         "--engine", "batched"])
    assert rc == 0
    stdout = capsys.readouterr().out
    assert CampaignTrace.from_jsonl(stdout) == tr


# -- seed / empty-input hygiene satellites ---------------------------------

def test_bool_seeds_rejected_everywhere():
    """``True`` is an ``Integral`` (and ``np.bool_`` registers with
    neither numbers ABC): both used to sail through the float guard and
    silently run seed 1."""
    import numpy as np
    for bad in (True, False, np.True_, np.False_):
        with pytest.raises(TypeError, match="bool"):
            run(SMALL_SPEC, seeds=bad)
        with pytest.raises(TypeError, match="bool"):
            run(SMALL_SPEC, seeds=[2021, bad])
        with pytest.raises(TypeError, match="bool"):
            api_sweep([SMALL_SPEC], [bad])
        with pytest.raises(TypeError):
            SimConfig.from_spec(SMALL_SPEC, bad)
    # the float rejection is unchanged
    with pytest.raises(TypeError, match="float"):
        run(SMALL_SPEC, seeds=2021.0)
    with pytest.raises(TypeError):
        SimConfig.from_spec(SMALL_SPEC, 2021.7)


def test_sweep_rejects_empty_specs_and_seeds():
    """sweep([], []) used to return an empty SweepResult silently."""
    with pytest.raises(ValueError, match="at least one spec"):
        api_sweep([], [2021])
    with pytest.raises(ValueError, match="at least one seed"):
        api_sweep([SMALL_SPEC], [])
    with pytest.raises(ValueError, match="at least one spec"):
        api_sweep([], [])
