"""Property tests for CampaignSpec (hypothesis): JSON round-trip is the
identity for arbitrary specs, random small specs — including the
PriceCurve / GpuSlicing surfaces — run bit-identically solo vs batched,
and the typed event traces they emit serialize to identical bytes on
every engine.  The strategies and the differential assertions live in
tests/engine_equivalence.py; this module degrades gracefully where
hypothesis is absent (the deterministic variants live in
tests/test_spec.py, tests/test_curve_slicing.py and
tests/test_events.py)."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st_  # noqa: F401  (re-export convention)

from hypothesis import given, settings

from repro.core.events import CampaignTrace
from repro.core.spec import CampaignSpec
from tests.engine_equivalence import (assert_engines_equivalent,
                                      assert_traces_equivalent,
                                      spec_strategy)

_specs = spec_strategy()


@settings(max_examples=50, deadline=None)
@given(_specs)
def test_spec_json_roundtrip_is_identity(spec):
    assert CampaignSpec.from_json(spec.to_json()) == spec


@settings(max_examples=8, deadline=None)
@given(_specs, st_.integers(0, 2 ** 16))
def test_random_specs_solo_vs_batched_bit_identical(spec, seed):
    assert_engines_equivalent(spec, seed, engines=("batched",))


@settings(max_examples=8, deadline=None)
@given(_specs, st_.integers(0, 2 ** 16))
def test_random_specs_trace_bytes_identical_and_roundtrip(spec, seed):
    """The trace contract swept over every spec surface: solo array and
    batched lanes serialize identical traces, and the JSONL form is a
    lossless round-trip."""
    ref = assert_traces_equivalent(spec, seed, engines=("batched",))
    tr = CampaignTrace.from_jsonl(ref)
    assert tr.to_jsonl() == ref
