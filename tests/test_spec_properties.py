"""Property tests for CampaignSpec (hypothesis): JSON round-trip is the
identity for arbitrary specs, and random small specs run bit-identically
solo vs batched.  Degrades gracefully where hypothesis is absent (the
deterministic variants live in tests/test_spec.py)."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st_

from hypothesis import given, settings

from repro.core.api import run
from repro.core.spec import (BudgetFloor, CampaignSpec, CapacityShift,
                             CEOutage, PriceShift, SetTarget, run_solo)
from tests.test_spec import _assert_results_match

_times = st_.integers(0, 120).map(lambda q: q * 0.25)
_events = st_.one_of(
    st_.builds(SetTarget, at_h=_times, target=st_.integers(0, 600)),
    st_.builds(CEOutage, at_h=_times,
               duration_h=st_.sampled_from([1.0, 2.0, 6.0]),
               resume_target=st_.integers(0, 400)),
    st_.builds(PriceShift, at_h=_times,
               factor=st_.sampled_from([0.5, 0.8, 1.25, 2.0])),
    st_.builds(CapacityShift, at_h=_times,
               factor=st_.sampled_from([0.25, 0.5, 1.5, 2.0])),
    st_.builds(BudgetFloor, at_h=_times,
               # ledger-threshold values only: the cap decision is then
               # charge-order independent (see sweep._check_thresholds)
               fraction=st_.sampled_from([0.05, 0.1, 0.2, 0.25, 0.5]),
               downscale_target=st_.integers(0, 300)))

_specs = st_.builds(
    CampaignSpec,
    name=st_.sampled_from(["a", "b"]),
    catalog=st_.sampled_from(["t4", "heterogeneous"]),
    capacity_scale=st_.sampled_from([0.5, 1.0]),
    spot=st_.booleans(),
    ondemand_fraction=st_.sampled_from([0.0, 0.25]),
    price_scale=st_.sampled_from([0.8, 1.0, 1.25]),
    budget=st_.sampled_from([2000.0, 8000.0, 1e9]),
    budget_floor_fraction=st_.sampled_from([0.1, 0.2, 0.25]),
    downscale_target=st_.integers(0, 300),
    duration_h=st_.sampled_from([12.0, 24.0, 30.0]),
    lease_interval_s=st_.sampled_from([120.0, 300.0]),
    job_wall_h=st_.sampled_from([1.0, 4.0]),
    min_queue=st_.sampled_from([500, 4000]),
    timeline=st_.lists(_events, max_size=5).map(tuple))


@settings(max_examples=50, deadline=None)
@given(_specs)
def test_spec_json_roundtrip_is_identity(spec):
    assert CampaignSpec.from_json(spec.to_json()) == spec


@settings(max_examples=8, deadline=None)
@given(_specs, st_.integers(0, 2 ** 16))
def test_random_specs_solo_vs_batched_bit_identical(spec, seed):
    solo, _ctl = run_solo(spec, seed)
    batched = run(spec, seeds=seed, engine="batched")
    _assert_results_match(batched.to_dict(), solo.to_dict())
    assert list(batched.events_fired) == list(solo.events_fired)
