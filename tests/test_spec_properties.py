"""Property tests for CampaignSpec (hypothesis): JSON round-trip is the
identity for arbitrary specs, and random small specs — including the
PriceCurve / GpuSlicing surfaces — run bit-identically solo vs batched.
The strategies and the differential assertion live in
tests/engine_equivalence.py; this module degrades gracefully where
hypothesis is absent (the deterministic variants live in
tests/test_spec.py and tests/test_curve_slicing.py)."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st_  # noqa: F401  (re-export convention)

from hypothesis import given, settings

from repro.core.spec import CampaignSpec
from tests.engine_equivalence import (assert_engines_equivalent,
                                      spec_strategy)

_specs = spec_strategy()


@settings(max_examples=50, deadline=None)
@given(_specs)
def test_spec_json_roundtrip_is_identity(spec):
    assert CampaignSpec.from_json(spec.to_json()) == spec


@settings(max_examples=8, deadline=None)
@given(_specs, st_.integers(0, 2 ** 16))
def test_random_specs_solo_vs_batched_bit_identical(spec, seed):
    assert_engines_equivalent(spec, seed, engines=("batched",))
