"""Array-engine correctness: the vectorized struct-of-arrays engine
(core/fleet.py) must be indistinguishable from the seed dataclass engine,
and its billing must conserve money (charged $ == instance-hours x rate).

These tests run without hypothesis; a hypothesis-powered randomized
schedule identity test rides along where hypothesis is installed.
"""
import numpy as np
import pytest

from repro.core.campaign import (RampStage, replay_paper_campaign,
                                 run_campaign)
from repro.core.overlay import Job
from repro.core.provider import heterogeneous_catalog, t4_catalog
from repro.core.simulator import CloudSimulator, SimConfig
from tests.engine_equivalence import assert_results_match


def _assert_results_match(a, o):
    """Shared comparison policy (tests/engine_equivalence.py) plus a
    both-ways key check: engine-vs-engine results must carry exactly the
    same keys (the harness's one-way check serves lane >= solo rows)."""
    assert set(a) == set(o)
    assert_results_match(a, o)


def test_paper_replay_engines_identical():
    """The flagship invariant: both engines consume the RNG identically
    and report matching totals for the paper replay at seed 2021."""
    res_a, ctl_a = replay_paper_campaign(seed=2021, engine="array")
    res_o, ctl_o = replay_paper_campaign(seed=2021, engine="object")
    _assert_results_match(res_a, res_o)
    # the operational sequence (ramp, outage, budget cap) happens at the
    # same ticks; only within-tick $ snapshots in alert text may differ
    ev_a = [l for l in ctl_a.log if l.startswith("t=")]
    ev_o = [l for l in ctl_o.log if l.startswith("t=")]
    assert ev_a == ev_o
    # and the replay still reproduces the paper's numbers
    assert 14500 <= res_a["accel_days"] <= 17500
    assert 52000 <= res_a["cost"] <= 60000
    assert 2.7 <= res_a["eflop_hours_fp32"] <= 3.4


def test_engines_identical_with_scale_events():
    """Scale-up/down/deprovision mid-run: totals still match exactly."""
    results = {}
    for engine in ("array", "object"):
        cfg = SimConfig(duration_h=30.0, seed=7, engine=engine)
        sim = CloudSimulator(t4_catalog(), 1e6, cfg)
        sim.at(0.0, lambda s: s.prov.scale_to(250, s.now))
        sim.at(5.0, lambda s: s.prov.scale_to(1200, s.now))
        sim.at(12.0, lambda s: s.prov.deprovision_all(s.now))
        sim.at(14.0, lambda s: s.prov.scale_to(600, s.now))
        sim.run_until(30.0)
        results[engine] = sim.results()
    _assert_results_match(results["array"], results["object"])


def test_engines_identical_nat_storm():
    """Misconfigured lease (>= Azure's 240 s NAT timeout) causes the
    paper's preemption storm in both engines identically."""
    results = {}
    for engine in ("array", "object"):
        cfg = SimConfig(duration_h=10.0, seed=3, lease_interval_s=300.0,
                        engine=engine)
        sim = CloudSimulator(t4_catalog(), 1e6, cfg)
        sim.at(0.0, lambda s: s.prov.scale_to(300, s.now))
        sim.run_until(10.0)
        results[engine] = sim.results()
    _assert_results_match(results["array"], results["object"])
    assert results["array"]["nat_drops"] > 0


def test_array_engine_money_conservation():
    """charged $ == sum over instances of billed hours x group spot rate,
    including instances compacted out of the arrays mid-run."""
    cfg = SimConfig(duration_h=48.0, seed=11, overhead_per_day=0.0)
    sim = CloudSimulator(t4_catalog(), 1e9, cfg)
    sim.at(0.0, lambda s: s.prov.scale_to(1500, s.now))
    sim.at(20.0, lambda s: s.prov.scale_to(400, s.now))
    sim.run_until(48.0)
    sim.settle()
    eng = sim.fleet
    hours = eng.billed_hours_by_group()
    by_provider = {}
    for gi in range(eng.G):
        name = eng.g_provider[gi].name
        by_provider[name] = by_provider.get(name, 0.0) \
            + hours[gi] * eng.rate_h(gi)
    for name, dollars in by_provider.items():
        assert dollars == pytest.approx(
            sim.ledger.by_provider.get(name, 0.0), rel=1e-9, abs=1e-6)
    assert sum(by_provider.values()) == pytest.approx(sim.ledger.spent,
                                                      rel=1e-9)


def test_array_engine_compaction_bounds_memory():
    """High-churn run: the instance arrays track the live fleet, not
    every instance ever created."""
    cfg = SimConfig(duration_h=72.0, seed=5, overhead_per_day=0.0)
    sim = CloudSimulator(t4_catalog(), 1e9, cfg)
    sim.at(0.0, lambda s: s.prov.scale_to(2000, s.now))
    sim.run_until(72.0)
    eng = sim.fleet
    assert eng.retired_count > 0, "churn should have retired instances"
    total_created = eng.n + eng.retired_count
    assert eng.n < total_created   # arrays actually shrank
    # fleet held at target (final tick's preemptions are replaced at the
    # next tick's maintain, so allow that one tick of slack)
    assert 1950 <= eng.total_running() <= 2000


def test_heterogeneous_catalog_campaign():
    """The §III mixed pool is expressible: cheapest-$/day SKUs fill first
    and EFLOP accounting weights each provider's GPU peak."""
    cat = heterogeneous_catalog()
    cfg = SimConfig(duration_h=24.0, seed=2, overhead_per_day=0.0)
    sim = CloudSimulator(cat, 1e9, cfg)
    sim.at(0.0, lambda s: s.prov.scale_to(3000, s.now))
    sim.run_until(24.0)
    res = sim.results()
    # price priority: the $2.7/day azure-m60 and $2.9/day azure-t4 SKUs
    # fill before any V100 capacity
    assert res["by_provider"]["azure-m60"] > 0
    assert res["by_provider"]["azure-t4"] > 0
    # weighted EFLOP accounting != homogeneous formula (M60s drag it down)
    homog = res["busy_hours"] * cfg.accel_tflops * 1e12 / 1e18
    assert res["eflop_hours_fp32"] != pytest.approx(homog, rel=1e-3)
    assert res["eflop_hours_fp32"] > 0


def test_heterogeneous_engines_identical():
    results = {}
    for engine in ("array", "object"):
        cfg = SimConfig(duration_h=12.0, seed=13, engine=engine)
        sim = CloudSimulator(heterogeneous_catalog(), 1e8, cfg)
        sim.at(0.0, lambda s: s.prov.scale_to(2500, s.now))
        sim.run_until(12.0)
        results[engine] = sim.results()
    _assert_results_match(results["array"], results["object"])


def test_array_ce_facade_views():
    """The ce/prov facades answer the same questions as the seed objects."""
    cfg = SimConfig(duration_h=4.0, seed=9)
    sim = CloudSimulator(t4_catalog(), 1e6, cfg)
    sim.at(0.0, lambda s: s.prov.scale_to(100, s.now))
    sim.run_until(4.0)
    st = sim.ce.stats()
    assert st["pilots_live"] == 100
    assert st["pilots_busy"] == sum(sim.ce.busy_by_provider().values())
    assert len(sim.ce.queue) == st["queued"]
    live = list(sim.prov.live_instances())
    assert len(live) == 100
    assert all(i.alive for i in live)
    g0 = sim.prov.groups[0]
    assert g0.provider.name == "azure"       # cheapest first
    assert 0.0 < g0.utilization() <= 1.0


def test_facade_submit_preserves_job_identity():
    """ce.submit through the array facade keeps the Job's id and
    checkpointed progress, like the object CE."""
    cfg = SimConfig(duration_h=2.0, seed=1)
    sim = CloudSimulator(t4_catalog(), 1e6, cfg)
    sim.ce.submit(Job(id=777, wall_h=2.0, done_h=1.5, attempts=3))
    eng = sim.fleet
    assert eng.j_id[0] == 777
    assert eng.j_done[0] == 1.5
    assert eng.j_attempts[0] == 3
    assert eng.next_job_id() == 778    # counter advanced past it
    with pytest.raises(PermissionError):
        sim.ce.submit(Job(id=1, wall_h=1.0, policy="not-icecube"))
    # the 1.5h-done job needs only 0.5h on a pilot: give it one tick
    sim.prov.scale_to(1, 0.0)
    sim.run_until(0.5)
    assert len(sim.ce.finished) == 1


def test_all_instances_includes_compacted():
    """prov.all_instances() stays complete after compaction (the object
    engine's retired-list semantics): summed billed hours x rate must
    reproduce the ledger, counting compacted instances."""
    cfg = SimConfig(duration_h=72.0, seed=5, overhead_per_day=0.0)
    sim = CloudSimulator(t4_catalog(), 1e9, cfg)
    sim.at(0.0, lambda s: s.prov.scale_to(2000, s.now))
    sim.run_until(72.0)
    sim.settle()
    eng = sim.fleet
    assert eng.retired_count > 0
    insts = list(sim.prov.all_instances())
    assert len(insts) == eng.n + eng.retired_count
    rate = {g.provider.name: eng.rate_h(gi)
            for gi, g in enumerate(sim.prov.groups)}
    dollars = sum((i.last_charged - i.started_at) * rate[i.provider]
                  for i in insts)
    assert dollars == pytest.approx(
        sum(sim.ledger.by_provider.get(p, 0.0)
            for p in rate), rel=1e-9)


def test_run_campaign_custom_ramp_and_outage():
    """run_campaign: custom catalogs/ramps are expressible and the
    outage + budget-cap machinery works outside the T4 replay."""
    ramp = (RampStage(0.0, 100), RampStage(4.0, 2000))
    cfg = SimConfig(duration_h=48.0, seed=6)
    res, ctl = run_campaign(heterogeneous_catalog(), budget=30000.0,
                            ramp=ramp, sim_cfg=cfg, outage=True)
    log = "\n".join(ctl.log)
    assert "scale_to(100)" in log and "scale_to(2000)" in log
    assert res["accel_hours"] > 0
    assert res["budget"]["overdraft"] == 0
    assert sum(res["by_provider"].values()) > 0
    # engine parameter honored
    res_o, _ = run_campaign(heterogeneous_catalog(), budget=30000.0,
                            ramp=ramp, sim_cfg=SimConfig(
                                duration_h=48.0, seed=6, engine="object"),
                            outage=True)
    _assert_results_match(res, res_o)


def test_job_ids_unique_across_requeues():
    """Seed bug: ensure_jobs derived IDs from queue+finished lengths,
    ignoring jobs attached to pilots -> collisions. Monotonic CE counter
    fixes it in both engines."""
    for engine in ("array", "object"):
        cfg = SimConfig(duration_h=12.0, seed=4, engine=engine)
        sim = CloudSimulator(t4_catalog(), 1e6, cfg)
        sim.at(0.0, lambda s: s.prov.scale_to(500, s.now))
        sim.run_until(12.0)
        if engine == "array":
            ids = sim.fleet.j_id[:sim.fleet.jn]
            assert len(np.unique(ids)) == len(ids)
        else:
            seen = [j.id for j in sim.ce.finished] \
                + [j.id for j in sim.ce.queue] \
                + [p.job.id for p in sim.ce.pilots.values()
                   if p.job is not None]
            assert len(set(seen)) == len(seen), engine
