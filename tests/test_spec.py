"""CampaignSpec / api.run contract tests (deterministic; a hypothesis
round-trip + equivalence property rides along in
tests/test_spec_properties.py where hypothesis is installed):

  * JSON round-trip is lossless, including inline provider catalogs and
    every timeline event kind,
  * the committed golden spec (tests/data/paper_replay.spec.json) equals
    paper_spec() and reproduces the seed-2021 replay totals bit-for-bit
    through the run() front door,
  * randomized specs — including the new timed PriceShift / BudgetFloor /
    CapacityShift events — run bit-identically solo vs batched, with
    matching events_fired provenance,
  * SweepResult.to_csv is deterministic and row-ordered,
  * the legacy Scenario / run_campaign / replay_paper_campaign shims
    keep working (deprecation-warned) with unchanged semantics.
"""
import json
import os
import warnings

import pytest

from repro.core.api import run, sweep as api_sweep
from repro.core.campaign import replay_paper_campaign, sweep_campaigns
from repro.core.provider import t4_catalog
from repro.core.spec import (BudgetFloor, CampaignResult, CampaignSpec,
                             CapacityShift, CEOutage, GpuSlicing,
                             PAPER_RAMP_EVENTS, PriceCurve, PriceShift,
                             SetTarget, paper_spec, run_solo)
from tests.engine_equivalence import (assert_engines_equivalent,
                                      assert_results_match,
                                      assert_sweep_equivalent)

# migrated call sites keep the historical underscore name
_assert_results_match = assert_results_match

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "paper_replay.spec.json")

# seed-2021 paper-replay totals (pinned; must never drift)
PAPER_2021 = {"cost": 56936.43, "accel_days": 16407.9,
              "eflop_hours_fp32": 3.007, "preemptions": 3716,
              "jobs_finished": 97852}


# -- serialization ---------------------------------------------------------

def test_json_roundtrip_every_event_kind_and_inline_catalog():
    spec = CampaignSpec(
        name="kitchen-sink", catalog="heterogeneous",
        providers=tuple(t4_catalog().values()),   # inline catalog wins
        capacity_scale=0.5, spot=False, ondemand_fraction=0.25,
        price_scale=1.25, budget=12345.67, budget_floor_fraction=0.25,
        downscale_target=321, duration_h=48.0, dt_h=0.25,
        lease_interval_s=90.0, job_wall_h=3.0, job_checkpoint_h=0.5,
        min_queue=1234, overhead_per_day=10.0, accel_tflops=7.5,
        gpu_slicing=GpuSlicing(slices=4, providers=("azure", "gcp"),
                               price_factor=1.1, tflops_factor=0.95),
        timeline=(SetTarget(0.0, 100), PriceShift(6.0, 1.3),
                  CapacityShift(12.0, 0.5), BudgetFloor(18.0, 0.1, 50),
                  CEOutage(24.0, 3.0, 77), SetTarget(30.0, 200),
                  PriceCurve(((32.0, 1.2), (40.0, 0.8))),
                  PriceCurve(((36.0, 1.5),), provider="azure/4")))
    again = CampaignSpec.from_json(spec.to_json())
    assert again == spec
    # and the dict form is pure JSON (no dataclasses smuggled through)
    d = json.loads(spec.to_json())
    assert d["timeline"][1] \
        == {"kind": "price_shift", "at_h": 6.0, "factor": 1.3}
    assert d["timeline"][6] == {"kind": "price_curve", "provider": None,
                                "points": [[32.0, 1.2], [40.0, 0.8]]}
    assert d["gpu_slicing"]["slices"] == 4
    assert again.gpu_slicing.providers == ("azure", "gcp")
    assert again.timeline[6].points == ((32.0, 1.2), (40.0, 0.8))


def test_inline_catalog_json_is_strict_json():
    """nat_idle_timeout_s defaults to inf; the serialized spec must still
    be standard JSON (no Python-only Infinity tokens) and round-trip."""
    spec = CampaignSpec(name="inline",
                        providers=tuple(t4_catalog().values()))
    text = spec.to_json()
    assert "Infinity" not in text
    # strict parse: reject non-standard constants outright
    strict = json.loads(text, parse_constant=lambda c: (_ for _ in ()
                                                        ).throw(
                            ValueError(c)))
    assert strict["providers"][1]["nat_idle_timeout_s"] is None
    again = CampaignSpec.from_json(text)
    assert again == spec
    assert again.providers[1].nat_idle_timeout_s == float("inf")


def test_run_treats_string_seed_as_one_seed():
    """seeds="2021" must not become the per-character sweep [2,0,2,1]."""
    spec = CampaignSpec(name="strseed", duration_h=12.0, budget=2000.0,
                        timeline=(SetTarget(0.0, 50),))
    res = run(spec, seeds="7")
    assert isinstance(res, CampaignResult)
    assert res.seed == 7


def test_from_json_rejects_unknowns():
    with pytest.raises(ValueError):
        CampaignSpec.from_dict({"schema_version": 99})
    with pytest.raises(ValueError):
        CampaignSpec.from_dict({"no_such_field": 1})
    with pytest.raises(ValueError):
        CampaignSpec.from_dict(
            {"timeline": [{"kind": "warp_drive", "at_h": 0.0}]})


def test_golden_paper_spec_file_is_current():
    with open(GOLDEN) as f:
        assert CampaignSpec.from_json(f.read()) == paper_spec()


# -- the flagship invariant: golden spec -> paper totals -------------------

@pytest.fixture(scope="module")
def paper_result():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    return run(spec, seeds=[2021])


def test_run_paper_spec_reproduces_pinned_totals(paper_result):
    res = paper_result
    assert isinstance(res, CampaignResult)
    for k, v in PAPER_2021.items():
        assert res[k] == v, k
    # typed accessors agree with the legacy mapping facade
    assert res.cost == res["cost"]
    assert res.to_dict()["budget"]["overdraft"] == 0
    cmp = res.compare_paper()
    assert abs(cmp["cost"]["err_pct"]) < 15
    assert 1.8 <= res.doubling_factor() <= 2.4
    # provenance: the full operational sequence was recorded
    events = [e["event"] for e in res.events_fired]
    assert events == ["scale"] * 6 + ["outage_on", "outage_off",
                                      "budget_floor"]
    assert any("budget floor hit" in line for line in res.log)
    assert len(res.history) == 336 * 4


def test_run_matches_deprecated_replay_shim(paper_result):
    with pytest.warns(DeprecationWarning):
        legacy, ctl = replay_paper_campaign(seed=2021)
    assert paper_result.to_dict() == legacy
    assert list(paper_result.log) == ctl.log


# -- randomized specs: solo == batched, including the new event kinds ------

def _random_specs():
    """A deliberately gnarly mix of catalogs, mixes and timed events.
    Floors sit on ledger-threshold values so the cap tick is
    engine-order independent."""
    return [
        CampaignSpec(
            name="shifty", duration_h=36.0, budget=9000.0,
            budget_floor_fraction=0.25, downscale_target=150,
            timeline=(SetTarget(0.0, 300), PriceShift(6.0, 1.4),
                      CapacityShift(10.0, 0.4), SetTarget(18.0, 500),
                      PriceShift(24.0, 0.7))),
        CampaignSpec(
            name="floor-rearm", duration_h=36.0, budget=6000.0,
            budget_floor_fraction=0.1, downscale_target=50,
            timeline=(SetTarget(0.0, 400), BudgetFloor(8.0, 0.5, 120),
                      SetTarget(12.0, 600), CEOutage(20.0, 4.0, 250))),
        CampaignSpec(
            name="hetero-squeeze", catalog="heterogeneous",
            duration_h=30.0, budget=40000.0, min_queue=6000,
            timeline=(SetTarget(0.0, 2500), CapacityShift(8.0, 0.3),
                      CapacityShift(16.0, 2.0), PriceShift(12.0, 1.1))),
        CampaignSpec(
            name="od-mix", ondemand_fraction=0.25, price_scale=0.9,
            duration_h=30.0, budget=15000.0,
            timeline=(SetTarget(0.0, 800), PriceShift(10.0, 2.0),
                      SetTarget(20.0, 200))),
        CampaignSpec(
            name="ondemand-storm", spot=False, duration_h=24.0,
            budget=30000.0, lease_interval_s=300.0,  # NAT-drop regime
            timeline=(SetTarget(0.0, 350), CEOutage(10.0, 2.0, 300))),
    ]


@pytest.mark.parametrize("spec", _random_specs(),
                         ids=lambda s: s.name)
def test_solo_vs_batched_bit_identical(spec):
    ref = assert_engines_equivalent(spec, 13, engines=("batched",))
    # the spec actually exercised its timeline
    assert len(ref.events_fired) >= len(spec.timeline)


def test_mixed_spec_sweep_batched_matches_sequential():
    """All the gnarly specs in ONE sweep call: lanes group into
    structurally-compatible engines and every row still matches the
    sequential reference, events_fired included."""
    specs = _random_specs()
    batched = assert_sweep_equivalent(specs, [3, 13])
    for row in batched.rows:
        assert row["events_fired"], "provenance must not be empty"


def test_sweep_campaigns_sequential_carries_events_fired():
    """Regression (satellite): the sequential engine used to discard the
    per-lane controller provenance; both engines now record it."""
    spec = CampaignSpec(name="tiny", duration_h=24.0, budget=3000.0,
                        timeline=(SetTarget(0.0, 120),
                                  CEOutage(6.0, 2.0, 80)))
    for engine in ("batched", "sequential"):
        sw = sweep_campaigns([spec], [5], engine=engine)
        (row,) = sw.rows
        kinds = [e["event"] for e in row["events_fired"]]
        assert kinds[:2] == ["scale", "outage_on"], engine
        assert "outage_off" in kinds, engine


# -- price/capacity shifts actually bite -----------------------------------

def test_price_shift_charges_more():
    base = CampaignSpec(name="flat", duration_h=24.0, budget=1e9,
                        overhead_per_day=0.0,
                        timeline=(SetTarget(0.0, 200),))
    shifted = CampaignSpec(name="spike", duration_h=24.0, budget=1e9,
                           overhead_per_day=0.0,
                           timeline=(SetTarget(0.0, 200),
                                     PriceShift(12.0, 3.0)))
    r0 = run(base, seeds=2)
    r1 = run(shifted, seeds=2)
    # 12h at 1x + 12h at 3x => roughly 2x the flat bill
    assert 1.7 * r0.cost < r1.cost < 2.3 * r0.cost
    assert r1.accel_hours == r0.accel_hours   # fleet behavior unchanged


def test_capacity_shift_limits_refill_without_evicting():
    spec = CampaignSpec(name="shrink", duration_h=24.0, budget=1e9,
                        timeline=(SetTarget(0.0, 1000),
                                  CapacityShift(8.0, 0.1)))
    res, ctl = run_solo(spec, 4)
    running = [t.running for t in res.history]
    assert max(running[:32]) >= 990         # filled before the shift
    # capacity shrink does not evict: fleet persists above the new cap
    assert running[33] > 500
    assert ctl.sim.prov.groups[0].region.capacity \
        == max(1, int(500 * 0.1))


# -- CSV artifact ----------------------------------------------------------

def test_sweep_csv_deterministic_and_sorted(tmp_path):
    specs = [CampaignSpec(name="b", duration_h=24.0, budget=4000.0,
                          timeline=(SetTarget(0.0, 100),)),
             CampaignSpec(name="a", duration_h=24.0, budget=4000.0,
                          timeline=(SetTarget(0.0, 150),))]
    sw = api_sweep(specs, [2, 1], engine="batched")
    text = sw.to_csv()
    assert text == sw.to_csv()              # byte-deterministic
    lines = text.strip().split("\n")
    assert lines[0].startswith("scenario,seed,")
    assert "budget.total_spent" in lines[0]
    assert "events_fired" in lines[0]
    # rows sorted by (scenario, seed) regardless of input order
    keys = [tuple(line.split(",")[:2]) for line in lines[1:]]
    assert keys == [("a", "1"), ("a", "2"), ("b", "1"), ("b", "2")]
    out = tmp_path / "sweep.csv"
    sw.to_csv(str(out))
    assert out.read_text() == text


# -- CLI -------------------------------------------------------------------

def test_campaigns_cli_run_and_show(tmp_path, capsys):
    from repro import campaigns as cli
    spec = CampaignSpec(name="cli-smoke", duration_h=12.0, budget=2000.0,
                        timeline=(SetTarget(0.0, 80),))
    spec_path = tmp_path / "smoke.spec.json"
    spec_path.write_text(spec.to_json())
    out_json = tmp_path / "out.json"
    assert cli.main(["run", str(spec_path), "--seeds", "3",
                     "--json", str(out_json)]) == 0
    payload = json.loads(out_json.read_text())
    assert payload["kind"] == "campaign"
    assert payload["results"]["cost"] > 0
    assert payload["spec"]["name"] == "cli-smoke"
    # sweep path + csv artifact
    out_csv = tmp_path / "out.csv"
    assert cli.main(["run", str(spec_path), "--seeds", "3,4",
                     "--csv", str(out_csv)]) == 0
    assert out_csv.read_text().startswith("scenario,seed,")
    assert cli.main(["show", str(spec_path)]) == 0
    assert "cli-smoke" in capsys.readouterr().out


def test_campaigns_cli_paper_emits_golden(tmp_path):
    from repro import campaigns as cli
    out = tmp_path / "paper.spec.json"
    assert cli.main(["paper", "--out", str(out)]) == 0
    assert out.read_text() == open(GOLDEN).read()


def test_campaigns_cli_lint(tmp_path, capsys):
    from repro import campaigns as cli
    good = tmp_path / "good.spec.json"
    good.write_text(paper_spec().to_json())
    assert cli.main(["lint", str(good)]) == 0
    assert "OK" in capsys.readouterr().out
    # a spec with unsorted/duplicate times, a negative target, a bad
    # catalog and a bogus curve provider lints dirty (exit 1), listing
    # every finding at once
    bad = CampaignSpec(
        name="bad", catalog="t4", budget=1000.0, duration_h=24.0,
        timeline=(SetTarget(12.0, 100), SetTarget(6.0, -5),
                  SetTarget(6.0, 7),
                  PriceCurve(((3.0, -2.0),), provider="warp-cloud")))
    bad_path = tmp_path / "bad.spec.json"
    bad_path.write_text(bad.to_json())
    assert cli.main(["lint", str(bad_path)]) == 1
    out = capsys.readouterr().out
    assert "not sorted" in out
    assert "negative target" in out
    assert "non-positive price factor" in out
    assert "unknown provider 'warp-cloud'" in out
    assert "share t=6.0" in out
    # unloadable file: reported, nonzero exit
    mangled = tmp_path / "mangled.spec.json"
    mangled.write_text("{\"no_such_field\": 1}")
    assert cli.main(["lint", str(mangled), str(good)]) == 1
    out = capsys.readouterr().out
    assert "cannot load spec" in out
    assert "OK" in out                    # the good file still lints


def test_campaigns_cli_lint_unknown_catalog(tmp_path, capsys):
    spec_d = CampaignSpec(name="x", duration_h=12.0,
                          timeline=()).to_dict()
    spec_d["catalog"] = "no-such-cloud"
    p = tmp_path / "cat.spec.json"
    p.write_text(json.dumps(spec_d))
    from repro import campaigns as cli
    assert cli.main(["lint", str(p)]) == 1
    assert "unknown catalog name" in capsys.readouterr().out


# -- float seeds are rejected, not truncated --------------------------------

def test_run_rejects_float_seeds():
    """Regression: seeds=2021.7 used to truncate to 2021 via int() and
    silently run a different campaign."""
    spec = CampaignSpec(name="floaty", duration_h=12.0, budget=2000.0,
                        timeline=(SetTarget(0.0, 50),))
    with pytest.raises(TypeError, match="silently truncated"):
        run(spec, seeds=2021.7)
    with pytest.raises(TypeError, match="integers"):
        run(spec, seeds=[3, 4.5])
    with pytest.raises(TypeError):
        run(spec, seeds=3.0)              # integral floats too: be strict
    import numpy as np
    with pytest.raises(TypeError):
        run(spec, seeds=np.float64(3))
    with pytest.raises(TypeError):
        api_sweep([spec, spec], [1.5, 2], engine="batched")
    with pytest.raises(TypeError):
        sweep_campaigns([spec], [2.5])
    # and the SimConfig derivation itself is guarded
    from repro.core.simulator import SimConfig
    with pytest.raises(TypeError):
        SimConfig.from_spec(spec, 7.2)
    # ints (and numpy ints) still work
    assert run(spec, seeds=np.int64(5)).seed == 5


# -- shims stay importable and equivalent ----------------------------------

def test_scenario_shim_bridges_to_spec():
    with pytest.warns(DeprecationWarning):
        from repro.core.scenarios import Scenario
        sc = Scenario()
    assert sc.to_spec() == paper_spec()
    with pytest.warns(DeprecationWarning):
        custom = Scenario(outage_at_h=60.0, outage_duration_h=12.0)
    tl = custom.to_spec().timeline
    assert tl[:-1] == PAPER_RAMP_EVENTS
    assert tl[-1] == CEOutage(60.0, 12.0, 1000)


def test_run_accepts_scenario_shims():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.scenarios import Scenario
        sc = Scenario(duration_h=24.0, outage=False, budget=5000.0)
        res = run(sc, seeds=9)
    solo, _ = run_solo(sc.to_spec(), 9)
    assert res.to_dict() == solo.to_dict()
