"""Math-equivalence tests for the model-level fast paths:

  * triangular-segmented chunked attention == unsegmented == plain sdpa
  * chunked mamba scan == sequential oracle
  * chunkwise-parallel mLSTM (model) == sequential oracle (exact stabilized)
  * sLSTM full-sequence == step-by-step decode
  * MoE capacity monotonicity (hypothesis)
"""
import pytest

pytest.importorskip("hypothesis")  # property tests degrade gracefully
import hypothesis.strategies as st_
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

KEY = jax.random.PRNGKey(7)


def test_segmented_attention_matches_unsegmented():
    from repro.models.attention import chunked_attention
    B, S, H, D = 2, 256, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S)
    seg = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, q_chunk=64)           # segments
    ref = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, q_chunk=64, _segment=False)
    np.testing.assert_allclose(np.asarray(seg), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    one = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, q_chunk=S)            # single sdpa
    np.testing.assert_allclose(np.asarray(seg), np.asarray(one),
                               rtol=2e-5, atol=2e-5)


def test_mamba_chunked_matches_sequential():
    from repro.configs.base import MambaConfig
    from repro.models.mamba import init_mamba, mamba_forward, mamba_decode, \
        init_mamba_state
    mcfg = MambaConfig(d_state=8, d_conv=4, expand=2)
    D, B, S = 16, 2, 48
    p = init_mamba(jax.random.PRNGKey(1), D, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
    y_chunk, (h_c, conv_c) = mamba_forward(p, x, mcfg, chunk=8)
    y_full, (h_f, _) = mamba_forward(p, x, mcfg, chunk=S)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_f),
                               rtol=1e-4, atol=1e-5)
    # decode continuation == full forward over S+1
    x1 = jax.random.normal(jax.random.PRNGKey(3), (B, 1, D))
    y_step, _ = mamba_decode(p, x1, {"h": h_c, "conv": conv_c}, mcfg)
    y_ext, _ = mamba_forward(p, jnp.concatenate([x, x1], 1), mcfg, chunk=49)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_ext[:, -1]),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_chunked_matches_stepwise():
    from repro.configs.base import XLSTMConfig
    from repro.models.xlstm import (init_mlstm, init_mlstm_state,
                                    mlstm_decode, mlstm_forward)
    xcfg = XLSTMConfig()
    D, B, S, H = 16, 2, 32, 4
    p = init_mlstm(jax.random.PRNGKey(4), D, H, xcfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, D)) * 0.5
    y_par, st_par = mlstm_forward(p, x, H, xcfg, chunk=8)
    # stepwise: decode token by token from fresh state
    st = init_mlstm_state(B, D, H, xcfg, jnp.float32)
    ys = []
    for t in range(S):
        y_t, st = mlstm_decode(p, x[:, t:t + 1], st, H, xcfg)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_par["C"]), np.asarray(st["C"]),
                               rtol=2e-3, atol=2e-4)


def test_slstm_forward_matches_decode():
    from repro.configs.base import XLSTMConfig
    from repro.models.xlstm import (init_slstm, init_slstm_state,
                                    slstm_decode, slstm_forward)
    xcfg = XLSTMConfig()
    D, B, S, H = 16, 2, 12, 4
    p = init_slstm(jax.random.PRNGKey(6), D, H, xcfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, D)) * 0.5
    y_full, _ = slstm_forward(p, x, H, xcfg)
    st = init_slstm_state(B, D, H, xcfg, jnp.float32)
    ys = []
    for t in range(S):
        y_t, st = slstm_decode(p, x[:, t:t + 1], st, H, xcfg)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(st_.integers(8, 4096), st_.integers(2, 64), st_.integers(1, 8))
def test_moe_capacity_properties(tokens, experts, k):
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity
    moe = MoEConfig(num_experts=experts, top_k=k, d_ff_expert=8)
    c = capacity(tokens, moe)
    assert c % 8 == 0 and c >= 8
    assert capacity(tokens * 2, moe) >= c          # monotone in tokens
