"""The one-registry refactor (core/timeline.py) + WorkloadCurve.

Covers the PR-6 tentpole end to end:

  * the drift guard: every registered event has serialization, lint,
    compile and ``apply`` coverage, and every compiled op's required
    ``EngineOps`` members exist on all engine adapters and provisioner
    facades (what ``python -m repro.campaigns lint --registry`` checks),
  * hypothesis strategies auto-derived from the registry, so the
    differential harness in tests/engine_equivalence.py sweeps newly
    registered events — WorkloadCurve included — without hand edits,
  * ``WorkloadCurve`` semantics: piecewise-constant request-rate factors
    scale the CE queue top-up level bit-identically in all three
    engines; starving factors cut busy hours / finished jobs while the
    fleet (accel hours) keeps running,
  * the committed golden workload campaign
    (tests/data/workload_curve.spec.json) pinned bit-for-bit at seed
    2021, with the batched lane byte-identical to the solo run.
"""
import json
import os

import pytest

from repro.campaigns import _registry_findings
from repro.core import timeline
from repro.core.api import run
from repro.core.scenarios import (WORKLOAD_CURVES, workload_burst,
                                  workload_curve_scenarios)
from repro.core.spec import (CampaignSpec, SetTarget, WorkloadCurve,
                             lint_spec, paper_spec)
from tests.engine_equivalence import (assert_engines_equivalent,
                                      assert_traces_equivalent)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "workload_curve.spec.json")

# seed-2021 workload-burst totals (pinned; must never drift)
WORKLOAD_BURST_2021 = {"cost": 65082.93, "accel_days": 15631.1,
                       "eflop_hours_fp32": 2.848, "preemptions": 3976,
                       "jobs_finished": 92601}


# -- registry completeness (the drift guard) -------------------------------

def test_registry_covers_every_event_kind():
    assert set(timeline.EVENT_KINDS) == set(timeline.REGISTRY)
    for kind, et in timeline.REGISTRY.items():
        assert et.kind == kind
        assert et.cls.kind == kind
        assert timeline.EVENT_KINDS[kind] is et.cls


def test_every_event_round_trips_json():
    """Serialization coverage: each kind's canonical sample survives
    dict -> JSON text -> dict -> event unchanged, and validates."""
    for kind, et in timeline.REGISTRY.items():
        sample = et.sample()
        timeline.validate_event(sample)
        d = timeline.event_to_dict(sample)
        assert d["kind"] == kind
        back = timeline.event_from_dict(json.loads(json.dumps(d)))
        assert back == sample, kind


def test_every_event_compiles_to_registered_ops():
    """Compile + apply coverage: each sample expands to (t, op, arg)
    tuples whose op kinds are declared by the event and handled by a
    registered OpSpec with a describe renderer."""
    for kind, et in timeline.REGISTRY.items():
        compiled = timeline.compile_event(et.sample())
        assert compiled, kind
        for t, op_kind, _arg in compiled:
            assert isinstance(t, float) or isinstance(t, int), kind
            assert op_kind in et.ops, kind
        for op_kind in et.ops:
            op = timeline.OPS[op_kind]
            assert op.event in timeline._DESCRIBE


def test_every_event_has_lint_coverage():
    """Lint coverage: each kind exposes lint + dead-event check times
    (the generic timeline lint consumes both)."""
    for kind, et in timeline.REGISTRY.items():
        sample = et.sample()
        assert et.lint(sample, "timeline[0]", None) == [], kind
        times = et.lint_times(sample)
        assert times and all(isinstance(t, float) for t in times), kind


def test_registry_findings_clean_on_the_real_engines():
    """Every registered event is implemented by the solo controller,
    the batched lane adapter, and both provisioner facades — the exact
    check ``python -m repro.campaigns lint --registry`` runs in CI."""
    assert _registry_findings() == []


def test_registry_findings_flag_an_incomplete_engine():
    class HalfEngine:
        budget_capped = False
        downscale_target = 0

        def scale_to(self, n):
            pass

    findings = timeline.registry_findings({"half": HalfEngine})
    assert findings
    assert any("set_workload_factor" in f for f in findings)
    assert any("HalfEngine" in f for f in findings)


def test_duplicate_registration_rejected():
    et = timeline.REGISTRY[timeline.SetTarget.kind]
    with pytest.raises(ValueError, match="duplicate event kind"):
        timeline.register_event(et)
    op = timeline.OPS["scale"]
    with pytest.raises(ValueError, match="duplicate op kind"):
        timeline.register_op(op)


def test_unknown_event_kind_raises():
    with pytest.raises(ValueError, match="unknown timeline event kind"):
        timeline.event_from_dict({"kind": "warp-drive", "at_h": 0.0})
    with pytest.raises(ValueError, match="unknown timeline event"):
        timeline.compile_event(object())


def test_event_strategies_cover_the_registry():
    st = pytest.importorskip("hypothesis.strategies")
    import hypothesis

    strategies = timeline.event_strategies(st)
    assert len(strategies) == len(timeline.REGISTRY)
    # the differential harness consumes them: its one-event strategy
    # generates every registered kind, WorkloadCurve included
    from tests.engine_equivalence import event_strategy
    kinds = set()

    @hypothesis.settings(max_examples=200, database=None)
    @hypothesis.given(event_strategy())
    def collect(ev):
        kinds.add(type(ev).kind)

    collect()
    assert kinds == set(timeline.REGISTRY)


# -- WorkloadCurve semantics -----------------------------------------------

def _short(name, *events, duration_h=48.0):
    # min_queue=500: shallow enough that a starving factor actually
    # drains the pre-existing backlog inside the campaign window
    return CampaignSpec(name=name, duration_h=duration_h, budget=1e9,
                        overhead_per_day=0.0, min_queue=500,
                        timeline=(SetTarget(0.0, 400), *events))


def test_workload_curve_starves_the_queue():
    """A near-zero request-rate factor idles pilots: busy hours and
    finished jobs drop while the fleet itself keeps running (accel
    hours and instance cost are untouched)."""
    base = run(_short("wl-base"), seeds=3)
    starved = run(_short("wl-starved",
                         WorkloadCurve(((12.0, 0.001),))), seeds=3)
    assert starved.accel_hours == base.accel_hours
    assert starved["cost"] == base["cost"]
    assert starved.busy_hours < 0.6 * base.busy_hours
    assert starved["jobs_finished"] < base["jobs_finished"]


def test_workload_factor_one_is_a_noop():
    base = run(_short("wl-base"), seeds=5)
    unity = run(_short("wl-unity", WorkloadCurve(((6.0, 1.0),))), seeds=5)
    assert unity.to_dict() == base.to_dict()


def test_workload_curve_bit_identical_across_engines():
    spec = _short("wl-eq", WorkloadCurve(((6.0, 0.02), (18.0, 1.0),
                                          (30.0, 0.25))),
                  duration_h=36.0)
    assert_engines_equivalent(spec, 7)
    assert_traces_equivalent(spec, 7, engines=("batched", "object"))


def test_workload_events_fire_into_the_trace():
    spec = _short("wl-trace", WorkloadCurve(((6.0, 0.5),)),
                  duration_h=12.0)
    res = run(spec, seeds=2, collect="trace")
    fired = [e for e in res.trace.events
             if e.kind == "timeline" and e.event == "workload"]
    assert [(e.t, e.payload["factor"]) for e in fired] == [(6.0, 0.5)]


def test_lint_flags_bad_workload_curves():
    spec = paper_spec(timeline=(SetTarget(0.0, 100),
                                WorkloadCurve(((10.0, -0.5),
                                               (900.0, 1.0)))))
    findings = lint_spec(spec)
    assert any("negative" in f and "-0.5" in f for f in findings)
    assert any("t=900.0" in f and "never" in f for f in findings)
    assert any("empty curve" in f for f in lint_spec(
        paper_spec(timeline=(WorkloadCurve(()),))))


# -- scenario library ------------------------------------------------------

def test_workload_scenarios_are_wellformed():
    specs = workload_curve_scenarios() + [workload_burst()]
    assert len({s.name for s in specs}) == len(specs)
    for s in specs:
        assert lint_spec(s) == [], s.name
        s.validate()
    assert set(WORKLOAD_CURVES) == {"diurnal", "flash-crowd"}


# -- the committed golden campaign -----------------------------------------

def test_golden_workload_spec_file_is_current():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    assert spec == workload_burst()
    assert lint_spec(spec) == []


@pytest.fixture(scope="module")
def golden_result():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    return run(spec, seeds=2021)


def test_golden_workload_reproduces_pinned_totals(golden_result):
    res = golden_result
    for k, v in WORKLOAD_BURST_2021.items():
        assert res[k] == v, k
    # the curve actually fired: three factor changes in the provenance
    wl = [e for e in res.events_fired if e["event"] == "workload"]
    assert [(e["t"], e["factor"]) for e in wl] \
        == [(0.0, 0.05), (120.0, 1.0), (132.0, 0.05)]


def test_golden_workload_batched_lane_is_identical(golden_result):
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    batched = run(spec, seeds=2021, engine="batched")
    assert batched.to_dict() == golden_result.to_dict()
    assert list(batched.events_fired) == list(golden_result.events_fired)
