"""Gradient-compression properties: bounded quantization error, error
feedback accumulates to zero bias, wire-byte accounting."""
import pytest

pytest.importorskip("hypothesis")  # property tests degrade gracefully
import hypothesis.strategies as st_
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.optim.compress import (dequantize_int8, quantize_int8,
                                  wire_bytes)


@settings(max_examples=50, deadline=None)
@given(st_.lists(st_.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                 max_size=64))
def test_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    # per-tensor int8: error <= scale/2 = max|x|/254 (+eps)
    bound = float(jnp.max(jnp.abs(x))) / 254.0 + 1e-12
    assert err.max() <= bound * 1.001


def test_zero_exact():
    q, s = quantize_int8(jnp.zeros((8,)))
    assert np.all(np.asarray(q) == 0)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)),
                                  np.zeros(8))


def test_error_feedback_reduces_bias():
    """With error feedback, the time-averaged dequantized signal converges
    to the true constant gradient (quantization bias cancels)."""
    g = jnp.asarray([0.013, -0.47, 0.29, 0.051])     # constant "gradient"
    resid = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(200):
        x = g + resid
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        resid = x - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 200), np.asarray(g),
                               rtol=1e-3, atol=1e-5)


def test_wire_bytes_favors_compression():
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    assert wire_bytes(tree, 2, compressed=True) < \
        wire_bytes(tree, 2, compressed=False)


def test_compressed_psum_multidevice():
    """End-to-end inside shard_map (subprocess keeps 1-device invariant of
    the main test process unnecessary: runs only if >1 device)."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum_mean
        from repro.sharding_ctx import make_mesh, shard_map
        mesh = make_mesh((4,), ("pod",))
        x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) / 7.0

        def f(xl):
            m, r = compressed_psum_mean(xl[0], "pod")
            return m[None]

        y = shard_map(f, mesh=mesh, in_specs=P("pod"),
                      out_specs=P("pod"), check_replication=False)(x)
        want = x.mean(0)
        err = np.abs(np.asarray(y[0]) - np.asarray(want)).max()
        assert err < np.abs(np.asarray(x)).max() / 100, err
        print("COMPRESS OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COMPRESS OK" in r.stdout
