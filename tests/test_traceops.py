"""Trace analytics (core/traceops.py) — the PR's differential tier:

  * streamed collection (``collect="stream"`` through a TraceSink) is
    BYTE-identical to the in-memory ``collect="trace"`` path on all
    three trace engines — pinned on hand-built specs, on all four
    committed goldens, and on the sha256-pinned paper replay,
  * the streaming recorder's window discipline (monotone t, canonical
    per-window ordering) and sink lifecycle are enforced,
  * ``diff_traces`` is empty on self-comparison and detects any
    single-event drop/retime/retarget with the correct divergence t —
    unit fixtures plus a seeded-fuzz tier that upgrades to hypothesis
    where installed (test_sorted_ops.py pattern),
  * the paper-replay vs ``outage_burst()`` diff at seed 2021 is pinned
    as a committed golden (tests/data/paper_vs_outage.diff.json),
  * CLI: ``campaigns diff`` exits 0/1/2 correctly, ``campaigns trace
    --engine jax`` exits 2 with the friendly no-trace line, and
    ``campaigns pareto`` argument errors are regression-covered.
"""
import dataclasses
import gzip
import hashlib
import json
import os
import random

import pytest

from repro.campaigns import main as campaigns_main
from repro.core.api import run
from repro.core.events import CampaignTrace, event_to_dict
from repro.core.spec import CampaignSpec, run_solo
from repro.core.traceops import (CallbackSink, JsonlStreamSink,
                                 StreamingRecorder, TraceDigest,
                                 diff_traces, load_trace, trace_digest)
from tests.engine_equivalence import (HAVE_HYPOTHESIS,
                                      assert_stream_equivalent,
                                      serialized_trace)
from tests.test_events import (NAT_SPEC, PAPER_TRACE_SHA256, SMALL_SPEC)

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN_SPECS = ("paper_replay", "curve_sliced", "workload_curve",
                "dataplane", "outage_burst")
TRACE_ENGINES = ("array", "object", "batched")


def _golden_spec(name: str) -> CampaignSpec:
    with open(os.path.join(DATA, f"{name}.spec.json")) as f:
        return CampaignSpec.from_json(f.read())


def _mutate(trace: CampaignTrace, events) -> CampaignTrace:
    return dataclasses.replace(trace, events=tuple(events))


@pytest.fixture(scope="module")
def small_trace():
    res, _ctl = run_solo(SMALL_SPEC, 7, collect="trace")
    return res.trace


# -- streamed == built: the byte-identity contract -------------------------

def test_stream_equals_trace_bytes_scheduled_mode(tmp_path):
    assert_stream_equivalent(SMALL_SPEC, 7, tmp_path,
                             engines=TRACE_ENGINES)


def test_stream_equals_trace_bytes_nat_mode(tmp_path):
    assert_stream_equivalent(NAT_SPEC, 11, tmp_path,
                             engines=TRACE_ENGINES)


@pytest.mark.parametrize("golden", GOLDEN_SPECS)
def test_stream_equivalent_on_committed_goldens(golden, tmp_path):
    """All three trace engines stream every committed golden campaign
    byte-identically to the in-memory trace; the paper replay's sha256
    must be the pinned one — the sink path can never drift the
    canonical bytes."""
    spec = _golden_spec(golden)
    ref = assert_stream_equivalent(spec, 2021, tmp_path,
                                   engines=TRACE_ENGINES)
    if golden == "paper_replay":
        assert hashlib.sha256(ref.encode()).hexdigest() \
            == PAPER_TRACE_SHA256


def test_stream_through_plain_and_gzip_sinks_roundtrips(tmp_path):
    """A streamed file re-reads (load_trace, .gz transparently) into a
    trace equal to the in-memory one, and streaming never changes the
    summary results."""
    ref = run(SMALL_SPEC, seeds=7, collect="trace")
    for fname in ("t.jsonl", "t.jsonl.gz"):
        path = str(tmp_path / fname)
        res = run(SMALL_SPEC, seeds=7, collect="stream",
                  sink=JsonlStreamSink(path))
        assert res.to_dict() == ref.to_dict()
        got = load_trace(path)
        assert got == ref.trace
        assert diff_traces(ref.trace, got).identical


def test_callback_sink_sees_canonical_event_order():
    seen = []
    headers = []
    sink = CallbackSink(seen.append, on_close=headers.append)
    res = run(SMALL_SPEC, seeds=7, collect="stream", sink=sink)
    ref = run(SMALL_SPEC, seeds=7, collect="trace").trace
    assert seen == list(ref.events)
    assert sink.events_seen == len(ref.events)
    assert headers == [{"schema_version": 1, "kind": "campaign_trace",
                        "name": SMALL_SPEC.name, "seed": 7,
                        "duration_h": SMALL_SPEC.duration_h,
                        "dt_h": SMALL_SPEC.dt_h,
                        "events": len(ref.events)}]
    assert res.trace is None


# -- streaming recorder discipline -----------------------------------------

def test_streaming_recorder_rejects_out_of_order_time():
    rec = StreamingRecorder(CallbackSink(lambda ev: None))
    rec.launched(2.0, 1, "azure", "eastus")
    rec.launched(3.0, 2, "azure", "eastus")    # window advances
    with pytest.raises(ValueError, match="out-of-order"):
        rec.launched(2.5, 3, "azure", "eastus")


def test_streaming_recorder_finish_is_single_shot(tmp_path):
    path = str(tmp_path / "t.jsonl")
    rec = StreamingRecorder(JsonlStreamSink(path))
    rec.launched(0.0, 1, "azure", "eastus")
    n = rec.finish("x", 1, 1.0, 0.25)
    assert n == 1
    with pytest.raises(ValueError, match="finished"):
        rec.finish("x", 1, 1.0, 0.25)
    with pytest.raises(ValueError, match="finished"):
        rec.launched(1.0, 2, "azure", "eastus")
    # the finished file is a valid one-event trace
    t = load_trace(path)
    assert len(t.events) == 1 and t.name == "x"


def test_empty_campaign_streams_a_valid_header_only_trace(tmp_path):
    path = str(tmp_path / "empty.jsonl.gz")
    rec = StreamingRecorder(JsonlStreamSink(path))
    assert rec.finish("empty", 5, 2.0, 0.5) == 0
    t = load_trace(path)
    assert t.events == () and t.seed == 5 and t.duration_h == 2.0


def test_stream_mode_argument_validation(tmp_path):
    with pytest.raises(ValueError, match="sink"):
        run(SMALL_SPEC, seeds=7, collect="stream")          # no sink
    with pytest.raises(ValueError, match="stream"):
        run(SMALL_SPEC, seeds=7,
            sink=CallbackSink(lambda ev: None))             # sink w/o mode
    with pytest.raises(ValueError, match="ONE campaign"):
        run(SMALL_SPEC, seeds=[7, 8], collect="stream",
            sink=CallbackSink(lambda ev: None))             # sweep-shaped
    with pytest.raises(ValueError, match="statistical"):
        run(SMALL_SPEC, seeds=7, engine="jax", collect="stream",
            sink=CallbackSink(lambda ev: None))             # no jax stream


# -- diff_traces: self-identity and mutation detection ---------------------

def test_diff_self_identity(small_trace):
    d = diff_traces(small_trace, small_trace)
    assert d.identical
    assert d.divergence is None and not d.header_changes
    assert d.by_kind == {} and d.entities == {}
    assert all(v == 0 for v in d.deltas().values())


def test_diff_detects_single_event_drop(small_trace):
    i = len(small_trace.events) // 2
    evs = list(small_trace.events)
    dropped = evs.pop(i)
    d = diff_traces(small_trace, _mutate(small_trace, evs))
    assert not d.identical
    assert d.divergence.index == i
    assert d.divergence.t == dropped.t
    assert d.digest_b.events == d.digest_a.events - 1


def test_diff_detects_retime(small_trace):
    evs = list(small_trace.events)
    i = next(j for j, ev in enumerate(evs) if ev.kind == "preempt")
    evs[i] = dataclasses.replace(evs[i], t=evs[i].t + 0.25)
    d = diff_traces(small_trace, _mutate(small_trace, evs))
    assert not d.identical
    assert d.divergence.index <= i
    assert d.divergence.t <= evs[i].t
    assert d.by_kind["preempt"]["changed"] >= 1
    assert d.entities["instances"]["changed"] >= 1


def test_diff_detects_retarget(small_trace):
    evs = list(small_trace.events)
    i = next(j for j, ev in enumerate(evs) if ev.kind == "job_done")
    evs[i] = dataclasses.replace(evs[i], job=10 ** 6)
    d = diff_traces(small_trace, _mutate(small_trace, evs))
    assert not d.identical
    assert d.divergence.index == i
    assert d.divergence.t == small_trace.events[i].t
    assert d.entities["jobs"]["added"] == 1
    assert d.entities["jobs"]["removed"] == 1


def test_diff_reports_header_changes(small_trace):
    other = dataclasses.replace(small_trace, name="renamed", seed=99)
    d = diff_traces(small_trace, other)
    assert not d.identical
    assert d.divergence is None                 # events still equal
    assert d.header_changes == {"name": ("small", "renamed"),
                                "seed": (7, 99)}


def test_diff_digest_reconciles_with_trace_counts(small_trace):
    dig = trace_digest(small_trace)
    counts = small_trace.counts()
    assert dig.events == len(small_trace.events)
    assert dig.launches == counts.get("launch", 0)
    assert dig.preemptions == counts.get("preempt", 0)
    assert dig.jobs_finished == counts.get("job_done", 0)
    assert dig.accel_hours > 0
    assert isinstance(dig, TraceDigest)


def test_diff_to_dict_is_json_stable(small_trace):
    evs = list(small_trace.events)[:-1]
    d = diff_traces(small_trace, _mutate(small_trace, evs))
    payload = json.dumps(d.to_dict(), sort_keys=True)
    assert json.loads(payload) == d.to_dict()
    assert d.to_dict()["identical"] is False
    assert d.to_dict()["divergence"]["index"] == len(evs)


# -- the committed golden diff: paper replay vs outage_burst ---------------

def test_outage_burst_matches_committed_spec():
    from repro.core.scenarios import outage_burst
    assert outage_burst().to_dict() == _golden_spec("outage_burst").to_dict()


def test_paper_vs_outage_diff_matches_golden():
    """The full paper-replay vs outage-burst diff at seed 2021 is
    byte-stable: divergence point, per-kind counts and digest deltas
    can never drift silently.  Regenerate (deliberately) via the
    snippet in tests/data/paper_vs_outage.diff.json's git history."""
    ta = run(_golden_spec("paper_replay"), seeds=2021, engine="batched",
             collect="trace").trace
    tb = run(_golden_spec("outage_burst"), seeds=2021, engine="batched",
             collect="trace").trace
    d = diff_traces(ta, tb)
    with open(os.path.join(DATA, "paper_vs_outage.diff.json")) as f:
        golden = json.load(f)
    assert not d.identical
    assert d.divergence.t == 60.0              # the outage instant
    assert d.to_dict() == golden


# -- property tier: seeded fuzz always, hypothesis where installed ---------

def _random_mutation(rng, trace):
    """One random drop/retime/retarget; returns (mutated, index)."""
    evs = list(trace.events)
    i = rng.randrange(len(evs))
    op = rng.choice(["drop", "retime", "retarget"])
    if op == "drop":
        evs.pop(i)
    elif op == "retime":
        evs[i] = dataclasses.replace(evs[i], t=evs[i].t + 1000.0)
    else:
        ev = evs[i]
        for attr in ("instance", "pilot", "job"):
            if hasattr(ev, attr):
                evs[i] = dataclasses.replace(
                    ev, **{attr: getattr(ev, attr) + 10 ** 7})
                break
        else:
            evs.pop(i)                          # no entity: drop instead
    return _mutate(trace, evs), i


def _check_mutation_detected(trace, mutated, i):
    d = diff_traces(trace, mutated)
    assert not d.identical
    assert d.divergence is not None
    assert d.divergence.index <= i
    # the reported first-divergence time is the mutated position's
    # canonical time (or earlier, when the reorder bubbles it up)
    assert d.divergence.t <= max(ev.t for ev in trace.events)


def test_diff_seeded_fuzz_identity_and_mutations(small_trace):
    """Deterministic fallback tier: runs everywhere, hypothesis or
    not."""
    rng = random.Random(20210807)
    assert diff_traces(small_trace, small_trace).identical
    for _ in range(25):
        mutated, i = _random_mutation(rng, small_trace)
        _check_mutation_detected(small_trace, mutated, i)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, HealthCheck
    import hypothesis.strategies as st
    from tests.engine_equivalence import spec_strategy

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(spec=spec_strategy(), seed=st.integers(0, 2 ** 20),
           data=st.data())
    def test_diff_property_identity_and_mutation(spec, seed, data):
        res, _ctl = run_solo(spec, seed, collect="trace")
        t = res.trace
        assert diff_traces(t, t).identical
        if not t.events:
            return
        mut_seed = data.draw(st.integers(0, 2 ** 31))
        mutated, i = _random_mutation(random.Random(mut_seed), t)
        _check_mutation_detected(t, mutated, i)
else:                                            # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed; seeded-fuzz "
                             "tier above covers the property")
    def test_diff_property_identity_and_mutation():
        pass


# -- CLI regressions -------------------------------------------------------

@pytest.fixture()
def small_spec_file(tmp_path):
    p = tmp_path / "small.spec.json"
    p.write_text(SMALL_SPEC.to_json())
    return str(p)


def test_cli_trace_jax_engine_exits_2_with_friendly_line(
        small_spec_file, capsys):
    rc = campaigns_main(["trace", small_spec_file, "--engine", "jax"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "statistical" in err and "trace-capable" in err


def test_cli_trace_stream_flag_writes_identical_bytes(
        small_spec_file, tmp_path, capsys):
    a = str(tmp_path / "a.jsonl")
    b = str(tmp_path / "b.jsonl")
    assert campaigns_main(["trace", small_spec_file, "--seed", "7",
                           "--out", a]) == 0
    assert campaigns_main(["trace", small_spec_file, "--seed", "7",
                           "--out", b, "--stream"]) == 0
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()
    assert "(streamed)" in capsys.readouterr().err


def test_cli_trace_stream_without_out_exits_2(small_spec_file, capsys):
    rc = campaigns_main(["trace", small_spec_file, "--stream"])
    assert rc == 2
    assert "--out" in capsys.readouterr().err


def test_cli_diff_exit_codes_and_json(small_spec_file, tmp_path, capsys):
    a = str(tmp_path / "a.jsonl")
    b = str(tmp_path / "b.jsonl.gz")
    campaigns_main(["trace", small_spec_file, "--seed", "7", "--out", a])
    campaigns_main(["trace", small_spec_file, "--seed", "8", "--out", b])
    capsys.readouterr()

    assert campaigns_main(["diff", a, a]) == 0
    assert "identical" in capsys.readouterr().out

    out_json = str(tmp_path / "d.json")
    assert campaigns_main(["diff", a, b, "--json", out_json]) == 1
    assert "first divergence" in capsys.readouterr().out
    with open(out_json) as f:
        payload = json.load(f)
    assert payload["identical"] is False
    assert payload["divergence"]["index"] >= 0

    # --json - : machine payload on stdout, summary on stderr
    assert campaigns_main(["diff", a, b, "--json", "-"]) == 1
    cap = capsys.readouterr()
    assert json.loads(cap.out)["kind"] == "trace_diff"
    assert "first divergence" in cap.err


def test_cli_diff_bad_file_exits_2(tmp_path, capsys):
    missing = str(tmp_path / "nope.jsonl")
    rc = campaigns_main(["diff", missing, missing])
    assert rc == 2
    assert "error:" in capsys.readouterr().err


def test_cli_diff_non_trace_file_exits_2(tmp_path, capsys):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "not_a_trace"}\n')
    rc = campaigns_main(["diff", str(bad), str(bad)])
    assert rc == 2
    assert "error:" in capsys.readouterr().err


def test_cli_pareto_bad_axis_exits_2(small_spec_file, capsys):
    rc = campaigns_main(["pareto", small_spec_file, "--y", "nonsense"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "error:" in err and "nonsense" in err
