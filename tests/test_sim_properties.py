"""Simulator/provisioner conservation properties (hypothesis).

busy_hours <= accel_hours caught a real accounting bug during development
(pilots surviving their stopped instances); these pin the whole family.
"""
import pytest

pytest.importorskip("hypothesis")  # property tests degrade gracefully
import hypothesis.strategies as st_
from hypothesis import given, settings

from repro.core.budget import BudgetLedger
from repro.core.provider import t4_catalog
from repro.core.provisioner import MultiCloudProvisioner
from repro.core.simulator import CloudSimulator, SimConfig


@settings(max_examples=15, deadline=None)
@given(st_.lists(st_.tuples(st_.floats(0.5, 6.0), st_.integers(0, 1500)),
                 min_size=1, max_size=6),
       st_.integers(0, 2 ** 16))
def test_sim_conservation(schedule, seed):
    """For arbitrary scale schedules: busy <= delivered accel hours; spend
    matches instance-hours x price within the catalog's price band; fleet
    never exceeds the target or total capacity."""
    cfg = SimConfig(duration_h=sum(t for t, _ in schedule) + 1.0,
                    seed=seed, overhead_per_day=0.0)
    sim = CloudSimulator(t4_catalog(), 1e9, cfg)
    t = 0.0
    cap = sum(p.total_capacity for p in sim.prov.catalog.values())
    for dur, target in schedule:
        sim.at(t, lambda s, n=target: s.prov.scale_to(n, s.now))
        t += dur
    sim.run_until(t)
    sim.settle()
    assert sim.busy_hours <= sim.accel_hours + 1e-6
    for tick in sim.history:
        assert tick.running <= cap
    prices = [p.spot_price_per_day / 24 for p in sim.prov.catalog.values()]
    if sim.accel_hours > 1.0:
        eff = sim.ledger.spent / sim.accel_hours
        # accel_hours counts interval starts, billing counts elapsed ends:
        # allow one dt of skew either side of the exact price band
        skew = 1.0 + 2 * cfg.dt_h / max(sim.accel_hours, 1.0)
        assert min(prices) / skew <= eff <= max(prices) * skew


@settings(max_examples=30, deadline=None)
@given(st_.lists(st_.integers(0, 4000), min_size=1, max_size=10))
def test_provisioner_scale_sequence(targets):
    """scale_to is idempotent and capacity-clamped for any sequence."""
    prov = MultiCloudProvisioner(t4_catalog(), BudgetLedger(1e12))
    cap = sum(p.total_capacity for p in prov.catalog.values())
    for i, n in enumerate(targets):
        got = prov.scale_to(n, now=float(i))
        assert got == min(n, cap)
        again = prov.scale_to(n, now=float(i) + 0.5)
        assert again == got                      # idempotent
    prov.deprovision_all(now=99.0)
    assert prov.total_running() == 0


@settings(max_examples=30, deadline=None)
@given(st_.integers(1, 2000), st_.floats(1.0, 100.0))
def test_billing_proportional(n, hours):
    led = BudgetLedger(1e12)
    prov = MultiCloudProvisioner(t4_catalog(), led)
    got = prov.scale_to(n, now=0.0)
    prov.bill(now=hours)
    # cheapest-first fill: cost bounded by [min,max] spot price
    lo = got * hours / 24 * 2.9
    hi = got * hours / 24 * 4.8
    assert lo - 1e-6 <= led.spent <= hi + 1e-6
