"""The engine-contract static analyzer (repro.analysis.staticcheck).

Covers the PR-10 tentpole:

  * the committed tree is clean — ``analyze()`` returns no findings
    (the same gate CI runs),
  * each rule family catches its seeded contract mutation, injected
    through ``overrides`` without touching the working tree: an event
    left unimplemented on ``JaxLaneOps`` (REG002), a stray
    ``np.random.seed`` in core (RNG001), a recorder choke point removed
    from one engine (TRC001), a Pallas kernel landing without its
    oracle or test exercise (KRN001/KRN002),
  * per-rule positive *and* negative fixtures (the sanctioned idioms —
    ``default_rng``, ``random.Random``, ``sorted(set(...))`` — stay
    silent),
  * inline ``# staticcheck: ignore[...]`` suppressions and the
    baseline file (apply/unused/write round trip),
  * the CLI surfaces: exit codes 0/1/2, ``--json`` payload schema,
    ``--rules`` filtering, ``--list-rules``, and the ``campaigns
    check`` / ``campaigns lint --json`` front doors sharing one
    findings schema.
"""
import json

import pytest

from repro.analysis.staticcheck import RULES, analyze, find_repo_root
from repro.analysis.staticcheck.baseline import (apply_baseline,
                                                 load_baseline,
                                                 write_baseline)
from repro.analysis.staticcheck.cli import main as staticcheck_main
from repro.analysis.staticcheck.findings import Finding

ROOT = str(find_repo_root())

# a synthetic module matched by the determinism rule's core/ glob; the
# file does not exist on disk — overrides add it
SYNTH = "src/repro/core/_synthetic_fixture.py"


def rules_of(findings):
    return {f.rule for f in findings}


# -- the committed tree is the contract ------------------------------------

def test_committed_tree_is_clean():
    assert analyze(ROOT) == []


def test_rule_catalog_families():
    assert {r[:3] for r in RULES} == {"REG", "RNG", "TRC", "KRN"}
    # ids share the lint SPEC id shape: family + 3 digits
    assert all(len(r) == 6 and r[3:].isdigit() for r in RULES)


# -- seeded contract mutations (the acceptance matrix) ---------------------

def test_reg002_event_without_jax_adapter_body():
    # gut sweep_jax.py: JaxLaneOps loses every EngineOps body.  The
    # module would no longer even import — the static rule still sees it.
    gutted = "class JaxLaneOps:\n    pass\n"
    findings = analyze(ROOT, overrides={
        "src/repro/core/sweep_jax.py": gutted})
    reg2 = [f for f in findings if f.rule == "REG002"]
    assert reg2, findings
    assert all(f.file == "src/repro/core/sweep_jax.py" for f in reg2)
    assert any("'jax' adapter" in f.message for f in reg2)
    # scale_to is required by the set_target op on every adapter
    assert any("scale_to" in f.message for f in reg2)


def test_rng001_global_numpy_rng_in_core():
    fleet = open(f"{ROOT}/src/repro/core/fleet.py").read()
    findings = analyze(ROOT, overrides={
        "src/repro/core/fleet.py":
            fleet + "\n\ndef _warmup():\n    np.random.seed(0)\n"})
    rng = [f for f in findings if f.rule == "RNG001"]
    assert len(rng) == 1
    assert rng[0].file == "src/repro/core/fleet.py"
    assert "np.random.seed" in rng[0].message
    # trailing newline + two blank lines + the def line put the call
    # four lines past the original last line
    assert rng[0].line == len(fleet.splitlines()) + 4


def test_trc001_recorder_call_removed_from_one_engine():
    # disconnect the array engine's nat_drop choke point (the call's
    # receiver no longer ends in `recorder`, so the call disappears
    # from the engine's emission set)
    fleet = open(f"{ROOT}/src/repro/core/fleet.py").read()
    assert "self.recorder.nat_drop(" in fleet
    findings = analyze(ROOT, overrides={
        "src/repro/core/fleet.py": fleet.replace(
            "self.recorder.nat_drop(", "self._nat_drop_disabled(")})
    trc = [f for f in findings if f.rule == "TRC001"]
    assert len(trc) == 1
    assert trc[0].file == "src/repro/core/fleet.py"
    assert "nat_drop" in trc[0].message and "'array'" in trc[0].message


def test_krn001_krn002_kernel_without_oracle_or_test():
    findings = analyze(ROOT, overrides={
        "src/repro/kernels/fancy.py":
            "def fancy_kernel(x_ref, o_ref):\n"
            "    o_ref[...] = x_ref[...]\n"})
    assert rules_of(findings) == {"KRN001", "KRN002"}
    assert all(f.file == "src/repro/kernels/fancy.py" for f in findings)
    assert any("fancy_ref" in f.message for f in findings)


# -- per-rule synthetic fixtures (positive + negative) ---------------------

def test_rng_rules_flag_the_bad_forms():
    findings = analyze(ROOT, overrides={SYNTH: (
        "import random\n"
        "import time\n"
        "import numpy as np\n\n"
        "def bad():\n"
        "    a = np.random.rand(3)\n"            # RNG001
        "    b = random.random()\n"              # RNG002
        "    t = time.time()\n"                  # RNG003
        "    for x in {1, 2, 3}:\n"              # RNG004
        "        pass\n"
        "    return a, b, t\n")}, rules=frozenset(
            {"RNG001", "RNG002", "RNG003", "RNG004"}))
    mine = [f for f in findings if f.file == SYNTH]
    assert [f.rule for f in mine] == ["RNG001", "RNG002", "RNG003",
                                      "RNG004"]
    assert [f.line for f in mine] == [6, 7, 8, 9]


def test_rng_rules_stay_silent_on_the_sanctioned_idioms():
    findings = analyze(ROOT, overrides={SYNTH: (
        "import random\n"
        "import numpy as np\n\n"
        "def good(seed):\n"
        "    rng = np.random.default_rng(seed)\n"
        "    r = random.Random(seed)\n"
        "    for x in sorted({3, 1, 2}):\n"
        "        pass\n"
        "    for y in sorted(set('ab') | set('cd')):\n"
        "        pass\n"
        "    return rng, r\n")})
    assert [f for f in findings if f.file == SYNTH] == []


def test_rng002_from_import_and_set_algebra_iteration():
    findings = analyze(ROOT, overrides={SYNTH: (
        "from random import shuffle\n\n"
        "def bad(a, b):\n"
        "    for k in set(a) | set(b):\n"
        "        pass\n")})
    mine = [f for f in findings if f.file == SYNTH]
    assert [f.rule for f in mine] == ["RNG002", "RNG004"]


def test_trc002_unknown_recorder_method():
    fleet = open(f"{ROOT}/src/repro/core/fleet.py").read()
    findings = analyze(ROOT, overrides={
        "src/repro/core/fleet.py": fleet.replace(
            "self.recorder.nat_drop(", "self.recorder.nat_dropped(")})
    assert {"TRC001", "TRC002"} <= rules_of(findings)


def test_trc003_trace_engine_without_instrumentation_map():
    api = open(f"{ROOT}/src/repro/core/api.py").read()
    assert 'TRACE_ENGINES = frozenset(SWEEP_ENGINES - {"jax"})' in api
    findings = analyze(ROOT, overrides={
        "src/repro/core/api.py": api.replace(
            'TRACE_ENGINES = frozenset(SWEEP_ENGINES - {"jax"})',
            'TRACE_ENGINES = frozenset(SWEEP_ENGINES)')})
    trc3 = [f for f in findings if f.rule == "TRC003"]
    assert len(trc3) == 1 and "'jax'" in trc3[0].message


def test_reg001_event_compiling_to_unregistered_op():
    timeline = open(f"{ROOT}/src/repro/core/timeline.py").read()
    findings = analyze(ROOT, overrides={
        "src/repro/core/timeline.py": timeline.replace(
            'ops=("scale",),', 'ops=("scale", "warp"),', 1)})
    reg1 = [f for f in findings if f.rule == "REG001"]
    assert len(reg1) == 1 and "'warp'" in reg1[0].message


def test_reg004_missing_adapter_metadata():
    timeline = open(f"{ROOT}/src/repro/core/timeline.py").read()
    findings = analyze(ROOT, overrides={
        "src/repro/core/timeline.py": timeline.replace(
            "ENGINE_ADAPTERS", "ENGINE_ADAPTERS_RENAMED")})
    assert "REG004" in rules_of(findings)


# -- suppressions ----------------------------------------------------------

def test_inline_suppression_same_line_and_line_above():
    base = ("import numpy as np\n\n"
            "def f():\n")
    same = base + ("    np.random.rand()  "
                   "# staticcheck: ignore[RNG001]\n")
    above = base + ("    # staticcheck: ignore[RNG001] — fixture\n"
                    "    np.random.rand()\n")
    wrong = base + ("    np.random.rand()  "
                    "# staticcheck: ignore[RNG002]\n")
    for text, want in ((same, []), (above, []), (wrong, ["RNG001"])):
        findings = analyze(ROOT, overrides={SYNTH: text})
        assert [f.rule for f in findings if f.file == SYNTH] == want


def test_baseline_round_trip(tmp_path):
    f1 = Finding("src/a.py", 3, "TRC001", "engine gap")
    f2 = Finding("src/b.py", 9, "RNG001", "np.random.rand")
    path = tmp_path / "base.json"
    write_baseline(str(path), [f1], reason="accepted debt")
    sups = load_baseline(str(path))
    assert sups[0]["reason"] == "accepted debt"
    kept, unused = apply_baseline([f1, f2], sups)
    assert kept == [f2] and unused == []
    # prefix match + unused surfacing
    kept, unused = apply_baseline(
        [f2], [{"rule": "RNG001", "file": "src/b.py", "match": "np.*"},
               {"rule": "TRC001", "file": "src/a.py"}])
    assert kept == [] and unused == [{"rule": "TRC001",
                                      "file": "src/a.py"}]


# -- CLI surfaces ----------------------------------------------------------

def test_cli_exit_0_and_json_payload(tmp_path, capsys):
    out = tmp_path / "findings.json"
    assert staticcheck_main(["--root", ROOT, "--json", str(out)]) == 0
    assert "staticcheck: OK" in capsys.readouterr().out
    payload = json.loads(out.read_text())
    assert payload["schema_version"] == 1
    assert payload["ok"] is True
    assert payload["findings"] == [] and payload["counts"] == {}


def test_cli_exit_1_on_findings(tmp_path, capsys):
    bad = tmp_path / "repo"
    (bad / "src" / "repro" / "core").mkdir(parents=True)
    (bad / "tests").mkdir()
    (bad / "src" / "repro" / "core" / "loose.py").write_text(
        "import numpy as np\nnp.random.seed(7)\n")
    assert staticcheck_main(["--root", str(bad), "--rules", "RNG001",
                             "--json", "-"]) == 1
    cap = capsys.readouterr()
    payload = json.loads(cap.out)
    assert payload["ok"] is False
    assert payload["counts"] == {"RNG001": 1}
    (f,) = payload["findings"]
    assert f["rule"] == "RNG001" and f["line"] == 2
    assert "staticcheck: 1 finding(s)" in cap.err


def test_cli_exit_2_on_unknown_rule(capsys):
    assert staticcheck_main(["--root", ROOT,
                             "--rules", "NOPE999"]) == 2
    assert "unknown rule" in capsys.readouterr().err


def test_cli_baseline_flow(tmp_path, capsys):
    bad = tmp_path / "repo"
    (bad / "src" / "repro" / "core").mkdir(parents=True)
    (bad / "tests").mkdir()
    (bad / "src" / "repro" / "core" / "loose.py").write_text(
        "import numpy as np\nnp.random.seed(7)\n")
    base = tmp_path / "base.json"
    args = ["--root", str(bad), "--rules", "RNG001"]
    assert staticcheck_main(args + ["--write-baseline",
                                    str(base)]) == 0
    # baselined finding no longer fails the gate ...
    assert staticcheck_main(args + ["--baseline", str(base)]) == 0
    # ... --no-baseline reports the raw state again
    assert staticcheck_main(args + ["--no-baseline"]) == 1
    # the default committed baseline is picked up from the root
    capsys.readouterr()
    (bad / ".staticcheck-baseline.json").write_text(base.read_text())
    assert staticcheck_main(args) == 0
    # fixing the finding surfaces the now-stale suppression
    (bad / "src" / "repro" / "core" / "loose.py").write_text("x = 1\n")
    assert staticcheck_main(args) == 0
    assert "unused baseline suppression" in capsys.readouterr().out
    # a *requested* baseline that is missing is a usage error
    assert staticcheck_main(args + ["--baseline",
                                    str(tmp_path / "no.json")]) == 2


def test_cli_list_rules(capsys):
    assert staticcheck_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert all(r in out for r in RULES)


# -- the campaigns front doors ---------------------------------------------

def test_campaigns_check_clean(capsys):
    from repro import campaigns as cli
    assert cli.main(["check", "--root", ROOT]) == 0
    assert "staticcheck: OK" in capsys.readouterr().out


def test_campaigns_lint_json_shares_the_findings_schema(tmp_path,
                                                        capsys):
    from repro import campaigns as cli
    from repro.core.spec import CampaignSpec, SetTarget
    bad = CampaignSpec(name="bad", duration_h=24.0,
                       timeline=(SetTarget(6.0, -5),))
    p = tmp_path / "bad.spec.json"
    p.write_text(bad.to_json())
    assert cli.main(["lint", str(p), "--json", "-"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is False
    assert payload["counts"] == {"SPEC110": 1}
    (f,) = payload["findings"]
    # the exact field set `campaigns check --json` emits
    assert set(f) == {"file", "line", "rule", "message", "hint"}
    assert f["rule"] == "SPEC110" and f["file"] == str(p)
    assert "negative target" in f["message"]


def test_campaigns_lint_json_registry_and_file(tmp_path, capsys):
    from repro import campaigns as cli
    from repro.core.spec import paper_spec
    good = tmp_path / "good.spec.json"
    good.write_text(paper_spec().to_json())
    out = tmp_path / "findings.json"
    assert cli.main(["lint", str(good), "--registry",
                     "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["ok"] is True and payload["findings"] == []
    assert "OK" in capsys.readouterr().out


def test_spec_rule_ids_are_stable_and_catalogued():
    from repro.core.spec import SPEC_RULES, CampaignSpec, lint_spec
    from repro.core.timeline import SetTarget
    findings = lint_spec(CampaignSpec(
        name="bad", catalog="warp", duration_h=-1.0,
        timeline=(SetTarget(6.0, -5), SetTarget(6.0, 7))))
    ids = {f.split(":", 1)[0] for f in findings}
    # every finding leads with a catalogued SPEC id
    assert ids <= set(SPEC_RULES)
    assert {"SPEC001", "SPEC002", "SPEC110"} <= ids


def test_registry_findings_carry_reg_ids():
    from repro.core import timeline

    class HalfEngine:
        pass

    findings = timeline.registry_findings({"half": HalfEngine})
    assert findings
    assert all(f.startswith("REG00") for f in findings)
