"""Batched sweep engine correctness: every lane of a batched multi-
campaign sweep must report the same ``results()`` totals as a solo
``run_scenario()`` at the same (seed, scenario) — including the paper
replay at seed 2021 — and money must conserve per lane.  Plus the
per-engine instance-ID determinism regression (IDs used to come from a
module-global ``itertools.count``, so they depended on how many
simulators ran earlier in the process)."""
import numpy as np
import pytest

from repro.core import sweep
from repro.core.campaign import replay_paper_campaign, sweep_campaigns
from repro.core.provider import t4_catalog
from repro.core.provisioner import MultiCloudProvisioner
from repro.core.scenarios import (Scenario, budget_floor_variants,
                                  build_catalog, default_suite,
                                  outage_grid, run_scenario,
                                  spot_ondemand_mixes)
from repro.core.simulator import CloudSimulator, SimConfig
from tests.engine_equivalence import assert_results_match

# migrated call sites keep the historical underscore name
_assert_results_match = assert_results_match


def test_sweep_lanes_match_solo_campaigns():
    """The flagship sweep invariant, pinned on three lanes including the
    full paper replay at seed 2021: batched lane totals == solo run."""
    lanes = [(Scenario(), 2021), (Scenario(), 7),
             (outage_grid((60.0,), (12.0,))[0], 7)]
    sw = sweep_campaigns([Scenario(), outage_grid((60.0,), (12.0,))[0]],
                         [2021, 7])
    by_key = {(r["scenario"], r["seed"]): r for r in sw.rows}
    for sc, seed in lanes:
        solo, _ = run_scenario(sc, seed)
        _assert_results_match(by_key[(sc.name, seed)], solo)
    # and the seed-2021 paper lane reproduces the replay helper's totals
    replay, _ = replay_paper_campaign(seed=2021)
    _assert_results_match(by_key[("paper", 2021)], replay)
    # ... which are the paper's numbers
    paper_lane = by_key[("paper", 2021)]
    assert 14500 <= paper_lane["accel_days"] <= 17500
    assert 52000 <= paper_lane["cost"] <= 60000


def test_instance_ids_deterministic_per_engine():
    """Regression: IDs came from module-global itertools.count, so a
    sim's instance numbering depended on process history.  Every engine
    — array, object, and each batched lane — must number from 0."""
    for _ in range(2):          # second run must look identical
        sim = CloudSimulator(t4_catalog(), 1e6, SimConfig(duration_h=1.0))
        sim.prov.scale_to(50, 0.0)
        ids = sorted(i.id for i in sim.prov.live_instances())
        assert ids == list(range(50))
    for _ in range(2):
        prov = MultiCloudProvisioner(t4_catalog())
        prov.scale_to(50, 0.0)
        assert sorted(i.id for i in prov.live_instances()) \
            == list(range(50))
    # batched: every lane numbers its own instances from 0
    lanes = [sweep._prepare(Scenario(duration_h=2.0), s)[1] for s in (1, 2)]
    eng = sweep.BatchedFleetEngine(lanes).run()
    for b in range(eng.B):
        lane_rows = (eng.i_lg[:eng.n] // eng.G) == b
        ids = np.sort(eng.i_id[:eng.n][lane_rows])
        assert ids[0] == 0
        assert len(np.unique(ids)) == len(ids)


def test_batched_money_conservation():
    """Per lane: charged $ == billed instance-hours x group rate
    (+ infra overhead), including compacted-away instances."""
    sc = Scenario(duration_h=72.0, outage=False, budget=1e9)
    lanes = [sweep._prepare(sc, s)[1] for s in (5, 6)]
    eng = sweep.BatchedFleetEngine(lanes).run()
    hours = eng.billed_hours_by_lg()
    dollars = hours * eng.rate_h_lg
    for b in range(eng.B):
        lane_fleet = float(dollars.reshape(eng.B, eng.G)[b].sum())
        infra = float(eng.by_provider[b, eng.infra_col])
        assert lane_fleet + infra == pytest.approx(
            float(eng.spent[b]), rel=1e-9)
        assert infra > 0            # overhead charged per tick


def test_sequential_engine_matches_batched():
    """sweep_campaigns(engine='sequential') is the reference loop; the
    batched engine must agree row by row."""
    scs = [Scenario(duration_h=36.0), Scenario(name="early-outage",
                                               duration_h=36.0,
                                               outage_at_h=12.0,
                                               outage_duration_h=4.0)]
    seeds = [1, 9]
    batched = sweep_campaigns(scs, seeds, engine="batched")
    seq = sweep_campaigns(scs, seeds, engine="sequential")
    assert [r["scenario"] for r in batched.rows] \
        == [r["scenario"] for r in seq.rows]
    for rb, rs in zip(batched.rows, seq.rows):
        _assert_results_match(rb, rs)


def test_sweep_summary_bands():
    sw = sweep_campaigns([Scenario(duration_h=48.0)], [1, 2, 3])
    assert len(sw.rows) == 3
    summ = sw.summary()
    assert set(summ) == {"paper"}
    stats = summ["paper"]
    assert stats["seeds"] == 3
    for metric in ("cost", "accel_days", "preemptions"):
        s = stats[metric]
        assert s["p5"] <= s["mean"] <= s["p95"]
    table = sw.table()
    assert "paper" in table and "cost" in table


def test_scenario_library():
    suite = default_suite()
    names = [s.name for s in suite]
    assert len(names) == len(set(names)) and len(suite) >= 8
    assert sum(1 for s in suite if not s.spot) == 1
    # the on-demand split carves preemption-free capacity at o-d prices
    cat = build_catalog(spot_ondemand_mixes((0.5,))[0])
    assert "azure-od" in cat
    od = cat["azure-od"]
    assert od.spot_price_per_day == cat["azure"].ondemand_price_per_day
    assert all(r.preempt_rate_per_hour == 0.0 for r in od.regions)
    # price perturbation scales both price axes
    pp = build_catalog(Scenario(price_scale=2.0))
    base = t4_catalog()
    assert pp["azure"].spot_price_per_day \
        == pytest.approx(2.0 * base["azure"].spot_price_per_day)
    grid = outage_grid((60.0, 252.0), (2.0, 12.0))
    assert len(grid) == 4
    assert {s.budget_floor_fraction
            for s in budget_floor_variants((0.1, 0.3))} == {0.1, 0.3}


def test_ondemand_costs_more_per_gpu_day():
    """Same ramp, same seed: the on-demand lane pays a much higher
    $/GPU-day and sees zero spot preemptions."""
    sw = sweep_campaigns([Scenario(duration_h=48.0, outage=False,
                                   budget=1e9),
                          Scenario(name="od", spot=False, duration_h=48.0,
                                   outage=False, budget=1e9)], [4])
    spot_row, od_row = sw.rows
    assert od_row["cost_per_accel_day"] \
        > 2.0 * spot_row["cost_per_accel_day"]
    assert od_row["cost"] > spot_row["cost"]
