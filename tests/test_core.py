"""Control-plane invariants: budget conservation, overlay safety, the NAT
preemption storm, provisioner semantics, campaign reproduction of the
paper's published numbers, straggler policies. Property-based where the
invariant is over arbitrary event sequences (hypothesis)."""
import pytest

pytest.importorskip("hypothesis")  # property tests degrade gracefully
import hypothesis.strategies as st_

from hypothesis import given, settings

from repro.core.budget import BudgetLedger
from repro.core.campaign import (ICECUBE_BASELINE_GPUH_PER_2W,
                                 replay_paper_campaign)
from repro.core.overlay import ComputeElement, Job
from repro.core.provider import t4_catalog
from repro.core.provisioner import MultiCloudProvisioner
from repro.core.simulator import CloudSimulator, SimConfig
from repro.core.straggler import SpeculativeScheduler, StragglerMonitor


# --------------------------------------------------------------------------
# budget (CloudBank) — property tests
# --------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st_.lists(st_.tuples(st_.sampled_from(["azure", "gcp", "aws"]),
                            st_.floats(0, 500)), max_size=60),
       st_.floats(100, 10000))
def test_budget_conservation(charges, budget):
    led = BudgetLedger(budget)
    t = 0.0
    for prov, amt in charges:
        led.charge(prov, amt, t)
        t += 1.0
    assert abs(led.spent - sum(a for _, a in charges)) < 1e-6
    assert abs(led.spent - sum(led.by_provider.values())) < 1e-6
    assert led.remaining() >= 0
    assert abs((led.remaining() + min(led.spent, budget)) - budget) < 1e-6


@settings(max_examples=40, deadline=None)
@given(st_.lists(st_.floats(1, 300), min_size=1, max_size=80))
def test_budget_thresholds_fire_once_descending(amounts):
    led = BudgetLedger(1000.0)
    fired = []
    led.on_threshold(lambda frac, rem, rate: fired.append(frac))
    for i, a in enumerate(amounts):
        led.charge("azure", a, float(i))
    assert len(fired) == len(set(
        th for th in led.thresholds if led.remaining_fraction() <= th))
    assert fired == sorted(fired, reverse=True)


def test_budget_rejects_negative():
    led = BudgetLedger(100.0)
    with pytest.raises(ValueError):
        led.charge("azure", -1.0, 0.0)


# --------------------------------------------------------------------------
# overlay — property tests
# --------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st_.lists(st_.sampled_from(["submit", "pilot", "lose", "tick"]),
                 min_size=1, max_size=120),
       st_.integers(0, 2 ** 31 - 1))
def test_overlay_invariants(script, seed):
    import random
    rng = random.Random(seed)
    ce = ComputeElement(lease_interval_s=120.0)
    submitted = 0
    for op in script:
        if op == "submit":
            submitted += 1
            ce.submit(Job(submitted, wall_h=rng.choice([0.5, 1.0, 2.0])))
        elif op == "pilot":
            ce.register_pilot(rng.randrange(1000), "azure", 240.0, 0.0)
        elif op == "lose" and ce.pilots:
            ce.pilot_lost(rng.choice(list(ce.pilots)), 0.0)
        elif op == "tick":
            ce.match(0.0)
            ce.advance(0.5, 0.0)
        # invariant: jobs are never lost
        running = sum(1 for p in ce.pilots.values() if p.job is not None)
        assert len(ce.queue) + running + len(ce.finished) == submitted
        # invariant: no job on a dead pilot
        assert not any(p.dead and p.job for p in ce.pilots.values())
        # invariant: a job sits on at most one pilot
        jobs = [id(p.job) for p in ce.pilots.values() if p.job]
        assert len(jobs) == len(set(jobs))


def test_nat_timeout_preemption_storm():
    """The paper's Azure bug: OSG's 5-min keepalive vs Azure's 4-min NAT
    timeout caused 'constant preemption of the user jobs'; fixed by tuning
    the interval below the timeout."""
    broken = ComputeElement(lease_interval_s=300.0)   # OSG default
    broken.submit(Job(1, wall_h=10.0))
    broken.register_pilot(1, "azure", nat_timeout_s=240.0, now_h=0.0)
    broken.match(0.0)
    broken.advance(0.25, 0.25)
    assert broken.nat_drop_events == 1                # job got preempted
    assert len(broken.queue) == 1                     # ... and requeued

    fixed = ComputeElement(lease_interval_s=120.0)    # the paper's fix
    fixed.submit(Job(1, wall_h=0.5))
    fixed.register_pilot(1, "azure", nat_timeout_s=240.0, now_h=0.0)
    fixed.match(0.0)
    fixed.advance(0.5, 0.5)
    assert fixed.nat_drop_events == 0
    assert len(fixed.finished) == 1


def test_ce_policy_rejects_foreign_jobs():
    ce = ComputeElement(accept_policy="icecube")
    with pytest.raises(PermissionError):
        ce.submit(Job(1, wall_h=1.0, policy="atlas"))


# --------------------------------------------------------------------------
# provisioner
# --------------------------------------------------------------------------
def test_provisioner_price_priority_and_capacity():
    prov = MultiCloudProvisioner(t4_catalog(), BudgetLedger(1e6))
    got = prov.scale_to(800, now=0.0)
    assert got == 800
    by = prov.running_by_provider()
    assert by["azure"] == 800                 # cheapest filled first
    prov.scale_to(1500, now=1.0)
    by = prov.running_by_provider()
    assert by["azure"] == 1200                # azure capacity exhausted
    assert by["gcp"] + by["aws"] == 300
    prov.deprovision_all(now=2.0)
    assert prov.total_running() == 0


def test_provisioner_bills_ledger():
    led = BudgetLedger(1e6)
    prov = MultiCloudProvisioner(t4_catalog(), led)
    prov.scale_to(100, now=0.0)
    prov.bill(now=24.0)                       # one day at $2.9/day
    assert abs(led.spent - 100 * 2.9) < 1.0


# --------------------------------------------------------------------------
# campaign — reproduces the paper's published numbers
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def campaign():
    return replay_paper_campaign()


def test_campaign_gpu_days(campaign):
    res, _ = campaign
    assert 14500 <= res["accel_days"] <= 17500          # paper: ~16k

def test_campaign_cost(campaign):
    res, _ = campaign
    assert 52000 <= res["cost"] <= 60000                # paper: ~$58k
    assert res["budget"]["overdraft"] == 0

def test_campaign_eflop_hours(campaign):
    res, _ = campaign
    assert 2.7 <= res["eflop_hours_fp32"] <= 3.4        # paper: ~3.1

def test_campaign_doubling(campaign):
    res, _ = campaign
    factor = 1 + res["busy_hours"] / ICECUBE_BASELINE_GPUH_PER_2W
    assert 1.8 <= factor <= 2.4                         # "approx doubling"

def test_campaign_outage_and_budget_cap(campaign):
    _, ctl = campaign
    log = "\n".join(ctl.log)
    assert "CE OUTAGE" in log and "resume at 1000" in log
    assert "budget floor hit" in log


def test_outage_costs_little():
    """De-provisioning during the outage keeps burn near zero."""
    cfg = SimConfig(duration_h=6.0)
    sim = CloudSimulator(t4_catalog(), 1e6, cfg)
    sim.prov.scale_to(500, 0.0)
    sim.run_until(2.0)
    sim.prov.deprovision_all(sim.now)
    sim.prov.bill(sim.now)           # settle the final partial hour
    spent_before = sim.ledger.spent
    sim.run_until(6.0)
    idle_burn = sim.ledger.spent - spent_before
    assert idle_burn <= cfg.overhead_per_day * 4 / 24 + 1e-6


# --------------------------------------------------------------------------
# stragglers
# --------------------------------------------------------------------------
def test_speculative_scheduler():
    s = SpeculativeScheduler(spec_factor=2.0, min_samples=3)
    for t in (1.0, 1.1, 0.9, 1.0):
        s.record_completion(t)
    assert not s.should_speculate(1.5)
    assert s.should_speculate(2.5)
    assert s.speculated == 1


def test_straggler_monitor_evicts_slow_pod():
    m = StragglerMonitor(evict_factor=1.5, min_steps=5)
    for i in range(20):
        for pod in ("a", "b", "c", "d"):
            m.record(pod, 1.0 if pod != "d" else 2.5)
    assert m.stragglers() == ["d"]
    m.evict("d")
    assert m.stragglers() == []
