"""core/straggler.py — speculative re-execution + the per-pod EWMA
eviction policy (previously an untested island module).

Covers the PR-8 satellite checklist: EWMA update math, the eviction
threshold against the fleet median, and the no-evict-below-``min_pods``
guard that keeps a synchronous SPMD job from evicting itself to death.
"""
import pytest

from repro.core.straggler import SpeculativeScheduler, StragglerMonitor


# -- speculative re-execution ----------------------------------------------

def test_speculation_waits_for_min_samples_then_uses_the_median():
    s = SpeculativeScheduler(spec_factor=2.0, min_samples=3)
    s.record_completion(1.0)
    s.record_completion(1.0)
    assert not s.should_speculate(100.0)          # not enough samples
    s.record_completion(3.0)                      # median now 1.0
    assert not s.should_speculate(2.0)            # == 2x median: not over
    assert s.should_speculate(2.5)
    assert s.speculated == 1


# -- EWMA update -----------------------------------------------------------

def test_ewma_seeds_with_first_sample_then_blends():
    m = StragglerMonitor(ewma_alpha=0.2)
    m.record("pod0", 10.0)
    assert m.times["pod0"] == 10.0                # first sample seeds
    m.record("pod0", 20.0)
    assert m.times["pod0"] == pytest.approx(0.8 * 10.0 + 0.2 * 20.0)
    m.record("pod0", 20.0)
    assert m.times["pod0"] == pytest.approx(0.8 * 12.0 + 0.2 * 20.0)
    assert m.counts["pod0"] == 3


def test_fleet_median_ignores_evicted_pods():
    m = StragglerMonitor(min_pods=1)
    for pod, t in (("a", 1.0), ("b", 2.0), ("c", 9.0)):
        m.record(pod, t)
    assert m.fleet_median() == 2.0
    assert m.evict("c")
    assert m.fleet_median() == 1.5
    assert m.active_pods() == ["a", "b"]


# -- eviction threshold ----------------------------------------------------

def _warm(m, pods, steps=10):
    for pod, t in pods.items():
        for _ in range(steps):
            m.record(pod, t)


def test_stragglers_flags_pods_over_factor_times_median():
    m = StragglerMonitor(evict_factor=1.5, min_steps=10, min_pods=1)
    _warm(m, {"a": 1.0, "b": 1.0, "c": 1.0, "slow": 2.0})
    # median 1.0; only "slow" exceeds 1.5x
    assert m.stragglers() == ["slow"]
    # at exactly the threshold nothing is flagged
    m2 = StragglerMonitor(evict_factor=2.0, min_pods=1)
    _warm(m2, {"a": 1.0, "b": 1.0, "edge": 2.0})
    assert m2.stragglers() == []


def test_stragglers_respects_min_steps_warmup():
    m = StragglerMonitor(min_steps=10, min_pods=1)
    _warm(m, {"a": 1.0, "b": 1.0})
    m.record("noisy", 50.0)                       # one bad sample only
    assert m.stragglers() == []                   # still warming up
    _warm(m, {"noisy": 50.0}, steps=9)            # now 10 samples
    assert m.stragglers() == ["noisy"]


# -- the min_pods floor ----------------------------------------------------

def test_no_evict_below_min_pods():
    m = StragglerMonitor(evict_factor=1.2, min_steps=1, min_pods=2)
    _warm(m, {"a": 1.0, "slow1": 10.0, "slow2": 20.0}, steps=2)
    # both slow pods are over threshold but only ONE eviction fits
    # above the floor — the slowest is proposed first
    assert m.stragglers() == ["slow2"]
    assert m.evict("slow2")
    # fleet is at the floor now: nothing proposed, evictions refused
    assert m.stragglers() == []
    assert not m.evict("slow1")
    assert m.active_pods() == ["a", "slow1"]
    # double-evict and unknown pods are refused too
    assert not m.evict("slow2")
    assert not m.evict("ghost")
    assert m.evicted == ["slow2"]
