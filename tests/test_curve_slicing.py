"""Multi-day price curves + sub-GPU slicing as first-class spec surfaces.

Covers the PR-4 tentpole end to end:

  * ``PriceCurve`` semantics: breakpoints *set* the price factor
    (absolute), uniform and per-provider curves, stacking on the
    cumulative ``PriceShift`` scalar — billed identically by all three
    engines,
  * ``GpuSlicing`` semantics: the catalog transform (k-fold capacity at
    1/k price and TFLOPS per slice) and the sliced §III catalog in
    ``core/provider.py``,
  * the committed golden curve+sliced campaign
    (tests/data/curve_sliced.spec.json) pinned bit-for-bit at seed 2021,
  * the acceptance bar: a 64-lane sweep over curve+slicing scenarios
    through ``api.run`` with every lane bit-identical to its solo
    ``run(spec, seeds=s)`` counterpart (the differential harness in
    tests/engine_equivalence.py enforces it).
"""
import json
import os

import pytest

from repro.core.api import run
from repro.core.provider import (T4_FP32_TFLOPS, heterogeneous_catalog,
                                 slice_provider, sliced_catalog, t4_catalog)
from repro.core.scenarios import (MARKET_CURVES, curve_sliced_burst,
                                  gpu_slicing_variants,
                                  price_curve_scenarios)
from repro.core.spec import (CampaignSpec, GpuSlicing, PriceCurve,
                             PriceShift, SetTarget, build_catalog,
                             lint_spec, paper_spec, run_solo)
from tests.engine_equivalence import (assert_engines_equivalent,
                                      assert_sweep_equivalent)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "curve_sliced.spec.json")

# seed-2021 curve+sliced totals (pinned; must never drift)
CURVE_SLICED_2021 = {"cost": 19254.14, "accel_days": 16422.4,
                     "eflop_hours_fp32": 0.491, "preemptions": 1969,
                     "jobs_finished": 98019}


# -- PriceCurve semantics --------------------------------------------------

def _flat(duration_h=24.0, **over):
    base = dict(name="flat", duration_h=duration_h, budget=1e9,
                overhead_per_day=0.0, timeline=(SetTarget(0.0, 200),))
    base.update(over)
    return CampaignSpec(**base)


def test_price_curve_sets_absolute_factor():
    """A curve breakpoint SETS the factor; a PriceShift multiplies.  The
    same numbers therefore bill differently: shift 2.0 then shift 2.0 is
    x4, curve 2.0 then curve 2.0 stays x2."""
    shift2 = _flat(name="shifts", timeline=(
        SetTarget(0.0, 200), PriceShift(8.0, 2.0), PriceShift(16.0, 2.0)))
    curve2 = _flat(name="curve", timeline=(
        SetTarget(0.0, 200), PriceCurve(((8.0, 2.0), (16.0, 2.0)))))
    rs = run(shift2, seeds=2)
    rc = run(curve2, seeds=2)
    # shifts: 8h@1x + 8h@2x + 8h@4x = 56 rate-hours; curve: 8+16+16 = 40
    assert rs.cost == pytest.approx(rc.cost * 56 / 40, rel=0.02)
    assert rs.accel_hours == rc.accel_hours       # fleet untouched


def test_price_curve_dips_below_baseline():
    base = _flat()
    dip = _flat(name="dip", timeline=(
        SetTarget(0.0, 200), PriceCurve(((12.0, 0.5),))))
    assert run(dip, seeds=2).cost < run(base, seeds=2).cost


def test_provider_curve_hits_only_that_provider():
    """An azure-only squeeze reroutes nothing (targets are set by count)
    but bills only azure hours at the new rate."""
    base = _flat(duration_h=16.0)
    sq = _flat(name="sq", duration_h=16.0, timeline=(
        SetTarget(0.0, 200), PriceCurve(((8.0, 3.0),), provider="azure")))
    rb = run(base, seeds=3)
    rq = run(sq, seeds=3)
    extra = rq["budget"]["by_provider"].get("azure", 0.0) \
        - rb["budget"]["by_provider"].get("azure", 0.0)
    assert extra > 0
    for name in ("gcp", "aws"):
        assert rq["budget"]["by_provider"].get(name, 0.0) \
            == pytest.approx(rb["budget"]["by_provider"].get(name, 0.0),
                             abs=0.02)


def test_curve_stacks_on_price_shift():
    """Curve factors multiply the cumulative PriceShift scalar: shift
    x2 then curve-set 1.5 bills at x3, engine-identically."""
    spec = _flat(name="stack", timeline=(
        SetTarget(0.0, 200), PriceShift(6.0, 2.0),
        PriceCurve(((12.0, 1.5),))))
    assert_engines_equivalent(spec, 5, engines=("batched", "object"))


def test_unknown_curve_provider_is_consistent_noop():
    """A curve naming a provider absent from the catalog fires (and is
    recorded) but changes nothing — identically in every engine."""
    spec = _flat(name="ghost", timeline=(
        SetTarget(0.0, 150), PriceCurve(((6.0, 9.0),), provider="ghost")))
    ref = assert_engines_equivalent(spec, 4, engines=("batched", "object"))
    assert ref.cost == run(_flat(timeline=(SetTarget(0.0, 150),)),
                           seeds=4).cost
    assert [e["event"] for e in ref.events_fired] == ["scale",
                                                      "price_curve"]


# -- GpuSlicing semantics --------------------------------------------------

def test_slice_provider_transform():
    azure = t4_catalog()["azure"]
    s4 = slice_provider(azure, 4, default_tflops=T4_FP32_TFLOPS)
    assert s4.name == "azure/4" and s4.accel == "t4/4"
    assert s4.spot_price_per_day == pytest.approx(2.9 / 4)
    assert s4.ondemand_price_per_day == pytest.approx(12.7 / 4)
    assert s4.fp32_tflops == pytest.approx(T4_FP32_TFLOPS / 4)
    assert [r.capacity for r in s4.regions] \
        == [4 * r.capacity for r in azure.regions]
    # overhead factors: slicing is rarely perfectly proportional
    s2 = slice_provider(azure, 2, price_factor=1.2, tflops_factor=0.9)
    assert s2.spot_price_per_day == pytest.approx(2.9 / 2 * 1.2)
    assert s2.fp32_tflops == pytest.approx(T4_FP32_TFLOPS / 2 * 0.9)
    with pytest.raises(ValueError):
        slice_provider(azure, 0)


def test_sliced_catalog_covers_the_full_pool():
    het = heterogeneous_catalog()
    cat = sliced_catalog(4)
    assert set(cat) == {f"{n}/4" for n in het}
    v100 = cat["azure-v100/4"]
    assert v100.fp32_tflops == pytest.approx(
        het["azure-v100"].fp32_tflops / 4)
    assert v100.total_capacity == 4 * het["azure-v100"].total_capacity


def test_build_catalog_applies_gpu_slicing():
    spec = paper_spec(gpu_slicing=GpuSlicing(
        slices=2, providers=("azure",)))
    cat = build_catalog(spec)
    assert set(cat) == {"azure/2", "gcp", "aws"}      # mixed whole/sliced
    assert cat["azure/2"].spot_price_per_day == pytest.approx(2.9 / 2)
    assert cat["gcp"].spot_price_per_day == t4_catalog()["gcp"] \
        .spot_price_per_day
    # slices=1 and None are whole-GPU no-ops
    assert build_catalog(paper_spec(gpu_slicing=GpuSlicing(slices=1))) \
        .keys() == t4_catalog().keys()
    with pytest.raises(ValueError):
        paper_spec(gpu_slicing=GpuSlicing(slices=0)).validate()


def test_sliced_campaign_eflops_account_fractionally():
    """2000 quarter-T4 slices deliver ~1/4 the fp32 EFLOP-hours of 2000
    whole T4s (same slot count, 4x less silicon), at ~1/4 the cost."""
    whole = CampaignSpec(name="whole", duration_h=24.0, budget=1e9,
                         overhead_per_day=0.0,     # infra $ doesn't slice
                         timeline=(SetTarget(0.0, 1000),))
    sliced = CampaignSpec(name="sliced", duration_h=24.0, budget=1e9,
                          overhead_per_day=0.0,
                          gpu_slicing=GpuSlicing(slices=4),
                          timeline=(SetTarget(0.0, 1000),))
    rw = run(whole, seeds=6)
    rsl = run(sliced, seeds=6)
    assert rsl.eflop_hours_fp32 == pytest.approx(
        rw.eflop_hours_fp32 / 4, rel=0.05)
    assert rsl.cost == pytest.approx(rw.cost / 4, rel=0.05)


# -- scenario library ------------------------------------------------------

def test_lint_flags_dead_curve_breakpoints():
    """A curve breakpoint at/after duration_h never fires; lint must
    flag it even when the curve's first point is in range."""
    spec = CampaignSpec(name="late", duration_h=24.0,
                        timeline=(SetTarget(0.0, 100),
                                  PriceCurve(((10.0, 1.2), (500.0, 1.5)))))
    findings = lint_spec(spec)
    assert any("t=500.0" in f and "never" in f for f in findings)


def test_curve_and_slicing_scenarios_are_wellformed():
    specs = price_curve_scenarios() + gpu_slicing_variants()
    assert len({s.name for s in specs}) == len(specs)
    for s in specs:
        assert lint_spec(s) == [], s.name
        s.validate()
    # named curves target real timeline windows
    assert MARKET_CURVES["azure-squeeze"].provider == "azure"


# -- the committed golden campaign -----------------------------------------

def test_golden_curve_sliced_spec_file_is_current():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    assert spec == curve_sliced_burst()
    assert lint_spec(spec) == []


@pytest.fixture(scope="module")
def golden_result():
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    return run(spec, seeds=2021)


def test_golden_curve_sliced_reproduces_pinned_totals(golden_result):
    res = golden_result
    for k, v in CURVE_SLICED_2021.items():
        assert res[k] == v, k
    # both new surfaces actually fired: slicing in the catalog,
    # curve points in the provenance
    assert all("/" in name for name in res["by_provider"])
    curve_events = [e for e in res.events_fired
                    if e["event"] == "price_curve"]
    assert len(curve_events) == 5
    assert {e["provider"] for e in curve_events} == {None, "azure-t4/4"}


def test_golden_curve_sliced_batched_lane_is_identical(golden_result):
    with open(GOLDEN) as f:
        spec = CampaignSpec.from_json(f.read())
    batched = run(spec, seeds=2021, engine="batched")
    assert batched.to_dict() == golden_result.to_dict()
    assert list(batched.events_fired) == list(golden_result.events_fired)


# -- acceptance: 64 curve+slicing lanes, every one solo-identical ----------

def _grid_specs():
    """8 short curve/slicing what-ifs (x 8 seeds = 64 lanes)."""
    curve_a = PriceCurve(((6.0, 1.3), (15.0, 0.8), (24.0, 1.1)))
    curve_az = PriceCurve(((9.0, 1.6),), provider="azure")
    base = dict(duration_h=30.0, budget=1e9)
    return [
        CampaignSpec(name="c-flat", timeline=(SetTarget(0.0, 250),),
                     **base),
        CampaignSpec(name="c-drift", timeline=(SetTarget(0.0, 250),
                                               curve_a), **base),
        CampaignSpec(name="c-az", timeline=(SetTarget(0.0, 250),
                                            curve_az), **base),
        CampaignSpec(name="c-stack",
                     timeline=(SetTarget(0.0, 250), PriceShift(3.0, 1.2),
                               curve_a, curve_az), **base),
        CampaignSpec(name="s-2", gpu_slicing=GpuSlicing(slices=2),
                     timeline=(SetTarget(0.0, 400),), **base),
        CampaignSpec(name="s-7", gpu_slicing=GpuSlicing(slices=7),
                     timeline=(SetTarget(0.0, 400),), **base),
        CampaignSpec(name="cs-az",
                     gpu_slicing=GpuSlicing(slices=2,
                                            providers=("azure",)),
                     timeline=(SetTarget(0.0, 400),
                               PriceCurve(((9.0, 1.6),),
                                          provider="azure/2")), **base),
        CampaignSpec(name="cs-het", catalog="heterogeneous",
                     gpu_slicing=GpuSlicing(slices=4, price_factor=1.1,
                                            tflops_factor=0.9),
                     timeline=(SetTarget(0.0, 600), curve_a), **base),
    ]


def test_64_lane_curve_slicing_sweep_matches_solo():
    """The PR acceptance bar: a 64-lane (8 spec x 8 seed) sweep over
    curve+slicing scenarios through api.run, every lane bit-identical to
    its solo counterpart (including events_fired provenance)."""
    specs = _grid_specs()
    seeds = list(range(8))
    sw = assert_sweep_equivalent(specs, seeds)
    assert len(sw.rows) == 64
    # every scenario exercised its surface
    by_name = {r["scenario"]: r for r in sw.rows}
    assert any(e["event"] == "price_curve"
               for e in by_name["c-drift"]["events_fired"])
    assert any("/" in p for p in by_name["s-7"]["by_provider"])
    # CSV artifact stays deterministic with the new surfaces in play
    assert sw.to_csv() == sw.to_csv()
    assert json.dumps(sw.summary(), sort_keys=True)   # JSON-serializable
