"""Deterministic ComputeElement view tests (no hypothesis needed — the
property-based overlay invariants live in tests/test_core.py, which
importorskips hypothesis; these must run everywhere tier-1 does).

``busy_by_provider()`` and ``stats()`` feed the weighted EFLOP
accounting for heterogeneous catalogs, so they are exercised under
every pilot state at once: busy (job attached), idle (no job) and dead
(lost instance).
"""
from repro.core.overlay import ComputeElement, Job


def test_busy_by_provider_and_stats_mixed_pilot_states():
    """Dead pilots must drop out of both views even if they died
    mid-job; idle pilots never appear in the busy view."""
    ce = ComputeElement(lease_interval_s=120.0)
    for jid in (1, 2, 3):
        ce.submit(Job(jid, wall_h=10.0))
    azure_busy = ce.register_pilot(1, "azure", 240.0, 0.0)
    azure_doomed = ce.register_pilot(2, "azure", 240.0, 0.0)
    gcp_busy = ce.register_pilot(3, "gcp", float("inf"), 0.0)
    gcp_idle = ce.register_pilot(4, "gcp", float("inf"), 0.0)
    assert ce.match(0.0) == 3                 # three jobs, four pilots
    assert {p.id for p in (azure_busy, azure_doomed, gcp_busy)
            if p.job is not None} == {azure_busy.id, azure_doomed.id,
                                      gcp_busy.id}
    assert gcp_idle.idle

    assert ce.busy_by_provider() == {"azure": 2, "gcp": 1}
    stats = ce.stats()
    assert stats["pilots_live"] == 4
    assert stats["pilots_busy"] == 3
    assert stats["queued"] == 0

    # one azure pilot's instance is preempted mid-job: its busy slot
    # disappears from the per-provider view, its job re-queues
    ce.pilot_lost(azure_doomed.id, 1.0)
    assert ce.busy_by_provider() == {"azure": 1, "gcp": 1}
    stats = ce.stats()
    assert stats["pilots_live"] == 3
    assert stats["pilots_busy"] == 2
    assert stats["queued"] == 1
    assert stats["preemptions"] == 1

    # idle pilots never show up in busy_by_provider, even alone
    ce.pilot_lost(azure_busy.id, 2.0)
    ce.pilot_lost(gcp_busy.id, 2.0)
    assert ce.busy_by_provider() == {}
    assert ce.stats()["pilots_live"] == 1     # the idle gcp pilot
    assert ce.stats()["pilots_busy"] == 0


def test_stats_counts_finished_and_nat_drops():
    """stats() surfaces the cumulative finished / preemption / NAT
    counters alongside the live views."""
    ce = ComputeElement(lease_interval_s=300.0)   # > azure NAT 240 s
    ce.submit(Job(1, wall_h=0.25))
    ce.submit(Job(2, wall_h=10.0))
    ce.register_pilot(1, "gcp", float("inf"), 0.0)     # safe NAT
    ce.register_pilot(2, "azure", 240.0, 0.0)          # doomed NAT
    ce.match(0.0)
    ce.advance(0.25, 0.25)
    stats = ce.stats()
    assert stats["finished"] == 1             # the short gcp job
    assert stats["nat_drops"] == 1            # the azure mid-job drop
    assert stats["preemptions"] == 1          # ... which re-queued job 2
    assert stats["queued"] == 1
    assert ce.busy_by_provider() == {}
