"""The batched engine's sorted-row primitives
(``sweep._sorted_insert`` / ``_sorted_remove``) — the single-pass
searchsorted merges every hot row mutation (requeue, finish, kill)
rides on.

Contracts under test:

  * insert: result is sorted and its multiset is exactly
    ``multiset(a) + multiset(vs)`` — duplicates (within ``vs``, and
    between ``vs`` and ``a``) included; the empty ``vs`` is a no-op
    returning ``a`` itself.
  * remove: for ``vs`` drawn as *distinct values present in* ``a``, the
    result is sorted and the multiset drops exactly one copy of each —
    by construction (one searchsorted index per value) a duplicated
    value in ``a`` loses a single copy, which is precisely how the
    engine uses it (row ids are unique within a lane).

The deterministic edge cases plus a seeded fuzz sweep always run; the
hypothesis-driven generalizations activate where hypothesis is
installed (same degrade-gracefully split as test_spec_properties.py vs
test_spec.py).
"""
from collections import Counter

import numpy as np
import pytest

from engine_equivalence import HAVE_HYPOTHESIS
from repro.core.sweep import _sorted_insert, _sorted_remove


def _arr(xs):
    return np.sort(np.asarray(xs, dtype=np.int64))


def _check_insert(a, vs):
    out = _sorted_insert(a, vs)
    assert out.dtype == a.dtype
    assert len(out) == len(a) + len(vs)
    assert (np.diff(out) >= 0).all(), "result must stay sorted"
    assert Counter(out.tolist()) == \
        Counter(a.tolist()) + Counter(vs.tolist())
    return out


def _check_remove(a, vs):
    out = _sorted_remove(a, vs)
    assert len(out) == len(a) - len(vs)
    assert (np.diff(out) >= 0).all(), "result must stay sorted"
    want = Counter(a.tolist())
    want.subtract(vs.tolist())
    assert Counter(out.tolist()) == +want
    return out


# -- deterministic edge cases ----------------------------------------------

def test_insert_empty_vs_is_identity():
    a = _arr([1, 3, 5])
    assert _sorted_insert(a, np.empty(0, dtype=a.dtype)) is a
    empty = np.empty(0, dtype=np.int64)
    assert _check_insert(empty, _arr([2, 2, 9])).tolist() == [2, 2, 9]


def test_insert_duplicates_within_vs_and_against_a():
    a = _arr([1, 2, 2, 5])
    _check_insert(a, _arr([2, 2]))           # dup of an existing dup
    _check_insert(a, _arr([0, 0, 6, 6]))     # dups at both boundaries
    out = _check_insert(a, a.copy())         # self-merge doubles counts
    assert Counter(out.tolist()) == \
        {k: 2 * c for k, c in Counter(a.tolist()).items()}


def test_remove_empty_vs_is_identity():
    a = _arr([1, 3, 5])
    assert _sorted_remove(a, np.empty(0, dtype=a.dtype)) is a


def test_remove_one_copy_of_duplicated_value():
    out = _check_remove(_arr([1, 2, 2, 2, 5]), _arr([2]))
    assert out.tolist() == [1, 2, 2, 5]


def test_remove_everything():
    a = _arr([4, 7, 9])
    assert _check_remove(a, a.copy()).tolist() == []


def test_remove_inverts_insert():
    a = _arr([0, 1000, 2000, 3000])
    vs = _arr([-3, 17, 17, 2500])
    merged = _check_insert(a, vs)
    distinct = _arr(sorted(set(vs.tolist())))
    _check_remove(merged, distinct)


def test_seeded_fuzz_sweep():
    """Poor-man's property test (runs even without hypothesis): 200
    random (a, vs) pairs through both contracts."""
    rng = np.random.default_rng(7)
    for _ in range(200):
        a = _arr(rng.integers(-50, 50, size=rng.integers(0, 60)))
        vs = _arr(rng.integers(-50, 50, size=rng.integers(0, 20)))
        merged = _check_insert(a, vs)
        if len(merged):
            uniq = np.unique(merged)
            take = rng.permutation(len(uniq))[:rng.integers(0, len(uniq) + 1)]
            _check_remove(merged, _arr(uniq[take]))


# -- hypothesis generalizations --------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    ints = st.integers(-50, 50)

    @given(st.lists(ints, max_size=60), st.lists(ints, max_size=20))
    @settings(max_examples=200, deadline=None)
    def test_sorted_insert_properties(base, ins):
        _check_insert(_arr(base), _arr(ins))

    @given(st.data())
    @settings(max_examples=200, deadline=None)
    def test_sorted_remove_properties(data):
        base = data.draw(st.lists(ints, min_size=1, max_size=60))
        a = _arr(base)
        # distinct present values — the engine's row ids are unique,
        # and _sorted_remove drops exactly one copy per value
        uniq = sorted(set(a.tolist()))
        vs = _arr(data.draw(st.lists(st.sampled_from(uniq), unique=True,
                                     max_size=len(uniq))))
        _check_remove(a, vs)
else:                                                 # pragma: no cover
    def test_hypothesis_generalizations_skipped():
        pytest.skip("hypothesis not installed; deterministic tier ran")
