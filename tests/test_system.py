"""End-to-end behaviour tests for the paper's system: the full campaign
replay, elastic training across pod-count changes (subprocess with faked
devices), sharded-MoE equivalence, and the dry-run machinery itself."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_py(code, devices=8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_paper_campaign_end_to_end():
    """The flagship reproduction: all paper claims in one run (fast: pure
    python simulation)."""
    from repro.core.campaign import replay_paper_campaign
    res, ctl = replay_paper_campaign()
    assert 14500 <= res["accel_days"] <= 17500
    assert 52000 <= res["cost"] <= 60000
    assert 2.7 <= res["eflop_hours_fp32"] <= 3.4
    assert res["preemptions"] > 0                  # spot is spot
    assert res["jobs_finished"] > 50000
    # operational sequence happened in order
    log = "\n".join(ctl.log)
    assert log.index("scale_to(2000)") < log.index("CE OUTAGE") \
        < log.index("resume at 1000")


@pytest.mark.slow
def test_elastic_pod_change_preserves_training(tmp_path):
    """2 pods -> preemption -> 1 pod -> checkpoint-restore continuation;
    loss keeps improving and params stay finite. Runs in a subprocess with
    4 faked devices (pod_shape (2,1), 2 pods)."""
    out = _run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REDUCED_SHAPE, RunConfig, get_reduced
        from repro.core.elastic import ElasticRunner, PodPool
        from repro.checkpoint import Checkpointer
        from repro.data import make_batch
        from repro.launch import steps as st
        from repro import sharding as sh
        from repro.models import init_params
        from repro.optim import adamw_init
        from repro.sharding_ctx import use_mesh

        cfg = get_reduced("yi-9b")
        run = RunConfig(model=cfg, shape=REDUCED_SHAPE,
                        compute_dtype="float32", remat=False)
        params = jax.device_get(init_params(cfg, jax.random.PRNGKey(0)))
        opt = jax.device_get(adamw_init(params))

        def builder(mesh):
            fn = st.make_train_step(cfg, run)
            psh = sh.param_shardings(params, mesh)
            osh = sh.opt_shardings(opt, mesh)
            jf = jax.jit(fn, in_shardings=(psh, osh, None),
                         out_shardings=(psh, osh, None))
            def wrapped(p, o, b):
                with use_mesh(mesh):
                    return jf(p, o, b)
            return wrapped

        ck = Checkpointer(r"{tmp_path}", keep=2)
        runner = ElasticRunner(builder, params, opt, pod_shape=(2, 1),
                               checkpointer=ck)
        pool = PodPool()
        pool.on_change(lambda n: runner.ensure(max(n, 1)))
        pool.join("pod-a"); pool.join("pod-b")
        assert runner.n_pods == 2, runner.n_pods

        losses = []
        for step in range(6):
            m = runner.step(make_batch(cfg, REDUCED_SHAPE, step))
            losses.append(float(m["loss"]))
        runner.checkpoint(6); ck.wait()

        pool.preemption_notice("pod-b")
        runner.handle_preemption(6)
        pool.leave("pod-b")                       # spot reclaim
        assert runner.n_pods == 1
        for step in range(6, 12):
            m = runner.step(make_batch(cfg, REDUCED_SHAPE, step))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        # 12 tiny-batch steps are noisy; assert stability (no divergence)
        # across the pod change rather than monotone descent
        assert sum(losses[6:]) / 6 < sum(losses[:6]) / 6 + 0.5, losses
        assert runner.rebuilds == 3, runner.rebuilds  # 1 pod -> 2 -> 1
        print("LOSSES", losses[0], losses[-1], runner.rebuilds)
    """, devices=4)
    assert "LOSSES" in out


@pytest.mark.slow
def test_sharded_moe_equivalence_multidevice():
    _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import MoEConfig
        from repro.models import moe as moe_mod
        from repro.models.moe_sharded import apply_moe_sharded
        from repro.sharding_ctx import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0)
        p = moe_mod.init_moe(jax.random.PRNGKey(1), 16, moe)
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 4, 16))
        y0, _ = moe_mod._apply_moe_naive(p, x, moe)
        y1, _ = jax.jit(lambda p, x: apply_moe_sharded(p, x, moe,
                                                       "swiglu", mesh))(p, x)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=3e-5, rtol=3e-5)
        # gradients flow through the explicit all-to-alls
        g = jax.grad(lambda p: apply_moe_sharded(p, x, moe, "swiglu",
                                                 mesh)[0].sum())(p)
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(g))
        print("MOE OK")
    """, devices=8)


@pytest.mark.slow
def test_dryrun_machinery():
    """run_cell lowers+compiles a real cell on the 256-chip mesh and emits
    sane roofline terms (the fast whisper decode cell)."""
    out = _run_py("""
        import json
        from repro.launch.dryrun import run_cell
        r = run_cell("whisper-large-v3", "decode_32k")
        assert r["status"] == "ok"
        assert r["n_chips"] == 256
        assert r["hlo_parsed"]["dot_flops"] > 0
        assert r["roofline"]["bottleneck"] in ("compute", "memory",
                                               "collective")
        print("DRYRUN", json.dumps(r["roofline"]))
    """, devices=512, timeout=600)
    assert "DRYRUN" in out


def test_hlo_parser_on_known_module():
    """Parser unit test: dot flops, while multipliers, promoted all-reduce."""
    from repro.analysis import hlo
    text = """\
HloModule test, num_partitions=4

%add_promoted (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant(0)
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%d), replica_groups={}, to_apply=%add_promoted
  ROOT %t = (s32[], f32[8,16]) tuple(%iv, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""
    res = hlo.analyze(text)
    # dot: 2*8*16*16 = 4096 flops x 12 trips (trips from the cond constant)
    assert res["dot_flops"] == 4096 * 12
    # promoted f32 all-reduce: 8*16*4 bytes halved, x 12 trips
    assert res["collective_bytes"] == (8 * 16 * 4 // 2) * 12
