"""Reusable differential harness for the repo's flagship invariant:
every engine interprets a CampaignSpec bit-identically —

    solo object == solo array == batched sweep lane

``assert_results_match`` is the single comparison policy (counts exact,
rounded $ values one rounding ulp of slack) that used to be duplicated
across test_spec.py / test_sweep.py / test_fleet_engine.py.
``assert_engines_equivalent`` runs one (spec, seed) campaign on the solo
array reference plus any requested engines and cross-checks results AND
``events_fired`` provenance; ``assert_sweep_equivalent`` does the same
for a whole (specs x seeds) sweep against the sequential reference loop.

``serialized_trace`` / ``assert_traces_equivalent`` extend the contract
to the typed event-trace API (core/events.py): at matching (spec, seed)
every engine must emit a **byte-identical** serialized CampaignTrace.

``assert_statistically_equivalent`` is the *statistical* tier for
``engine="jax"`` (core/sweep_jax.py): the compiled engine replaces
per-instance PCG64 draws with per-group threefry Poisson totals, so it
can never be bit-identical — instead its per-scenario means must sit
within a relative band of the batched reference and its [p5, p95]
spread must lie inside the reference band widened by the same margin,
for cost, GPU-days and jobs over a seed sweep.

Where hypothesis is installed, this module also exports the strategies
(``spec_strategy`` / ``event_strategy``) that generate random
CampaignSpec timelines — including the PriceCurve / GpuSlicing surfaces
— for the property tests in test_spec_properties.py.
"""
import numpy as np
import pytest

from repro.core.api import run, sweep as api_sweep
from repro.core.spec import run_solo


def assert_results_match(lane, solo):
    """Counts exact; rounded $ values get one rounding ulp of slack."""
    assert set(lane) >= set(solo)
    for k in solo:
        vs, vl = solo[k], lane[k]
        if isinstance(vs, dict):
            assert set(vs) == set(vl), k
            for kk in vs:
                assert vl[kk] == pytest.approx(vs[kk], rel=1e-9,
                                               abs=0.02), (k, kk)
        elif isinstance(vs, (int, np.integer)) and not isinstance(vs, bool):
            assert vl == vs, k
        else:
            assert vl == pytest.approx(vs, rel=1e-9, abs=0.02), k


def assert_engines_equivalent(spec, seed, engines=("batched",),
                              check_events=True):
    """Run one (spec, seed) campaign on the solo array engine (the
    reference semantics) and on every engine in ``engines`` ("batched"
    and/or "object"), asserting bit-identical results and — for engines
    that carry it — identical executed-event provenance.  Returns the
    reference CampaignResult."""
    ref, _ctl = run_solo(spec, seed)
    ref_d = ref.to_dict()
    for engine in engines:
        if engine == "object":
            other, _ = run_solo(spec, seed, engine="object")
        elif engine == "batched":
            other = run(spec, seeds=seed, engine="batched")
        else:
            raise ValueError(f"unknown differential engine {engine!r}")
        assert_results_match(other.to_dict(), ref_d)
        if check_events:
            assert list(other.events_fired) == list(ref.events_fired), \
                engine
    return ref


def assert_sweep_equivalent(specs, seeds):
    """Batched (specs x seeds) sweep row-for-row against the sequential
    solo reference loop, events_fired included.  Returns the batched
    SweepResult."""
    batched = api_sweep(specs, seeds, engine="batched")
    seq = api_sweep(specs, seeds, engine="sequential")
    assert len(batched.rows) == len(specs) * len(seeds)
    for rb, rs in zip(batched.rows, seq.rows):
        assert (rb["scenario"], rb["seed"]) == (rs["scenario"], rs["seed"])
        assert_results_match(rb, rs)
        assert rb["events_fired"] == rs["events_fired"]
    return batched


#: the statistical-equivalence contract surface (README "Simulation
#: engines"): metric -> relative tolerance on the per-scenario mean
#: (and band-widening margin).  ``preemptions`` is deliberately looser:
#: the compiled engine kills proportionally across occupancy cells
#: where the row engines kill newest-first, which shifts how many of a
#: tick's kills land on busy instances without moving cost/throughput.
STAT_BANDS = {"cost": 0.02, "accel_days": 0.02, "jobs_finished": 0.02,
              "preemptions": 0.25, "egress_usd": 0.05}


def assert_statistically_equivalent(specs, seeds, engine="jax",
                                    bands=None, reference="batched"):
    """Run a (specs x seeds) sweep on the statistical ``engine`` and on
    the bit-identical ``reference``, asserting for every scenario and
    every metric in ``bands`` (default :data:`STAT_BANDS`) that

      * the means agree within ``rel * |reference mean|``, and
      * the engine's [p5, p95] seed spread lies inside the reference's
        band widened by the same margin (shape, not just location).

    Returns ``(engine SweepResult, reference SweepResult)``."""
    bands = dict(STAT_BANDS if bands is None else bands)
    metrics = tuple(bands)
    got = api_sweep(specs, seeds, engine=engine)
    ref = api_sweep(specs, seeds, engine=reference)
    gs, rs = got.summary(metrics), ref.summary(metrics)
    assert set(gs) == set(rs)
    for scen in sorted(rs):
        for metric, rel in bands.items():
            a, b = rs[scen][metric], gs[scen][metric]
            margin = rel * max(abs(a["mean"]), 1e-9)
            assert abs(b["mean"] - a["mean"]) <= margin, \
                (scen, metric, "mean", a, b)
            assert a["p5"] - margin <= b["p5"] and \
                b["p95"] <= a["p95"] + margin, \
                (scen, metric, "band", a, b)
    return got, ref


def serialized_trace(spec, seed, engine: str = "array") -> str:
    """One (spec, seed) campaign's canonical JSONL trace bytes on the
    requested engine ("array" | "object" | "batched")."""
    if engine == "batched":
        res = run(spec, seeds=seed, engine="batched", collect="trace")
    elif engine in ("array", "object"):
        res, _ctl = run_solo(spec, seed,
                             engine=None if engine == "array" else engine,
                             collect="trace")
    else:
        raise ValueError(f"unknown trace engine {engine!r}")
    return res.trace.to_jsonl()


def assert_traces_equivalent(spec, seed, engines=("batched",)) -> str:
    """The trace contract: every engine in ``engines`` serializes the
    same (spec, seed) campaign to exactly the solo-array reference
    bytes.  Returns the reference JSONL."""
    ref = serialized_trace(spec, seed)
    for engine in engines:
        assert serialized_trace(spec, seed, engine) == ref, engine
    return ref


def assert_stream_equivalent(spec, seed, tmp_dir,
                             engines=("array", "object", "batched"),
                             ref: str = None) -> str:
    """The streaming contract: ``collect="stream"`` through a gzip
    :class:`~repro.core.traceops.JsonlStreamSink`, re-read from disk,
    equals the ``collect="trace"`` serialized bytes on every engine in
    ``engines``.  ``tmp_dir`` is a writable directory (pytest's
    ``tmp_path``); pass ``ref`` to reuse already-computed reference
    JSONL.  Returns the reference JSONL."""
    import gzip
    import os
    from repro.core.traceops import JsonlStreamSink
    if ref is None:
        ref = serialized_trace(spec, seed)
    ref_bytes = ref.encode("utf-8")
    for engine in engines:
        path = os.path.join(str(tmp_dir), f"stream-{engine}.jsonl.gz")
        sink = JsonlStreamSink(path)
        res = run(spec, seeds=seed, engine=engine, collect="stream",
                  sink=sink)
        assert res.trace is None, engine       # streamed, not held
        assert sink.closed and not os.path.exists(path + ".spool")
        with gzip.open(path, "rb") as f:
            assert f.read() == ref_bytes, engine
    return ref


# -- hypothesis strategies (exported only where hypothesis exists) ---------

try:
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    st = None
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.dataplane import DataOrigin, DataPlane
    from repro.core.spec import CampaignSpec, GpuSlicing
    from repro.core.timeline import event_strategies

    def event_strategy():
        """One random timeline event — every registered kind included,
        derived from the registry so newly registered events are swept
        here with zero hand edits."""
        return st.one_of(*event_strategies(st))

    def dataplane_strategy():
        """A random DataPlane over the t4 catalog's base providers —
        origins with and without caches or egress pricing."""
        origin = st.builds(
            DataOrigin,
            bandwidth_gbps=st.sampled_from([0.5, 2.0, 8.0]),
            egress_usd_per_gb=st.sampled_from([0.0, 0.05, 0.12]),
            cache_hit_rate=st.sampled_from([0.0, 0.5, 0.9]),
            cache_bandwidth_gbps=st.sampled_from([0.0, 16.0]))
        return st.dictionaries(
            st.sampled_from(["azure", "gcp", "aws"]), origin,
            min_size=1, max_size=3).map(DataPlane)

    def spec_strategy():
        """A random small CampaignSpec over every spec surface, the new
        PriceCurve timeline events, GpuSlicing and DataPlane fields
        included."""
        return st.builds(
            CampaignSpec,
            name=st.sampled_from(["a", "b"]),
            catalog=st.sampled_from(["t4", "heterogeneous"]),
            capacity_scale=st.sampled_from([0.5, 1.0]),
            spot=st.booleans(),
            ondemand_fraction=st.sampled_from([0.0, 0.25]),
            price_scale=st.sampled_from([0.8, 1.0, 1.25]),
            budget=st.sampled_from([2000.0, 8000.0, 1e9]),
            budget_floor_fraction=st.sampled_from([0.1, 0.2, 0.25]),
            downscale_target=st.integers(0, 300),
            duration_h=st.sampled_from([12.0, 24.0, 30.0]),
            lease_interval_s=st.sampled_from([120.0, 300.0]),
            job_wall_h=st.sampled_from([1.0, 4.0]),
            min_queue=st.sampled_from([500, 4000]),
            gpu_slicing=st.one_of(
                st.none(),
                st.builds(GpuSlicing,
                          slices=st.sampled_from([2, 4, 7]),
                          price_factor=st.sampled_from([1.0, 1.1]),
                          tflops_factor=st.sampled_from([0.9, 1.0]))),
            job_input_gb=st.sampled_from([0.0, 2.0, 25.0]),
            dataplane=st.one_of(st.none(), dataplane_strategy()),
            timeline=st.lists(event_strategy(), max_size=5).map(tuple))
