"""Checkpoint/restart: roundtrip fidelity, atomicity, retention, and the
trainer-level preemption -> restore -> bitwise-identical continuation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.launch.train import Trainer, build


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jax.random.normal(k, (3,)).astype(jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, {"params": t})
    step, out = restore(str(tmp_path), {"params": t})
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out["params"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_blocking(s, {"params": _tree(s)})
    assert latest_step(str(tmp_path)) == 4
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_0000000003", "step_0000000004"]


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomicity: only fully-renamed step dirs count."""
    os.makedirs(tmp_path / ".tmp-9-123")       # simulated dead partial write
    (tmp_path / ".tmp-9-123" / "params.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) is None


def test_async_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save_async(7, {"params": _tree()})
    ck.wait()
    assert latest_step(str(tmp_path)) == 7


def test_trainer_restore_is_bitwise_identical(tmp_path):
    """Train 10 steps saving at 5; restart from 5 and re-run 5 steps; the
    parameters must match the uninterrupted run exactly (determinism is the
    elastic-restart contract)."""
    cfg, shape, run = build("internvl2-2b", reduced=True)
    tr1 = Trainer(cfg, shape, run, ckpt_dir=str(tmp_path / "a"), seed=3)
    tr1.train(10, ckpt_every=5, log_every=0, log=lambda *a: None)
    p_full = jax.device_get(tr1.params)

    # second trainer restores step 5 from the same dir and continues
    tr2 = Trainer(cfg, shape, run, ckpt_dir=str(tmp_path / "a"), seed=3)
    assert tr2.step_num == 10            # restored the latest
    tr2.restore(str(tmp_path / "a"))
    tr2.step_num = 5
    _, trees = restore(str(tmp_path / "a"), {"params": tr2.params,
                                             "opt": tr2.opt}, step=5)
    tr2.params, tr2.opt = trees["params"], trees["opt"]
    tr2.train(10, ckpt_every=100, log_every=0, log=lambda *a: None)
    p_resumed = jax.device_get(tr2.params)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)
