"""analysis/ cost-model coverage (previously untested): the roofline
terms the Pareto tooling trusts for tokens/s-per-dollar inputs.

  * ``count_params`` / ``model_flops`` / ``cache_bytes`` /
    ``hbm_bytes`` / ``compute_roofline`` pinned per (arch x shape) on
    two committed ``configs/`` entries — a dense xLSTM and a MoE
    transformer, exercising both the active/total split and every
    shape kind,
  * the HLO text parser (analysis/hlo.py) on a synthetic module with
    known dot FLOPs, while trip counts, fusion calls and the bf16
    all-reduce promotion halving,
  * the artifact renderers (analysis/report.py) on dict fixtures
    covering ok / skipped / error cells.
"""
import json

import pytest

from repro.analysis import roofline as rl
from repro.analysis.hlo import analyze, parse_module, while_trip_count
from repro.analysis.report import dryrun_md, fmt_bytes, load, roofline_md
from repro.configs import get_config, get_shape

XLSTM = "xlstm-350m"
MOE = "qwen3-moe-30b-a3b"


# -- parameter counting: pinned totals -------------------------------------

def test_count_params_pinned_dense_xlstm():
    total, active = rl.count_params(get_config(XLSTM))
    assert total == 529_871_872
    assert active == total                 # dense: every param active


def test_count_params_pinned_moe():
    total, active = rl.count_params(get_config(MOE))
    assert total == 30_538_727_424         # the "30b" in the name
    assert active == 3_347_054_592         # the "a3b": top-8 of 128
    assert active < total


# -- model FLOPs per shape kind --------------------------------------------

def test_model_flops_train_prefill_decode():
    cfg = get_config(MOE)
    _, active = rl.count_params(cfg)
    train = get_shape("train_4k")
    prefill = get_shape("prefill_32k")
    decode = get_shape("decode_32k")
    assert rl.model_flops(cfg, train) == 6 * active * train.tokens_per_step
    assert rl.model_flops(cfg, prefill) \
        == 2 * active * prefill.tokens_per_step
    # decode advances one token per sequence
    assert rl.model_flops(cfg, decode) == 2 * active * decode.global_batch
    assert rl.model_flops(cfg, train) == 21_057_846_695_165_952


def test_model_flops_uses_active_not_total_params():
    cfg = get_config(MOE)
    total, active = rl.count_params(cfg)
    shape = get_shape("decode_32k")
    assert rl.model_flops(cfg, shape) == 2 * active * shape.global_batch
    assert rl.model_flops(cfg, shape) < 2 * total * shape.global_batch


# -- memory terms ----------------------------------------------------------

def test_cache_bytes_pinned():
    assert rl.cache_bytes(get_config(XLSTM),
                          get_shape("decode_32k")) == 12_935_233_536
    assert rl.cache_bytes(get_config(MOE),
                          get_shape("decode_32k")) == 412_316_860_416


def test_hbm_bytes_decode_touches_active_experts_only():
    cfg = get_config(MOE)
    total, active = rl.count_params(cfg)
    decode = get_shape("decode_32k")
    hbm = rl.hbm_bytes(cfg, decode, 256)
    # B=128 tokens x active params each, well below total -> touched
    # weights are min(total, B * active)
    touched = min(total, active * decode.global_batch)
    expected = (touched * 2 + rl.cache_bytes(cfg, decode)) / 256
    assert hbm == expected == pytest.approx(1_849_196_544.0, abs=1.0)


def test_hbm_bytes_train_pinned():
    assert rl.hbm_bytes(get_config(XLSTM), get_shape("train_4k"), 256) \
        == pytest.approx(842_562_984.0, abs=1.0)
    assert rl.hbm_bytes(get_config(MOE), get_shape("train_4k"), 256) \
        == pytest.approx(5_368_479_744.0, abs=1.0)


def test_state_bytes_train_vs_serve():
    cfg = get_config(XLSTM)
    total, _ = rl.count_params(cfg)
    train = rl.state_bytes(cfg, get_shape("train_4k"), 256)
    serve = rl.state_bytes(cfg, get_shape("decode_32k"), 256)
    assert train == total * 18.0 / 256
    assert serve == (total * 2.0
                     + rl.cache_bytes(cfg, get_shape("decode_32k"))) / 256


# -- the roofline itself ---------------------------------------------------

def test_compute_roofline_terms_and_bottleneck():
    cfg = get_config(XLSTM)
    shape = get_shape("train_4k")
    mf = rl.model_flops(cfg, shape)
    dot_dev = mf / 256 * 1.5               # 1.5x HLO redundancy
    r = rl.compute_roofline(cfg, shape, 256, dot_dev, 1e9)
    assert r.compute_s == pytest.approx(dot_dev / rl.PEAK_FLOPS)
    assert r.memory_s == pytest.approx(
        rl.hbm_bytes(cfg, shape, 256) / rl.HBM_BW)
    assert r.collective_s == pytest.approx(1e9 / rl.ICI_BW)
    assert r.useful_ratio == pytest.approx(1 / 1.5)
    assert r.bottleneck == "compute"
    assert r.to_dict()["bottleneck"] == "compute"


def test_roofline_bottleneck_flips_with_the_dominant_term():
    cfg = get_config(MOE)
    decode = get_shape("decode_32k")
    mf = rl.model_flops(cfg, decode)
    r = rl.compute_roofline(cfg, decode, 256, mf / 256, 1e9)
    # tiny decode FLOPs, big collective -> collective-bound
    assert r.bottleneck == "collective"
    r2 = rl.compute_roofline(cfg, decode, 256, mf / 256, 0.0)
    assert r2.bottleneck == "memory"


# -- HLO text parser -------------------------------------------------------

SYNTHETIC_HLO = """\
HloModule synthetic

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %x, f32[] %y)
}

%layer (p: bf16[128,256], w: bf16[256,512]) -> bf16[128,512] {
  %p = bf16[128,256]{1,0} parameter(0)
  %w = bf16[256,512]{1,0} parameter(1)
  %d = bf16[128,512]{1,0} dot(bf16[128,256]{1,0} %p, bf16[256,512]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %ar = bf16[128,512]{1,0} all-reduce(bf16[128,512]{1,0} %d), to_apply=%add
}

%body (t: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %t = (s32[], bf16[128,256]) parameter(0)
  %f = bf16[128,512]{1,0} fusion(bf16[128,256]{1,0} %a, bf16[256,512]{1,0} %wt), kind=kLoop, calls=%layer
  ROOT %r = (s32[], bf16[128,256]) tuple(%i, %a)
}

%cond (t: (s32[], bf16[128,256])) -> pred[] {
  %t = (s32[], bf16[128,256]) parameter(0)
  %lim = s32[] constant(24)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %lim), direction=LT
}

ENTRY %main (p0: bf16[128,256], w0: bf16[256,512]) -> bf16[128,512] {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %w0 = bf16[256,512]{1,0} parameter(1)
  %wl = (s32[], bf16[128,256]) while((s32[], bf16[128,256]) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"24"}}
  %d0 = bf16[128,512]{1,0} dot(bf16[128,256]{1,0} %p0, bf16[256,512]{1,0} %w0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %arp = f32[1024]{0} all-reduce(f32[1024]{0} %g), to_apply=%add_promoted
  ROOT %out = bf16[128,512]{1,0} add(bf16[128,512]{1,0} %d0, bf16[128,512]{1,0} %f2)
}
"""

# one layer dot: 2 * (128*512) * 256 contracted
_LAYER_FLOPS = 2 * 128 * 512 * 256
# its bf16 all-reduce payload
_LAYER_AR = 128 * 512 * 2


def test_hlo_parse_module_finds_entry_and_computations():
    comps, entry = parse_module(SYNTHETIC_HLO)
    assert entry == "main"
    assert set(comps) == {"add", "layer", "body", "cond", "main"}
    assert comps["layer"].symbols["p"] == (128, 256)


def test_hlo_analyze_applies_while_trip_multipliers():
    res = analyze(SYNTHETIC_HLO)
    # scanned layer x24 trips (via fusion call) + the entry dot
    assert res["dot_flops"] == 24 * _LAYER_FLOPS + _LAYER_FLOPS
    # 24 in-loop all-reduces + the promoted f32 one at half wire bytes
    promoted = 1024 * 4 // 2
    assert res["collective_bytes"] == 24 * _LAYER_AR + promoted
    assert res["collective_bytes_by_kind"] \
        == {"all-reduce": 24 * _LAYER_AR + promoted}


def test_hlo_trip_count_falls_back_to_condition_constant():
    # strip the backend_config annotation: the parser must recover the
    # trip count from the condition's s32[] constant(24)
    text = SYNTHETIC_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"24"}}', "")
    comps, _entry = parse_module(text)
    wl = next(i for i in comps["main"].instrs if i.opcode == "while")
    assert while_trip_count(comps, wl) == 24
    assert analyze(text)["dot_flops"] == 25 * _LAYER_FLOPS


def test_hlo_analyze_empty_module_is_zero():
    assert analyze("HloModule empty\n") \
        == {"dot_flops": 0, "collective_bytes": 0,
            "collective_bytes_by_kind": {}}


# -- artifact renderers ----------------------------------------------------

def _cell(arch="xlstm-350m", shape="train_4k", mesh="16x16", status="ok"):
    return {"arch": arch, "shape": shape, "mesh": mesh, "status": status,
            "compile_s": 12.3,
            "memory": {"argument_bytes": 2.5e9, "temp_bytes": 1.5e9},
            "hlo_parsed": {"dot_flops": 8.0e12,
                           "collective_bytes": 3.0e8},
            "roofline": {"compute_s": 0.0406, "memory_s": 0.0031,
                         "collective_s": 0.006, "bottleneck": "compute",
                         "hlo_flops_device": 8.0e12,
                         "model_flops": 1.3e16, "useful_ratio": 0.66}}


def test_fmt_bytes_units():
    assert fmt_bytes(512) == "512B"
    assert fmt_bytes(2.5e6) == "2.50MB"
    assert fmt_bytes(3.0e9) == "3.00GB"
    assert fmt_bytes(1.2e12) == "1.20TB"


def test_roofline_md_renders_ok_skipped_and_error_rows():
    cells = {
        ("a1", "train_4k", "16x16"): _cell("a1"),
        ("a2", "train_4k", "16x16"): _cell("a2", status="skipped"),
        ("a3", "train_4k", "16x16"): _cell("a3", status="error"),
        ("a4", "train_4k", "2x16x16"): _cell("a4", mesh="2x16x16"),
    }
    md = roofline_md(cells)
    lines = md.splitlines()
    assert lines[0].startswith("| arch | shape |")
    assert "| a1 | train_4k | 0.0406 |" in md
    assert "**compute**" in md and "300.00MB" in md and "2.50GB" in md
    assert "skipped" in md and "ERROR" in md
    assert "a4" not in md                   # other mesh filtered out
    assert "a4" in roofline_md(cells, mesh="2x16x16")


def test_dryrun_md_renders_all_statuses():
    cells = {
        ("a1", "train_4k", "16x16"): _cell("a1"),
        ("a2", "train_4k", "16x16"): _cell("a2", status="skipped"),
        ("a3", "train_4k", "16x16"): _cell("a3", status="boom"),
    }
    md = dryrun_md(cells)
    assert "| a1 | train_4k | 16x16 | ok | 12 | 2.50GB | 1.50GB | 8000 |" \
        in md
    assert "SKIP (full attn)" in md and "ERROR" in md


def test_load_merges_artifact_files(tmp_path):
    f1 = [_cell("a1"), _cell("a1", shape="decode_32k")]
    f2 = [_cell("a2")]
    (tmp_path / "one.json").write_text(json.dumps(f1))
    (tmp_path / "two.json").write_text(json.dumps(f2))
    cells = load(str(tmp_path))
    assert set(cells) == {("a1", "train_4k", "16x16"),
                          ("a1", "decode_32k", "16x16"),
                          ("a2", "train_4k", "16x16")}
