"""Elastic pod pools as spec-driven trace consumers (core/elastic.py):

  * PodPool.join is observable at max_pods (returns bool, counts
    rejected joins) instead of a silent no-op,
  * ElasticRunner.rebuild_s reads 0.0 before the first ensure()
    (previously an AttributeError),
  * the tentpole payoff end-to-end: a preemption-bearing
    ``scenarios.default_suite`` outage campaign, run with
    ``collect="trace"``, replays into PodPool + SimulatedElasticRunner
    via ``drive_pool`` and reports goodput / lost steps / rebuilds —
    the CE outage dents goodput, honoring preemption notices beats hard
    kills, and pool clipping at max_pods is visible.
"""
import pytest

from repro.core import scenarios
from repro.core.api import run
from repro.core.elastic import (ElasticRunner, GoodputReport, PodPool,
                                SimulatedElasticRunner, drive_pool)


# -- PodPool observability --------------------------------------------------

def test_podpool_join_observable_at_max_pods():
    pool = PodPool(max_pods=2)
    assert pool.join("a") is True
    assert pool.join("b") is True
    assert pool.join("c") is False            # full: observable refusal
    assert pool.rejected_joins == 1
    assert pool.size == 2
    # re-joining a member is an idempotent no-op, not a capacity refusal
    assert pool.join("a") is False
    assert pool.rejected_joins == 1
    pool.leave("a")
    assert pool.join("c") is True
    assert pool.size == 2


def test_podpool_notify_fires_on_membership_change():
    pool = PodPool(max_pods=1)
    seen = []
    pool.on_change(seen.append)
    pool.join("a")
    pool.join("b")                            # rejected: no notification
    pool.leave("a")
    assert seen == [1, 0]


# -- ElasticRunner init hygiene --------------------------------------------

def test_elastic_runner_rebuild_s_initialized():
    runner = ElasticRunner(lambda mesh: None, {}, {})
    assert runner.rebuild_s == 0.0            # was: AttributeError
    assert runner.rebuilds == 0 and runner.lost_steps == 0


def test_simulated_runner_matches_real_runner_surface():
    sim, real = SimulatedElasticRunner(), ElasticRunner(None, {}, {})
    for attr in ("ensure", "handle_preemption", "rebuilds", "rebuild_s",
                 "lost_steps", "n_pods"):
        assert hasattr(sim, attr) and hasattr(real, attr), attr
    assert sim.ensure(4) is True
    assert sim.ensure(4) is False             # no-op: same pod count
    assert sim.rebuilds == 1 and sim.n_pods == 4


# -- drive_pool end-to-end on a default_suite outage scenario ---------------

@pytest.fixture(scope="module")
def outage_trace():
    spec = scenarios.outage_burst()
    # the spec IS a default_suite member — the "no new glue" claim
    assert spec.name in [s.name for s in scenarios.default_suite()]
    return run(spec, seeds=2021, collect="trace").trace


def test_drive_pool_outage_goodput_accounting(outage_trace):
    pool = PodPool(min_pods=1, max_pods=128)
    runner = SimulatedElasticRunner(rebuild_s=45.0)
    rep = drive_pool(outage_trace, pool, runner)
    assert isinstance(rep, GoodputReport)
    assert rep.wall_h == outage_trace.duration_h
    assert rep.steps_done > 0 and rep.pod_hours > 0
    # rebuilds count every membership change (same-size member swaps
    # included, via ensure(force=True)); report and runner agree
    assert rep.rebuilds == runner.rebuilds > 0
    assert rep.rebuild_downtime_s == pytest.approx(45.0 * rep.rebuilds)
    # spot churn reached the pool, and notices were honored: blocking
    # checkpoints happened, nothing was lost
    assert rep.preemptions > 0
    assert runner.blocking_checkpoints == rep.preemptions
    assert rep.steps_lost == 0.0 and runner.lost_steps == 0
    # the CE outage deprovisions the fleet: graceful leaves, and the
    # training pause is visible as goodput < 1
    assert rep.graceful_leaves > 0
    assert rep.goodput_fraction < 1.0
    # the 2k-instance ramp clips at max_pods, observably
    assert rep.peak_pods == 128
    assert rep.joins_rejected == pool.rejected_joins > 0
    assert rep.to_dict()["goodput_fraction"] == rep.goodput_fraction


def test_drive_pool_notice_beats_hard_kills(outage_trace):
    """The paper's operational stance, quantified: honoring the cloud's
    preemption notice (checkpoint before the kill) strictly beats losing
    work since the last periodic checkpoint."""
    kw = dict(step_time_s=2.0, checkpoint_period_s=600.0)
    soft = drive_pool(outage_trace, PodPool(max_pods=128),
                      SimulatedElasticRunner(rebuild_s=45.0),
                      notice=True, **kw)
    hard_runner = SimulatedElasticRunner(rebuild_s=45.0)
    hard = drive_pool(outage_trace, PodPool(max_pods=128), hard_runner,
                      notice=False, **kw)
    assert hard.steps_lost > 0
    assert hard_runner.lost_steps > 0
    assert soft.steps_done > hard.steps_done
    assert soft.goodput_fraction > hard.goodput_fraction
    # both replays saw the identical membership stream
    assert (soft.joins, soft.preemptions, soft.graceful_leaves) == \
        (hard.joins, hard.preemptions, hard.graceful_leaves)


def test_drive_pool_same_size_member_swap_still_rebuilds():
    """k preemptions + k replacement launches sharing one timestamp swap
    members at constant pool size — the mesh still re-forms over the new
    device set, so the rebuild (and its downtime) must be charged."""
    from repro.core.events import (CampaignTrace, InstanceLaunched,
                                   InstancePreempted)
    trace = CampaignTrace(
        name="swap", seed=0, duration_h=2.0, dt_h=0.25,
        events=(InstanceLaunched(0.0, 0, "azure", "eastus"),
                InstanceLaunched(0.0, 1, "azure", "eastus"),
                # t=1.0: pod 0 preempted AND pod 2 launched — size stays 2
                InstanceLaunched(1.0, 2, "azure", "eastus"),
                InstancePreempted(1.0, 0, "azure", "eastus")))
    runner = SimulatedElasticRunner(rebuild_s=30.0)
    rep = drive_pool(trace, PodPool(max_pods=8), runner)
    assert rep.preemptions == 1 and rep.joins == 3
    assert rep.peak_pods == 2
    assert rep.rebuilds == 2                  # initial fill + the swap
    assert runner.rebuilds == 2               # ensure(force=True) on swap
    assert rep.rebuild_downtime_s == pytest.approx(60.0)


def test_drive_pool_provider_filter(outage_trace):
    """Restricting pods to one provider consumes only that provider's
    instance stream."""
    azure_only = drive_pool(outage_trace, PodPool(max_pods=100000),
                            SimulatedElasticRunner(),
                            providers=("azure",))
    everything = drive_pool(outage_trace, PodPool(max_pods=100000),
                            SimulatedElasticRunner())
    launches = outage_trace.filter("launch")
    azure_launches = sum(1 for ev in launches if ev.provider == "azure")
    assert azure_only.joins == azure_launches
    assert everything.joins == len(launches)
    assert azure_only.joins < everything.joins
