"""One benchmark per paper figure/claim (eScience'21 §IV/§V + Figs 1-2).

The campaign benches run the golden paper-replay CampaignSpec through
the ``repro.core.api.run`` front door once (typed CampaignResult,
cached) and read its paper-comparison helpers.

Each returns (us_per_call, derived, detail_rows)."""
from __future__ import annotations

import time

from repro.core.api import paper_spec, run
from repro.core.overlay import ComputeElement, Job
from repro.core.provider import t4_catalog
from repro.core.simulator import CloudSimulator, SimConfig
from repro.core.spec import ICECUBE_BASELINE_GPUH_PER_2W, PAPER_CLAIMS

PAPER = {"cost": PAPER_CLAIMS["cost"], "gpu_days": PAPER_CLAIMS["accel_days"],
         "eflop_hours": PAPER_CLAIMS["eflop_hours_fp32"],
         "doubling": PAPER_CLAIMS["doubling"], "max_fleet": 2000}

_campaign_cache = {}


def _campaign():
    if "res" not in _campaign_cache:
        t0 = time.time()
        res = run(paper_spec(), seeds=2021)
        _campaign_cache.update(res=res, wall=(time.time() - t0) * 1e6)
    return _campaign_cache["res"], _campaign_cache["wall"]


def bench_fig1_fleet_timeline():
    """Fig 1 (monitoring snapshot): ramp to 2k, outage dip, 1k resume."""
    res, wall = _campaign()
    hist = res.history
    peaks = max(t.running for t in hist) if hist else 0
    rows = []
    for t in hist[:: max(1, len(hist) // 14)]:
        rows.append(f"  t={t.t_h:6.1f}h fleet={t.running:5d} "
                    f"busy={t.busy:5d} spent=${t.spent:9.0f}")
    return wall, peaks, rows


def bench_fig2_gpu_hours_doubling():
    """Fig 2: cloud GPU-hours vs IceCube's baseline ('approx doubling')."""
    res, wall = _campaign()
    factor = res.doubling_factor()
    rows = [f"  baseline 2w GPU-h: {ICECUBE_BASELINE_GPUH_PER_2W:,.0f}",
            f"  cloud busy GPU-h:  {res.busy_hours:,.0f}",
            f"  total/baseline:    {factor:.2f}x  (paper: ~2x)"]
    return wall, round(factor, 3), rows


def bench_claims_table():
    """§V summary claims: ~$58k, ~16k GPU-days, ~3.1 fp32 EFLOP-h."""
    res, wall = _campaign()
    cmp = res.compare_paper()
    rows = [f"  {name:18s} sim={row['sim']:12,.2f} "
            f"paper={row['paper']:12,.1f} err={row['err_pct']:+6.1f}%"
            for name, row in cmp.items() if name != "doubling"]
    return wall, round(res.max_paper_err_pct(), 2), rows


def bench_preemption_economics():
    """§II claim: spot 'cost effective even at high scales' despite
    preemption. Derived: on-demand/spot cost ratio per finished job."""
    t0 = time.time()
    outcomes = {}
    for spot in (True, False):
        cfg = SimConfig(duration_h=72.0, seed=7)
        sim = CloudSimulator(t4_catalog(), 1e9, cfg)
        sim.prov.spot = spot
        sim.prov.scale_to(500, 0.0)
        sim.run_until(72.0)
        r = sim.results()
        outcomes[spot] = (r["cost"] / max(r["jobs_finished"], 1),
                          r["jobs_finished"], r["preemptions"])
    wall = (time.time() - t0) * 1e6
    ratio = outcomes[False][0] / outcomes[True][0]
    rows = [f"  spot:      $/job={outcomes[True][0]:.3f} "
            f"jobs={outcomes[True][1]} preempt={outcomes[True][2]}",
            f"  on-demand: $/job={outcomes[False][0]:.3f} "
            f"jobs={outcomes[False][1]} preempt={outcomes[False][2]}",
            f"  on-demand/spot cost ratio: {ratio:.2f}x (spot wins > 1)"]
    return wall, round(ratio, 3), rows


def bench_budget_control():
    """§III: threshold alerts drive scale decisions. Derived: ticks between
    the 20% alert and the fleet cap taking effect (0 = same tick)."""
    res, wall = _campaign()
    log = res.log
    alert_i = next(i for i, l in enumerate(log) if "20% remaining" in l)
    cap_i = next(i for i, l in enumerate(log) if "budget floor" in l)
    rows = [f"  {l}" for l in log if "BUDGET" in l or "floor" in l]
    rows.append(f"  overdraft: ${res.budget.overdraft}")
    return wall, cap_i - alert_i, rows


def bench_nat_keepalive():
    """§IV: Azure NAT 4-min timeout vs OSG 5-min default. Derived:
    preemption-storm drops with the broken config (fixed config must be 0)."""
    t0 = time.time()
    drops = {}
    for lease in (300.0, 120.0):
        ce = ComputeElement(lease_interval_s=lease)
        for i in range(50):
            ce.submit(Job(i, wall_h=2.0))
        for i in range(50):
            ce.register_pilot(i, "azure", nat_timeout_s=240.0, now_h=0.0)
        for tick in range(8):
            ce.match(tick * 0.25)
            ce.advance(0.25, tick * 0.25)
        drops[lease] = ce.nat_drop_events
    wall = (time.time() - t0) * 1e6
    rows = [f"  lease=300s (OSG default): {drops[300.0]} NAT drops",
            f"  lease=120s (paper's fix): {drops[120.0]} NAT drops"]
    assert drops[120.0] == 0
    return wall, drops[300.0], rows


def bench_overlay_throughput():
    """CE matchmaking scalability: jobs matched/sec at 2k pilots."""
    ce = ComputeElement()
    for i in range(20000):
        ce.submit(Job(i, wall_h=1.0))
    for i in range(2000):
        ce.register_pilot(i, "azure", 240.0, 0.0)
    t0 = time.time()
    total = 0
    for tick in range(10):
        total += ce.match(tick * 1.0)
        ce.advance(1.0, tick * 1.0)
    dt = time.time() - t0
    rate = total / dt
    return dt * 1e6 / 10, round(rate), [f"  {total} matches in {dt:.3f}s"]
