"""Benchmark harness — one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV rows (with detail blocks
on indented lines below each row).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --only campaign
    PYTHONPATH=src python -m benchmarks.run --only sweep --json BENCH.json

``--json PATH`` additionally writes
``{schema_version, benches: {name: {us_per_call, derived}}}`` so the
perf trajectory stays machine-comparable across PRs (the committed
``BENCH_sweep.json`` / ``BENCH_sweep_jax.json`` are the sweep-engine
baselines; CI uploads fresh ones per run as artifacts).  Benches that
declare an acceptance bar (the sweep engines' speedups) additionally
report ``{"bar": <threshold>, "pass": <derived >= bar>}`` — CI fails
the sweep smoke when ``pass`` is false (``--check-bars`` makes any
failed bar a non-zero exit).  Consumers should check ``schema_version``
(currently 2; version 1 was the bare ``{name: ...}`` mapping — bar/pass
are additive to 2).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

BENCH_SCHEMA_VERSION = 2

#: acceptance bars on a bench's ``derived`` value (see each bench's
#: docstring for the configuration the bar is defined at)
BENCH_BARS = {
    "sweep_campaign_speedup": 10.0,   # batched numpy vs sequential, B=64
    "sweep_jax_speedup": 3.0,         # compiled jax vs batched, B=512
}


def _benches():
    from benchmarks import fleet_scale as fs
    from benchmarks import framework_benches as fb
    from benchmarks import paper_tables as pt
    from benchmarks import sweep_jax_scale as sjs
    from benchmarks import sweep_scale as ss

    return [
        ("fleet_tick_speedup", fs.bench_fleet_tick_throughput),
        ("sweep_campaign_speedup", ss.bench_sweep_throughput),
        ("sweep_jax_speedup", sjs.bench_sweep_jax_throughput),
        ("fig1_fleet_timeline", pt.bench_fig1_fleet_timeline),
        ("fig2_gpu_hours_doubling", pt.bench_fig2_gpu_hours_doubling),
        ("claims_table_maxerr_pct", pt.bench_claims_table),
        ("preemption_economics", pt.bench_preemption_economics),
        ("budget_control_latency", pt.bench_budget_control),
        ("nat_keepalive_drops", pt.bench_nat_keepalive),
        ("overlay_matches_per_s", pt.bench_overlay_throughput),
        ("elastic_restart_steps", fb.bench_elastic_train_restart),
        ("kernels_max_err", fb.bench_kernels),
        ("roofline_cells_ok", fb.bench_roofline_table),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benches whose name contains this substring")
    ap.add_argument("--list", action="store_true",
                    help="print registered bench names and exit")
    ap.add_argument("--json", default=None,
                    help="also write {name: {us_per_call, derived}} here")
    ap.add_argument("--check-bars", action="store_true",
                    help="exit non-zero if any bench with a declared "
                         "acceptance bar reports pass=false")
    args = ap.parse_args()

    benches = _benches()
    if args.list:
        for name, _fn in benches:
            bar = BENCH_BARS.get(name)
            print(name if bar is None else f"{name} (bar >= {bar:g}x)")
        return
    if args.only:
        benches = [(n, f) for n, f in benches if args.only in n]
        if not benches:
            print(f"unknown bench filter {args.only!r}: matches no "
                  "registered bench (see --list)", file=sys.stderr)
            raise SystemExit(2)

    print("name,us_per_call,derived")
    report = {}
    failures = 0
    barfails = []
    for name, fn in benches:
        try:
            us, derived, rows = fn()
            print(f"{name},{us:.1f},{derived}")
            for r in rows:
                print(r)
            report[name] = {"us_per_call": round(us, 1), "derived": derived}
            bar = BENCH_BARS.get(name)
            if bar is not None:
                ok = isinstance(derived, (int, float)) and derived >= bar
                report[name]["bar"] = bar
                report[name]["pass"] = bool(ok)
                if not ok:
                    barfails.append(f"{name}: derived {derived} < "
                                    f"bar {bar:g}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,ERROR")
            traceback.print_exc(limit=5)
            report[name] = {"us_per_call": None, "derived": "ERROR"}
            if name in BENCH_BARS:
                report[name]["bar"] = BENCH_BARS[name]
                report[name]["pass"] = False
                barfails.append(f"{name}: ERROR")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": BENCH_SCHEMA_VERSION,
                       "benches": report},
                      f, indent=2, sort_keys=True, default=str)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if args.check_bars and barfails:
        for line in barfails:
            print(f"bar failed: {line}", file=sys.stderr)
        raise SystemExit(1)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
