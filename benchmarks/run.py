"""Benchmark harness — one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV rows (with detail blocks
on indented lines below each row).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only campaign
    PYTHONPATH=src python -m benchmarks.run --only sweep --json BENCH.json

``--json PATH`` additionally writes
``{schema_version, benches: {name: {us_per_call, derived}}}`` so the
perf trajectory stays machine-comparable across PRs (the committed
``BENCH_sweep.json`` is the sweep-engine baseline; CI uploads a fresh
one per run as an artifact).  Consumers should check ``schema_version``
(currently 2; version 1 was the bare ``{name: ...}`` mapping).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

BENCH_SCHEMA_VERSION = 2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="also write {name: {us_per_call, derived}} here")
    args = ap.parse_args()

    from benchmarks import fleet_scale as fs
    from benchmarks import framework_benches as fb
    from benchmarks import paper_tables as pt
    from benchmarks import sweep_scale as ss

    benches = [
        ("fleet_tick_speedup", fs.bench_fleet_tick_throughput),
        ("sweep_campaign_speedup", ss.bench_sweep_throughput),
        ("fig1_fleet_timeline", pt.bench_fig1_fleet_timeline),
        ("fig2_gpu_hours_doubling", pt.bench_fig2_gpu_hours_doubling),
        ("claims_table_maxerr_pct", pt.bench_claims_table),
        ("preemption_economics", pt.bench_preemption_economics),
        ("budget_control_latency", pt.bench_budget_control),
        ("nat_keepalive_drops", pt.bench_nat_keepalive),
        ("overlay_matches_per_s", pt.bench_overlay_throughput),
        ("elastic_restart_steps", fb.bench_elastic_train_restart),
        ("kernels_max_err", fb.bench_kernels),
        ("roofline_cells_ok", fb.bench_roofline_table),
    ]
    if args.only:
        benches = [(n, f) for n, f in benches if args.only in n]

    print("name,us_per_call,derived")
    report = {}
    failures = 0
    for name, fn in benches:
        try:
            us, derived, rows = fn()
            print(f"{name},{us:.1f},{derived}")
            for r in rows:
                print(r)
            report[name] = {"us_per_call": round(us, 1), "derived": derived}
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,ERROR")
            traceback.print_exc(limit=5)
            report[name] = {"us_per_call": None, "derived": "ERROR"}
        sys.stdout.flush()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": BENCH_SCHEMA_VERSION,
                       "benches": report},
                      f, indent=2, sort_keys=True, default=str)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
