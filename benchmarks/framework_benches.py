"""Framework-layer benchmarks: elastic restart overhead, checkpoint I/O,
kernel interpret-mode validation timing, roofline table from the dry-run
artifacts."""
from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def bench_elastic_train_restart(tmp="/tmp/bench_ck"):
    """Reduced-model train: step time vs (checkpoint save + restore) —
    derived: restart overhead in equivalent steps."""
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    from repro.launch.train import Trainer, build
    cfg, shape, run = build("internvl2-2b", reduced=True)
    tr = Trainer(cfg, shape, run, ckpt_dir=tmp, seed=0)
    tr.train(3, ckpt_every=100, log_every=0, log=lambda *a: None)  # warm
    t0 = time.time()
    tr.train(13, ckpt_every=100, log_every=0, log=lambda *a: None)
    step_s = (time.time() - t0) / 10
    t0 = time.time()
    tr.ckpt.save_blocking(13, {"params": tr.params, "opt": tr.opt})
    save_s = time.time() - t0
    t0 = time.time()
    tr.restore(tmp)
    restore_s = time.time() - t0
    overhead_steps = (save_s + restore_s) / step_s
    rows = [f"  step={step_s * 1e3:.1f}ms save={save_s * 1e3:.1f}ms "
            f"restore={restore_s * 1e3:.1f}ms",
            f"  restart costs ~{overhead_steps:.1f} steps of work"]
    return step_s * 1e6, round(overhead_steps, 2), rows


def bench_kernels():
    """interpret-mode us/call + max|err| vs oracle for all four kernels."""
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    rows, worst = [], 0.0
    t_all = time.time()

    def run(name, fn_k, fn_r, *args):
        nonlocal worst
        t0 = time.time()
        o = fn_k(*args)
        jax.block_until_ready(o)
        us = (time.time() - t0) * 1e6
        err = float(jnp.abs(o - fn_r(*args)).max())
        worst = max(worst, err)
        rows.append(f"  {name:16s} {us:10.0f}us  max|err|={err:.2e}")

    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))

    def fa_ref(q, k, v):
        B, S, H, D = q.shape
        Hkv = k.shape[2]
        qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
        vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
        o = ref.flash_attention_ref(qr, kr, vr, causal=True)
        return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    run("flash_attention",
        lambda q, k, v: ops.flash_attention(q, k, v, causal=True),
        fa_ref, q, k, v)

    xc = jax.random.normal(ks[3], (1, 64, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 64, 32)))
    bm = jax.random.normal(ks[5], (1, 64, 8))
    cm = jax.random.normal(ks[6], (1, 64, 8))
    a = -jnp.exp(jax.random.normal(ks[7], (32, 8)))
    run("mamba_scan",
        lambda *t: ops.mamba_scan(*t, block_d=32, block_s=32),
        ref.mamba_scan_ref, xc, dt, bm, cm, a)

    q2 = jax.random.normal(ks[0], (2, 128, 32))
    k2 = jax.random.normal(ks[1], (2, 128, 32))
    v2 = jax.random.normal(ks[2], (2, 128, 32))
    li = jax.random.normal(ks[3], (2, 128, 1)) - 5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (2, 128, 1)) + 3)
    run("mlstm_chunk",
        lambda *t: ops.mlstm_chunk(*t, block_s=64), ref.mlstm_ref,
        q2, k2, v2, li, lf)

    x3 = jax.random.normal(ks[5], (4, 64, 32))
    w3 = jax.random.normal(ks[6], (4, 32, 64))
    run("moe_gmm", lambda *t: ops.moe_gmm(*t, block_c=32, block_f=32,
                                          block_k=16),
        ref.moe_gmm_ref, x3, w3)
    return (time.time() - t_all) * 1e6 / 4, worst, rows


def load_dryrun_results():
    cells = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        for r in json.load(open(path)):
            key = (r["arch"], r["shape"], r["mesh"])
            if r.get("status") == "ok" or key not in cells:
                cells[key] = r
    return cells


def bench_roofline_table():
    """Per (arch x shape x mesh) roofline terms from the dry-run artifacts;
    derived = worst useful-compute fraction across compute-bound cells."""
    cells = load_dryrun_results()
    if not cells:
        return 0.0, 0, ["  (no dry-run artifacts found)"]
    rows = [f"  {'arch':24s} {'shape':11s} {'mesh':8s} "
            f"{'compute_s':>9s} {'memory_s':>9s} {'coll_s':>9s} "
            f"{'bound':>10s} {'useful':>6s}"]
    worst_frac, n_ok = 1.0, 0
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r.get("status") == "skipped":
            rows.append(f"  {arch:24s} {shape:11s} {mesh:8s} "
                        f"{'skip (full attention @500k)':>40s}")
            continue
        if r.get("status") != "ok":
            rows.append(f"  {arch:24s} {shape:11s} {mesh:8s} ERROR")
            continue
        n_ok += 1
        rf = r["roofline"]
        frac = min(1.0, rf["useful_ratio"])
        worst_frac = min(worst_frac, frac)
        rows.append(
            f"  {arch:24s} {shape:11s} {mesh:8s} "
            f"{rf['compute_s']:9.4f} {rf['memory_s']:9.4f} "
            f"{rf['collective_s']:9.4f} {rf['bottleneck']:>10s} "
            f"{frac:6.2f}")
    return 0.0, n_ok, rows
