"""Sweep-scale benchmark: batched multi-campaign engine vs a sequential
solo-campaign loop at paper scale.

    PYTHONPATH=src python -m benchmarks.sweep_scale
    PYTHONPATH=src python -m benchmarks.sweep_scale --lanes 16 \
        --seq-lanes 2 --duration 84

Prints ``name,us_per_call,derived`` CSV rows (run.py idiom) where
``us_per_call`` is microseconds per simulated campaign on the batched
engine and ``derived`` is the batched/sequential campaigns-per-second
speedup.  The acceptance bar is >= 10x at B=64 paper-scale (336 h, 2k-GPU
ramp) campaigns; the sequential baseline is timed on ``--seq-lanes``
campaigns and extrapolated per-campaign (it is a plain
solo loop, so its per-campaign cost is constant).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

from repro.core.api import paper_spec, sweep


def _spec(duration_h: float):
    sc = paper_spec()
    if duration_h and duration_h != sc.duration_h:
        sc = replace(sc, duration_h=duration_h)
    return sc


def time_sweep(lanes: int, seq_lanes: int, duration_h: float = 336.0):
    """(batched s/campaign, sequential s/campaign, batched results)."""
    sc = _spec(duration_h)
    seeds = list(range(lanes))
    t0 = time.perf_counter()
    sw = sweep([sc], seeds, engine="batched")
    batched_per = (time.perf_counter() - t0) / lanes
    t0 = time.perf_counter()
    sweep([sc], seeds[:seq_lanes], engine="sequential")
    seq_per = (time.perf_counter() - t0) / seq_lanes
    return batched_per, seq_per, sw


def bench_sweep_throughput():
    """run.py-registered entry: the acceptance-bar configuration itself
    (B=64 paper-scale campaigns, 2-lane sequential baseline, ~6 s).  An
    earlier quarter-length B=16 shape under-reported the speedup by
    ~40%: the engine's fixed per-tick Python cost amortizes across
    lanes, so the 10x bar is defined — and must be measured — at
    B=64."""
    batched_per, seq_per, sw = time_sweep(64, 2, duration_h=336.0)
    speedup = seq_per / batched_per
    lane0 = sw.rows[0]
    rows = [f"    batched {batched_per * 1e3:.0f} ms/campaign vs "
            f"sequential {seq_per * 1e3:.0f} ms/campaign at B=64 "
            f"(paper-scale 336h campaigns)",
            f"    lane0: cost=${lane0['cost']:,.0f} "
            f"accel_days={lane0['accel_days']:,.1f} "
            f"preemptions={lane0['preemptions']}"]
    return batched_per * 1e6, round(speedup, 1), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=64,
                    help="batched sweep width B")
    ap.add_argument("--seq-lanes", type=int, default=4,
                    help="campaigns timed for the sequential baseline")
    ap.add_argument("--duration", type=float, default=336.0,
                    help="campaign length in hours (336 = paper)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    batched_per, seq_per, sw = time_sweep(args.lanes, args.seq_lanes,
                                          args.duration)
    speedup = seq_per / batched_per
    print(f"sweep_campaign_speedup_{args.lanes},{batched_per * 1e6:.1f},"
          f"{speedup:.1f}")
    print(f"    sequential {seq_per:.2f} s/campaign -> batched "
          f"{batched_per:.2f} s/campaign at B={args.lanes} "
          f"({1.0 / batched_per:.2f} campaigns/s)"
          f" -> {speedup:.1f}x (bar: >=10x at B=64)")
    summ = sw.summary(("cost", "accel_days", "preemptions"))["paper"]
    print(f"    paper bands over {summ['seeds']} seeds: "
          f"cost ${summ['cost']['mean']:,.0f} "
          f"[{summ['cost']['p5']:,.0f}, {summ['cost']['p95']:,.0f}]  "
          f"accel_days {summ['accel_days']['mean']:,.0f} "
          f"[{summ['accel_days']['p5']:,.0f}, "
          f"{summ['accel_days']['p95']:,.0f}]")


if __name__ == "__main__":
    main()
