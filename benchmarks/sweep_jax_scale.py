"""Compiled-sweep benchmark: ``engine="jax"`` vs the batched numpy
engine at planning-grid scale.

    PYTHONPATH=src python -m benchmarks.sweep_jax_scale
    PYTHONPATH=src python -m benchmarks.sweep_jax_scale --lanes 64 \
        --duration 84 --pallas on --json BENCH_sweep_jax.json

Prints ``name,us_per_call,derived`` CSV rows (run.py idiom) where
``us_per_call`` is microseconds per simulated campaign on the compiled
engine (cold — tracing and XLA compile included) and ``derived`` is the
jax/batched campaigns-per-second speedup.  The acceptance bar is
**>= 3x at B=512 paper-scale on CPU**, compile cost included; the
committed ``BENCH_sweep_jax.json`` records the full-shape run, and CI
re-runs a reduced shape with the Pallas kernels forced through
interpret mode (``--pallas on``) so the kernel path stays exercised
per-commit.

``--pallas``: "auto" (kernels on TPU, jnp oracles elsewhere — the
engine default), "on" (force the Pallas kernels; on CPU they run in
interpret mode, which is far slower but proves the path), "off".
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace

from repro.core.api import paper_spec, sweep

JAX_SPEEDUP_BAR = 3.0


def _spec(duration_h: float):
    sc = paper_spec()
    if duration_h and duration_h != sc.duration_h:
        sc = replace(sc, duration_h=duration_h)
    return sc


def time_jax_sweep(lanes: int, duration_h: float = 336.0,
                   use_pallas=None, numpy_lanes: int = 0):
    """(jax cold s/campaign, jax warm s/campaign, numpy s/campaign,
    jax SweepResult).  The numpy baseline is timed on ``numpy_lanes``
    lanes (0 = same width) and normalized per campaign."""
    from repro.core.sweep_jax import run_jax

    sc = _spec(duration_h)
    seeds = list(range(lanes))
    lane_specs = [(sc, s) for s in seeds]
    t0 = time.perf_counter()
    run_jax(lane_specs, use_pallas=use_pallas)
    cold_per = (time.perf_counter() - t0) / lanes
    t0 = time.perf_counter()
    sw = sweep([sc], seeds, engine="jax")
    warm_per = (time.perf_counter() - t0) / lanes
    nb = numpy_lanes or lanes
    t0 = time.perf_counter()
    sweep([sc], seeds[:nb], engine="batched")
    numpy_per = (time.perf_counter() - t0) / nb
    return cold_per, warm_per, numpy_per, sw


def bench_sweep_jax_throughput():
    """run.py-registered entry: the acceptance-bar configuration itself
    (B=512 paper-scale campaigns on whatever backend is present — the
    bar is defined on CPU, where XLA has one core and no excuses).  The
    speedup is **cold**, compile included: a planner running one grid
    pays tracing exactly once, so that is the honest number."""
    cold_per, warm_per, numpy_per, sw = time_jax_sweep(512)
    speedup = numpy_per / cold_per
    lane0 = sw.rows[0]
    rows = [f"    jax {cold_per * 1e3:.1f} ms/campaign cold "
            f"({warm_per * 1e3:.1f} warm) vs numpy batched "
            f"{numpy_per * 1e3:.1f} ms/campaign at B=512 "
            f"(paper-scale 336h campaigns; warm speedup "
            f"{numpy_per / warm_per:.1f}x)",
            f"    lane0: cost=${lane0['cost']:,.0f} "
            f"accel_days={lane0['accel_days']:,.1f} "
            f"preemptions={lane0['preemptions']}"]
    return cold_per * 1e6, round(speedup, 1), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=512,
                    help="compiled sweep width B")
    ap.add_argument("--numpy-lanes", type=int, default=0,
                    help="lanes timed for the numpy baseline "
                         "(0 = same as --lanes)")
    ap.add_argument("--duration", type=float, default=336.0,
                    help="campaign length in hours (336 = paper)")
    ap.add_argument("--pallas", choices=["auto", "on", "off"],
                    default="auto",
                    help="kernel path: auto (TPU only), on (force — "
                         "interpret mode on CPU), off (jnp oracles)")
    ap.add_argument("--json", default=None,
                    help="write the run.py bench schema here "
                         "(bar/pass included)")
    args = ap.parse_args()
    use_pallas = {"auto": None, "on": True, "off": False}[args.pallas]
    print("name,us_per_call,derived")
    cold_per, warm_per, numpy_per, sw = time_jax_sweep(
        args.lanes, args.duration, use_pallas=use_pallas,
        numpy_lanes=args.numpy_lanes)
    speedup = numpy_per / cold_per
    name = f"sweep_jax_speedup_{args.lanes}"
    print(f"{name},{cold_per * 1e6:.1f},{speedup:.1f}")
    print(f"    numpy batched {numpy_per:.3f} s/campaign -> jax "
          f"{cold_per:.3f} s/campaign cold ({warm_per:.3f} warm) at "
          f"B={args.lanes} (pallas={args.pallas}) -> {speedup:.1f}x "
          f"(bar: >={JAX_SPEEDUP_BAR:.0f}x at B=512 paper-scale)")
    summ = sw.summary(("cost", "accel_days"))["paper"]
    print(f"    paper bands over {summ['seeds']} seeds: "
          f"cost ${summ['cost']['mean']:,.0f} "
          f"[{summ['cost']['p5']:,.0f}, {summ['cost']['p95']:,.0f}]  "
          f"accel_days {summ['accel_days']['mean']:,.0f} "
          f"[{summ['accel_days']['p5']:,.0f}, "
          f"{summ['accel_days']['p95']:,.0f}]")
    if args.json:
        # bar/pass follow the run.py --json schema; the reduced-shape
        # CI run keeps the fields so consumers never branch on shape
        bar = JAX_SPEEDUP_BAR if args.lanes >= 512 else None
        entry = {"us_per_call": round(cold_per * 1e6, 1),
                 "derived": round(speedup, 1)}
        if bar is not None:
            entry["bar"] = bar
            entry["pass"] = bool(speedup >= bar)
        with open(args.json, "w") as f:
            json.dump({"schema_version": 2, "benches": {name: entry}},
                      f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
